"""Dataset preparation (Section IV-A).

Two sources, selected automatically:

1. **Real CIFAR-10** — if ``cfg.cifar_dir`` (or ``$CIFAR10_DIR``) points at an
   extracted ``cifar-10-batches-py`` directory, the standard pickle batches
   are loaded.
2. **Synthetic CIFAR-like generator** — otherwise, ten procedurally generated
   32x32 texture/shape classes with controlled intra-class variability.  The
   *identical* generator is implemented in ``rust/src/dataset/synthetic.rs``
   so the Rust serving workload and the Python training distribution match
   bit-for-bit in structure (same class recipes, same parameter ranges).

Both paths apply the paper's grayscale conversion
``Y = 0.2989 R + 0.5870 G + 0.1140 B`` and per-dataset normalisation.
"""

from __future__ import annotations

import os
import pickle
from typing import Optional, Tuple

import numpy as np

from .config import DataConfig

GRAY_WEIGHTS = np.array([0.2989, 0.5870, 0.1140], dtype=np.float32)

CLASS_NAMES = (
    "airplane", "automobile", "bird", "cat", "deer",
    "dog", "frog", "horse", "ship", "truck",
)


def to_grayscale(rgb: np.ndarray) -> np.ndarray:
    """Paper Eq.: Y = 0.2989 R + 0.5870 G + 0.1140 B.  rgb: [..., 3] in [0,1]."""
    return np.tensordot(rgb, GRAY_WEIGHTS, axes=([-1], [0]))


# ---------------------------------------------------------------------------
# Synthetic CIFAR-like generator (mirrored by rust/src/dataset/synthetic.rs)
# ---------------------------------------------------------------------------
#
# Each class is a parameterised recipe mixing low-frequency structure (the
# "object") with textured background, at an SNR low enough that a linear
# classifier cannot saturate — the teacher/student/matching accuracy ordering
# of the paper then has room to show.  All randomness is drawn from a
# SplitMix64-seeded Philox-free LCG identical to the Rust implementation, so
# sample i of class c is the same image in both languages.


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return z ^ (z >> 31)


class Lcg:
    """64-bit LCG (MMIX constants) seeded via SplitMix64; u01 uses top 53 bits.

    Kept deliberately simple so the Rust mirror (dataset/synthetic.rs) is a
    line-for-line translation.
    """

    A = 6364136223846793005
    C = 1442695040888963407
    MASK = 0xFFFFFFFFFFFFFFFF

    def __init__(self, seed: int):
        self.state = _splitmix64(seed & self.MASK)

    def next_u64(self) -> int:
        self.state = (self.A * self.state + self.C) & self.MASK
        return self.state

    def u01(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / 9007199254740992.0)

    def range(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.u01()


def _grid(size: int) -> Tuple[np.ndarray, np.ndarray]:
    ax = (np.arange(size, dtype=np.float32) + 0.5) / size
    return np.meshgrid(ax, ax, indexing="ij")


def synth_image(class_id: int, sample_id: int, seed: int, size: int = 32) -> np.ndarray:
    """Render one grayscale synthetic sample in [0, 1].

    Class recipes (matched in rust/src/dataset/synthetic.rs::render):
      0 horizontal band   1 vertical band     2 centered disc
      3 ring              4 diagonal stripes  5 anti-diagonal stripes
      6 checkerboard      7 radial gradient   8 two-blob
      9 cross
    """
    rng = Lcg((seed << 40) ^ (class_id << 20) ^ sample_id)
    yy, xx = _grid(size)
    cx, cy = rng.range(0.35, 0.65), rng.range(0.35, 0.65)
    scale = rng.range(0.8, 1.25)
    phase = rng.range(0.0, 1.0)
    amp = rng.range(0.7, 1.0)

    if class_id == 0:
        img = np.exp(-(((yy - cy) / (0.12 * scale)) ** 2))
    elif class_id == 1:
        img = np.exp(-(((xx - cx) / (0.12 * scale)) ** 2))
    elif class_id == 2:
        r = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
        img = (r < 0.22 * scale).astype(np.float32)
    elif class_id == 3:
        r = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
        img = (np.abs(r - 0.25 * scale) < 0.06).astype(np.float32)
    elif class_id == 4:
        img = 0.5 + 0.5 * np.sin(2 * np.pi * (xx + yy) * 4.0 * scale + phase * 6.2831853)
    elif class_id == 5:
        img = 0.5 + 0.5 * np.sin(2 * np.pi * (xx - yy) * 4.0 * scale + phase * 6.2831853)
    elif class_id == 6:
        fx = np.floor(xx * 4.0 * scale + phase)
        fy = np.floor(yy * 4.0 * scale + phase)
        img = np.mod(fx + fy, 2.0)
    elif class_id == 7:
        r = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
        img = np.clip(1.0 - r / (0.7 * scale), 0.0, 1.0)
    elif class_id == 8:
        d1 = (xx - cx * 0.6) ** 2 + (yy - cy) ** 2
        d2 = (xx - (cx * 0.6 + 0.4)) ** 2 + (yy - cy) ** 2
        img = np.exp(-d1 / (0.02 * scale)) + np.exp(-d2 / (0.02 * scale))
    elif class_id == 9:
        img = np.maximum(
            np.exp(-(((yy - cy) / 0.08) ** 2)), np.exp(-(((xx - cx) / 0.08) ** 2))
        )
    else:
        raise ValueError(f"class_id out of range: {class_id}")

    img = amp * img.astype(np.float32)
    # Textured background noise — deterministic per-pixel stream.
    noise = np.empty((size, size), dtype=np.float32)
    for i in range(size):
        for j in range(size):
            noise[i, j] = rng.u01()
    img = 0.4 * img + 1.2 * (noise - 0.5)
    return np.clip(img, 0.0, 1.0)


def synth_dataset(n: int, seed: int, size: int = 32, num_classes: int = 10):
    """Generate ``n`` samples round-robin over classes. Returns (x[N,S,S,1], y[N])."""
    xs = np.zeros((n, size, size, 1), dtype=np.float32)
    ys = np.zeros((n,), dtype=np.int32)
    for i in range(n):
        c = i % num_classes
        xs[i, :, :, 0] = synth_image(c, i // num_classes, seed, size)
        ys[i] = c
    return xs, ys


# ---------------------------------------------------------------------------
# Real CIFAR-10 loader
# ---------------------------------------------------------------------------


def _load_cifar_batches(d: str):
    def unpickle(p):
        with open(p, "rb") as f:
            return pickle.load(f, encoding="bytes")

    xs, ys = [], []
    for b in range(1, 6):
        d_ = unpickle(os.path.join(d, f"data_batch_{b}"))
        xs.append(d_[b"data"])
        ys.extend(d_[b"labels"])
    train_x = np.concatenate(xs).reshape(-1, 3, 32, 32).transpose(0, 2, 3, 1)
    train_y = np.array(ys, dtype=np.int32)
    t = unpickle(os.path.join(d, "test_batch"))
    test_x = t[b"data"].reshape(-1, 3, 32, 32).transpose(0, 2, 3, 1)
    test_y = np.array(t[b"labels"], dtype=np.int32)
    return (train_x / 255.0).astype(np.float32), train_y, (test_x / 255.0).astype(
        np.float32
    ), test_y


def load(cfg: DataConfig, color: bool = False):
    """Load (train_x, train_y, test_x, test_y) per the config.

    Grayscale output shape is [N, S, S, 1]; colour is [N, S, S, 3] (only the
    real dataset supports colour — the synthetic generator is gray-native and
    tiles the channel for the "teacher colour" Table I row).
    Values are normalised to zero mean / unit variance using *train* stats.
    """
    cifar_dir = cfg.cifar_dir or os.environ.get("CIFAR10_DIR")
    if cifar_dir and os.path.isdir(cifar_dir):
        tx, ty, vx, vy = _load_cifar_batches(cifar_dir)
        tx, ty = tx[: cfg.train_samples], ty[: cfg.train_samples]
        vx, vy = vx[: cfg.test_samples], vy[: cfg.test_samples]
        if not color:
            tx = to_grayscale(tx)[..., None]
            vx = to_grayscale(vx)[..., None]
    else:
        tx, ty = synth_dataset(cfg.train_samples, cfg.seed, cfg.image_size, cfg.num_classes)
        vx, vy = synth_dataset(
            cfg.test_samples, cfg.seed + 1_000_003, cfg.image_size, cfg.num_classes
        )
        if color:  # synthetic is gray-native; tile channels for colour models
            tx = np.repeat(tx, 3, axis=-1)
            vx = np.repeat(vx, 3, axis=-1)

    mean, std = float(tx.mean()), float(tx.std() + 1e-7)
    tx = (tx - mean) / std
    vx = (vx - mean) / std
    return tx, ty, vx, vy, {"mean": mean, "std": std}

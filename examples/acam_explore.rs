//! ACAM design exploration: sweep device variability and compare the two
//! published TXL cells (6T4R charging vs 3T1R precharging), plus a window
//! diagnostic and an on-device template-refresh demo using the Rust k-means
//! substrate.
//!
//!     cargo run --release --example acam_explore

use hec::acam::cell::CellKind;
use hec::acam::program::{binary_query_voltages, program_array, WindowMode};
use hec::acam::{wta, ArrayConfig, Variability};
use hec::config::{Backend, ServeConfig};
use hec::coordinator::Pipeline;
use hec::dataset::SyntheticDataset;
use hec::kmeans;

fn main() -> hec::Result<()> {
    // ---- 1. variability sweep, both cell kinds --------------------------
    // The pipeline loads artifacts/templates.json when present or
    // bootstraps a store from the synthetic dataset otherwise, so this
    // exploration runs on a clean checkout too.
    let cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::FeatureCount,
        ..Default::default()
    };
    let mut pipeline = Pipeline::new(&cfg)?;
    let store = pipeline.store.clone();
    let set = store.set(1)?;
    let n = 300;
    let ds = SyntheticDataset::new(
        1_000_003,
        n,
        pipeline.meta.norm.mean as f32,
        pipeline.meta.norm.std as f32,
    );
    let (images, labels) = ds.batch(0, n);
    // Extract features once through PJRT; replay them through the ACAM sim
    // at each corner (isolates device effects from the front-end).
    let feats = pipeline.extract_features(&images, n)?;
    let nf = pipeline.meta.artifacts.n_features;

    println!("=== accuracy vs variability level (feature replay, {n} samples) ===");
    println!("{:>8} {:>14} {:>14}", "level", "6T4R", "3T1R");
    for level in [0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let mut accs = Vec::new();
        for kind in [CellKind::Charging6T4R, CellKind::Precharging3T1R] {
            let var = Variability::at_level(level);
            let mut arr = program_array(
                set,
                WindowMode::Binary,
                ArrayConfig { kind, ..Default::default() },
                var.clone(),
                42,
            );
            let mut rng = hec::rng::Rng::new(7);
            let mut correct = 0usize;
            for (i, row) in feats.chunks_exact(nf).enumerate() {
                let bits = store.binarize(row);
                let out = arr.search(&binary_query_voltages(&bits));
                let pred = wta::winner_take_all_classes(
                    &out.similarity,
                    &set.class_of,
                    store.num_classes,
                    &var,
                    &mut rng,
                );
                correct += usize::from(pred == labels[i]);
            }
            accs.push(correct as f64 / n as f64);
        }
        println!("{level:>8.2} {:>14.4} {:>14.4}", accs[0], accs[1]);
    }

    // ---- 2. window diagnostic: programming error vs variability ----------
    println!("\n=== programmed-window error vs variability (volts, row 0) ===");
    for level in [0.0, 1.0, 4.0] {
        let arr = program_array(
            set,
            WindowMode::Binary,
            ArrayConfig::default(),
            Variability::at_level(level),
            42,
        );
        println!(
            "level {level:>4}: full-match headroom {:.2}x (rows={}, width={})",
            arr.full_match_headroom(),
            arr.num_rows(),
            arr.width()
        );
    }

    // ---- 3. on-device template refresh with the Rust k-means -------------
    // Cluster served binary feature maps per class and measure how well the
    // regenerated templates agree with the deployed ones.
    println!("\n=== on-device template refresh (k-means over served features) ===");
    let mut agreements = Vec::new();
    for class in 0..store.num_classes {
        let rows: Vec<Vec<f64>> = feats
            .chunks_exact(nf)
            .enumerate()
            .filter(|(i, _)| labels[*i] == class)
            .map(|(_, row)| store.binarize(row).iter().map(|&b| b as f64).collect())
            .collect();
        if rows.is_empty() {
            continue;
        }
        let clustering = kmeans::kmeans(&rows, 1, 20, 2, 7);
        let refreshed: Vec<u8> = clustering.centroids[0]
            .iter()
            .map(|&v| u8::from(v > 0.5))
            .collect();
        let deployed = &set.templates[set
            .class_of
            .iter()
            .position(|&c| c == class)
            .unwrap()];
        let agree = refreshed
            .iter()
            .zip(deployed.iter())
            .filter(|(a, b)| a == b)
            .count() as f64
            / nf as f64;
        agreements.push(agree);
        println!("class {class}: refreshed/deployed agreement {:.1}%", agree * 100.0);
    }
    let mean = agreements.iter().sum::<f64>() / agreements.len() as f64;
    println!("mean agreement {:.1}%", mean * 100.0);
    Ok(())
}

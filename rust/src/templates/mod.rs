//! Template store: loads, validates and packs `artifacts/templates.json`.
//!
//! The store carries, per k in {1, 2, 3} (Table II):
//! * binary templates (the patterns programmed into the ACAM),
//! * real-feature matching windows `[lo, hi]` (Eq. 9 bounds / RRAM targets),
//! * binary-domain windows (`t ± 0.5`) for the similarity model on binary
//!   queries,
//! * the owning class of each template (Eq. 12 per-class max).
//!
//! Binary templates are additionally packed into u64 words (64 features per
//! word) for the popcount fast path in [`crate::matching`].

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::jsonlite::{self, Value};

/// One template set (a fixed `templates_per_class`).
#[derive(Debug, Clone)]
pub struct TemplateSet {
    /// Binary templates, row-major `[m][n]` with values 0/1.
    pub templates: Vec<Vec<u8>>,
    /// Packed rows: `words_per_row` u64s per template, LSB-first bit order.
    pub packed: Vec<u64>,
    pub words_per_row: usize,
    /// Real-feature windows (Eq. 9 bounds).
    pub lo: Vec<Vec<f32>>,
    pub hi: Vec<Vec<f32>>,
    /// Binary-domain windows (t ± 0.5).
    pub bin_lo: Vec<Vec<f32>>,
    pub bin_hi: Vec<Vec<f32>>,
    /// Owning class per template.
    pub class_of: Vec<usize>,
    /// Per-class silhouette scores from the build-time clustering.
    pub silhouette: Vec<f64>,
}

impl TemplateSet {
    /// Number of stored templates (rows).
    pub fn num_templates(&self) -> usize {
        self.templates.len()
    }

    /// Feature width.
    pub fn num_features(&self) -> usize {
        self.templates.first().map_or(0, |t| t.len())
    }

    /// Pack a binary query the same way the templates are packed.
    pub fn pack_query(&self, q: &[u8]) -> Vec<u64> {
        pack_bits(q, self.words_per_row)
    }

    fn validate(&self, n_features: usize, num_classes: usize) -> Result<()> {
        if self.templates.is_empty() {
            return Err(Error::Template("empty template set".into()));
        }
        for (i, t) in self.templates.iter().enumerate() {
            if t.len() != n_features {
                return Err(Error::Template(format!(
                    "template {i} has {} features, expected {n_features}",
                    t.len()
                )));
            }
            if t.iter().any(|&b| b > 1) {
                return Err(Error::Template(format!("template {i} is not binary")));
            }
        }
        if self.class_of.len() != self.templates.len() {
            return Err(Error::Template("class_of length mismatch".into()));
        }
        if self.class_of.iter().any(|&c| c >= num_classes) {
            return Err(Error::Template("class id out of range".into()));
        }
        let mut seen = vec![false; num_classes];
        for &c in &self.class_of {
            seen[c] = true;
        }
        if !seen.iter().all(|&s| s) {
            return Err(Error::Template("some class has no template".into()));
        }
        for (lo, hi) in self.lo.iter().zip(self.hi.iter()) {
            if lo.len() != n_features || hi.len() != n_features {
                return Err(Error::Template("window width mismatch".into()));
            }
            if lo.iter().zip(hi.iter()).any(|(l, h)| l > h) {
                return Err(Error::Template("window lo > hi".into()));
            }
        }
        Ok(())
    }
}

/// Pack 0/1 bytes into u64 words, LSB-first.
pub fn pack_bits(bits: &[u8], words_per_row: usize) -> Vec<u64> {
    let mut out = vec![0u64; words_per_row];
    for (i, &b) in bits.iter().enumerate() {
        if b != 0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
    out
}

/// The full store: thresholds + one [`TemplateSet`] per templates-per-class.
#[derive(Debug, Clone)]
pub struct TemplateStore {
    pub num_classes: usize,
    pub n_features: usize,
    /// Per-feature binarisation thresholds (the deployed mode from training).
    pub thresholds: Vec<f32>,
    /// Both threshold variants, kept for the Fig. 1 bench.
    pub thresholds_mean: Vec<f32>,
    pub thresholds_median: Vec<f32>,
    pub threshold_mode: String,
    pub similarity_alpha: f32,
    /// Keyed by templates-per-class (1, 2, 3).
    pub sets: BTreeMap<usize, TemplateSet>,
}

struct RawSet {
    templates: Vec<Vec<u8>>,
    lo: Vec<Vec<f32>>,
    hi: Vec<Vec<f32>>,
    bin_lo: Vec<Vec<f32>>,
    bin_hi: Vec<Vec<f32>>,
    class_of: Vec<usize>,
    silhouette: Vec<f64>,
}

struct RawStore {
    num_classes: usize,
    n_features: usize,
    threshold_mode: String,
    thresholds: Vec<f32>,
    thresholds_mean: Vec<f32>,
    thresholds_median: Vec<f32>,
    similarity_alpha: f32,
    stores: BTreeMap<String, RawSet>,
}

/// Schema-error helper: `field(v.get("x"), "x")?`.
fn field<'a>(v: Option<&'a Value>, name: &str) -> Result<&'a Value> {
    v.ok_or_else(|| Error::Schema(format!("templates.json: missing field '{name}'")))
}

fn f32_matrix(v: &Value, name: &str) -> Result<Vec<Vec<f32>>> {
    v.as_f32_matrix()
        .ok_or_else(|| Error::Schema(format!("templates.json: '{name}' must be a numeric matrix")))
}

fn parse_raw_set(v: &Value) -> Result<RawSet> {
    let templates: Vec<Vec<u8>> = f32_matrix(field(v.get("templates"), "templates")?, "templates")?
        .into_iter()
        .map(|row| row.into_iter().map(|f| f as u8).collect())
        .collect();
    let class_of = field(v.get("class_of"), "class_of")?
        .as_array()
        .ok_or_else(|| Error::Schema("class_of must be an array".into()))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| Error::Schema("class_of must be ints".into())))
        .collect::<Result<Vec<usize>>>()?;
    let silhouette = field(v.get("silhouette"), "silhouette")?
        .as_array()
        .ok_or_else(|| Error::Schema("silhouette must be an array".into()))?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0))
        .collect();
    Ok(RawSet {
        templates,
        lo: f32_matrix(field(v.get("lo"), "lo")?, "lo")?,
        hi: f32_matrix(field(v.get("hi"), "hi")?, "hi")?,
        bin_lo: f32_matrix(field(v.get("bin_lo"), "bin_lo")?, "bin_lo")?,
        bin_hi: f32_matrix(field(v.get("bin_hi"), "bin_hi")?, "bin_hi")?,
        class_of,
        silhouette,
    })
}

impl TemplateStore {
    /// Load and validate `templates.json`.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let doc = jsonlite::parse(&std::fs::read_to_string(path)?)?;
        let f32_vec = |name: &str| -> Result<Vec<f32>> {
            field(doc.get(name), name)?
                .as_f32_vec()
                .ok_or_else(|| Error::Schema(format!("'{name}' must be a numeric array")))
        };
        let mut stores = BTreeMap::new();
        for (k, v) in field(doc.get("stores"), "stores")?
            .as_object()
            .ok_or_else(|| Error::Schema("'stores' must be an object".into()))?
        {
            stores.insert(k.clone(), parse_raw_set(v)?);
        }
        let raw = RawStore {
            num_classes: field(doc.get("num_classes"), "num_classes")?
                .as_usize()
                .ok_or_else(|| Error::Schema("num_classes must be an int".into()))?,
            n_features: field(doc.get("n_features"), "n_features")?
                .as_usize()
                .ok_or_else(|| Error::Schema("n_features must be an int".into()))?,
            threshold_mode: field(doc.get("threshold_mode"), "threshold_mode")?
                .as_str()
                .unwrap_or("mean")
                .to_string(),
            thresholds: f32_vec("thresholds")?,
            thresholds_mean: f32_vec("thresholds_mean")?,
            thresholds_median: f32_vec("thresholds_median")?,
            similarity_alpha: field(doc.get("similarity_alpha"), "similarity_alpha")?
                .as_f64()
                .ok_or_else(|| Error::Schema("similarity_alpha must be a number".into()))?
                as f32,
            stores,
        };
        Self::from_raw(raw)
    }

    fn from_raw(raw: RawStore) -> Result<Self> {
        if raw.thresholds.len() != raw.n_features {
            return Err(Error::Template("threshold width mismatch".into()));
        }
        let words_per_row = raw.n_features.div_ceil(64);
        let mut sets = BTreeMap::new();
        for (k, rs) in raw.stores {
            let k: usize = k
                .parse()
                .map_err(|_| Error::Template(format!("bad store key {k}")))?;
            let packed = rs
                .templates
                .iter()
                .flat_map(|t| pack_bits(t, words_per_row))
                .collect();
            let set = TemplateSet {
                templates: rs.templates,
                packed,
                words_per_row,
                lo: rs.lo,
                hi: rs.hi,
                bin_lo: rs.bin_lo,
                bin_hi: rs.bin_hi,
                class_of: rs.class_of,
                silhouette: rs.silhouette,
            };
            set.validate(raw.n_features, raw.num_classes)?;
            sets.insert(k, set);
        }
        if sets.is_empty() {
            return Err(Error::Template("no template sets".into()));
        }
        Ok(TemplateStore {
            num_classes: raw.num_classes,
            n_features: raw.n_features,
            thresholds: raw.thresholds,
            thresholds_mean: raw.thresholds_mean,
            thresholds_median: raw.thresholds_median,
            threshold_mode: raw.threshold_mode,
            similarity_alpha: raw.similarity_alpha,
            sets,
        })
    }

    /// The template set for `k` templates per class.
    pub fn set(&self, k: usize) -> Result<&TemplateSet> {
        self.sets
            .get(&k)
            .ok_or_else(|| Error::Template(format!("no set with {k} templates/class")))
    }

    /// Binarise a real-valued feature vector with the deployed thresholds
    /// (strict `>`, matching the Python/Pallas kernels).
    pub fn binarize(&self, features: &[f32]) -> Vec<u8> {
        features
            .iter()
            .zip(self.thresholds.iter())
            .map(|(f, t)| u8::from(f > t))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_raw(n_features: usize) -> RawStore {
        let t0 = vec![1u8; n_features];
        let t1 = vec![0u8; n_features];
        let mk = |t: &Vec<u8>| RawSet {
            templates: vec![t.clone(), t.iter().map(|b| 1 - b).collect()],
            lo: vec![vec![0.0; n_features]; 2],
            hi: vec![vec![1.0; n_features]; 2],
            bin_lo: vec![vec![-0.5; n_features]; 2],
            bin_hi: vec![vec![0.5; n_features]; 2],
            class_of: vec![0, 1],
            silhouette: vec![0.0, 0.0],
        };
        RawStore {
            num_classes: 2,
            n_features,
            threshold_mode: "mean".into(),
            thresholds: vec![0.5; n_features],
            thresholds_mean: vec![0.5; n_features],
            thresholds_median: vec![0.6; n_features],
            similarity_alpha: 0.05,
            stores: BTreeMap::from([("1".to_string(), mk(&t0)), ("2".to_string(), mk(&t1))]),
        }
    }

    #[test]
    fn pack_bits_lsb_first() {
        let bits = [1u8, 0, 1, 1];
        let packed = pack_bits(&bits, 1);
        assert_eq!(packed[0], 0b1101);
    }

    #[test]
    fn pack_bits_multiword() {
        let mut bits = vec![0u8; 70];
        bits[0] = 1;
        bits[64] = 1;
        bits[69] = 1;
        let packed = pack_bits(&bits, 2);
        assert_eq!(packed[0], 1);
        assert_eq!(packed[1], 0b100001);
    }

    #[test]
    fn load_roundtrip_and_binarize() {
        let store = TemplateStore::from_raw(toy_raw(8)).unwrap();
        assert_eq!(store.set(1).unwrap().num_templates(), 2);
        let b = store.binarize(&[0.4, 0.6, 0.5, 0.9, 0.0, 1.0, 0.51, 0.49]);
        assert_eq!(b, vec![0, 1, 0, 1, 0, 1, 1, 0]); // strict >
    }

    #[test]
    fn validate_rejects_nonbinary() {
        let mut raw = toy_raw(4);
        raw.stores.get_mut("1").unwrap().templates[0][0] = 2;
        assert!(TemplateStore::from_raw(raw).is_err());
    }

    #[test]
    fn validate_rejects_missing_class() {
        let mut raw = toy_raw(4);
        raw.stores.get_mut("1").unwrap().class_of = vec![0, 0];
        assert!(TemplateStore::from_raw(raw).is_err());
    }

    #[test]
    fn validate_rejects_bad_window() {
        let mut raw = toy_raw(4);
        raw.stores.get_mut("2").unwrap().lo[0][2] = 5.0;
        assert!(TemplateStore::from_raw(raw).is_err());
    }

    #[test]
    fn missing_set_is_error() {
        let store = TemplateStore::from_raw(toy_raw(4)).unwrap();
        assert!(store.set(3).is_err());
    }
}

//! §V.D reproduction: every published energy figure regenerated from the
//! Horowitz constants and Eq. 13/14, paper-scale and as-built, with the
//! strict-pJ variant alongside (unit-slip note in `hec::energy`).

use hec::benchkit::{paper_row, section};
use hec::energy::{constants as c, EnergyModel, Scale};
use hec::runtime::Meta;

fn main() {
    let m = EnergyModel::default();

    section("§V.D — published arithmetic (paper scale)");
    let r = m.report(Scale::Paper);
    paper_row("E_back-end (nJ)", c::E_BACKEND_NJ, r.e_backend_nj, "nJ");
    paper_row("E_front-end (nJ)", c::E_FRONTEND_NJ, r.e_frontend_nj, "nJ");
    paper_row("E_total (nJ)", c::E_TOTAL_NJ, r.e_total_nj, "nJ");
    paper_row("E_teacher (uJ)", c::E_TEACHER_UJ, r.e_teacher_uj, "uJ");
    paper_row("reduction (x)", c::ENERGY_REDUCTION, r.reduction, "x");

    // Eq. 14 is exact; front/teacher within 0.5%; reduction within a few %
    // of the published rounding.
    assert!((r.e_backend_nj - c::E_BACKEND_NJ).abs() < 0.01);
    assert!((r.e_frontend_nj - c::E_FRONTEND_NJ).abs() / c::E_FRONTEND_NJ < 0.005);
    assert!((r.e_teacher_uj - c::E_TEACHER_UJ).abs() / c::E_TEACHER_UJ < 0.005);
    assert!(r.reduction > 700.0 && r.reduction < 900.0);

    section("strict-pJ variant (x1000 unit-slip check)");
    println!(
        "front-end strict-pJ: {:.0} nJ (published arithmetic: {:.2} nJ)",
        m.frontend_strict_pj_nj(c::FRONTEND_OPS_ACAM),
        r.e_frontend_nj
    );

    section("per-MAC decomposition");
    println!(
        "mul8 {} pJ + add8 {} pJ + mem {} pJ = {:.2} pJ/MAC",
        c::MUL8_PJ,
        c::ADD8_PJ,
        c::MEM_32K_PJ,
        m.per_mac_pj()
    );
    println!(
        "ops: softmax head removed = {} (frontend {} = {} - {})",
        c::SOFTMAX_HEAD_OPS,
        c::FRONTEND_OPS_ACAM,
        c::STUDENT_OPT.macs,
        c::SOFTMAX_HEAD_OPS
    );

    if let Ok(meta) = Meta::load("artifacts") {
        section("as-built deployment");
        let ab = m.report(Scale::AsBuilt {
            frontend_ops: meta.macs.as_built.student_effective,
            teacher_macs: meta.macs.as_built.teacher_gray.macs,
            n_templates: meta.artifacts.n_templates as u64,
            n_features: meta.artifacts.n_features as u64,
        });
        println!("{ab}");
        // Back-end term is scale-independent (same 10x784 array).
        assert!((ab.e_backend_nj - c::E_BACKEND_NJ).abs() < 0.01);
    }
    println!("\nenergy_estimates: PASS");
}

"""Student ablation studies (Section IV-B1) + KD hyper-parameter sweeps.

The paper reports that (i) widening dense terminations (128 -> 256 -> 512)
*degrades* accuracy, (ii) convolutional terminations beat dense ones and are
more stable under quantisation (±1.2% vs ±3.5%), and (iii) knowledge
distillation lifts every configuration (average +5.2%, up to +9.4% for
CNNs).  This driver re-runs those comparisons on the synthetic workload:

    cd python && python -m compile.ablation --out ../artifacts/ablation.json

Each variant trains the same front-end conv stack but swaps the termination:

* ``conv16``    — the Fig. 5 termination (2x2-valid conv, 784 features);
* ``dense128``  / ``dense256`` / ``dense512`` — GAP-free flatten into a
  dense layer of the given width, then the classifier head.

For every variant we report baseline accuracy, distilled accuracy, and the
accuracy drop under 8-bit weight quantisation (the stability metric the
paper frames as ±x%).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .config import PipelineConfig
from .model import (
    bn_apply,
    conv_apply,
    dense_apply,
    init_bn,
    init_conv,
    init_dense,
    init_teacher,
    teacher_logits,
)
from .kernels import ref
from .qat import quantize_params
from .train import (
    adam_init,
    adam_update,
    cross_entropy,
    composite_loss,
    evaluate,
    train_teacher,
    _batches,
)


# ---------------------------------------------------------------------------
# Variant models: shared conv trunk, swappable termination
# ---------------------------------------------------------------------------


def init_variant(key, termination: str, num_classes=10):
    k = jax.random.split(key, 6)
    bn1_p, bn1_s = init_bn(32)
    bn2_p, bn2_s = init_bn(128)
    params = {
        "conv1": init_conv(k[0], 3, 3, 1, 32),
        "bn1": bn1_p,
        "conv2": init_conv(k[1], 3, 3, 32, 128),
        "bn2": bn2_p,
        "conv3": init_conv(k[2], 3, 3, 128, 256),
    }
    state = {"bn1": bn1_s, "bn2": bn2_s}
    if termination == "conv16":
        params["term"] = init_conv(k[3], 2, 2, 256, 16)
        params["head"] = init_dense(k[4], 784, num_classes)
    elif termination.startswith("dense"):
        width = int(termination[len("dense"):])
        # GAP to 256 features, then the dense termination the paper ablates.
        params["term"] = init_dense(k[3], 256, width)
        params["head"] = init_dense(k[4], width, num_classes)
    else:
        raise ValueError(f"unknown termination: {termination}")
    return params, state


def variant_logits(params, state, x, termination: str, training=False):
    h = conv_apply(params["conv1"], x, "SAME")
    h, s1 = bn_apply(params["bn1"], state["bn1"], h, training)
    h = ref.maxpool2(jax.nn.relu(h))
    h = conv_apply(params["conv2"], h, "SAME")
    h, s2 = bn_apply(params["bn2"], state["bn2"], h, training)
    h = ref.maxpool2(jax.nn.relu(h))
    h = jax.nn.relu(conv_apply(params["conv3"], h, "SAME"))
    if termination == "conv16":
        h = jax.nn.relu(conv_apply(params["term"], h, "VALID"))
        feats = h.reshape(h.shape[0], -1)
    else:
        gap = jnp.mean(h, axis=(1, 2))
        feats = jax.nn.relu(dense_apply(params["term"], gap))
    return dense_apply(params["head"], feats), {"bn1": s1, "bn2": s2}


# ---------------------------------------------------------------------------
# Training loops (hard-label and distilled)
# ---------------------------------------------------------------------------


def train_variant(
    termination, tx, ty, vx, vy, epochs=3, lr=1e-3, batch=64, seed=0,
    teacher_apply=None, alpha=0.7, temperature=4.0,
):
    params, state = init_variant(jax.random.PRNGKey(seed), termination)
    t_logits_all = None
    if teacher_apply is not None:
        t_logits_all = np.concatenate(
            [np.asarray(teacher_apply(jnp.asarray(tx[i : i + 256])))
             for i in range(0, len(tx), 256)]
        )

    @jax.jit
    def step_hard(params, state, opt, xb, yb):
        def loss_fn(p):
            logits, new_s = variant_logits(p, state, xb, termination, training=True)
            return cross_entropy(logits, yb), new_s

        (loss, new_s), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, new_s, opt, loss

    @jax.jit
    def step_kd(params, state, opt, xb, yb, tb):
        def loss_fn(p):
            logits, new_s = variant_logits(p, state, xb, termination, training=True)
            return composite_loss(logits, tb, yb, alpha, temperature), new_s

        (loss, new_s), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, new_s, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(seed + 5)
    for _ in range(epochs):
        for bidx in _batches(len(tx), batch, rng):
            xb, yb = jnp.asarray(tx[bidx]), jnp.asarray(ty[bidx])
            if t_logits_all is None:
                params, state, opt, _ = step_hard(params, state, opt, xb, yb)
            else:
                params, state, opt, _ = step_kd(
                    params, state, opt, xb, yb, jnp.asarray(t_logits_all[bidx])
                )
    infer = jax.jit(
        lambda p, s, xb: variant_logits(p, s, xb, termination, training=False)[0]
    )
    acc = evaluate(infer, params, state, vx, vy)
    # Quantisation-stability metric: accuracy drop under hard 8-bit weights.
    acc_q = evaluate(infer, quantize_params(params), state, vx, vy)
    return {"accuracy": acc, "accuracy_int8": acc_q, "int8_drop": acc - acc_q}


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def run(out_path: str, epochs: int = 3):
    cfg = PipelineConfig.fast()
    cfg.data.train_samples = 1500
    cfg.data.test_samples = 400
    tx, ty, vx, vy, _ = data.load(cfg.data)

    cfg.teacher.epochs = 3
    tparams, tstate = init_teacher(cfg.teacher, jax.random.PRNGKey(1))
    tparams, tstate, _ = train_teacher(cfg.teacher, tparams, tstate, tx, ty, vx, vy, [])
    teacher_apply = jax.jit(
        lambda xb: teacher_logits(tparams, tstate, xb, cfg.teacher, training=False)[0]
    )

    results = {}
    for term in ("conv16", "dense128", "dense256", "dense512"):
        t0 = time.time()
        base = train_variant(term, tx, ty, vx, vy, epochs=epochs)
        kd = train_variant(term, tx, ty, vx, vy, epochs=epochs, teacher_apply=teacher_apply)
        results[term] = {
            "baseline": base,
            "distilled": kd,
            "kd_gain": kd["accuracy"] - base["accuracy"],
            "secs": time.time() - t0,
        }
        print(
            f"[{term:>9}] base={base['accuracy']:.3f} kd={kd['accuracy']:.3f} "
            f"(+{kd['accuracy'] - base['accuracy']:+.3f})  "
            f"int8 drop base={base['int8_drop']:+.4f} kd={kd['int8_drop']:+.4f}"
        )

    # Paper-shape summary (§IV-B1).
    summary = {
        "kd_helps_everywhere": all(r["kd_gain"] > -0.02 for r in results.values()),
        "conv_termination_stable": abs(results["conv16"]["distilled"]["int8_drop"])
        <= abs(results["dense512"]["distilled"]["int8_drop"]) + 0.02,
    }
    with open(out_path, "w") as f:
        json.dump({"results": results, "summary": summary}, f, indent=1)
    print(f"[ablation] -> {out_path}  summary={summary}")
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/ablation.json")
    ap.add_argument("--epochs", type=int, default=3)
    args = ap.parse_args()
    run(args.out, args.epochs)


if __name__ == "__main__":
    main()

//! k-means + silhouette substrate — the on-device mirror of the build-time
//! clustering in `python/compile/templates.py`.
//!
//! The paper generates multi-template sets with k-means at training time; an
//! edge deployment that adapts templates in the field (program-once-read-many
//! RRAM still allows periodic re-programming maintenance windows) needs the
//! same machinery on-device.  Used by `examples/acam_explore.rs` and the
//! Table II bench to regenerate template sets from served feature maps.


/// Result of one clustering run.
#[derive(Debug, Clone)]
pub struct Clustering {
    /// Centroids, row-major `[k][dim]`.
    pub centroids: Vec<Vec<f64>>,
    /// Cluster index per input point.
    pub assignment: Vec<usize>,
    /// Sum of squared distances to assigned centroids.
    pub inertia: f64,
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k-means++ seeding (matches the Python implementation's scheme).
fn kmeanspp(x: &[Vec<f64>], k: usize, rng: &mut crate::rng::Rng) -> Vec<Vec<f64>> {
    let n = x.len();
    let mut cents = vec![x[rng.below(n)].clone()];
    while cents.len() < k {
        let d2: Vec<f64> = x
            .iter()
            .map(|p| {
                cents
                    .iter()
                    .map(|c| sq_dist(p, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            cents.push(x[rng.below(n)].clone());
            continue;
        }
        let mut r = rng.u01() * total;
        let mut pick = n - 1;
        for (i, &d) in d2.iter().enumerate() {
            r -= d;
            if r <= 0.0 {
                pick = i;
                break;
            }
        }
        cents.push(x[pick].clone());
    }
    cents
}

/// Lloyd's algorithm with k-means++ seeding and restarts; empty clusters are
/// re-seeded at the worst-fit point.
pub fn kmeans(x: &[Vec<f64>], k: usize, iters: usize, restarts: usize, seed: u64) -> Clustering {
    assert!(!x.is_empty() && k >= 1, "kmeans needs data and k >= 1");
    let k = k.min(x.len());
    let mut rng = crate::rng::Rng::new(seed);
    let mut best: Option<Clustering> = None;
    for _ in 0..restarts.max(1) {
        let mut cents = kmeanspp(x, k, &mut rng);
        let mut assign = vec![0usize; x.len()];
        for _ in 0..iters {
            let mut changed = false;
            // Assignment step (and track the worst-fit point for re-seeding).
            let mut worst = (0usize, 0f64);
            for (i, p) in x.iter().enumerate() {
                let (mut bi, mut bd) = (0usize, f64::INFINITY);
                for (c, cent) in cents.iter().enumerate() {
                    let d = sq_dist(p, cent);
                    if d < bd {
                        bd = d;
                        bi = c;
                    }
                }
                if assign[i] != bi {
                    assign[i] = bi;
                    changed = true;
                }
                if bd > worst.1 {
                    worst = (i, bd);
                }
            }
            // Update step.
            let dim = x[0].len();
            let mut sums = vec![vec![0f64; dim]; k];
            let mut counts = vec![0usize; k];
            for (p, &a) in x.iter().zip(assign.iter()) {
                counts[a] += 1;
                for (s, v) in sums[a].iter_mut().zip(p.iter()) {
                    *s += v;
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    cents[c] = x[worst.0].clone();
                } else {
                    for (s, cv) in sums[c].iter().zip(cents[c].iter_mut()) {
                        *cv = s / counts[c] as f64;
                    }
                }
            }
            if !changed {
                break;
            }
        }
        let inertia: f64 = x
            .iter()
            .zip(assign.iter())
            .map(|(p, &a)| sq_dist(p, &cents[a]))
            .sum();
        if best.as_ref().map_or(true, |b| inertia < b.inertia) {
            best = Some(Clustering {
                centroids: cents,
                assignment: assign,
                inertia,
            });
        }
    }
    best.unwrap()
}

/// Mean silhouette score over (a capped subsample of) the data; returns 0
/// for a single cluster, values in [-1, 1] otherwise.
pub fn silhouette(x: &[Vec<f64>], assignment: &[usize], sample_cap: usize, seed: u64) -> f64 {
    let ks: std::collections::BTreeSet<usize> = assignment.iter().copied().collect();
    if ks.len() < 2 {
        return 0.0;
    }
    let mut rng = crate::rng::Rng::new(seed);
    let mut idx: Vec<usize> = (0..x.len()).collect();
    // Fisher-Yates prefix shuffle for the subsample.
    for i in 0..idx.len().min(sample_cap) {
        let j = i + rng.below(idx.len() - i);
        idx.swap(i, j);
    }
    idx.truncate(sample_cap.min(x.len()));

    let mut total = 0f64;
    for &i in &idx {
        let own = assignment[i];
        let mut a_sum = 0f64;
        let mut a_n = 0usize;
        let mut b_per: std::collections::BTreeMap<usize, (f64, usize)> = Default::default();
        for (j, p) in x.iter().enumerate() {
            let d = sq_dist(&x[i], p).sqrt();
            if assignment[j] == own {
                if j != i {
                    a_sum += d;
                    a_n += 1;
                }
            } else {
                let e = b_per.entry(assignment[j]).or_insert((0.0, 0));
                e.0 += d;
                e.1 += 1;
            }
        }
        let a = if a_n > 0 { a_sum / a_n as f64 } else { 0.0 };
        let b = b_per
            .values()
            .map(|(s, n)| s / *n as f64)
            .fold(f64::INFINITY, f64::min);
        let denom = a.max(b);
        total += if denom == 0.0 { 0.0 } else { (b - a) / denom };
    }
    total / idx.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(center: f64, n: usize, dim: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = crate::rng::Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| center + rng.u01() - 0.5).collect())
            .collect()
    }

    #[test]
    fn separates_two_blobs() {
        let mut x = blob(5.0, 40, 4, 1);
        x.extend(blob(-5.0, 40, 4, 2));
        let c = kmeans(&x, 2, 50, 3, 0);
        let first = c.assignment[0];
        assert!(c.assignment[..40].iter().all(|&a| a == first));
        assert!(c.assignment[40..].iter().all(|&a| a != first));
    }

    #[test]
    fn k1_is_mean() {
        let x = blob(0.0, 30, 3, 3);
        let c = kmeans(&x, 1, 10, 1, 0);
        for d in 0..3 {
            let mean: f64 = x.iter().map(|p| p[d]).sum::<f64>() / x.len() as f64;
            assert!((c.centroids[0][d] - mean).abs() < 1e-9);
        }
    }

    #[test]
    fn inertia_nonincreasing_in_k() {
        let mut x = blob(3.0, 30, 4, 4);
        x.extend(blob(-3.0, 30, 4, 5));
        x.extend(blob(0.0, 30, 4, 6));
        let i1 = kmeans(&x, 1, 30, 3, 0).inertia;
        let i2 = kmeans(&x, 2, 30, 3, 0).inertia;
        let i3 = kmeans(&x, 3, 30, 3, 0).inertia;
        assert!(i1 >= i2 && i2 >= i3);
    }

    #[test]
    fn k_clamped_to_n() {
        let x = blob(0.0, 3, 2, 7);
        let c = kmeans(&x, 10, 5, 1, 0);
        assert_eq!(c.centroids.len(), 3);
    }

    #[test]
    fn deterministic_for_seed() {
        let x = blob(1.0, 50, 3, 8);
        let a = kmeans(&x, 3, 20, 2, 42);
        let b = kmeans(&x, 3, 20, 2, 42);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn silhouette_separated_beats_random() {
        let mut x = blob(4.0, 30, 3, 9);
        x.extend(blob(-4.0, 30, 3, 10));
        let good: Vec<usize> = (0..60).map(|i| usize::from(i >= 30)).collect();
        let bad: Vec<usize> = (0..60).map(|i| i % 2).collect();
        let sg = silhouette(&x, &good, 60, 0);
        let sb = silhouette(&x, &bad, 60, 0);
        assert!(sg > 0.5 && sg > sb, "good={sg} bad={sb}");
    }

    #[test]
    fn silhouette_single_cluster_zero() {
        let x = blob(0.0, 10, 2, 11);
        assert_eq!(silhouette(&x, &vec![0; 10], 10, 0), 0.0);
    }
}

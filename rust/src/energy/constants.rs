//! Paper-reported constants (Table I + §V.D) and Horowitz ISSCC'14 energy
//! figures — mirrored from `python/compile/macs.py::PAPER`; the pytest /
//! cargo-test pair pins both sides to the same numbers.

/// Table I rows (params, MACs, accuracy %) at paper scale.
pub struct PaperModel {
    pub params: u64,
    pub macs: u64,
    pub accuracy: f64,
}

pub const TEACHER_COLOR: PaperModel = PaperModel {
    params: 26_215_810,
    macs: 3_858_551_808,
    accuracy: 93.77,
};

pub const TEACHER_GRAY: PaperModel = PaperModel {
    params: 26_209_538,
    macs: 3_808_375_808,
    accuracy: 91.04,
};

pub const STUDENT_BASE: PaperModel = PaperModel {
    params: 380_314,
    macs: 23_785_120,
    accuracy: 76.29,
};

pub const STUDENT_OPT: PaperModel = PaperModel {
    params: 380_314,
    macs: 4_757_024,
    accuracy: 82.22,
};

/// §V.D: ops of the dense softmax head removed by the ACAM (784*10 + 10).
pub const SOFTMAX_HEAD_OPS: u64 = 7_850;

/// §V.D: front-end ops with the head removed: 4,757,024 - 7,850.
pub const FRONTEND_OPS_ACAM: u64 = 4_749_174;

/// Pruning sparsity of the optimised student.
pub const SPARSITY: f64 = 0.80;

/// TXL-ACAM energy per similarity-search operation per cell (Section III-B).
pub const ACAM_CELL_ENERGY_FJ: f64 = 185.0;

/// 9T4R analogue ACAM cell (arxiv 2410.03414) per-search energy (fJ):
/// same 4-RRAM window storage as the TXL pixel, plus three extra periphery
/// transistors that keep conducting through near-miss overdrive — modelled
/// as a 9/6 transistor-count scaling of the 185 fJ TXL figure, rounded to
/// the published design's simulation corner.
pub const ACAM_9T4R_CELL_ENERGY_FJ: f64 = 278.0;

/// RBF-neuron cell (arxiv 2606.14739) per-evaluation energy (fJ): the RBF
/// synapse computes its Gaussian bump with a 2-RRAM divider and a shared
/// current-mode squarer instead of a 4-RRAM dual-inverter window, roughly
/// halving the per-cell search charge relative to the TXL pixel.
pub const RBF_CELL_ENERGY_FJ: f64 = 92.0;

/// RBF-neuron (re-)programming energy per cell (pJ): two filamentary
/// devices per synapse instead of the ACAM pixel's four, at the same
/// ~20 pJ program-and-verify cost per device.
pub const RBF_PROGRAM_CELL_PJ: f64 = 40.0;

/// RRAM (re-)programming energy per ACAM cell (pJ): each TXL pixel holds
/// four filamentary devices, each SET with program-and-verify pulses in the
/// ~2 V x ~100 µA x ~100 ns regime (~20 pJ per device).  Re-programming the
/// deployed 10 x 784 array therefore charges ~627 nJ — hundreds of search
/// energies, which is why the degradation ladder re-programs on canary
/// evidence instead of every few requests.
pub const RRAM_PROGRAM_CELL_PJ: f64 = 80.0;

/// Deployed back-end geometry: 10 templates x 784 features.
pub const N_TEMPLATES: u64 = 10;
pub const N_FEATURES: u64 = 784;

/// Horowitz ISSCC'14, 45 nm: 8-bit integer op energies (pJ).
pub const MUL8_PJ: f64 = 0.2;
pub const ADD8_PJ: f64 = 0.03;
/// 32 KB cache access (pJ) — the §V.D per-MAC memory-access charge.
pub const MEM_32K_PJ: f64 = 20.0;

/// Horowitz 32-bit float op energies (pJ) — used for the teacher estimate.
pub const FMUL32_PJ: f64 = 3.7;
pub const FADD32_PJ: f64 = 0.9;

/// Published §V.D results.
pub const E_BACKEND_NJ: f64 = 1.45;
pub const E_FRONTEND_NJ: f64 = 96.07;
pub const E_TOTAL_NJ: f64 = 97.52;
pub const E_TEACHER_UJ: f64 = 78.06;
pub const ENERGY_REDUCTION: f64 = 792.0;

/// §V.B binary matching accuracy and Table II sweep.
pub const MATCH_ACCURACY_BINARY: f64 = 70.91;
pub const MULTI_TEMPLATE_ACCURACY: [(usize, f64); 3] =
    [(1, 70.91), (2, 71.64), (3, 71.60)];

//! Single-use response channel (tokio's `oneshot` is unavailable offline).
//!
//! Thin wrapper over a bounded `std::sync::mpsc` channel of capacity 1 with
//! a send-once API: the worker thread sends exactly one result; the waiter
//! blocks on [`Receiver::recv`] or polls [`Receiver::try_recv`].

use std::sync::mpsc;

/// Create a connected (sender, receiver) pair.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let (tx, rx) = mpsc::sync_channel(1);
    (Sender { tx }, Receiver { rx })
}

/// Send-once handle.
pub struct Sender<T> {
    tx: mpsc::SyncSender<T>,
}

impl<T> Sender<T> {
    /// Deliver the result. Returns the value back if the receiver is gone.
    pub fn send(self, value: T) -> Result<(), T> {
        self.tx.try_send(value).map_err(|e| match e {
            mpsc::TrySendError::Full(v) | mpsc::TrySendError::Disconnected(v) => v,
        })
    }
}

/// Await-once handle.
pub struct Receiver<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> Receiver<T> {
    /// Block until the result arrives; `Err` if the sender was dropped.
    pub fn recv(self) -> Result<T, RecvError> {
        self.rx.recv().map_err(|_| RecvError)
    }

    /// Non-blocking poll.
    pub fn try_recv(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

/// The sender was dropped without sending.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "oneshot sender dropped without sending")
    }
}

impl std::error::Error for RecvError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_then_recv() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn recv_after_drop_is_error() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_after_drop_returns_value() {
        let (tx, rx) = channel();
        drop(rx);
        assert_eq!(tx.send(7), Err(7));
    }

    #[test]
    fn try_recv_none_before_send_some_after() {
        let (tx, rx) = channel();
        assert_eq!(rx.try_recv(), None);
        tx.send(5).unwrap();
        assert_eq!(rx.try_recv(), Some(5));
        // The single value is consumed; the channel yields nothing further.
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn drop_before_send_wakes_blocked_receiver() {
        // A worker that dies mid-batch drops the Sender without sending;
        // a receiver blocked in recv() must wake with RecvError rather
        // than hang (the server maps this to an INTERNAL api error).
        let (tx, rx) = channel::<u32>();
        let waiter = std::thread::spawn(move || rx.recv());
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(tx);
        assert_eq!(waiter.join().unwrap(), Err(RecvError));
    }

    #[test]
    fn cross_thread() {
        let (tx, rx) = channel();
        std::thread::spawn(move || tx.send("done").unwrap());
        assert_eq!(rx.recv().unwrap(), "done");
    }
}

//! The request pipeline: image batch -> PJRT student front-end -> feature
//! binarisation -> back-end classification (simulated ACAM, digital matcher,
//! or softmax baseline) -> prediction + energy estimate.
//!
//! This is the paper's Fig. 2 as executable structure.  Everything here runs
//! on the serving thread; no Python, no allocation churn after warmup (the
//! padded input buffer and the packed-query scratch are reused).

use std::time::Instant;

use crate::acam::program::{binary_query_voltages, program_array, WindowMode};
use crate::acam::{wta, AcamArray, ArrayConfig, Variability};
use crate::config::{Backend, ServeConfig};
use crate::energy::{EnergyModel, Scale};
use crate::error::{Error, Result};
use crate::matching;
use crate::runtime::{Meta, Runtime};
use crate::templates::TemplateStore;

/// One classification outcome.
#[derive(Debug, Clone)]
pub struct Classification {
    pub class: usize,
    /// Modelled per-inference energy (nJ): front-end effective MACs +
    /// back-end search.
    pub energy_nj: f64,
}

/// The assembled serving pipeline.
pub struct Pipeline {
    runtime: Runtime,
    pub meta: Meta,
    pub store: TemplateStore,
    backend: Backend,
    k: usize,
    acam: Option<AcamArray>,
    acam_var: Variability,
    energy: EnergyModel,
    /// Front-end artifact prefix ("student_fwd_fast" on the CPU hot path,
    /// "student_fwd" for the Pallas-lowered variant).
    fwd_prefix: &'static str,
    /// Per-inference front-end energy (nJ), precomputed from the as-built
    /// effective MAC count.
    e_frontend_nj: f64,
    /// Reusable padded image buffer (allocation-free hot path).
    scratch: Vec<f32>,
    rng: crate::rng::Rng,
}

impl Pipeline {
    /// Build from a serving config: loads meta.json + templates.json,
    /// compiles the needed HLO artifacts, programs the ACAM array.
    pub fn new(cfg: &ServeConfig) -> Result<Self> {
        cfg.validate()?;
        let meta = Meta::load(&cfg.artifacts_dir)?;
        let store = TemplateStore::load(cfg.artifacts_dir.join("templates.json"))?;
        let mut runtime = Runtime::new(&cfg.artifacts_dir)?;

        // Precompile every batch variant of the front-end (and the softmax
        // head when it is the backend) so compilation never hits the request
        // path.
        let fwd_prefix = if cfg.use_fast_frontend && has_fast_variant(&cfg.artifacts_dir, &meta) {
            "student_fwd_fast"
        } else {
            "student_fwd"
        };
        let prefix = if cfg.backend == Backend::Softmax {
            "student_softmax"
        } else {
            fwd_prefix
        };
        for &b in &meta.artifacts.batch_sizes {
            runtime.load(&format!("{prefix}_b{b}"))?;
        }

        let set = store.set(cfg.templates_per_class)?;
        let acam = if cfg.backend == Backend::AcamSim {
            Some(program_array(
                set,
                WindowMode::Binary,
                ArrayConfig {
                    kind: cfg.acam.cell_kind,
                    ..Default::default()
                },
                Variability::at_level(cfg.acam.variability_level),
                cfg.acam.seed,
            ))
        } else {
            None
        };

        let frontend_ops = meta.macs.as_built.student_effective;
        let energy = EnergyModel::default();
        let e_frontend_nj = energy.frontend_nj(frontend_ops);

        Ok(Pipeline {
            runtime,
            backend: cfg.backend,
            k: cfg.templates_per_class,
            acam,
            acam_var: Variability::at_level(cfg.acam.variability_level),
            energy,
            e_frontend_nj,
            fwd_prefix,
            scratch: Vec::new(),
            rng: crate::rng::Rng::new(cfg.acam.seed ^ 0x5EED),
            meta,
            store,
        })
    }

    /// Pixels per image.
    pub fn image_len(&self) -> usize {
        let s = self.meta.artifacts.image_size;
        s * s
    }

    /// Run the front-end on `n` images packed in `images`, padding to the
    /// artifact batch `b`; returns the first `n` rows of the output matrix
    /// with `row_len` columns.
    fn run_frontend(
        &mut self,
        name_prefix: &str,
        images: &[f32],
        n: usize,
        b: usize,
        row_len: usize,
    ) -> Result<Vec<f32>> {
        let img_len = self.image_len();
        let s = self.meta.artifacts.image_size as i64;
        if images.len() != n * img_len {
            return Err(Error::Request(format!(
                "batch buffer has {} floats, expected {} ({} images)",
                images.len(),
                n * img_len,
                n
            )));
        }
        // Pad into the reusable scratch buffer.
        self.scratch.clear();
        self.scratch.resize(b * img_len, 0.0);
        self.scratch[..images.len()].copy_from_slice(images);
        let name = format!("{name_prefix}_b{b}");
        let exe = self.runtime.load(&name)?;
        let out = exe.run_f32(&[(&self.scratch, &[b as i64, s, s, 1])])?;
        if out.len() != b * row_len {
            return Err(Error::Artifact(format!(
                "{name} returned {} floats, expected {}",
                out.len(),
                b * row_len
            )));
        }
        Ok(out[..n * row_len].to_vec())
    }

    /// Extract (real-valued) feature maps for `n` images (public for the
    /// benches and template-refresh example).
    pub fn extract_features(&mut self, images: &[f32], n: usize) -> Result<Vec<f32>> {
        let nf = self.meta.artifacts.n_features;
        let max_b = *self.meta.artifacts.batch_sizes.iter().max().unwrap();
        let prefix = self.fwd_prefix;
        if n <= max_b {
            let b = self.meta.batch_for(n);
            return self.run_frontend(prefix, images, n, b, nf);
        }
        // Chunk oversized requests to artifact-sized dispatches.
        let img_len = self.image_len();
        let mut out = Vec::with_capacity(n * nf);
        let mut i = 0;
        while i < n {
            let m = max_b.min(n - i);
            let b = self.meta.batch_for(m);
            out.extend(self.run_frontend(
                prefix,
                &images[i * img_len..(i + m) * img_len],
                m,
                b,
                nf,
            )?);
            i += m;
        }
        Ok(out)
    }

    /// Classify a batch of `n` images (timings recorded by the caller).
    /// Batches beyond the largest exported artifact size are split into
    /// artifact-sized chunks.
    pub fn classify_batch(&mut self, images: &[f32], n: usize) -> Result<Vec<Classification>> {
        let max_b = *self.meta.artifacts.batch_sizes.iter().max().unwrap();
        if n > max_b {
            let img_len = self.image_len();
            let mut out = Vec::with_capacity(n);
            let mut i = 0;
            while i < n {
                let m = max_b.min(n - i);
                out.extend(self.classify_batch(&images[i * img_len..(i + m) * img_len], m)?);
                i += m;
            }
            return Ok(out);
        }
        let num_classes = self.store.num_classes;
        match self.backend {
            Backend::Softmax => {
                let b = self.meta.batch_for(n);
                let logits = self.run_frontend("student_softmax", images, n, b, num_classes)?;
                // Softmax baseline pays for the dense head: no ACAM term,
                // head ops not removed (they are excluded from
                // student_effective, which covers the pruned conv stack).
                let e = self.energy.frontend_nj(
                    self.meta.macs.as_built.student_effective
                        + self.meta.macs.as_built.head_ops,
                );
                Ok(logits
                    .chunks_exact(num_classes)
                    .map(|row| Classification {
                        class: argmax(row),
                        energy_nj: e,
                    })
                    .collect())
            }
            Backend::FeatureCount | Backend::Similarity | Backend::AcamSim => {
                let feats = self.extract_features(images, n)?;
                let nf = self.meta.artifacts.n_features;
                let mut out = Vec::with_capacity(n);
                for row in feats.chunks_exact(nf) {
                    out.push(self.classify_features(row)?);
                }
                Ok(out)
            }
        }
    }

    /// Classify one already-extracted feature map.
    pub fn classify_features(&mut self, features: &[f32]) -> Result<Classification> {
        let num_classes = self.store.num_classes;
        let set = self.store.set(self.k)?;
        let bits = self.store.binarize(features);
        let (class, e_backend) = match self.backend {
            Backend::FeatureCount => {
                let c = matching::classify_feature_count(&bits, set, num_classes);
                // Digital matcher modelled at the same ACAM energy envelope
                // (it replaces the same head); report the Eq. 14 figure.
                (c, self.energy.backend_nj(set.num_templates() as u64, set.num_features() as u64))
            }
            Backend::Similarity => {
                let qf: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
                let c = matching::classify_similarity(
                    &qf,
                    set,
                    self.store.similarity_alpha,
                    num_classes,
                    true,
                );
                (c, self.energy.backend_nj(set.num_templates() as u64, set.num_features() as u64))
            }
            Backend::AcamSim => {
                let arr = self
                    .acam
                    .as_mut()
                    .ok_or_else(|| Error::Config("ACAM array not programmed".into()))?;
                let search = arr.search(&binary_query_voltages(&bits));
                let c = wta::winner_take_all_classes(
                    &search.similarity,
                    &set.class_of,
                    num_classes,
                    &self.acam_var,
                    &mut self.rng,
                );
                (c, search.energy_nj)
            }
            Backend::Softmax => unreachable!("handled in classify_batch"),
        };
        Ok(Classification {
            class,
            energy_nj: self.e_frontend_nj + e_backend,
        })
    }

    /// Evaluate accuracy + confusion matrix over a labelled workload.
    pub fn evaluate(
        &mut self,
        images: &[f32],
        labels: &[usize],
        batch: usize,
    ) -> Result<Evaluation> {
        let img_len = self.image_len();
        let n = labels.len();
        let num_classes = self.store.num_classes;
        let mut confusion = vec![vec![0u64; num_classes]; num_classes];
        let mut correct = 0usize;
        let mut energy_nj = 0f64;
        let t0 = Instant::now();
        let mut i = 0;
        while i < n {
            let m = batch.min(n - i);
            let chunk = &images[i * img_len..(i + m) * img_len];
            for (j, c) in self.classify_batch(chunk, m)?.into_iter().enumerate() {
                let truth = labels[i + j];
                confusion[truth][c.class] += 1;
                correct += usize::from(c.class == truth);
                energy_nj += c.energy_nj;
            }
            i += m;
        }
        Ok(Evaluation {
            accuracy: correct as f64 / n as f64,
            confusion,
            total_energy_nj: energy_nj,
            wall_secs: t0.elapsed().as_secs_f64(),
            n,
        })
    }

    /// The §V.D report for this deployment (as-built scale).
    pub fn energy_report(&self) -> crate::energy::EnergyReport {
        let set = self.store.set(self.k).expect("validated at construction");
        self.energy.report(Scale::AsBuilt {
            frontend_ops: self.meta.macs.as_built.student_effective,
            teacher_macs: self.meta.macs.as_built.teacher_gray.macs,
            n_templates: set.num_templates() as u64,
            n_features: set.num_features() as u64,
        })
    }

    /// Access the underlying runtime (benches).
    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }
}

/// Accuracy/confusion summary of an evaluation run.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub accuracy: f64,
    pub confusion: Vec<Vec<u64>>,
    pub total_energy_nj: f64,
    pub wall_secs: f64,
    pub n: usize,
}

impl Evaluation {
    /// Per-class accuracy (Fig. 7).
    pub fn per_class_accuracy(&self) -> Vec<f64> {
        self.confusion
            .iter()
            .enumerate()
            .map(|(c, row)| {
                let total: u64 = row.iter().sum();
                if total == 0 {
                    0.0
                } else {
                    row[c] as f64 / total as f64
                }
            })
            .collect()
    }
}

/// Does the artifact set include the jnp-lowered fast front-end?
fn has_fast_variant(dir: &std::path::Path, meta: &Meta) -> bool {
    let b = meta.artifacts.batch_sizes.first().copied().unwrap_or(1);
    dir.join(format!("student_fwd_fast_b{b}.hlo.txt")).is_file()
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0); // tie -> low index
    }
}

//! `hec::api` v1 — the versioned public classification protocol.
//!
//! Everything that crosses the serving boundary speaks these types: the
//! in-process [`crate::coordinator::Handle`], the HTTP/JSON front door in
//! [`crate::gateway`], the CLI driver, and the e2e benches.  The surface is
//! transport-ready by construction:
//!
//! * [`ClassifyRequest`] — image + `top_k` + optional per-request backend
//!   override + `return_features` + a client-chosen request id;
//! * [`ClassifyResponse`] — ranked [`Prediction`]s (per-class best scores
//!   from the top-k matching path), a per-stage [`EnergyBreakdown`], queue /
//!   compute [`Timing`], and the engine + backend that actually served the
//!   request;
//! * [`ApiError`] — a stable machine-readable [`ErrorCode`] plus a human
//!   message; [`crate::error::Error`] maps onto it (`From<Error>`), and the
//!   gateway maps codes onto HTTP statuses.
//!
//! JSON encode/decode (over [`crate::jsonlite`], no serde) lives in
//! [`wire`]; the in-memory types here carry no transport concerns.
//!
//! Versioning contract: additive changes (new optional request fields, new
//! response fields, new error codes) stay v1; anything that re-interprets an
//! existing field is v2 under a new URL prefix.

pub mod binary;
pub mod stream;
pub mod wire;

use crate::config::Backend;

/// Protocol version tag (`/v1/...` URL prefix, `"api"` response field).
pub const API_VERSION: &str = "v1";

/// One ranked class candidate.
///
/// Score semantics follow the serving backend (documented per backend in
/// README §HTTP API): Eq. 8 match counts for `fc`, Eq. 9-11 similarities for
/// `sim`, normalised (offset-noised) match-line voltages for `acam`, raw
/// logits for `softmax`.  Within one response, scores are non-increasing.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    pub class: usize,
    pub score: f64,
}

/// Per-stage modelled energy (nJ).  `front_end_nj + back_end_nj` equals the
/// single `energy_nj` figure the pre-v1 API reported.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyBreakdown {
    /// Student-CNN front-end (effective MACs; includes the dense head for
    /// the softmax backend, which has no separate back-end stage).
    pub front_end_nj: f64,
    /// Back-end search (ACAM Eq. 14 envelope / match-line energy; zero for
    /// softmax).
    pub back_end_nj: f64,
}

impl EnergyBreakdown {
    pub fn total_nj(&self) -> f64 {
        self.front_end_nj + self.back_end_nj
    }
}

/// Where a request's latency went.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Timing {
    /// Time spent queued before the batcher dispatched the batch (µs).
    pub queue_us: u64,
    /// Engine + matcher compute time of the carrying batch (µs).
    pub compute_us: u64,
}

/// A v1 classification request.
#[derive(Debug, Clone)]
pub struct ClassifyRequest {
    /// Row-major grayscale pixels, `image_size^2` floats (the deployment's
    /// `/healthz` reports the expected length).
    pub image: Vec<f32>,
    /// How many ranked classes to return.  Must be in
    /// `1..=num_classes` — `0` and values above the deployment's class
    /// count are both rejected as `INVALID_ARGUMENT` (uniformly across
    /// the JSON, streaming, and binary ingest paths).
    pub top_k: usize,
    /// Per-request backend override; `None` serves on the deployment
    /// backend.  Overrides the deployment did not provision for (e.g.
    /// `acam` when no array was programmed) fail with
    /// `BACKEND_UNAVAILABLE`.
    pub backend: Option<Backend>,
    /// Also return the raw front-end feature vector.
    pub return_features: bool,
    /// Client-chosen correlation id, echoed verbatim in the response.
    pub request_id: Option<String>,
    /// Queue deadline in milliseconds, measured from submit.  A request
    /// still queued when its deadline elapses fails fast with
    /// `DEADLINE_EXCEEDED` instead of being computed for a caller that has
    /// already given up.  Must be `>= 1`: every ingest decoder (JSON tree,
    /// streaming, binary meta) rejects an explicit `0` as
    /// `INVALID_ARGUMENT` — a zero deadline is indistinguishable from a
    /// client bug, not a request that could ever be served.  (In-process
    /// callers constructing `Some(0)` directly still get the "already too
    /// late" expiry semantics — the queue drop compares with `>=`.)
    /// Additive v1 field; `None` (the default) never expires.
    pub deadline_ms: Option<u64>,
}

impl ClassifyRequest {
    /// A default top-1 request on the deployment backend.
    pub fn new(image: Vec<f32>) -> Self {
        ClassifyRequest {
            image,
            top_k: 1,
            backend: None,
            return_features: false,
            request_id: None,
            deadline_ms: None,
        }
    }

    /// The per-item knobs the pipeline needs (everything but the image and
    /// transport metadata).
    pub fn options(&self) -> ClassifyOptions {
        ClassifyOptions {
            top_k: self.top_k,
            backend: self.backend,
            return_features: self.return_features,
        }
    }
}

/// Pipeline-level per-item options (see [`ClassifyRequest`] field docs).
#[derive(Debug, Clone, Copy)]
pub struct ClassifyOptions {
    pub top_k: usize,
    pub backend: Option<Backend>,
    pub return_features: bool,
}

impl Default for ClassifyOptions {
    fn default() -> Self {
        ClassifyOptions {
            top_k: 1,
            backend: None,
            return_features: false,
        }
    }
}

/// One classification outcome at the pipeline level — no transport metadata
/// yet (the server adds timing / ids and lifts this into a
/// [`ClassifyResponse`]).
#[derive(Debug, Clone)]
pub struct ClassifyResult {
    /// Ranked candidates, best first; never empty.
    pub predictions: Vec<Prediction>,
    pub energy: EnergyBreakdown,
    /// The backend that actually scored this item (override-resolved).
    pub backend: Backend,
    /// Raw front-end features, when requested.
    pub features: Option<Vec<f32>>,
    /// Template store that scored this item, as `(id, version)`.  `None`
    /// when the deployment's store registry is in single-default-store
    /// mode (no tenants, nothing published) — the pre-registry serving
    /// shape.
    pub store: Option<(std::sync::Arc<str>, u64)>,
    /// Whether the feature cache served this item (`Some(true)` = hit, the
    /// front-end was skipped and `front_end_nj` is 0; `Some(false)` = the
    /// cold path ran).  `None` when the cache is disabled or the item was
    /// not cache-eligible — the wire form then stays byte-identical to
    /// cache-free builds.
    pub cache: Option<bool>,
}

impl ClassifyResult {
    /// The winning candidate (the pre-v1 `Classification::class`).
    pub fn top1(&self) -> &Prediction {
        &self.predictions[0]
    }
}

/// A v1 classification response.
#[derive(Debug, Clone)]
pub struct ClassifyResponse {
    /// Echo of [`ClassifyRequest::request_id`].
    pub request_id: Option<String>,
    /// Ranked candidates, best first; never empty.
    pub predictions: Vec<Prediction>,
    pub energy: EnergyBreakdown,
    pub timing: Timing,
    /// Execution engine that served the request (`interp`, `interp-fast`,
    /// `pjrt`).
    pub engine: &'static str,
    /// Backend that scored the request (override-resolved).
    pub backend: Backend,
    /// The deployed [`MatchingBackend`] variant behind the `acam` route
    /// (`"acam-9t4r"`, `"rbf"`, `"digital"`).  Additive v1 field; `None`
    /// whenever the deployment runs the default `acam` variant **or** this
    /// request resolved to a digital route (`fc`/`sim`/`softmax`) — in
    /// both cases the wire form is byte-identical to pre-seam builds.
    ///
    /// [`MatchingBackend`]: crate::backend::MatchingBackend
    pub backend_variant: Option<&'static str>,
    pub features: Option<Vec<f32>>,
    /// Index of the worker shard that served the request.  Additive v1
    /// field.  `None` only for un-sharded in-process deployments
    /// (`coordinator::Server`/`Handle`); the `hec serve` binary always
    /// runs a `ShardSet`, so over HTTP this is present even at
    /// `--shards 1` (as `0`).
    pub shard: Option<usize>,
    /// Whether the serving shard's ACAM back-end was degraded (not
    /// `healthy` on the degradation ladder) when this request dispatched.
    /// Additive v1 field; `None` whenever the canary ladder is inactive —
    /// in that case the wire form is byte-identical to pre-faults builds.
    pub degraded: Option<bool>,
    /// The serving shard's degradation-ladder state at dispatch
    /// (`"healthy"`, `"reprogramming"`, `"digital_fallback"`).  Additive v1
    /// field; `None` whenever the canary ladder is inactive.
    pub backend_state: Option<String>,
    /// Id of the template store that scored this request.  Additive v1
    /// field; `None` whenever the store registry is in
    /// single-default-store mode (no tenant config, nothing published) —
    /// in that case the wire form is byte-identical to pre-registry
    /// builds.
    pub store: Option<String>,
    /// Version of the template store that scored this request (`0` is the
    /// bootstrap store a shard built itself).  Additive v1 field; same
    /// `None` rule as [`ClassifyResponse::store`].
    pub store_version: Option<u64>,
    /// Whether the per-shard feature cache served this request (`true` =
    /// content-hash hit, the CNN front-end was skipped and `front_end_nj`
    /// is 0).  Additive v1 field; `None` whenever the cache is disabled or
    /// the request was not cache-eligible (softmax backend,
    /// `return_features`, tenant-routed store) — in that case the wire
    /// form is byte-identical to cache-free builds.
    pub cache: Option<bool>,
}

impl ClassifyResponse {
    pub fn top1(&self) -> &Prediction {
        &self.predictions[0]
    }
}

/// Stable machine-readable failure codes.  The string form (SCREAMING_CASE,
/// [`ErrorCode::as_str`]) is the wire contract; variants are only ever
/// added, never re-used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Image length does not match the deployment's `image_size^2`.
    InvalidShape,
    /// A request field is out of range or unparseable (`top_k: 0`, unknown
    /// backend name, ...).
    InvalidArgument,
    /// Request body is not valid JSON / not the documented schema.
    MalformedRequest,
    /// The bounded request queue is full (backpressure) — retry later.
    QueueFull,
    /// The requested backend is not provisioned in this deployment.
    BackendUnavailable,
    /// The server is shutting down / the worker is gone.
    ServerStopped,
    /// No such route.
    NotFound,
    /// Route exists, method does not.
    MethodNotAllowed,
    /// The request's `deadline_ms` elapsed before compute dispatched (or,
    /// at the gateway, the client stalled past the body-read deadline).
    DeadlineExceeded,
    /// The resolved tenant is at its configured in-flight quota — retry
    /// after an outstanding request resolves.
    QuotaExceeded,
    /// A bodied request (POST/PUT) arrived with neither `Content-Length`
    /// nor `Transfer-Encoding: chunked` — the gateway cannot frame the
    /// body, so it refuses instead of silently reading it as empty.
    LengthRequired,
    /// Unexpected internal failure (engine error, dropped response, ...).
    Internal,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::InvalidShape => "INVALID_SHAPE",
            ErrorCode::InvalidArgument => "INVALID_ARGUMENT",
            ErrorCode::MalformedRequest => "MALFORMED_REQUEST",
            ErrorCode::QueueFull => "QUEUE_FULL",
            ErrorCode::BackendUnavailable => "BACKEND_UNAVAILABLE",
            ErrorCode::ServerStopped => "SERVER_STOPPED",
            ErrorCode::NotFound => "NOT_FOUND",
            ErrorCode::MethodNotAllowed => "METHOD_NOT_ALLOWED",
            ErrorCode::DeadlineExceeded => "DEADLINE_EXCEEDED",
            ErrorCode::QuotaExceeded => "QUOTA_EXCEEDED",
            ErrorCode::LengthRequired => "LENGTH_REQUIRED",
            ErrorCode::Internal => "INTERNAL",
        }
    }

    /// Parse the wire form back (test clients, log scrapers).
    pub fn parse(s: &str) -> Option<ErrorCode> {
        Some(match s {
            "INVALID_SHAPE" => ErrorCode::InvalidShape,
            "INVALID_ARGUMENT" => ErrorCode::InvalidArgument,
            "MALFORMED_REQUEST" => ErrorCode::MalformedRequest,
            "QUEUE_FULL" => ErrorCode::QueueFull,
            "BACKEND_UNAVAILABLE" => ErrorCode::BackendUnavailable,
            "SERVER_STOPPED" => ErrorCode::ServerStopped,
            "NOT_FOUND" => ErrorCode::NotFound,
            "METHOD_NOT_ALLOWED" => ErrorCode::MethodNotAllowed,
            "DEADLINE_EXCEEDED" => ErrorCode::DeadlineExceeded,
            "QUOTA_EXCEEDED" => ErrorCode::QuotaExceeded,
            "LENGTH_REQUIRED" => ErrorCode::LengthRequired,
            "INTERNAL" => ErrorCode::Internal,
            _ => return None,
        })
    }

    /// The HTTP status the gateway maps this code onto for API-level
    /// failures.  Two documented exceptions where the transport carries a
    /// more specific RFC status than this mapping: protocol rejections
    /// (oversized head/body, unsupported transfer encoding) carry
    /// `MALFORMED_REQUEST` with 431/413/501, and a client that stalls past
    /// the gateway's body-read deadline gets `DEADLINE_EXCEEDED` with 408
    /// (the queue-side deadline keeps the 504 below) — the code tells the
    /// client *what kind* of failure it is, the status carries the
    /// HTTP-level detail.
    pub fn http_status(&self) -> u16 {
        match self {
            ErrorCode::InvalidShape
            | ErrorCode::InvalidArgument
            | ErrorCode::MalformedRequest => 400,
            ErrorCode::NotFound => 404,
            ErrorCode::MethodNotAllowed => 405,
            ErrorCode::QueueFull | ErrorCode::QuotaExceeded => 429,
            ErrorCode::LengthRequired => 411,
            ErrorCode::BackendUnavailable | ErrorCode::ServerStopped => 503,
            ErrorCode::DeadlineExceeded => 504,
            ErrorCode::Internal => 500,
        }
    }
}

/// A structured API failure: stable code + human-readable detail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        ApiError {
            code,
            message: message.into(),
        }
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_breakdown_sums() {
        let e = EnergyBreakdown {
            front_end_nj: 1.25,
            back_end_nj: 1.45,
        };
        assert!((e.total_nj() - 2.7).abs() < 1e-12);
    }

    #[test]
    fn error_codes_roundtrip_and_have_statuses() {
        for code in [
            ErrorCode::InvalidShape,
            ErrorCode::InvalidArgument,
            ErrorCode::MalformedRequest,
            ErrorCode::QueueFull,
            ErrorCode::BackendUnavailable,
            ErrorCode::ServerStopped,
            ErrorCode::NotFound,
            ErrorCode::MethodNotAllowed,
            ErrorCode::DeadlineExceeded,
            ErrorCode::QuotaExceeded,
            ErrorCode::LengthRequired,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
            let s = code.http_status();
            assert!((400..=599).contains(&s), "{code:?} -> {s}");
        }
        assert_eq!(ErrorCode::parse("NOPE"), None);
    }

    #[test]
    fn api_error_displays_code_prefix() {
        let e = ApiError::new(ErrorCode::QueueFull, "queue full (backpressure)");
        assert_eq!(e.to_string(), "QUEUE_FULL: queue full (backpressure)");
    }

    #[test]
    fn request_defaults() {
        let r = ClassifyRequest::new(vec![0.0; 4]);
        assert_eq!(r.top_k, 1);
        assert!(r.backend.is_none());
        assert!(!r.return_features);
        assert!(r.request_id.is_none());
        assert!(r.deadline_ms.is_none());
        let o = r.options();
        assert_eq!(o.top_k, 1);
    }
}

"""Hyper-parameter configuration for the hybrid edge classifier pipeline.

Every stage of the paper's methodology (Section II) is parameterised here so
that the ablation sweeps in ``run_experiments.py`` and the AOT export in
``aot.py`` share a single source of truth.  Values default to the paper's
choices; scale knobs (dataset size, teacher width, epochs) default to values
that train in minutes on a single CPU — the paper-scale constants used for
the Table I / §V.D energy accounting live in :mod:`compile.macs`.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class DataConfig:
    """Dataset parameters (Section IV-A).

    If ``cifar_dir`` points at an extracted CIFAR-10 python-pickle directory
    the real dataset is used; otherwise the synthetic CIFAR-like generator in
    :mod:`compile.data` produces a matched-shape workload (see DESIGN.md
    §Substitutions).
    """

    cifar_dir: Optional[str] = None  # $CIFAR10_DIR override in data.py
    image_size: int = 32
    num_classes: int = 10
    grayscale: bool = True  # paper: Y = .2989 R + .5870 G + .1140 B
    train_samples: int = 4000  # synthetic generator sizes (paper: 50_000)
    test_samples: int = 1000  # paper: 10_000
    seed: int = 0


@dataclass
class TeacherConfig:
    """Teacher ResNet (Section IV-B): 3 stages of residual blocks.

    The paper calls it ResNet-50 but describes the CIFAR-style 3-stage
    residual network (16/32/64-channel stages, two 3x3 convs per block).
    ``width`` scales the first-stage channel count; ``blocks_per_stage``
    scales depth.  Paper-scale: width=16 with enough blocks for 26.2M params;
    default here is CPU-trainable.
    """

    width: int = 16
    blocks_per_stage: int = 1
    l2: float = 1e-4
    epochs: int = 6
    batch_size: int = 64
    lr: float = 1e-3
    seed: int = 1


@dataclass
class StudentConfig:
    """Student CNN (Fig. 5): conv32-BN-pool, conv128-BN-pool, conv256, conv16.

    The trailing 2x2-valid conv16 reduces the 8x8x256 map to 7x7x16 = 784
    features — the template width used throughout Section V.
    """

    filters: tuple = (32, 128, 256, 16)
    feature_dim: int = 784  # 7*7*16, fixed by the Fig. 5 architecture
    epochs: int = 6
    batch_size: int = 64
    lr: float = 1e-3
    seed: int = 2


@dataclass
class DistillConfig:
    """Knowledge distillation (Section II-A, Eq. 1-4)."""

    alpha: float = 0.7  # weight on the KD term in Eq. 1
    temperature: float = 4.0  # T in Eq. 2-3
    curriculum: bool = True  # teacher-loss-ordered batches (Eq. 4)
    epochs: int = 6


@dataclass
class PruneConfig:
    """Magnitude pruning (Section II-B, Eq. 5-7)."""

    initial_sparsity: float = 0.50  # s_i
    final_sparsity: float = 0.80  # s_f
    pruning_steps: int = 8  # n_t in Eq. 5
    finetune_steps_per_prune: int = 30
    final_finetune_epochs: int = 2


@dataclass
class QuantConfig:
    """Quantisation scheme (Section II-C)."""

    weight_bits: int = 8
    qat_epochs: int = 2
    # Feature-map binarisation threshold mode for templates: "mean" | "median"
    threshold_mode: str = "mean"


@dataclass
class TemplateConfig:
    """ACAM template generation (Section II-D1)."""

    templates_per_class: int = 1  # Table II sweeps 1, 2, 3
    kmeans_iters: int = 50
    kmeans_restarts: int = 4
    similarity_alpha: float = 0.05  # alpha in Eq. 11
    window_margin: float = 0.0  # half-width added around binary template bounds
    seed: int = 3


@dataclass
class PipelineConfig:
    """Top-level pipeline configuration."""

    data: DataConfig = field(default_factory=DataConfig)
    teacher: TeacherConfig = field(default_factory=TeacherConfig)
    student: StudentConfig = field(default_factory=StudentConfig)
    distill: DistillConfig = field(default_factory=DistillConfig)
    prune: PruneConfig = field(default_factory=PruneConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)
    template: TemplateConfig = field(default_factory=TemplateConfig)
    # Batch sizes for which AOT inference artifacts are emitted.
    export_batch_sizes: tuple = (1, 8, 32)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=list)

    @staticmethod
    def fast() -> "PipelineConfig":
        """A configuration that completes the full pipeline in ~1-2 min on CPU.

        Used by the default ``make artifacts`` target and by the integration
        tests; ``run_experiments.py --full`` scales everything up.
        """
        cfg = PipelineConfig()
        cfg.data.train_samples = 2000
        cfg.data.test_samples = 600
        cfg.teacher.epochs = 4
        cfg.student.epochs = 4
        cfg.distill.epochs = 6
        cfg.prune.pruning_steps = 6
        cfg.prune.finetune_steps_per_prune = 25
        cfg.prune.final_finetune_epochs = 2
        cfg.quant.qat_epochs = 1
        return cfg

"""Pallas pattern-matching kernels — the software model of the ACAM array.

The physical ACAM compares a query against *all* stored templates
simultaneously: every TXL cell checks one (template, feature) pair and the
per-template matchline integrates the per-cell match currents.  The TPU
analogue of that all-parallel compare is a VPU broadcast-compare-reduce over
a (templates x features) tile: each grid step holds one (BB queries, BM
templates) score tile in VMEM, streams BN-feature slabs of the query block
and template block through, and accumulates the reduction — exactly the
matchline's charge accumulation, with the innermost grid axis playing the
role of time.

Two kernels, mirroring Section II-D2:
  * ``match_feature_count`` — Eq. 8, exact-equality count (binary ACAM).
  * ``match_similarity``    — Eq. 9-11, windowed distance + hit-ratio model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: BB query rows x BM template rows x BN features per grid step.
# Score tile (BB x BM) stays VMEM-resident across the feature axis (the
# accumulator), query/template slabs are (BB x BN) and (BM x BN).
BB, BM, BN = 32, 16, 256


def _pad(x, m0, m1, value=0.0):
    p0, p1 = (-x.shape[0]) % m0, (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=value)
    return x


def _fc_kernel(q_ref, t_ref, o_ref, *, n_pad: int, n_k: int):
    """Feature-count tile: o[b,m] += sum_n I(q[b,n] == t[m,n])."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    eq = q_ref[...][:, None, :] == t_ref[...][None, :, :]
    o_ref[...] += jnp.sum(eq.astype(jnp.float32), axis=-1)

    # Padded feature columns compare 0 == 0 and inflate every score by the
    # same constant; remove it on the last slab so scores equal Eq. 8 exactly.
    @pl.when((k == n_k - 1) & (n_pad > 0))
    def _depad():
        o_ref[...] -= jnp.float32(n_pad)


def match_feature_count(q: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8 scores: q [B,N] x t [M,N] -> f32 [B,M]."""
    bq, n = q.shape
    m, n2 = t.shape
    assert n == n2
    bb, bm, bn = min(BB, bq), min(BM, m), min(BN, n)
    qp, tp = _pad(q, bb, bn), _pad(t, bm, bn)
    n_pad = qp.shape[1] - n
    n_k = qp.shape[1] // bn
    out = pl.pallas_call(
        functools.partial(_fc_kernel, n_pad=n_pad, n_k=n_k),
        grid=(qp.shape[0] // bb, tp.shape[0] // bm, n_k),
        in_specs=[
            pl.BlockSpec((bb, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], tp.shape[0]), jnp.float32),
        interpret=True,
    )(qp, tp)
    return out[:bq, :m]


def _sim_kernel(q_ref, lo_ref, hi_ref, d_ref, h_ref):
    """Similarity tile: accumulate distance-outside-window and hit count."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    qb = q_ref[...][:, None, :]
    lo = lo_ref[...][None, :, :]
    hi = hi_ref[...][None, :, :]
    over = jnp.maximum(qb - hi, 0.0)
    under = jnp.maximum(lo - qb, 0.0)
    d_ref[...] += jnp.sum(over * over + under * under, axis=-1)
    h_ref[...] += jnp.sum(((qb >= lo) & (qb <= hi)).astype(jnp.float32), axis=-1)


def match_similarity(
    q: jnp.ndarray, t_lo: jnp.ndarray, t_hi: jnp.ndarray, alpha: float
) -> jnp.ndarray:
    """Eq. 9-11 scores: q [B,N], bounds [M,N] -> f32 [B,M].

    Padded feature columns are given the window [0, 0] and padded queries the
    value 0, so pads register as in-window hits with zero distance; the final
    hit-ratio division uses the *true* N and subtracts the pad hits.
    """
    bq, n = q.shape
    m, n2 = t_lo.shape
    assert n == n2 and t_hi.shape == t_lo.shape
    bb, bm, bn = min(BB, bq), min(BM, m), min(BN, n)
    qp = _pad(q, bb, bn)
    lop, hip = _pad(t_lo, bm, bn), _pad(t_hi, bm, bn)
    n_pad = qp.shape[1] - n
    d, h = pl.pallas_call(
        _sim_kernel,
        grid=(qp.shape[0] // bb, lop.shape[0] // bm, qp.shape[1] // bn),
        in_specs=[
            pl.BlockSpec((bb, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bm), lambda i, j, k: (i, j)),
            pl.BlockSpec((bb, bm), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0], lop.shape[0]), jnp.float32),
            jax.ShapeDtypeStruct((qp.shape[0], lop.shape[0]), jnp.float32),
        ],
        interpret=True,
    )(qp, lop, hip)
    d = d[:bq, :m]
    h = (h[:bq, :m] - n_pad) / jnp.float32(n)
    return h / (1.0 + alpha * d)

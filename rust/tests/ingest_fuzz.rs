//! Deterministic seeded fuzz for the streaming ingestion stack.
//!
//! Three attack surfaces, one invariant each:
//!
//! 1. JSON: the pull parser and streaming request decoders must agree with
//!    the `jsonlite` tree parser byte-for-byte — same accept/reject
//!    decision, same error message, same error byte offset — on seeded
//!    corpus documents (`rust/tests/corpus/`) and thousands of mutations
//!    of them.
//! 2. Chunked transfer-encoding: `read_request` must reassemble valid
//!    chunked bodies exactly, regardless of how the bytes are fragmented
//!    across reads, and must turn every truncation or framing corruption
//!    into a clean `ReadError` — never a panic, never a hang.
//! 3. Raw-binary frames: `encode_batch`/`decode_batch` must round-trip
//!    bit-exactly, and every truncation or byte flip of a valid frame must
//!    decode to a stable error, never a panic.
//!
//! Everything is seeded (`hec::rng::Rng`, SplitMix64) so a failure
//! reproduces exactly.  `HEC_FUZZ_CASES` scales the per-group case count
//! (default keeps `cargo test --release` in the tier-1 budget; CI raises
//! it).

use std::io::{BufReader, Read};

use hec::api::stream::{decode_batch_envelope, decode_classify_request};
use hec::api::{binary, ApiError, ClassifyRequest, ErrorCode};
use hec::config::Backend;
use hec::coordinator::ClassifySurface;
use hec::gateway::http::{read_request, ReadError, MAX_BODY_BYTES};
use hec::jsonlite::stream::PullParser;
use hec::jsonlite::{self};
use hec::rng::Rng;

/// Seed corpus: checked-in interesting inputs that mutations start from.
const SEEDS: &[&str] = &[
    include_str!("corpus/classify_single.json"),
    include_str!("corpus/classify_batch.json"),
    include_str!("corpus/numbers.json"),
    include_str!("corpus/strings.json"),
    include_str!("corpus/malformed.json"),
];

fn cases(default: usize) -> usize {
    std::env::var("HEC_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Bytes that matter to a JSON lexer — mutations draw from these so they
/// hit grammar edges instead of just corrupting string payloads.
const INTERESTING: &[u8] = b"{}[]:,\"\\eE.-+0159u truefalsenull\r\n\t\x00\x7f";

fn mutate(rng: &mut Rng, seed: &str) -> String {
    let mut b = seed.as_bytes().to_vec();
    for _ in 0..1 + rng.below(4) {
        match rng.below(4) {
            0 if !b.is_empty() => {
                let i = rng.below(b.len());
                b[i] = INTERESTING[rng.below(INTERESTING.len())];
            }
            1 => {
                let i = rng.below(b.len() + 1);
                b.insert(i, INTERESTING[rng.below(INTERESTING.len())]);
            }
            2 if !b.is_empty() => {
                b.remove(rng.below(b.len()));
            }
            _ if !b.is_empty() => {
                b.truncate(rng.below(b.len()) + 1);
            }
            _ => {}
        }
    }
    b.truncate(4096);
    // The gateway only hands UTF-8 to the parsers (`body_text` rejects the
    // rest), so lossy-decode mutations the same way a client never could.
    String::from_utf8_lossy(&b).into_owned()
}

/// Iterate the corpus verbatim first, then endless seeded mutations.
fn fuzz_inputs(rng: &mut Rng, case: usize) -> String {
    if case < SEEDS.len() {
        SEEDS[case].to_string()
    } else {
        mutate(rng, SEEDS[case % SEEDS.len()])
    }
}

// ---------------------------------------------------------------------------
// Group 1: JSON parity
// ---------------------------------------------------------------------------

#[test]
fn fuzz_pull_parser_matches_tree_parser() {
    let mut rng = Rng::new(0x19e5_7000_0001);
    for case in 0..cases(800) {
        let text = fuzz_inputs(&mut rng, case);
        let tree = jsonlite::parse(&text)
            .map(|_| ())
            .map_err(|e| e.to_string());
        let mut p = PullParser::new(&text);
        p.skip_ws();
        let pull = p
            .skip_value()
            .and_then(|_| p.end())
            .map_err(|e| e.to_string());
        assert_eq!(tree, pull, "raw parser parity diverged on {text:?}");
    }
}

fn malformed(e: impl std::fmt::Display) -> ApiError {
    ApiError::new(ErrorCode::MalformedRequest, format!("invalid JSON: {e}"))
}

fn err_parts(e: &ApiError) -> (ErrorCode, &str) {
    (e.code, e.message.as_str())
}

fn assert_item_parity(
    t: &Result<ClassifyRequest, ApiError>,
    s: &Result<ClassifyRequest, ApiError>,
    text: &str,
) {
    match (t, s) {
        (Ok(a), Ok(b)) => {
            let ab: Vec<u32> = a.image.iter().map(|p| p.to_bits()).collect();
            let bb: Vec<u32> = b.image.iter().map(|p| p.to_bits()).collect();
            assert_eq!(ab, bb, "image bits diverged on {text:?}");
            assert_eq!(a.top_k, b.top_k, "top_k diverged on {text:?}");
            assert_eq!(a.backend, b.backend, "backend diverged on {text:?}");
            assert_eq!(
                a.return_features, b.return_features,
                "return_features diverged on {text:?}"
            );
            assert_eq!(a.request_id, b.request_id, "request_id diverged on {text:?}");
            assert_eq!(a.deadline_ms, b.deadline_ms, "deadline_ms diverged on {text:?}");
        }
        (Err(a), Err(b)) => {
            assert_eq!(err_parts(a), err_parts(b), "error diverged on {text:?}");
        }
        (a, b) => panic!("accept/reject diverged on {text:?}: tree={a:?} stream={b:?}"),
    }
}

#[test]
fn fuzz_streaming_single_decode_matches_tree_decode() {
    let mut rng = Rng::new(0x19e5_7000_0002);
    for case in 0..cases(800) {
        let text = fuzz_inputs(&mut rng, case);
        let tree = jsonlite::parse(&text)
            .map_err(malformed)
            .and_then(|v| ClassifyRequest::from_value(&v));
        let streamed = decode_classify_request(&text, 16);
        assert_item_parity(&tree, &streamed, &text);
    }
}

#[test]
fn fuzz_streaming_batch_decode_matches_tree_decode() {
    fn tree_batch(text: &str) -> Result<Vec<Result<ClassifyRequest, ApiError>>, ApiError> {
        let doc = jsonlite::parse(text).map_err(malformed)?;
        let items = doc
            .get("requests")
            .and_then(jsonlite::Value::as_array)
            .ok_or_else(|| {
                ApiError::new(
                    ErrorCode::InvalidArgument,
                    "body must be {\"requests\": [...]}",
                )
            })?;
        Ok(items.iter().map(ClassifyRequest::from_value).collect())
    }

    let mut rng = Rng::new(0x19e5_7000_0003);
    for case in 0..cases(800) {
        let text = fuzz_inputs(&mut rng, case);
        let tree = tree_batch(&text);
        let streamed = decode_batch_envelope(&text, 16, |r| r);
        match (&tree, &streamed) {
            (Ok(ti), Ok(si)) => {
                assert_eq!(ti.len(), si.len(), "batch len diverged on {text:?}");
                for (t, s) in ti.iter().zip(si) {
                    assert_item_parity(t, s, &text);
                }
            }
            (Err(a), Err(b)) => {
                assert_eq!(err_parts(a), err_parts(b), "batch error diverged on {text:?}");
            }
            (a, b) => panic!("batch accept/reject diverged on {text:?}: tree={a:?} stream={b:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Group 2: chunked transfer-encoding
// ---------------------------------------------------------------------------

/// A reader that hands out the underlying bytes in a seeded, irregular
/// fragment schedule, so chunk-size lines and CRLF terminators straddle
/// `fill_buf` boundaries in every way.
struct Chopper {
    data: Vec<u8>,
    pos: usize,
    sizes: Vec<usize>,
    k: usize,
}

impl Chopper {
    fn new(data: Vec<u8>, rng: &mut Rng) -> Self {
        let sizes = (0..17).map(|_| 1 + rng.below(13)).collect();
        Chopper {
            data,
            pos: 0,
            sizes,
            k: 0,
        }
    }
}

impl Read for Chopper {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() {
            return Ok(0);
        }
        let want = self.sizes[self.k % self.sizes.len()];
        self.k += 1;
        let n = want.min(buf.len()).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

const CHUNKED_HEAD: &[u8] = b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";

/// Encode `payload` as a chunked request with seeded chunk sizes, optional
/// extensions and trailers.
fn chunked_request(rng: &mut Rng, payload: &[u8]) -> Vec<u8> {
    let mut out = CHUNKED_HEAD.to_vec();
    let mut pos = 0;
    while pos < payload.len() {
        let n = (1 + rng.below(19)).min(payload.len() - pos);
        out.extend_from_slice(format!("{n:x}").as_bytes());
        if rng.below(4) == 0 {
            out.extend_from_slice(b";ext=\"v;1\"");
        }
        out.extend_from_slice(b"\r\n");
        out.extend_from_slice(&payload[pos..pos + n]);
        out.extend_from_slice(b"\r\n");
        pos += n;
    }
    out.extend_from_slice(b"0\r\n");
    if rng.below(3) == 0 {
        out.extend_from_slice(b"X-Trailer: ignored\r\nX-More: 2\r\n");
    }
    out.extend_from_slice(b"\r\n");
    out
}

fn parse_fragmented(bytes: Vec<u8>, rng: &mut Rng) -> Result<hec::gateway::http::Request, ReadError> {
    let cap = [1, 2, 3, 5, 8, 64][rng.below(6)];
    let mut reader = BufReader::with_capacity(cap, Chopper::new(bytes, rng));
    read_request(&mut reader)
}

#[test]
fn fuzz_chunked_valid_bodies_reassemble_exactly() {
    let mut rng = Rng::new(0x19e5_7000_0004);
    for case in 0..cases(300) {
        let len = rng.below(600);
        let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let wire = chunked_request(&mut rng, &payload);
        match parse_fragmented(wire, &mut rng) {
            Ok(req) => assert_eq!(req.body, payload, "case {case}: body mangled"),
            Err(e) => panic!("case {case}: valid chunked request rejected: {e:?}"),
        }
    }
}

#[test]
fn fuzz_chunked_corruptions_fail_cleanly() {
    let mut rng = Rng::new(0x19e5_7000_0005);
    for case in 0..cases(600) {
        let len = rng.below(200);
        let payload: Vec<u8> = (0..len).map(|_| b'a' + (rng.below(26) as u8)).collect();
        let mut wire = chunked_request(&mut rng, &payload);
        let head_len = CHUNKED_HEAD.len();
        // Corrupt only the body framing; a mangled head is another test's
        // problem and would mask the chunked-reader edges.
        match rng.below(3) {
            0 => {
                // truncate anywhere inside the body (incl. mid size-line)
                let cut = head_len + rng.below(wire.len() - head_len);
                wire.truncate(cut);
            }
            1 => {
                let i = head_len + rng.below(wire.len() - head_len);
                wire[i] = INTERESTING[rng.below(INTERESTING.len())];
            }
            _ => {
                let i = head_len + rng.below(wire.len() - head_len);
                wire.insert(i, INTERESTING[rng.below(INTERESTING.len())]);
            }
        }
        // Must terminate with Ok or a clean error — never panic.  (A
        // corruption can still parse: e.g. flipping a payload byte.)
        match parse_fragmented(wire, &mut rng) {
            Ok(req) => assert!(req.body.len() <= MAX_BODY_BYTES),
            Err(ReadError::Eof) | Err(ReadError::Bad(..)) => {}
        }
    }
}

#[test]
fn fuzz_chunked_every_truncation_of_corpus_seed_errors() {
    // The checked-in seed uses LF line endings (git-friendly); the wire
    // format is CRLF.
    let body = include_str!("corpus/chunked_ok.txt").replace('\n', "\r\n");
    let mut wire = CHUNKED_HEAD.to_vec();
    wire.extend_from_slice(body.as_bytes());

    let mut rng = Rng::new(0x19e5_7000_0006);
    let full = parse_fragmented(wire.clone(), &mut rng).expect("corpus seed parses");
    assert_eq!(full.body, br#"{"image": [0.5], "top_k": 1}"#);

    for cut in CHUNKED_HEAD.len()..wire.len() {
        match parse_fragmented(wire[..cut].to_vec(), &mut rng) {
            Err(ReadError::Eof) | Err(ReadError::Bad(..)) => {}
            Ok(_) => panic!("truncation at {cut} still parsed"),
        }
    }
}

// ---------------------------------------------------------------------------
// Group 3: raw-binary frames
// ---------------------------------------------------------------------------

fn random_request(rng: &mut Rng) -> ClassifyRequest {
    let image: Vec<f32> = (0..rng.below(48))
        .map(|_| rng.range(-4.0, 4.0) as f32)
        .collect();
    let mut req = ClassifyRequest::new(image);
    req.top_k = 1 + rng.below(5);
    if rng.below(3) == 0 {
        req.backend = ["sim", "acam"][rng.below(2)].parse::<Backend>().ok();
    }
    if rng.below(3) == 0 {
        req.return_features = true;
    }
    if rng.below(4) == 0 {
        req.request_id = Some(format!("id-{}", rng.below(10_000)));
    }
    if rng.below(4) == 0 {
        // Valid deadlines only: every decoder rejects an explicit 0.
        req.deadline_ms = Some(1 + rng.below(4_999) as u64);
    }
    req
}

#[test]
fn fuzz_binary_roundtrips_bit_exactly() {
    let mut rng = Rng::new(0x19e5_7000_0007);
    for case in 0..cases(300) {
        let reqs: Vec<ClassifyRequest> = (0..rng.below(6)).map(|_| random_request(&mut rng)).collect();
        let wire = binary::encode_batch(&reqs);
        let back = binary::decode_batch(&wire)
            .unwrap_or_else(|e| panic!("case {case}: own encoding rejected: {e:?}"));
        assert_eq!(back.len(), reqs.len());
        for (orig, item) in reqs.iter().zip(&back) {
            let got = item.as_ref().expect("round-tripped item decodes");
            assert_item_parity(&Ok(orig.clone()), &Ok(got.clone()), "binary roundtrip");
        }
    }
}

#[test]
fn fuzz_binary_mutations_never_panic_and_truncations_error() {
    let mut rng = Rng::new(0x19e5_7000_0008);
    let reqs: Vec<ClassifyRequest> = (0..3).map(|_| random_request(&mut rng)).collect();
    let wire = binary::encode_batch(&reqs);

    // Every strict prefix is a framing error: the header commits to an
    // item count the bytes can no longer satisfy.
    for cut in 0..wire.len() {
        let err = binary::decode_batch(&wire[..cut])
            .err()
            .unwrap_or_else(|| panic!("truncation at {cut} decoded"));
        assert_eq!(err.code, ErrorCode::MalformedRequest, "truncation at {cut}");
    }

    // Byte flips: any outcome but a panic.  Flips inside a meta block may
    // surface as per-item errors rather than whole-call ones.
    for _ in 0..cases(600) {
        let mut b = wire.clone();
        match rng.below(3) {
            0 => {
                let i = rng.below(b.len());
                b[i] = b[i].wrapping_add(1 + rng.below(255) as u8);
            }
            1 => b.truncate(rng.below(b.len() + 1)),
            _ => {
                let i = rng.below(b.len());
                b.insert(i, rng.below(256) as u8);
            }
        }
        let _ = binary::decode_batch(&b);
        let _ = binary::decode_single(&b);
    }
}

#[test]
fn fuzz_binary_decode_single_enforces_item_count() {
    let mut rng = Rng::new(0x19e5_7000_0009);
    for n in [0usize, 2, 5] {
        let reqs: Vec<ClassifyRequest> = (0..n).map(|_| random_request(&mut rng)).collect();
        let err = binary::decode_single(&binary::encode_batch(&reqs))
            .err()
            .expect("multi/zero-item frame must be rejected for /v1/classify");
        assert_eq!(err.code, ErrorCode::InvalidArgument);
    }
}

// ---------------------------------------------------------------------------
// Group 4: top_k validation parity across decoders
// ---------------------------------------------------------------------------

/// `top_k == 0` is rejected at decode time with the same
/// `INVALID_ARGUMENT` everywhere a request can enter: the tree decoder,
/// the streaming decoder, and the binary frame's meta block — same code,
/// same message, no path silently clamping to 1.
#[test]
fn top_k_zero_rejects_identically_across_all_decoders() {
    let text = r#"{"image": [0.5], "top_k": 0}"#;
    let tree = jsonlite::parse(text)
        .map_err(malformed)
        .and_then(|v| ClassifyRequest::from_value(&v))
        .err()
        .expect("tree decoder must reject top_k=0");
    let streamed = decode_classify_request(text, 16)
        .err()
        .expect("streaming decoder must reject top_k=0");

    // Binary: hand-build the frame — `encode_batch` could never emit a
    // zero top_k, but a client can, and the wire must reject it.
    let meta = br#"{"top_k": 0}"#;
    let mut frame = b"HECB\x01".to_vec();
    frame.extend_from_slice(&1u32.to_le_bytes());
    frame.extend_from_slice(&(meta.len() as u32).to_le_bytes());
    frame.extend_from_slice(meta);
    frame.extend_from_slice(&1u32.to_le_bytes());
    frame.extend_from_slice(&0.5f32.to_le_bytes());
    let items = binary::decode_batch(&frame).expect("framing itself is valid");
    let bin = items[0]
        .as_ref()
        .err()
        .expect("binary meta must reject top_k=0")
        .clone();

    for (name, err) in [("tree", &tree), ("stream", &streamed), ("binary", &bin)] {
        assert_eq!(err.code, ErrorCode::InvalidArgument, "{name}: wrong code");
    }
    assert_eq!(err_parts(&tree), err_parts(&streamed));
    assert_eq!(err_parts(&tree), err_parts(&bin));
}

/// `deadline_ms == 0` gets the same treatment: a zero deadline is
/// indistinguishable from a client bug (it could never be served), so
/// every decoder rejects it at decode time with one `INVALID_ARGUMENT` —
/// identical code and message across the tree, streaming, and binary
/// paths.  (Regression: the tree decoder used to accept `0` and fail the
/// request later as `DEADLINE_EXCEEDED`, while the other paths diverged.)
#[test]
fn deadline_zero_rejects_identically_across_all_decoders() {
    let text = r#"{"image": [0.5], "deadline_ms": 0}"#;
    let tree = jsonlite::parse(text)
        .map_err(malformed)
        .and_then(|v| ClassifyRequest::from_value(&v))
        .err()
        .expect("tree decoder must reject deadline_ms=0");
    let streamed = decode_classify_request(text, 16)
        .err()
        .expect("streaming decoder must reject deadline_ms=0");

    // Binary: hand-build the frame — `encode_batch` could never emit a
    // zero deadline, but a client can, and the wire must reject it.
    let meta = br#"{"deadline_ms": 0}"#;
    let mut frame = b"HECB\x01".to_vec();
    frame.extend_from_slice(&1u32.to_le_bytes());
    frame.extend_from_slice(&(meta.len() as u32).to_le_bytes());
    frame.extend_from_slice(meta);
    frame.extend_from_slice(&1u32.to_le_bytes());
    frame.extend_from_slice(&0.5f32.to_le_bytes());
    let items = binary::decode_batch(&frame).expect("framing itself is valid");
    let bin = items[0]
        .as_ref()
        .err()
        .expect("binary meta must reject deadline_ms=0")
        .clone();

    for (name, err) in [("tree", &tree), ("stream", &streamed), ("binary", &bin)] {
        assert_eq!(err.code, ErrorCode::InvalidArgument, "{name}: wrong code");
    }
    assert_eq!(err_parts(&tree), err_parts(&streamed));
    assert_eq!(err_parts(&tree), err_parts(&bin));

    // A boundary deadline of 1 decodes everywhere.
    let ok = r#"{"image": [0.5], "deadline_ms": 1}"#;
    assert_eq!(
        ClassifyRequest::from_value(&jsonlite::parse(ok).unwrap())
            .unwrap()
            .deadline_ms,
        Some(1)
    );
    assert_eq!(decode_classify_request(ok, 16).unwrap().deadline_ms, Some(1));
}

/// The out-of-range half of the same contract: `top_k > num_classes` is
/// only checkable where the deployment bound is known, and both live
/// surfaces (single-pipeline server, sharded set) answer with the same
/// stable `INVALID_ARGUMENT` — never a silent clamp — while the boundary
/// value `top_k == num_classes` still serves.
#[test]
fn top_k_out_of_range_is_invalid_argument_at_submit() {
    let mut c = hec::config::ServeConfig {
        artifacts_dir: "/nonexistent-hec-artifacts".into(),
        backend: Backend::FeatureCount,
        ..Default::default()
    };
    c.batch.max_wait_us = 0;

    let server = hec::coordinator::Server::start(c.clone()).unwrap();
    let img_len = server.handle.caps().image_len;
    let num_classes = server.handle.caps().num_classes;
    let mut req = ClassifyRequest::new(vec![0.0; img_len]);
    req.top_k = num_classes + 1;
    let err = server
        .handle
        .submit_blocking(req.clone())
        .err()
        .expect("out-of-range top_k must be rejected");
    assert_eq!(err.code, ErrorCode::InvalidArgument);
    req.top_k = num_classes;
    let resp = server.handle.submit_blocking(req.clone()).unwrap();
    assert_eq!(resp.predictions.len(), num_classes);
    server.shutdown();

    let set = hec::coordinator::ShardSet::start(&c).unwrap();
    req.top_k = num_classes + 1;
    let err = set
        .handle
        .submit_blocking(req)
        .err()
        .expect("sharded surface must reject identically");
    assert_eq!(err.code, ErrorCode::InvalidArgument);
    set.shutdown();
}

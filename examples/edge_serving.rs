//! End-to-end serving driver — the repo's E2E validation workload.
//!
//! Starts the sharded coordinator (dynamic batcher + front-end engine +
//! ACAM-sim back-end per shard), drives it with multi-threaded clients
//! submitting a realistic synthetic request stream, and reports accuracy,
//! latency percentiles, throughput and the modelled per-inference energy.
//! The run recorded in EXPERIMENTS.md §E2E comes from this binary.
//!
//!     cargo run --release --example edge_serving [-- requests clients shards]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use hec::api::ClassifyRequest;
use hec::config::{Backend, ServeConfig};
use hec::coordinator::{ClassifySurface, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::runtime::Meta;

fn main() -> hec::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2000);
    let clients: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let shards: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(1);

    let mut cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::AcamSim,
        ..Default::default()
    };
    cfg.batch.max_batch = 32;
    cfg.batch.max_wait_us = 2_000;
    cfg.shards.count = shards;

    let set = ShardSet::start(&cfg)?;
    let meta = Meta::load_or_synthetic("artifacts")?;
    let img_len = meta.artifacts.image_size * meta.artifacts.image_size;
    let ds = SyntheticDataset::new(1_000_003, 512, meta.norm.mean as f32, meta.norm.std as f32);

    // Pre-render the request pool (clients replay it round-robin).
    let pool: Vec<(Vec<f32>, usize)> = (0..512).map(|i| (ds.image(i), ds.label(i))).collect();
    let pool = Arc::new(pool);
    let correct = Arc::new(AtomicUsize::new(0));
    let done = Arc::new(AtomicUsize::new(0));

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let handle = set.handle.clone();
        let pool = Arc::clone(&pool);
        let correct = Arc::clone(&correct);
        let done = Arc::clone(&done);
        let per_client = requests / clients;
        joins.push(std::thread::spawn(move || {
            for r in 0..per_client {
                let (img, label) = &pool[(c * per_client + r) % pool.len()];
                // Retry on backpressure.
                let rx = loop {
                    match handle.submit(ClassifyRequest::new(img.clone())) {
                        Ok(rx) => break rx,
                        Err(_) => std::thread::sleep(std::time::Duration::from_micros(200)),
                    }
                };
                if let Ok(Ok(res)) = rx.recv() {
                    if res.top1().class == *label {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                    done.fetch_add(1, Ordering::Relaxed);
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let n = done.load(Ordering::Relaxed);

    println!(
        "=== edge serving E2E ({n} requests, {clients} clients, {shards} shard{}, \
         batcher 32/2ms) ===",
        if shards == 1 { "" } else { "s" }
    );
    println!("{}", set.handle.snapshot());
    println!("throughput = {:.0} req/s", n as f64 / secs);
    println!(
        "accuracy   = {:.4} ({}/{})",
        correct.load(Ordering::Relaxed) as f64 / n as f64,
        correct.load(Ordering::Relaxed),
        n
    );
    println!(
        "energy     = {:.2} nJ / inference (modelled)",
        set.handle.snapshot().energy_nj / n as f64
    );
    assert_eq!(n, requests, "all requests must complete");
    set.shutdown();
    println!("img_len={img_len} (driver sanity)");
    Ok(())
}

//! Timing harness substrate (criterion is unavailable offline).
//!
//! [`bench`] runs a closure through warmup + timed iterations, reports
//! mean / p50 / p99 / min wall time per iteration, and returns the
//! [`BenchResult`] so bench binaries can print paper-style comparison rows
//! and assert shape properties (who wins, by what factor).  Results
//! serialise to [`crate::jsonlite::Value`] ([`BenchResult::to_json`] /
//! [`write_json_report`]) so benches can emit machine-readable `BENCH_*.json`
//! files and later PRs can track the perf trajectory.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::jsonlite::Value;

/// One benchmark's timing summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// Iterations per second at the mean.
    pub fn throughput(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            f64::INFINITY
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }

    /// Machine-readable summary (durations in microseconds).
    pub fn to_json(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Value::Str(self.name.clone()));
        o.insert("iters".into(), Value::Num(self.iters as f64));
        o.insert("mean_us".into(), Value::Num(self.mean.as_secs_f64() * 1e6));
        o.insert("p50_us".into(), Value::Num(self.p50.as_secs_f64() * 1e6));
        o.insert("p99_us".into(), Value::Num(self.p99.as_secs_f64() * 1e6));
        o.insert("min_us".into(), Value::Num(self.min.as_secs_f64() * 1e6));
        o.insert("throughput_per_s".into(), Value::Num(self.throughput()));
        Value::Obj(o)
    }
}

/// Write a bench report (`extra` scalar fields + per-result rows) to
/// `path` as JSON.  The fixed `schema` field versions the layout for the
/// perf-trajectory tooling of later PRs.
pub fn write_json_report(
    path: impl AsRef<Path>,
    schema: &str,
    extra: &[(&str, Value)],
    results: &[&BenchResult],
) -> std::io::Result<()> {
    let mut o = BTreeMap::new();
    o.insert("schema".into(), Value::Str(schema.into()));
    for (k, v) in extra {
        o.insert((*k).into(), v.clone());
    }
    o.insert(
        "results".into(),
        Value::Arr(results.iter().map(|r| r.to_json()).collect()),
    );
    std::fs::write(path, Value::Obj(o).to_json() + "\n")
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<38} mean {:>10.2?}  p50 {:>10.2?}  p99 {:>10.2?}  min {:>10.2?}  ({:.0}/s)",
            self.name,
            self.mean,
            self.p50,
            self.p99,
            self.min,
            self.throughput()
        )
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(name, &mut samples)
}

/// Adaptive variant: keeps iterating until `budget` wall time is spent
/// (at least `min_iters`), so slow PJRT paths don't stall the suite.
pub fn bench_for<F: FnMut()>(
    name: &str,
    warmup: usize,
    min_iters: usize,
    budget: Duration,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let t_start = Instant::now();
    while samples.len() < min_iters || t_start.elapsed() < budget {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    summarize(name, &mut samples)
}

fn summarize(name: &str, samples: &mut [Duration]) -> BenchResult {
    samples.sort_unstable();
    let iters = samples.len();
    let total: Duration = samples.iter().sum();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p99: samples[(iters * 99) / 100],
        min: samples[0],
    };
    println!("{r}");
    r
}

/// Print a section header in the bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one paper-vs-measured comparison row.
pub fn paper_row(label: &str, paper: f64, measured: f64, unit: &str) {
    let ratio = if paper != 0.0 { measured / paper } else { f64::NAN };
    println!("{label:<34} paper {paper:>12.4} {unit:<4} measured {measured:>12.4} {unit:<4} (x{ratio:.3})");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + measured
        assert!(r.min <= r.p50 && r.p50 <= r.p99);
    }

    #[test]
    fn bench_for_respects_min_iters() {
        let r = bench_for("noop", 0, 5, Duration::from_millis(0), || {});
        assert!(r.iters >= 5);
    }

    #[test]
    fn json_report_round_trips() {
        let r = bench("jsonable", 0, 3, || {
            std::thread::sleep(Duration::from_micros(50))
        });
        let path = std::env::temp_dir().join(format!("hec-bench-{}.json", std::process::id()));
        write_json_report(&path, "hec/test/v1", &[("alpha", Value::Num(2.0))], &[&r]).unwrap();
        let doc = crate::jsonlite::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("hec/test/v1"));
        assert_eq!(doc.get("alpha").and_then(|v| v.as_f64()), Some(2.0));
        let rows = doc.get("results").and_then(|v| v.as_array()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").and_then(|v| v.as_str()), Some("jsonable"));
        assert!(rows[0].get("mean_us").and_then(|v| v.as_f64()).unwrap() > 0.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn throughput_is_inverse_mean() {
        let r = bench("sleepless", 0, 3, || std::thread::sleep(Duration::from_micros(200)));
        let tp = r.throughput();
        assert!(tp > 1000.0 && tp < 6000.0, "{tp}");
    }
}

//! End-to-end serving bench: throughput/latency of the full coordinator
//! (dynamic batcher -> front-end engine -> back-end) across batching
//! policies, back-ends, and shard counts — the systems-side evaluation the
//! paper's Fig. 2 architecture implies, at the ROADMAP's serving scale.
//!
//! Artifact-free by design: with no `make artifacts` output the synthetic
//! fallback deployment serves (same code path CI runs), so this bench
//! finally emits a serving-path trajectory point (`BENCH_e2e_serving.json`)
//! on every machine.  `HEC_BENCH_SMOKE=1` shrinks the request counts for
//! CI smoke runs (absolute numbers are noisy there; the JSON artifact is
//! the deliverable, not a ratio gate).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use hec::api::ClassifyRequest;
use hec::benchkit::{section, BenchResult};
use hec::config::{Backend, ServeConfig};
use hec::coordinator::{ClassifySurface, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::jsonlite::Value;
use hec::runtime::Meta;

fn run(cfg: &ServeConfig, requests: usize, clients: usize) -> (f64, f64, u64, u64) {
    let set = ShardSet::start(cfg).unwrap();
    let meta = Meta::load_or_synthetic(&cfg.artifacts_dir).unwrap();
    let ds = SyntheticDataset::new(1_000_003, 256, meta.norm.mean as f32, meta.norm.std as f32);
    let pool: Arc<Vec<Vec<f32>>> = Arc::new((0..256).map(|i| ds.image(i)).collect());
    let done = Arc::new(AtomicUsize::new(0));

    let t0 = std::time::Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let handle = set.handle.clone();
            let pool = Arc::clone(&pool);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                for r in 0..requests / clients {
                    let img = pool[(c + r) % pool.len()].clone();
                    let rx = loop {
                        match handle.submit(ClassifyRequest::new(img.clone())) {
                            Ok(rx) => break rx,
                            Err(_) => std::thread::sleep(std::time::Duration::from_micros(100)),
                        }
                    };
                    if rx.recv().is_ok() {
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let snap = set.handle.snapshot();
    let n = done.load(Ordering::Relaxed);
    set.shutdown();
    (
        n as f64 / secs,
        snap.latency_mean_us,
        snap.latency_p50_us,
        snap.latency_p99_us,
    )
}

/// Lift one serving run into the benchkit report schema.  Field mapping
/// (also recorded in the report's `row_semantics`): `mean_us`/`min_us` =
/// 1e6 / request throughput (so `throughput_per_s` reads as system
/// req/s under the run's concurrency), `p50_us`/`p99_us` = measured
/// end-to-end request latency percentile upper bounds.  Under concurrent
/// clients 1/throughput is NOT per-request latency — read latency from
/// the percentile fields.
fn row(name: &str, requests: usize, tput: f64, p50_us: u64, p99_us: u64) -> BenchResult {
    let inv = std::time::Duration::from_secs_f64(if tput > 0.0 { 1.0 / tput } else { 0.0 });
    BenchResult {
        name: name.to_string(),
        iters: requests,
        mean: inv,
        p50: std::time::Duration::from_micros(p50_us),
        p99: std::time::Duration::from_micros(p99_us),
        min: inv,
    }
}

fn main() {
    let smoke = std::env::var("HEC_BENCH_SMOKE").is_ok();
    let have_artifacts = std::path::Path::new("artifacts/meta.json").is_file();
    if !have_artifacts {
        println!("e2e_serving: no artifacts/ — serving the synthetic fallback deployment");
    }
    let base = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::FeatureCount,
        ..Default::default()
    };
    let requests = if smoke { 96 } else { 600 };
    let mut report: Vec<BenchResult> = Vec::new();

    section("batching policy sweep (feature-count backend)");
    println!(
        "{:>10} {:>10} {:>12} {:>14} {:>14}",
        "max_batch", "wait_us", "req/s", "mean_lat_us", "p99_lat_us"
    );
    let mut results = Vec::new();
    for (max_batch, wait_us, clients) in
        [(1usize, 0u64, 4usize), (8, 500, 16), (32, 1000, 32)]
    {
        let mut cfg = base.clone();
        cfg.batch.max_batch = max_batch;
        cfg.batch.max_wait_us = wait_us;
        let (tput, mean_lat, p50, p99) = run(&cfg, requests, clients);
        println!(
            "{max_batch:>10} {wait_us:>10} {tput:>12.0} {mean_lat:>14.0} {p99:>14}   ({clients} clients)"
        );
        report.push(row(
            &format!("batch{max_batch}_wait{wait_us}us"),
            requests,
            tput,
            p50,
            p99,
        ));
        results.push(tput);
    }
    // The batching trade-off depends on offered concurrency: client threads
    // contend with the worker on small testbeds, so we assert completion +
    // sane throughput rather than a fixed ordering, and report the sweep
    // (the deadline-padding interaction is the interesting systems result).
    let floor = if smoke { 5.0 } else { 50.0 };
    assert!(
        results.iter().all(|&t| t > floor),
        "all configs must sustain >{floor} req/s"
    );

    section("backend sweep (batcher 32/2ms)");
    println!(
        "{:>14} {:>12} {:>14} {:>14}",
        "backend", "req/s", "mean_lat_us", "p99_lat_us"
    );
    for backend in [Backend::FeatureCount, Backend::Similarity, Backend::AcamSim, Backend::Softmax] {
        let mut cfg = base.clone();
        cfg.backend = backend;
        cfg.batch.max_batch = 32;
        cfg.batch.max_wait_us = 2000;
        let (tput, mean_lat, p50, p99) = run(&cfg, requests, 4);
        println!("{backend:>14?} {tput:>12.0} {mean_lat:>14.0} {p99:>14}");
        report.push(row(
            &format!("backend_{}", backend.name()),
            requests,
            tput,
            p50,
            p99,
        ));
    }

    section("shard sweep (feature-count, batcher 8/500us, 16 clients)");
    println!(
        "{:>8} {:>12} {:>14} {:>14}",
        "shards", "req/s", "mean_lat_us", "p99_lat_us"
    );
    for shards in [1usize, 2, 4] {
        let mut cfg = base.clone();
        cfg.batch.max_batch = 8;
        cfg.batch.max_wait_us = 500;
        cfg.shards.count = shards;
        let (tput, mean_lat, p50, p99) = run(&cfg, requests, 16);
        println!("{shards:>8} {tput:>12.0} {mean_lat:>14.0} {p99:>14}");
        report.push(row(&format!("shards{shards}"), requests, tput, p50, p99));
    }

    let rows: Vec<&BenchResult> = report.iter().collect();
    hec::benchkit::write_json_report(
        "BENCH_e2e_serving.json",
        "hec/e2e_serving/v1",
        &[
            ("requests_per_config", Value::Num(requests as f64)),
            ("smoke", Value::Bool(smoke)),
            ("artifacts", Value::Bool(have_artifacts)),
            (
                "row_semantics",
                Value::Str(
                    "mean_us/min_us = 1e6/req_throughput; p50_us/p99_us = \
                     end-to-end request latency upper bounds"
                        .to_string(),
                ),
            ),
        ],
        &rows,
    )
    .expect("write BENCH_e2e_serving.json");
    println!("\nwrote BENCH_e2e_serving.json ({} rows)", rows.len());
    println!("e2e_serving: PASS");
}

//! Digital matching engines — bit-exact implementations of the paper's two
//! pattern-matching models (Section II-D2) plus the Eq.-12 decision rule.
//!
//! Three interchangeable scorers:
//! * [`feature_count_dense`] — Eq. 8 over 0/1 bytes, the readable reference;
//! * [`feature_count_packed`] — the same scores via XOR + popcount on u64
//!   words (64 features per word), the serving hot path;
//! * [`similarity`] — Eq. 9-11 windowed distance + hit-ratio model.
//!
//! A property test (`prop_packed_equals_dense`) pins packed == dense, and
//! `prop_binary_fc_sim_agree` pins the §V.B observation that in the binary
//! domain feature-count and similarity argmax-coincide.

use crate::templates::TemplateSet;

/// Eq. 8: number of exactly-matching features, dense byte path.
pub fn feature_count_dense(query: &[u8], template: &[u8]) -> u32 {
    debug_assert_eq!(query.len(), template.len());
    query
        .iter()
        .zip(template.iter())
        .map(|(q, t)| u32::from(q == t))
        .sum()
}

/// Eq. 8 for all templates in a set, dense path. Returns one score per row.
pub fn feature_count_all_dense(query: &[u8], set: &TemplateSet) -> Vec<u32> {
    set.templates
        .iter()
        .map(|t| feature_count_dense(query, t))
        .collect()
}

/// Eq. 8 on packed words: matches = N - hamming(query, template).
///
/// `packed_query` must come from [`TemplateSet::pack_query`]; trailing pad
/// bits are zero in both operands so they XOR to zero and never count as
/// mismatches.
pub fn feature_count_packed(
    packed_query: &[u64],
    packed_row: &[u64],
    n_features: u32,
) -> u32 {
    debug_assert_eq!(packed_query.len(), packed_row.len());
    let hamming: u32 = packed_query
        .iter()
        .zip(packed_row.iter())
        .map(|(q, t)| (q ^ t).count_ones())
        .sum();
    n_features - hamming
}

/// Eq. 8 against every row of the packed template matrix.
pub fn feature_count_all_packed(packed_query: &[u64], set: &TemplateSet) -> Vec<u32> {
    let w = set.words_per_row;
    let n = set.num_features() as u32;
    set.packed
        .chunks_exact(w)
        .map(|row| feature_count_packed(packed_query, row, n))
        .collect()
}

/// Eq. 9-11: similarity of a real-valued query against one window pair.
pub fn similarity(query: &[f32], lo: &[f32], hi: &[f32], alpha: f32) -> f32 {
    debug_assert_eq!(query.len(), lo.len());
    debug_assert_eq!(query.len(), hi.len());
    let mut dist = 0f64;
    let mut hits = 0u32;
    for ((&q, &l), &h) in query.iter().zip(lo.iter()).zip(hi.iter()) {
        if q > h {
            let d = (q - h) as f64;
            dist += d * d;
        } else if q < l {
            let d = (l - q) as f64;
            dist += d * d;
        } else {
            hits += 1;
        }
    }
    let hit_ratio = hits as f64 / query.len() as f64;
    (hit_ratio / (1.0 + alpha as f64 * dist)) as f32
}

/// Eq. 9-11 against every template window in a set.
///
/// `binary_domain` selects the `t ± 0.5` windows (for binary queries) versus
/// the real-feature windows.
pub fn similarity_all(
    query: &[f32],
    set: &TemplateSet,
    alpha: f32,
    binary_domain: bool,
) -> Vec<f32> {
    let (los, his) = if binary_domain {
        (&set.bin_lo, &set.bin_hi)
    } else {
        (&set.lo, &set.hi)
    };
    los.iter()
        .zip(his.iter())
        .map(|(lo, hi)| similarity(query, lo, hi, alpha))
        .collect()
}

/// Eq. 12 with multi-template support: per-class max over the class's
/// templates, then argmax over classes. Ties break to the lower class id
/// (stable, matching the numpy reference).
pub fn classify<S: PartialOrd + Copy>(scores: &[S], class_of: &[usize], num_classes: usize) -> usize {
    debug_assert_eq!(scores.len(), class_of.len());
    let mut best: Vec<Option<S>> = vec![None; num_classes];
    for (&s, &c) in scores.iter().zip(class_of.iter()) {
        match best[c] {
            Some(b) if b >= s => {}
            _ => best[c] = Some(s),
        }
    }
    let mut arg = 0;
    let mut max: Option<S> = None;
    for (c, b) in best.iter().enumerate() {
        if let Some(v) = b {
            if max.is_none() || *v > max.unwrap() {
                max = Some(*v);
                arg = c;
            }
        }
    }
    arg
}

/// Eq. 12 inner max: the best score each class achieves over its own
/// templates.  `None` for classes without a template (template stores
/// validate full coverage, so serving paths never see it).
pub fn per_class_best<S: PartialOrd + Copy>(
    scores: &[S],
    class_of: &[usize],
    num_classes: usize,
) -> Vec<Option<S>> {
    debug_assert_eq!(scores.len(), class_of.len());
    let mut best: Vec<Option<S>> = vec![None; num_classes];
    for (&s, &c) in scores.iter().zip(class_of.iter()) {
        match best[c] {
            Some(b) if b >= s => {}
            _ => best[c] = Some(s),
        }
    }
    best
}

/// Rank classes by their per-class best score, descending.  Ties break to
/// the lower class id, so `rank_classes(..)[0].0 == classify(..)` always —
/// the top-1 of the ranked view is pinned to the Eq. 12 argmax.
pub fn rank_classes<S: PartialOrd + Copy>(
    scores: &[S],
    class_of: &[usize],
    num_classes: usize,
) -> Vec<(usize, S)> {
    let best = per_class_best(scores, class_of, num_classes);
    let mut ranked: Vec<(usize, S)> = best
        .into_iter()
        .enumerate()
        .filter_map(|(c, b)| b.map(|s| (c, s)))
        .collect();
    // Descending by score; class ids ascend within equal scores (matches the
    // strict-> tie rule in `classify`).  Scores are never NaN here (counts or
    // Eq. 9-11 similarities), so Equal on incomparable values is unreachable.
    ranked.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    ranked
}

/// Rank a dense per-class score row (one score per class, e.g. softmax
/// logits) descending, ties to the lower class id.
pub fn rank_scores<S: PartialOrd + Copy>(row: &[S]) -> Vec<(usize, S)> {
    let identity: Vec<usize> = (0..row.len()).collect();
    rank_classes(row, &identity, row.len())
}

/// Convenience: full binary feature-count classification (packed hot path).
pub fn classify_feature_count(query_bits: &[u8], set: &TemplateSet, num_classes: usize) -> usize {
    let packed = set.pack_query(query_bits);
    let scores = feature_count_all_packed(&packed, set);
    classify(&scores, &set.class_of, num_classes)
}

/// Top-k scored variant of [`classify_feature_count`]: the k best classes
/// with their per-class best Eq. 8 match counts, rank order pinned to the
/// argmax function (element 0 is always the `classify_feature_count` class).
pub fn classify_feature_count_topk(
    query_bits: &[u8],
    set: &TemplateSet,
    num_classes: usize,
    k: usize,
) -> Vec<(usize, u32)> {
    let packed = set.pack_query(query_bits);
    let scores = feature_count_all_packed(&packed, set);
    let mut ranked = rank_classes(&scores, &set.class_of, num_classes);
    ranked.truncate(k);
    ranked
}

/// Convenience: full similarity classification (Eq. 9-12).
pub fn classify_similarity(
    query: &[f32],
    set: &TemplateSet,
    alpha: f32,
    num_classes: usize,
    binary_domain: bool,
) -> usize {
    let scores = similarity_all(query, set, alpha, binary_domain);
    classify(&scores, &set.class_of, num_classes)
}

/// Top-k scored variant of [`classify_similarity`]: the k best classes with
/// their per-class best Eq. 9-11 similarities, rank order pinned to the
/// argmax function.
pub fn classify_similarity_topk(
    query: &[f32],
    set: &TemplateSet,
    alpha: f32,
    num_classes: usize,
    binary_domain: bool,
    k: usize,
) -> Vec<(usize, f32)> {
    let scores = similarity_all(query, set, alpha, binary_domain);
    let mut ranked = rank_classes(&scores, &set.class_of, num_classes);
    ranked.truncate(k);
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::templates::pack_bits;

    fn toy_set(templates: Vec<Vec<u8>>, class_of: Vec<usize>) -> TemplateSet {
        let n = templates[0].len();
        let w = n.div_ceil(64);
        let packed = templates.iter().flat_map(|t| pack_bits(t, w)).collect();
        let bin_lo = templates
            .iter()
            .map(|t| t.iter().map(|&b| b as f32 - 0.5).collect())
            .collect();
        let bin_hi = templates
            .iter()
            .map(|t| t.iter().map(|&b| b as f32 + 0.5).collect::<Vec<f32>>())
            .collect();
        TemplateSet {
            packed,
            words_per_row: w,
            lo: vec![vec![0.0; n]; templates.len()],
            hi: vec![vec![1.0; n]; templates.len()],
            bin_lo,
            bin_hi,
            silhouette: vec![],
            class_of,
            templates,
        }
    }

    #[test]
    fn feature_count_extremes() {
        let q = vec![1u8; 64];
        assert_eq!(feature_count_dense(&q, &vec![1u8; 64]), 64);
        assert_eq!(feature_count_dense(&q, &vec![0u8; 64]), 0);
    }

    #[test]
    fn packed_equals_dense_on_odd_width() {
        // 100 features: crosses a word boundary with 28 pad bits.
        let q: Vec<u8> = (0..100).map(|i| (i % 3 == 0) as u8).collect();
        let t: Vec<u8> = (0..100).map(|i| (i % 7 == 0) as u8).collect();
        let set = toy_set(vec![t.clone()], vec![0]);
        let dense = feature_count_dense(&q, &t);
        let packed = feature_count_all_packed(&set.pack_query(&q), &set)[0];
        assert_eq!(dense, packed);
    }

    #[test]
    fn similarity_inside_window_is_one() {
        let q = vec![0.5f32; 10];
        let lo = vec![0.0f32; 10];
        let hi = vec![1.0f32; 10];
        assert!((similarity(&q, &lo, &hi, 0.5) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn similarity_distance_penalty() {
        let lo = vec![0.0f32; 4];
        let hi = vec![1.0f32; 4];
        let near = similarity(&[1.1, 0.5, 0.5, 0.5], &lo, &hi, 1.0);
        let far = similarity(&[3.0, 0.5, 0.5, 0.5], &lo, &hi, 1.0);
        assert!(near > far);
        // Hit ratio identical (3/4), so ordering is purely the D term.
    }

    #[test]
    fn similarity_below_window() {
        let s = similarity(&[-1.0], &[0.0], &[1.0], 1.0);
        assert!((s - 0.0).abs() < 1e-6); // H=0 -> similarity 0 regardless of D
    }

    #[test]
    fn classify_per_class_max() {
        // class 0 templates score (1, 5); class 1 templates (3, 4).
        let scores = [1u32, 5, 3, 4];
        let class_of = [0, 0, 1, 1];
        assert_eq!(classify(&scores, &class_of, 2), 0);
    }

    #[test]
    fn classify_tie_breaks_low() {
        let scores = [2u32, 2];
        assert_eq!(classify(&scores, &[0, 1], 2), 0);
    }

    #[test]
    fn rank_classes_orders_by_per_class_best() {
        // class 0 best 5, class 1 best 4, class 2 best 9.
        let scores = [1u32, 5, 3, 4, 9];
        let class_of = [0, 0, 1, 1, 2];
        let ranked = rank_classes(&scores, &class_of, 3);
        assert_eq!(ranked, vec![(2, 9), (0, 5), (1, 4)]);
        assert_eq!(ranked[0].0, classify(&scores, &class_of, 3));
    }

    #[test]
    fn rank_classes_ties_break_to_low_class() {
        let scores = [7u32, 7, 3];
        let class_of = [1, 0, 2];
        let ranked = rank_classes(&scores, &class_of, 3);
        assert_eq!(ranked, vec![(0, 7), (1, 7), (2, 3)]);
        assert_eq!(ranked[0].0, classify(&scores, &class_of, 3));
    }

    #[test]
    fn rank_scores_is_identity_class_ranking() {
        let ranked = rank_scores(&[0.1f32, 0.9, 0.9, 0.4]);
        assert_eq!(
            ranked.iter().map(|&(c, _)| c).collect::<Vec<_>>(),
            vec![1, 2, 3, 0]
        );
    }

    #[test]
    fn topk_rank_order_pins_to_argmax() {
        // Randomised queries: top-1 of every top-k variant must equal the
        // corresponding argmax classifier, and scores must be descending.
        let mut rng = crate::rng::Rng::new(7);
        let n = 96;
        let templates: Vec<Vec<u8>> = (0..6)
            .map(|_| (0..n).map(|_| u8::from(rng.u01() < 0.5)).collect())
            .collect();
        let set = toy_set(templates, vec![0, 0, 1, 1, 2, 2]);
        for _ in 0..20 {
            let q: Vec<u8> = (0..n).map(|_| u8::from(rng.u01() < 0.5)).collect();
            let top = classify_feature_count_topk(&q, &set, 3, 3);
            assert_eq!(top.len(), 3);
            assert_eq!(top[0].0, classify_feature_count(&q, &set, 3));
            assert!(top[0].1 >= top[1].1 && top[1].1 >= top[2].1);

            let qf: Vec<f32> = q.iter().map(|&b| b as f32).collect();
            let tops = classify_similarity_topk(&qf, &set, 0.05, 3, true, 2);
            assert_eq!(tops.len(), 2);
            assert_eq!(tops[0].0, classify_similarity(&qf, &set, 0.05, 3, true));
            assert!(tops[0].1 >= tops[1].1);
        }
    }

    #[test]
    fn topk_truncates_to_available_classes() {
        let t0 = vec![1u8; 16];
        let t1 = vec![0u8; 16];
        let set = toy_set(vec![t0, t1], vec![0, 1]);
        let q = vec![1u8; 16];
        assert_eq!(classify_feature_count_topk(&q, &set, 2, 10).len(), 2);
        assert_eq!(classify_feature_count_topk(&q, &set, 2, 1).len(), 1);
    }

    #[test]
    fn end_to_end_binary_classification() {
        let t0 = vec![1u8; 32];
        let t1 = vec![0u8; 32];
        let set = toy_set(vec![t0, t1], vec![0, 1]);
        let mut q = vec![1u8; 32];
        q[0] = 0; // still closest to t0
        assert_eq!(classify_feature_count(&q, &set, 2), 0);
        let qf: Vec<f32> = q.iter().map(|&b| b as f32).collect();
        assert_eq!(classify_similarity(&qf, &set, 0.05, 2, true), 0);
    }
}

"""Template generation: thresholds (Fig. 1), k-means/silhouette, matching
predictors (Eq. 8-12) and the §V.B binary-equivalence property."""

import numpy as np
from numpy.testing import assert_allclose

from compile.templates import (
    binarize,
    feature_thresholds,
    generate_templates,
    kmeans,
    match_predict_fc,
    match_predict_sim,
    silhouette_score,
)

RNG = np.random.default_rng(4)


def test_mean_threshold_below_median_for_relu_sparse_features():
    """The paper's Fig.-1 argument: ReLU sparsity (many zeros) drags the mean
    below the median for most features."""
    feats = np.maximum(RNG.normal(size=(500, 64)) - 0.8, 0.0).astype(np.float32)
    mean_th = feature_thresholds(feats, "mean")
    med_th = feature_thresholds(feats, "median")
    assert (med_th <= mean_th + 1e-6).mean() > 0.9  # median is 0 almost everywhere
    # and crucially the mean keeps low-magnitude activations classifiable:
    assert (mean_th > 0).mean() > 0.9


def test_binarize_output_domain():
    feats = RNG.normal(size=(20, 16)).astype(np.float32)
    th = feature_thresholds(feats, "mean")
    b = binarize(feats, th)
    assert set(np.unique(b)).issubset({0.0, 1.0})


def test_kmeans_separates_two_blobs():
    a = RNG.normal(size=(50, 8)) + 5.0
    b = RNG.normal(size=(50, 8)) - 5.0
    x = np.vstack([a, b])
    cents, assign, inertia = kmeans(x, 2, iters=50, restarts=3, rng=RNG)
    # Each blob maps to a single cluster.
    assert len(set(assign[:50])) == 1 and len(set(assign[50:])) == 1
    assert assign[0] != assign[50]


def test_kmeans_k1_is_mean():
    x = RNG.normal(size=(30, 4))
    cents, assign, _ = kmeans(x, 1, iters=10, restarts=1, rng=RNG)
    assert_allclose(cents[0], x.mean(0), rtol=1e-6)


def test_kmeans_inertia_nonincreasing_in_k():
    x = RNG.normal(size=(60, 6))
    inertias = [kmeans(x, k, 30, 3, np.random.default_rng(0))[2] for k in (1, 2, 3)]
    assert inertias[0] >= inertias[1] >= inertias[2]


def test_silhouette_range_and_separation():
    a = RNG.normal(size=(40, 4)) + 4.0
    b = RNG.normal(size=(40, 4)) - 4.0
    x = np.vstack([a, b])
    assign = np.array([0] * 40 + [1] * 40)
    s = silhouette_score(x, assign)
    assert 0.5 < s <= 1.0
    # Random assignment scores far worse.
    s_rand = silhouette_score(x, RNG.integers(0, 2, size=80))
    assert s_rand < s


def test_silhouette_single_cluster_is_zero():
    x = RNG.normal(size=(20, 3))
    assert silhouette_score(x, np.zeros(20, dtype=np.int64)) == 0.0


def _toy_store(k=1):
    """Two well-separated classes in binary feature space."""
    n = 40
    f0 = (RNG.random((60, n)) < 0.15).astype(np.float32)
    f1 = (RNG.random((60, n)) > 0.15).astype(np.float32)
    feats = np.vstack([f0, f1])
    labels = np.array([0] * 60 + [1] * 60)
    store = generate_templates(feats, feats, labels, 2, k, seed=0)
    return feats, labels, store


def test_generate_templates_shapes():
    feats, labels, store = _toy_store(k=2)
    assert store["templates"].shape == (4, 40)
    assert list(store["class_of"]) == [0, 0, 1, 1]
    assert store["lo"].shape == store["hi"].shape == (4, 40)
    assert (store["hi"] >= store["lo"]).all()


def test_templates_are_binary():
    _, _, store = _toy_store(k=3)
    assert set(np.unique(store["templates"])).issubset({0, 1})


def test_match_predict_fc_separable():
    feats, labels, store = _toy_store(k=1)
    pred = match_predict_fc(feats, store, 2)
    assert (pred == labels).mean() > 0.95


def test_match_predict_sim_binary_agrees_with_fc():
    """§V.B: in the binary domain the similarity model and the feature count
    converge to the same classification."""
    feats, labels, store = _toy_store(k=1)
    p_fc = match_predict_fc(feats, store, 2)
    p_sim = match_predict_sim(feats, store, 2, alpha=0.05, binary=True)
    assert (p_fc == p_sim).all()


def test_multi_template_covers_subclusters():
    """A class made of two distant binary sub-modes needs k=2 to match both."""
    n = 40
    m0 = np.zeros(n, np.float32)
    m1 = np.ones(n, np.float32)
    cls0 = np.vstack([np.tile(m0, (30, 1)), np.tile(m1, (30, 1))])
    cls0 += (RNG.random(cls0.shape) < 0.05)  # flip a few bits
    cls0 = np.clip(cls0, 0, 1)
    cls1 = np.tile((np.arange(n) % 2).astype(np.float32), (60, 1))
    feats = np.vstack([cls0, cls1])
    labels = np.array([0] * 60 + [1] * 60)
    s1 = generate_templates(feats, feats, labels, 2, 1, seed=0)
    s2 = generate_templates(feats, feats, labels, 2, 2, seed=0)
    acc1 = (match_predict_fc(feats, s1, 2) == labels).mean()
    acc2 = (match_predict_fc(feats, s2, 2) == labels).mean()
    assert acc2 >= acc1  # Table II: the second template helps bimodal classes
    assert acc2 > 0.95

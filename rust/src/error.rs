//! Unified error type for the serving stack.

use thiserror::Error;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes the coordinator can surface to a caller.
#[derive(Error, Debug)]
pub enum Error {
    /// PJRT / XLA runtime failures (compile, execute, literal marshalling).
    #[error("xla: {0}")]
    Xla(String),

    /// Artifact loading / validation problems (missing files, shape
    /// mismatches between meta.json and the HLO modules).
    #[error("artifact: {0}")]
    Artifact(String),

    /// Template store inconsistencies (wrong feature width, empty classes).
    #[error("template: {0}")]
    Template(String),

    /// Request-level errors (bad image shape, closed channels, timeouts).
    #[error("request: {0}")]
    Request(String),

    /// Configuration errors.
    #[error("config: {0}")]
    Config(String),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("json: {0}")]
    Json(#[from] crate::jsonlite::ParseError),

    /// Schema errors while extracting typed fields from parsed JSON.
    #[error("schema: {0}")]
    Schema(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

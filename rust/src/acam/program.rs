//! "Program-once-read-many": map a trained [`TemplateSet`] onto ACAM
//! windows and program the array (Section II-D2's pragmatic flow — weights
//! are calibrated in software and written to the RRAM once).

use crate::templates::TemplateSet;

use super::array::{AcamArray, ArrayConfig};
use super::variability::Variability;
use super::feature_to_voltage;

/// Which window encoding to program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowMode {
    /// Binary templates: bit b -> window [b - 0.5, b + 0.5] * V_RANGE.
    /// Queries are binarised feature maps (0/1 volts).
    Binary,
    /// Real-feature windows from the template store's [lo, hi] percentile
    /// bounds; queries are raw (un-binarised) feature voltages.
    RealValued,
}

/// Program an ACAM array from a template set.
///
/// Row r of the array holds template r; [`TemplateSet::class_of`] maps rows
/// to classes for the downstream WTA.
pub fn program_array(
    set: &TemplateSet,
    mode: WindowMode,
    config: ArrayConfig,
    variability: Variability,
    seed: u64,
) -> AcamArray {
    let windows: Vec<(Vec<f64>, Vec<f64>)> = match mode {
        WindowMode::Binary => set
            .templates
            .iter()
            .map(|t| {
                let lo = t.iter().map(|&b| feature_to_voltage(b as f32 - 0.5)).collect();
                let hi = t.iter().map(|&b| feature_to_voltage(b as f32 + 0.5)).collect();
                (lo, hi)
            })
            .collect(),
        WindowMode::RealValued => set
            .lo
            .iter()
            .zip(set.hi.iter())
            .map(|(lo, hi)| {
                // Real features are normalised activations; scale into the
                // input voltage range the same way queries are.
                let l = lo.iter().map(|&v| feature_to_voltage(v)).collect();
                let h = hi.iter().map(|&v| feature_to_voltage(v)).collect();
                (l, h)
            })
            .collect(),
    };
    AcamArray::from_windows(config, variability, &windows, seed)
}

/// Encode a binary query (0/1 bytes) as input-line voltages.
pub fn binary_query_voltages(bits: &[u8]) -> Vec<f64> {
    bits.iter().map(|&b| feature_to_voltage(b as f32)).collect()
}

/// Encode a real-valued feature query as input-line voltages.
pub fn real_query_voltages(features: &[f32]) -> Vec<f64> {
    features.iter().map(|&f| feature_to_voltage(f)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::templates::pack_bits;

    fn toy_set() -> TemplateSet {
        let templates = vec![vec![1u8, 0, 1, 0], vec![0u8, 1, 0, 1]];
        let w = 1;
        TemplateSet {
            packed: templates.iter().flat_map(|t| pack_bits(t, w)).collect(),
            words_per_row: w,
            lo: vec![vec![0.0, 0.0, 0.5, 0.0]; 2],
            hi: vec![vec![1.0, 0.2, 1.0, 0.3]; 2],
            bin_lo: templates
                .iter()
                .map(|t| t.iter().map(|&b| b as f32 - 0.5).collect())
                .collect(),
            bin_hi: templates
                .iter()
                .map(|t| t.iter().map(|&b| b as f32 + 0.5).collect())
                .collect(),
            class_of: vec![0, 1],
            silhouette: vec![],
            templates,
        }
    }

    #[test]
    fn binary_programming_reproduces_eq8() {
        let set = toy_set();
        let mut arr = program_array(
            &set,
            WindowMode::Binary,
            ArrayConfig::default(),
            Variability::ideal(),
            0,
        );
        let q = [1u8, 0, 1, 0];
        let out = arr.search(&binary_query_voltages(&q));
        assert_eq!(out.match_counts, vec![4, 0]);
    }

    #[test]
    fn real_valued_windows_accept_in_range_queries() {
        let set = toy_set();
        let mut arr = program_array(
            &set,
            WindowMode::RealValued,
            ArrayConfig::default(),
            Variability::ideal(),
            0,
        );
        // Query inside row 0's [lo, hi] on all 4 features.
        let out = arr.search(&real_query_voltages(&[0.5, 0.1, 0.7, 0.15]));
        assert_eq!(out.match_counts[0], 4);
    }

    #[test]
    fn query_voltage_encodings() {
        use crate::acam::{V_GAIN, V_OFF};
        assert_eq!(
            binary_query_voltages(&[0, 1]),
            vec![V_OFF, V_OFF + V_GAIN]
        );
        let rv = real_query_voltages(&[0.25, 2.0, -1.0]);
        assert!((rv[0] - (V_OFF + 0.25 * V_GAIN)).abs() < 1e-9);
        assert!((rv[1] - (V_OFF + 1.5 * V_GAIN)).abs() < 1e-9); // clamped
        assert!((rv[2] - (V_OFF - 0.5 * V_GAIN)).abs() < 1e-9); // clamped
    }
}

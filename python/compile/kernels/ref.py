"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each kernel's pytest compares its
output against the function here with ``assert_allclose``.  They are also the
path used *during training* (interpret-mode Pallas is too slow for the train
loop); the AOT inference graphs switch to the Pallas implementations so the
exported HLO exercises the kernel lowering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain f32 matmul: [M,K] x [K,N] -> [M,N]."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def im2col(x: jnp.ndarray, kh: int, kw: int, padding: str) -> jnp.ndarray:
    """Extract conv patches: x[B,H,W,C] -> [B,Ho,Wo,kh*kw*C].

    Patch layout is (dy, dx, c) row-major — the same order the conv weights
    are reshaped with in :func:`conv2d`, and the order the Pallas kernel
    assumes.
    """
    b, h, w, c = x.shape
    if padding == "SAME":
        ph, pw = (kh - 1) // 2, (kw - 1) // 2
        x = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
        ho, wo = h, w
    elif padding == "VALID":
        ho, wo = h - kh + 1, w - kw + 1
    else:
        raise ValueError(padding)
    patches = []
    for dy in range(kh):
        for dx in range(kw):
            patches.append(x[:, dy : dy + ho, dx : dx + wo, :])
    return jnp.concatenate(patches, axis=-1)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, padding: str = "SAME") -> jnp.ndarray:
    """2-D convolution via im2col + matmul.

    x: [B,H,W,Cin], w: [kh,kw,Cin,Cout] -> [B,Ho,Wo,Cout].  This is the
    *definition* the Pallas kernel must match; it is itself validated against
    ``jax.lax.conv_general_dilated`` in the tests.
    """
    kh, kw, cin, cout = w.shape
    cols = im2col(x, kh, kw, padding)  # [B,Ho,Wo,kh*kw*Cin]
    b, ho, wo, k = cols.shape
    out = matmul(cols.reshape(b * ho * wo, k), w.reshape(kh * kw * cin, cout))
    return out.reshape(b, ho, wo, cout)


def binary_quantize(features: jnp.ndarray, thresholds: jnp.ndarray) -> jnp.ndarray:
    """Mean/median-threshold binarisation (Section II-C): f32 -> {0,1} f32.

    features: [B,N], thresholds: [N] (per-feature threshold vector).
    """
    return (features > thresholds[None, :]).astype(jnp.float32)


def match_feature_count(q: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8: S_fc[b,m] = sum_i I(q[b,i] == t[m,i]).

    q: [B,N] binary query feature maps; t: [M,N] binary templates.
    Returns f32 scores [B,M].
    """
    eq = q[:, None, :] == t[None, :, :]
    return jnp.sum(eq.astype(jnp.float32), axis=-1)


def match_similarity(
    q: jnp.ndarray, t_lo: jnp.ndarray, t_hi: jnp.ndarray, alpha: float
) -> jnp.ndarray:
    """Eq. 9-11: distance outside [lo,hi] window + hit ratio -> similarity.

    q: [B,N] real-valued queries; t_lo/t_hi: [M,N] per-template bounds.
    S_sim = H / (1 + alpha * D) with
      D = sum_i (q - hi)^2 [q>hi] + (lo - q)^2 [q<lo]
      H = mean_i 1(lo <= q <= hi)
    """
    qb = q[:, None, :]
    over = jnp.maximum(qb - t_hi[None, :, :], 0.0)
    under = jnp.maximum(t_lo[None, :, :] - qb, 0.0)
    d = jnp.sum(over * over + under * under, axis=-1)
    hit = jnp.mean(
        ((qb >= t_lo[None, :, :]) & (qb <= t_hi[None, :, :])).astype(jnp.float32),
        axis=-1,
    )
    return hit / (1.0 + alpha * d)


def classify(scores: jnp.ndarray, template_class: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """Eq. 12 with multi-template support: per-class max over that class's
    templates, then argmax over classes.

    scores: [B,M] similarity/count scores; template_class: [M] int class ids.
    """
    onehot = template_class[None, :, None] == jnp.arange(num_classes)[None, None, :]
    neg = jnp.full_like(scores, -jnp.inf)[:, :, None]
    per = jnp.where(onehot, scores[:, :, None], neg)  # [B,M,C]
    return jnp.argmax(jnp.max(per, axis=1), axis=-1)


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-2 max pooling, x: [B,H,W,C] with even H,W."""
    b, h, w, c = x.shape
    return jnp.max(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))

//! Table II reproduction: classification accuracy with 1 / 2 / 3 templates
//! per class, plus the matching-cost side of the trade-off (scores per
//! second vs template count on the packed popcount path).
//!
//! Shape assertions: a second template must not *hurt* (paper: +0.73%), and
//! gains must flatten (paper: the third template adds nothing) — asserted as
//! "k=2 within noise of best" and "k=3 not a large win over k=2".

use hec::benchkit::{bench, paper_row, section};
use hec::config::{Backend, ServeConfig};
use hec::coordinator::Pipeline;
use hec::dataset::SyntheticDataset;
use hec::energy::constants::MULTI_TEMPLATE_ACCURACY;
use hec::matching;
use hec::templates::TemplateStore;

fn main() {
    if !std::path::Path::new("artifacts/meta.json").is_file() {
        println!("table2_multi_template: run `make artifacts` first");
        return;
    }

    section("Table II — accuracy vs templates per class");
    let mut measured = Vec::new();
    for (k, paper_acc) in MULTI_TEMPLATE_ACCURACY {
        let cfg = ServeConfig {
            artifacts_dir: "artifacts".into(),
            backend: Backend::FeatureCount,
            templates_per_class: k,
            ..Default::default()
        };
        let mut p = Pipeline::new(&cfg).unwrap();
        let n = 400;
        let ds = SyntheticDataset::new(
            1_000_003,
            n,
            p.meta.norm.mean as f32,
            p.meta.norm.std as f32,
        );
        let (images, labels) = ds.batch(0, n);
        let e = p.evaluate(&images, &labels, 32).unwrap();
        paper_row(&format!("k={k}"), paper_acc / 100.0, e.accuracy, "acc");
        measured.push(e.accuracy);
    }
    // Shape: k=2 >= k=1 - noise; k=3 gives no big further win over k=2.
    assert!(measured[1] >= measured[0] - 0.02, "second template must not hurt");
    assert!(
        measured[2] <= measured[1] + 0.05,
        "third template must show diminishing returns"
    );

    section("matching cost vs template count (packed popcount path)");
    let store = TemplateStore::load("artifacts/templates.json").unwrap();
    let nf = store.n_features;
    let mut rng = hec::rng::Rng::new(3);
    let q: Vec<u8> = (0..nf).map(|_| u8::from(rng.u01() < 0.5)).collect();
    let mut results = Vec::new();
    for k in 1..=3usize {
        let set = store.set(k).unwrap();
        let packed = set.pack_query(&q);
        let r = bench(&format!("feature_count k={k} ({} rows)", set.num_templates()), 1000, 20000, || {
            std::hint::black_box(matching::feature_count_all_packed(
                std::hint::black_box(&packed),
                set,
            ));
        });
        results.push(r);
    }
    // Cost must grow with k (more rows to score).
    assert!(results[2].mean >= results[0].mean);
    println!("\ntable2_multi_template: PASS");
}

"""Magnitude pruning on the polynomial schedule of Section II-B (Eq. 5-7).

Sparsity ramps from ``s_i`` = 0.50 to ``s_f`` = 0.80 over ``n_t`` pruning
steps via ``s(t) = s_f + (s_i - s_f)(1 - t/n_t)^3``; at each step the global
weight-magnitude percentile (Eq. 7) sets the threshold, weights below it are
masked to zero (Eq. 6), and a brief masked fine-tune lets the survivors
adapt.  Masks persist through fine-tuning (gradient updates cannot resurrect
a pruned weight) — the standard iterative-magnitude-pruning contract.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .config import PruneConfig, StudentConfig
from .model import student_logits
from .train import adam_init, adam_update, cross_entropy, evaluate, _batches

# Only conv/dense kernels are pruned; biases and BN affine params are dense.
_PRUNABLE_KEY = "w"


def polynomial_sparsity(t: int, cfg: PruneConfig) -> float:
    """Eq. 5."""
    frac = 1.0 - t / cfg.pruning_steps
    return cfg.final_sparsity + (cfg.initial_sparsity - cfg.final_sparsity) * frac ** 3


def _prunable_leaves(params) -> List:
    return [
        (path, leaf)
        for path, leaf in jax.tree_util.tree_leaves_with_path(params)
        if path[-1].key == _PRUNABLE_KEY and path[0].key != "head"
    ]


def global_threshold(params, sparsity: float) -> float:
    """Eq. 7: the sparsity-percentile of |W| pooled over all prunable layers."""
    mags = np.concatenate(
        [np.abs(np.asarray(leaf)).ravel() for _, leaf in _prunable_leaves(params)]
    )
    return float(np.quantile(mags, sparsity))


def make_masks(params, sparsity: float) -> Dict:
    """Binary masks (Eq. 6): 1 where |w| >= theta, per the *global* threshold."""
    theta = global_threshold(params, sparsity)

    def mask_of(path_key, leaf):
        return (jnp.abs(leaf) >= theta).astype(jnp.float32)

    masks = jax.tree_util.tree_map(jnp.ones_like, params)
    masks = _set_prunable(masks, params, mask_of)
    return masks


def _set_prunable(masks, params, fn):
    flat_m, treedef = jax.tree_util.tree_flatten_with_path(masks)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    out = []
    for (path_m, m), (path_p, p) in zip(flat_m, flat_p):
        if path_m[-1].key == _PRUNABLE_KEY and path_m[0].key != "head":
            out.append(fn(path_m, p))
        else:
            out.append(m)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(masks), out
    )


def apply_masks(params, masks):
    return jax.tree_util.tree_map(lambda p, m: p * m, params, masks)


def sparsity_of(params, masks) -> float:
    """Achieved sparsity over prunable weights."""
    total, zeros = 0, 0
    for (path, m) in jax.tree_util.tree_leaves_with_path(masks):
        if path[-1].key == _PRUNABLE_KEY and path[0].key != "head":
            total += m.size
            zeros += int(m.size - jnp.sum(m))
    return zeros / max(total, 1)


def prune_student(
    cfg: PruneConfig, scfg: StudentConfig, params, state, tx, ty, vx, vy, log=None
):
    """Iterative prune + fine-tune (Section II-B), returns (params, state, masks)."""
    log = log if log is not None else []

    @jax.jit
    def step(params, state, opt, masks, xb, yb):
        def loss_fn(p):
            logits, new_s = student_logits(p, state, xb, training=True)
            return cross_entropy(logits, yb), new_s

        (loss, new_s), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads = jax.tree_util.tree_map(lambda g, m: g * m, grads, masks)
        params, opt = adam_update(params, grads, opt, scfg.lr * 0.3)
        params = apply_masks(params, masks)
        return params, new_s, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(scfg.seed + 31)
    infer = jax.jit(lambda p, s, xb: student_logits(p, s, xb, training=False)[0])
    masks = jax.tree_util.tree_map(jnp.ones_like, params)

    for t in range(1, cfg.pruning_steps + 1):
        t0 = time.time()
        s_t = polynomial_sparsity(t, cfg)
        masks = make_masks(params, s_t)
        params = apply_masks(params, masks)
        # Brief masked fine-tune so survivors compensate (Section II-B).
        steps_done = 0
        while steps_done < cfg.finetune_steps_per_prune:
            for bidx in _batches(len(tx), scfg.batch_size, rng):
                params, state, opt, _ = step(
                    params, state, opt, masks, jnp.asarray(tx[bidx]), jnp.asarray(ty[bidx])
                )
                steps_done += 1
                if steps_done >= cfg.finetune_steps_per_prune:
                    break
        log.append(
            {
                "phase": "prune",
                "step": t,
                "target_sparsity": s_t,
                "achieved_sparsity": sparsity_of(params, masks),
                "val_acc": evaluate(infer, params, state, vx, vy),
                "secs": time.time() - t0,
            }
        )

    # Final fine-tune phase at fixed (final) sparsity.
    for epoch in range(cfg.final_finetune_epochs):
        t0 = time.time()
        for bidx in _batches(len(tx), scfg.batch_size, rng):
            params, state, opt, _ = step(
                params, state, opt, masks, jnp.asarray(tx[bidx]), jnp.asarray(ty[bidx])
            )
        log.append(
            {
                "phase": "prune_finetune",
                "epoch": epoch,
                "achieved_sparsity": sparsity_of(params, masks),
                "val_acc": evaluate(infer, params, state, vx, vy),
                "secs": time.time() - t0,
            }
        )
    return params, state, masks, log

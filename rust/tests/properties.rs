//! Property-based tests (hand-rolled generator loops; proptest is
//! unavailable offline).  Each property runs a few hundred randomized cases
//! from a fixed seed, shrink-free but reproducible.

use hec::jsonlite::{self, Value};
use hec::matching;
use hec::rng::Rng;
use hec::templates::{pack_bits, TemplateSet};

fn toy_set(templates: Vec<Vec<u8>>, class_of: Vec<usize>) -> TemplateSet {
    let n = templates[0].len();
    let w = n.div_ceil(64);
    TemplateSet {
        packed: templates.iter().flat_map(|t| pack_bits(t, w)).collect(),
        words_per_row: w,
        lo: vec![vec![0.0; n]; templates.len()],
        hi: vec![vec![1.0; n]; templates.len()],
        bin_lo: templates
            .iter()
            .map(|t| t.iter().map(|&b| b as f32 - 0.5).collect())
            .collect(),
        bin_hi: templates
            .iter()
            .map(|t| t.iter().map(|&b| b as f32 + 0.5).collect())
            .collect(),
        silhouette: vec![],
        class_of,
        templates,
    }
}

fn random_bits(rng: &mut Rng, n: usize, p: f64) -> Vec<u8> {
    (0..n).map(|_| u8::from(rng.u01() < p)).collect()
}

/// Property: packed popcount scoring == dense byte scoring, any width.
#[test]
fn prop_packed_equals_dense() {
    let mut rng = Rng::new(42);
    for case in 0..300 {
        let n = 1 + rng.below(300);
        let m = 1 + rng.below(12);
        let p = rng.range(0.05, 0.95);
        let templates: Vec<Vec<u8>> = (0..m).map(|_| random_bits(&mut rng, n, p)).collect();
        let class_of: Vec<usize> = (0..m).collect();
        let set = toy_set(templates.clone(), class_of);
        let q = random_bits(&mut rng, n, p);
        let dense = matching::feature_count_all_dense(&q, &set);
        let packed = matching::feature_count_all_packed(&set.pack_query(&q), &set);
        assert_eq!(dense, packed, "case {case}: n={n} m={m}");
    }
}

/// Property (§V.B): on binary queries with unit windows, feature count and
/// similarity classification agree exactly.
#[test]
fn prop_binary_fc_sim_agree() {
    let mut rng = Rng::new(7);
    for case in 0..200 {
        let n = 8 + rng.below(200);
        let classes = 2 + rng.below(6);
        let templates: Vec<Vec<u8>> = (0..classes).map(|_| random_bits(&mut rng, n, 0.5)).collect();
        let class_of: Vec<usize> = (0..classes).collect();
        let set = toy_set(templates, class_of);
        let q = random_bits(&mut rng, n, 0.5);
        let fc = matching::classify_feature_count(&q, &set, classes);
        let qf: Vec<f32> = q.iter().map(|&b| b as f32).collect();
        let sim = matching::classify_similarity(&qf, &set, 0.05, classes, true);
        assert_eq!(fc, sim, "case {case}");
    }
}

/// Property: Eq. 12 multi-template per-class max equals brute force.
#[test]
fn prop_classify_equals_bruteforce() {
    let mut rng = Rng::new(13);
    for _ in 0..300 {
        let num_classes = 2 + rng.below(5);
        let m = num_classes + rng.below(10);
        let scores: Vec<u32> = (0..m).map(|_| rng.below(1000) as u32).collect();
        // Every class owns at least one template.
        let mut class_of: Vec<usize> = (0..num_classes).collect();
        for _ in num_classes..m {
            class_of.push(rng.below(num_classes));
        }
        let got = matching::classify(&scores, &class_of, num_classes);
        // Brute force: best (score, -class) pair.
        let mut best_class = 0;
        let mut best_score = None::<u32>;
        for c in 0..num_classes {
            let s = scores
                .iter()
                .zip(class_of.iter())
                .filter(|(_, &cc)| cc == c)
                .map(|(&s, _)| s)
                .max();
            if let Some(s) = s {
                if best_score.map_or(true, |b| s > b) {
                    best_score = Some(s);
                    best_class = c;
                }
            }
        }
        assert_eq!(got, best_class);
    }
}

/// Property: feature-count score is symmetric and bounded by N, and scoring
/// a template against itself gives exactly N.
#[test]
fn prop_feature_count_bounds() {
    let mut rng = Rng::new(99);
    for _ in 0..200 {
        let n = 1 + rng.below(256);
        let a = random_bits(&mut rng, n, 0.5);
        let b = random_bits(&mut rng, n, 0.5);
        let ab = matching::feature_count_dense(&a, &b);
        let ba = matching::feature_count_dense(&b, &a);
        assert_eq!(ab, ba);
        assert!(ab <= n as u32);
        assert_eq!(matching::feature_count_dense(&a, &a), n as u32);
    }
}

/// Property: similarity is 1 exactly when all features are in-window, and
/// decreases (weakly) as the query moves farther outside.
#[test]
fn prop_similarity_monotone_in_violation() {
    let mut rng = Rng::new(5);
    for _ in 0..200 {
        let n = 1 + rng.below(64);
        let lo = vec![0.0f32; n];
        let hi = vec![1.0f32; n];
        let inside: Vec<f32> = (0..n).map(|_| rng.range(0.0, 1.0) as f32).collect();
        assert!((matching::similarity(&inside, &lo, &hi, 0.3) - 1.0).abs() < 1e-6);
        let mut out1 = inside.clone();
        let mut out2 = inside.clone();
        out1[0] = 1.5;
        out2[0] = 3.0;
        let s1 = matching::similarity(&out1, &lo, &hi, 0.3);
        let s2 = matching::similarity(&out2, &lo, &hi, 0.3);
        assert!(s1 >= s2, "{s1} {s2}");
        assert!(s1 < 1.0);
    }
}

/// Property: jsonlite parse(write(v)) == v for random value trees.
#[test]
fn prop_jsonlite_roundtrip() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.u01() < 0.5),
            // Round-trippable numbers: scaled integers.
            2 => Value::Num((rng.below(2_000_001) as f64 - 1_000_000.0) / 64.0),
            3 => Value::Str(
                (0..rng.below(12))
                    .map(|_| char::from(32 + rng.below(94) as u8))
                    .collect(),
            ),
            4 => Value::Arr((0..rng.below(6)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(6))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(1234);
    for case in 0..300 {
        let v = random_value(&mut rng, 3);
        let text = v.to_json();
        let back = jsonlite::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}: {text}");
    }
}

/// Property: batcher padding picks the smallest exported size that fits and
/// chunking covers the batch exactly.
#[test]
fn prop_batcher_padding() {
    use hec::coordinator::batcher::{chunks_for, pad_to_artifact};
    let exported = [1usize, 8, 32];
    let mut rng = Rng::new(3);
    for _ in 0..300 {
        let n = 1 + rng.below(100);
        let (b, pad) = pad_to_artifact(n.min(32), &exported);
        assert!(b >= n.min(32));
        assert_eq!(b - n.min(32), pad);
        assert!(exported.contains(&b));
        let chunks = chunks_for(n, &exported);
        let covered: usize = chunks.iter().map(|(b, p)| b - p).sum();
        assert_eq!(covered, n);
        for (b, _) in chunks {
            assert!(exported.contains(&b));
        }
    }
}

/// Property: the ideal ACAM array's match counts equal Eq. 8 for random
/// binary templates/queries (the core fidelity contract).
#[test]
fn prop_ideal_acam_equals_eq8() {
    use hec::acam::program::{binary_query_voltages, program_array, WindowMode};
    use hec::acam::{ArrayConfig, Variability};
    let mut rng = Rng::new(21);
    for case in 0..25 {
        let n = 8 + rng.below(64);
        let m = 2 + rng.below(6);
        let templates: Vec<Vec<u8>> = (0..m).map(|_| random_bits(&mut rng, n, 0.5)).collect();
        let class_of: Vec<usize> = (0..m).collect();
        let set = toy_set(templates.clone(), class_of);
        let mut arr = program_array(
            &set,
            WindowMode::Binary,
            ArrayConfig::default(),
            Variability::ideal(),
            case as u64,
        );
        let q = random_bits(&mut rng, n, 0.5);
        let out = arr.search(&binary_query_voltages(&q));
        for (r, t) in templates.iter().enumerate() {
            let want = matching::feature_count_dense(&q, t);
            assert_eq!(out.match_counts[r], want, "case {case} row {r}");
        }
        // Analogue similarity ordering equals count ordering.
        let mut idx: Vec<usize> = (0..m).collect();
        idx.sort_by(|&a, &b| out.similarity[b].partial_cmp(&out.similarity[a]).unwrap());
        let mut idx2: Vec<usize> = (0..m).collect();
        idx2.sort_by_key(|&r| std::cmp::Reverse(out.match_counts[r]));
        let key = |v: &[usize]| -> Vec<u32> { v.iter().map(|&r| out.match_counts[r]).collect() };
        assert_eq!(key(&idx), key(&idx2), "case {case}");
    }
}

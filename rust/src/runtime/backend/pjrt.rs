//! `PjrtBackend` — the HLO/PJRT execution engine (cargo feature `pjrt`).
//!
//! One [`Runtime`] owns the PJRT CPU client and a cache of compiled
//! executables keyed by artifact name (`student_fwd_b8`, `match_fc_b32`,
//! …).  Artifacts are HLO *text* — see DESIGN.md (jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids).  All exported entry points return 1-tuples
//! (`return_tuple=True` at lowering), unwrapped here with `to_tuple1`.
//!
//! This module only compiles with `--features pjrt`, which additionally
//! requires the vendored `xla` crate (see Cargo.toml) — the default build
//! has zero unvendorable dependencies and uses
//! [`super::interp::InterpBackend`] instead.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::{Backend, ServeConfig};
use crate::error::{Error, Result};
use crate::runtime::meta::Meta;
use crate::runtime::params;

use super::FrontEnd;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Backend(format!("xla: {e}"))
    }
}

/// A loaded, compiled artifact plus its device-resident weight buffers.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Weight buffers (uploaded once; appended to every execute call after
    /// the caller's inputs — matching the exported argument order
    /// `(x, *flat_params)`).
    params: Vec<xla::PjRtBuffer>,
    /// Artifact name (diagnostics).
    pub name: String,
}

impl Executable {
    /// Execute with f32 inputs; the parameter buffers are appended
    /// automatically.  Returns the flattened f32 output of the single tuple
    /// element.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let client = self.exe.client();
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
            bufs.push(client.buffer_from_host_buffer::<f32>(data, &dims_usize, None)?);
        }
        let args: Vec<&xla::PjRtBuffer> = bufs.iter().chain(self.params.iter()).collect();
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let out = result[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Number of parameter arrays riding along with this artifact.
    pub fn num_params(&self) -> usize {
        self.params.len()
    }
}

/// The PJRT runtime: client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(Error::Artifact(format!(
                "artifacts directory not found: {} (run `make artifacts`)",
                dir.display()
            )));
        }
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            artifacts_dir: dir,
            cache: HashMap::new(),
        })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` (cached after the first call).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            if !path.is_file() {
                return Err(Error::Artifact(format!(
                    "missing artifact {} (expected {})",
                    name,
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            // Upload the weight sidecar (if any) once, device-resident.
            let params = params::load_params(&self.artifacts_dir, name)?
                .into_iter()
                .map(|p| {
                    self.client
                        .buffer_from_host_buffer::<f32>(&p.data, &p.shape, None)
                        .map_err(Error::from)
                })
                .collect::<Result<Vec<_>>>()?;
            self.cache.insert(
                name.to_string(),
                Executable {
                    exe,
                    params,
                    name: name.to_string(),
                },
            );
        }
        Ok(&self.cache[name])
    }

    /// Pre-compile a list of artifacts (warmup; keeps compile jitter off
    /// the request path).
    pub fn preload(&mut self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    /// Names currently compiled.
    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(String::as_str).collect()
    }
}

/// Does the artifact set include the jnp-lowered fast front-end?
fn has_fast_variant(dir: &Path, meta: &Meta) -> bool {
    let b = meta.artifacts.batch_sizes.first().copied().unwrap_or(1);
    dir.join(format!("student_fwd_fast_b{b}.hlo.txt")).is_file()
}

/// The PJRT-backed [`FrontEnd`]: dispatches to the AOT-exported batch
/// variants, padding each request up to the nearest exported batch size
/// and chunking oversized requests.
pub struct PjrtBackend {
    runtime: Runtime,
    /// "student_fwd_fast" on the CPU hot path, "student_fwd" for the
    /// Pallas-lowered variant (numerically identical).
    fwd_prefix: &'static str,
    batch_sizes: Vec<usize>,
    image_size: usize,
    n_features: usize,
    /// Reusable padded input buffer (allocation-free hot path).
    scratch: Vec<f32>,
}

impl PjrtBackend {
    pub fn new(cfg: &ServeConfig, meta: &Meta) -> Result<PjrtBackend> {
        let mut runtime = Runtime::new(&cfg.artifacts_dir)?;
        let fwd_prefix = if cfg.use_fast_frontend && has_fast_variant(&cfg.artifacts_dir, meta) {
            "student_fwd_fast"
        } else {
            "student_fwd"
        };
        // Precompile every batch variant of the entry point this deployment
        // serves through, so compilation never hits the request path (the
        // softmax baseline never calls the feature extractor and vice
        // versa; whichever is unused compiles lazily if ever requested).
        let preload_prefix = if cfg.backend == Backend::Softmax {
            "student_softmax"
        } else {
            fwd_prefix
        };
        for &b in &meta.artifacts.batch_sizes {
            runtime.load(&format!("{preload_prefix}_b{b}"))?;
        }
        let mut batch_sizes = meta.artifacts.batch_sizes.clone();
        batch_sizes.sort_unstable();
        Ok(PjrtBackend {
            runtime,
            fwd_prefix,
            batch_sizes,
            image_size: meta.artifacts.image_size,
            n_features: meta.artifacts.n_features,
            scratch: Vec::new(),
        })
    }

    /// Access the underlying runtime (benches).
    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }

    /// Smallest exported batch size >= n (or the largest available).
    fn batch_for(&self, n: usize) -> usize {
        for &b in &self.batch_sizes {
            if b >= n {
                return b;
            }
        }
        *self.batch_sizes.last().expect("validated batch sizes")
    }

    /// Run `<prefix>_b{b}` on `n` images padded to artifact batch `b`;
    /// returns the first `n` rows of `row_len` columns.
    fn run_padded(
        &mut self,
        prefix: &str,
        images: &[f32],
        n: usize,
        row_len: usize,
    ) -> Result<Vec<f32>> {
        let img_len = self.image_size * self.image_size;
        let s = self.image_size as i64;
        let b = self.batch_for(n);
        self.scratch.clear();
        self.scratch.resize(b * img_len, 0.0);
        self.scratch[..images.len()].copy_from_slice(images);
        let name = format!("{prefix}_b{b}");
        let exe = self.runtime.load(&name)?;
        let out = exe.run_f32(&[(&self.scratch, &[b as i64, s, s, 1])])?;
        if out.len() != b * row_len {
            return Err(Error::Artifact(format!(
                "{name} returned {} floats, expected {}",
                out.len(),
                b * row_len
            )));
        }
        Ok(out[..n * row_len].to_vec())
    }

    /// Chunk arbitrary `n` into artifact-sized dispatches.
    fn run(&mut self, prefix: &str, images: &[f32], n: usize, row_len: usize) -> Result<Vec<f32>> {
        let img_len = self.image_size * self.image_size;
        if images.len() != n * img_len {
            return Err(Error::Request(format!(
                "batch buffer has {} floats, expected {} ({n} images)",
                images.len(),
                n * img_len
            )));
        }
        let max_b = *self.batch_sizes.last().expect("validated batch sizes");
        if n <= max_b {
            return self.run_padded(prefix, images, n, row_len);
        }
        let mut out = Vec::with_capacity(n * row_len);
        let mut i = 0;
        while i < n {
            let m = max_b.min(n - i);
            out.extend(self.run_padded(
                prefix,
                &images[i * img_len..(i + m) * img_len],
                m,
                row_len,
            )?);
            i += m;
        }
        Ok(out)
    }
}

impl FrontEnd for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn padding_for(&self, n: usize) -> usize {
        let max_b = *self.batch_sizes.last().expect("validated batch sizes");
        let tail = n % max_b;
        if n > 0 && tail == 0 {
            0
        } else {
            self.batch_for(tail) - tail
        }
    }

    fn extract_features(&mut self, images: &[f32], n: usize) -> Result<Vec<f32>> {
        let nf = self.n_features;
        let prefix = self.fwd_prefix;
        self.run(prefix, images, n, nf)
    }

    fn logits(&mut self, images: &[f32], n: usize, num_classes: usize) -> Result<Vec<f32>> {
        self.run("student_softmax", images, n, num_classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scratch dir helper (tempfile crate unavailable offline); removed on
    /// drop.
    struct Scratch(std::path::PathBuf);

    impl Scratch {
        fn new(tag: &str) -> Self {
            let p = std::env::temp_dir().join(format!(
                "hec-rt-{tag}-{}-{:?}",
                std::process::id(),
                std::thread::current().id()
            ));
            std::fs::create_dir_all(&p).unwrap();
            Scratch(p)
        }
        fn path(&self) -> &std::path::Path {
            &self.0
        }
    }

    impl Drop for Scratch {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Runtime::new("/nonexistent/path").is_err());
    }

    #[test]
    fn missing_artifact_is_error() {
        let dir = Scratch::new("missing");
        let mut rt = Runtime::new(dir.path()).unwrap();
        match rt.load("student_fwd_b1") {
            Err(Error::Artifact(_)) => {}
            other => panic!(
                "expected artifact error, got {:?}",
                other.err().map(|e| e.to_string())
            ),
        }
    }

    /// Round-trip a hand-written HLO module through compile + execute.
    #[test]
    fn executes_handwritten_hlo() {
        let dir = Scratch::new("tiny");
        let hlo = r#"
HloModule tiny, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  two = f32[] constant(2)
  bt = f32[4]{0} broadcast(two), dimensions={}
  m = f32[4]{0} multiply(x, bt)
  ROOT t = (f32[4]{0}) tuple(m)
}
"#;
        std::fs::write(dir.path().join("tiny.hlo.txt"), hlo).unwrap();
        let mut rt = Runtime::new(dir.path()).unwrap();
        let exe = rt.load("tiny").unwrap();
        let out = exe.run_f32(&[(&[1.0, 2.0, 3.0, 4.0], &[4])]).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn cache_returns_same_executable() {
        let dir = Scratch::new("cache");
        std::fs::write(
            dir.path().join("t.hlo.txt"),
            "HloModule t\nENTRY main { x = f32[1]{0} parameter(0) ROOT t = (f32[1]{0}) tuple(x) }",
        )
        .unwrap();
        let mut rt = Runtime::new(dir.path()).unwrap();
        rt.load("t").unwrap();
        assert_eq!(rt.loaded(), vec!["t"]);
        rt.load("t").unwrap();
        assert_eq!(rt.loaded().len(), 1);
    }
}

//! Synthetic CIFAR-like generator — line-for-line mirror of
//! `python/compile/data.py` (see DESIGN.md §Substitutions for why this
//! stands in for CIFAR-10 in this environment).
//!
//! Determinism contract: `Lcg` and `render` reproduce the Python
//! implementation exactly; golden values are pinned in both test suites so
//! the two sides cannot drift.

/// Image edge length (CIFAR format).
pub const IMAGE_SIZE: usize = 32;
/// Number of classes.
pub const NUM_CLASSES: usize = 10;

/// SplitMix64 finaliser used to seed the LCG.
pub fn splitmix64(x: u64) -> u64 {
    let x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// 64-bit LCG (MMIX constants), seeded via SplitMix64; `u01` uses the top
/// 53 bits — identical to the Python `Lcg`.
#[derive(Debug, Clone)]
pub struct Lcg {
    state: u64,
}

impl Lcg {
    const A: u64 = 6364136223846793005;
    const C: u64 = 1442695040888963407;

    pub fn new(seed: u64) -> Self {
        Lcg {
            state: splitmix64(seed),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = Self::A.wrapping_mul(self.state).wrapping_add(Self::C);
        self.state
    }

    /// Uniform in [0, 1) from the top 53 bits.
    pub fn u01(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / 9007199254740992.0)
    }

    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.u01()
    }
}

/// Render one grayscale sample in [0, 1], row-major `[IMAGE_SIZE^2]`.
///
/// Class recipes (must match `data.synth_image`):
/// 0 horizontal band, 1 vertical band, 2 disc, 3 ring, 4 diagonal stripes,
/// 5 anti-diagonal stripes, 6 checkerboard, 7 radial gradient, 8 two-blob,
/// 9 cross.
pub fn render(class_id: usize, sample_id: u64, seed: u64) -> Vec<f32> {
    assert!(class_id < NUM_CLASSES, "class_id out of range");
    let size = IMAGE_SIZE;
    let mut rng = Lcg::new((seed << 40) ^ ((class_id as u64) << 20) ^ sample_id);
    let cx = rng.range(0.35, 0.65);
    let cy = rng.range(0.35, 0.65);
    let scale = rng.range(0.8, 1.25);
    let phase = rng.range(0.0, 1.0);
    let amp = rng.range(0.7, 1.0);

    let mut img = vec![0f32; size * size];
    for i in 0..size {
        // yy varies along i (rows), xx along j (cols) — matches np.meshgrid(indexing="ij").
        let yy = (i as f64 + 0.5) / size as f64;
        for j in 0..size {
            let xx = (j as f64 + 0.5) / size as f64;
            let v: f64 = match class_id {
                0 => (-((yy - cy) / (0.12 * scale)).powi(2)).exp(),
                1 => (-((xx - cx) / (0.12 * scale)).powi(2)).exp(),
                2 => {
                    let r = ((xx - cx).powi(2) + (yy - cy).powi(2)).sqrt();
                    if r < 0.22 * scale {
                        1.0
                    } else {
                        0.0
                    }
                }
                3 => {
                    let r = ((xx - cx).powi(2) + (yy - cy).powi(2)).sqrt();
                    if (r - 0.25 * scale).abs() < 0.06 {
                        1.0
                    } else {
                        0.0
                    }
                }
                4 => {
                    0.5 + 0.5
                        * (2.0 * std::f64::consts::PI * (xx + yy) * 4.0 * scale
                            + phase * 6.2831853)
                            .sin()
                }
                5 => {
                    0.5 + 0.5
                        * (2.0 * std::f64::consts::PI * (xx - yy) * 4.0 * scale
                            + phase * 6.2831853)
                            .sin()
                }
                6 => {
                    let fx = (xx * 4.0 * scale + phase).floor();
                    let fy = (yy * 4.0 * scale + phase).floor();
                    (fx + fy).rem_euclid(2.0)
                }
                7 => {
                    let r = ((xx - cx).powi(2) + (yy - cy).powi(2)).sqrt();
                    (1.0 - r / (0.7 * scale)).clamp(0.0, 1.0)
                }
                8 => {
                    let d1 = (xx - cx * 0.6).powi(2) + (yy - cy).powi(2);
                    let d2 = (xx - (cx * 0.6 + 0.4)).powi(2) + (yy - cy).powi(2);
                    (-d1 / (0.02 * scale)).exp() + (-d2 / (0.02 * scale)).exp()
                }
                9 => {
                    let a = (-((yy - cy) / 0.08).powi(2)).exp();
                    let b = (-((xx - cx) / 0.08).powi(2)).exp();
                    a.max(b)
                }
                _ => unreachable!(),
            };
            img[i * size + j] = (amp * v) as f32;
        }
    }
    // Deterministic per-pixel noise stream — same draw order as Python.
    for px in img.iter_mut() {
        let noise = rng.u01() as f32;
        *px = (0.4 * *px + 1.2 * (noise - 0.5)).clamp(0.0, 1.0);
    }
    img
}

/// A lazily-rendered synthetic dataset: sample `i` has class `i % 10`
/// (round-robin) — matching `data.synth_dataset`.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    pub seed: u64,
    pub len: usize,
    /// Normalisation stats from training (meta.json `norm`); applied so the
    /// serving inputs match what the student was trained on.
    pub mean: f32,
    pub std: f32,
}

impl SyntheticDataset {
    pub fn new(seed: u64, len: usize, mean: f32, std: f32) -> Self {
        SyntheticDataset {
            seed,
            len,
            mean,
            std,
        }
    }

    /// The label of sample `i`.
    pub fn label(&self, i: usize) -> usize {
        i % NUM_CLASSES
    }

    /// Render + normalise sample `i` (shape `[IMAGE_SIZE * IMAGE_SIZE]`).
    pub fn image(&self, i: usize) -> Vec<f32> {
        let mut img = render(self.label(i), (i / NUM_CLASSES) as u64, self.seed);
        for v in img.iter_mut() {
            *v = (*v - self.mean) / self.std;
        }
        img
    }

    /// Render a contiguous normalised batch `[n * IMAGE_SIZE^2]` with labels.
    pub fn batch(&self, start: usize, n: usize) -> (Vec<f32>, Vec<usize>) {
        let mut xs = Vec::with_capacity(n * IMAGE_SIZE * IMAGE_SIZE);
        let mut ys = Vec::with_capacity(n);
        for i in start..start + n {
            let idx = i % self.len;
            xs.extend_from_slice(&self.image(idx));
            ys.push(self.label(idx));
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden values pinned against python/tests/test_data_macs.py.
    #[test]
    fn lcg_golden_sequence() {
        let mut l = Lcg::new(42);
        assert_eq!(l.next_u64(), 13986908341085854848);
        assert_eq!(l.next_u64(), 2827560660634158031);
        assert_eq!(l.next_u64(), 776025860801273266);
        assert_eq!(l.next_u64(), 301797295797536665);
    }

    #[test]
    fn lcg_u01_golden() {
        let mut l = Lcg::new(0);
        assert!((l.u01() - 0.288574626916).abs() < 1e-10);
    }

    #[test]
    fn splitmix_golden() {
        assert_eq!(splitmix64(123), 13032462758197477675);
    }

    #[test]
    fn render_golden() {
        let img = render(3, 7, 0);
        let sum: f32 = img.iter().sum();
        assert!(
            (sum - 194.83780).abs() < 0.05,
            "render(3,7,0) sum drifted: {sum}"
        );
        assert_eq!(img[0], 0.0);
    }

    #[test]
    fn render_deterministic() {
        assert_eq!(render(5, 11, 3), render(5, 11, 3));
        assert_ne!(render(5, 11, 3), render(5, 12, 3));
    }

    #[test]
    fn render_in_unit_range() {
        for c in 0..NUM_CLASSES {
            let img = render(c, 0, 1);
            assert!(img.iter().all(|v| (0.0..=1.0).contains(v)), "class {c}");
        }
    }

    #[test]
    fn dataset_round_robin() {
        let ds = SyntheticDataset::new(0, 25, 0.0, 1.0);
        let labels: Vec<usize> = (0..12).map(|i| ds.label(i)).collect();
        assert_eq!(labels, vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 0, 1]);
    }

    #[test]
    fn dataset_batch_shapes_and_wraparound() {
        let ds = SyntheticDataset::new(0, 10, 0.5, 2.0);
        let (xs, ys) = ds.batch(8, 4);
        assert_eq!(xs.len(), 4 * IMAGE_SIZE * IMAGE_SIZE);
        assert_eq!(ys, vec![8, 9, 0, 1]); // wraps at len=10
    }

    #[test]
    fn dataset_normalisation_applied() {
        let raw = SyntheticDataset::new(0, 10, 0.0, 1.0).image(0);
        let norm = SyntheticDataset::new(0, 10, 0.5, 2.0).image(0);
        for (r, n) in raw.iter().zip(norm.iter()) {
            assert!((n - (r - 0.5) / 2.0).abs() < 1e-6);
        }
    }
}

//! Content-hash feature-cache acceptance tests — deterministic, Gate-based,
//! artifact-free (synthetic fallback deployment), no sleeps.
//!
//! The suite pins the cache's contract:
//!
//! 1. **Hit-vs-miss parity**: a cache-on deployment serves bitwise-identical
//!    predictions, scores, and back-end energy to a cache-off deployment fed
//!    the same request sequence — on both interpreter engines and on the
//!    stochastic ACAM path (the hit consumes the shard RNG exactly as a miss
//!    would).  Only the front-end charge disappears: hits report
//!    `front_end_nj == 0`.
//! 2. **Cache-off invisibility**: with the cache disabled the wire JSON
//!    carries no `cache` field and `/metrics` no `hec_cache_*` series — the
//!    serving path is the pre-cache one, bitwise.
//! 3. **Counter discipline**: hits/misses/evictions totals and the resident
//!    entries gauge on `/metrics`, deterministic under seeded eviction.
//! 4. **Swap correctness**: a default-store hot-swap flushes the cache —
//!    cached bits are binarised under the old store's thresholds and must
//!    never answer for the new version.
//! 5. **Degradation correctness**: hits stay bitwise-parity under
//!    `digital_fallback` (the cached bits feed the digital matcher, not a
//!    stale ACAM answer).
//! 6. **Restart hygiene**: a shard panic-restart flushes entries to zero
//!    while the hit/miss totals stay monotone.

use std::sync::Arc;

use hec::api::{ClassifyOptions, ClassifyRequest, ClassifyResponse};
use hec::config::{Backend, Engine, ServeConfig};
use hec::coordinator::cache::FeatureCache;
use hec::coordinator::shard::{Gate, ShardHooks};
use hec::coordinator::{ClassifySurface, Pipeline, Server, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::store::StoreRegistry;
use hec::templates::TemplateStore;

/// An artifacts directory that never exists -> synthetic fallback.
const NO_ARTIFACTS: &str = "/nonexistent-hec-artifacts";

fn cfg(backend: Backend) -> ServeConfig {
    let mut c = ServeConfig {
        artifacts_dir: NO_ARTIFACTS.into(),
        backend,
        engine: Engine::Interp,
        ..Default::default()
    };
    c.batch.max_batch = 1; // serial submits -> singleton batches, no timing
    c.batch.max_wait_us = 0;
    c
}

fn cached_cfg(backend: Backend, capacity: usize) -> ServeConfig {
    let mut c = cfg(backend);
    c.cache.enabled = true;
    c.cache.capacity = capacity;
    c
}

fn workload(n: usize, seed: u64) -> (Vec<f32>, usize) {
    let meta = hec::runtime::Meta::synthetic();
    let ds = SyntheticDataset::new(seed, n, meta.norm.mean as f32, meta.norm.std as f32);
    let (images, _) = ds.batch(0, n);
    let s = meta.artifacts.image_size;
    (images, s * s)
}

/// Class-separable labelled rows matching the registry's geometry
/// (mirrors rust/tests/store.rs), for building publishable stores.
fn publishable_store(reg: &StoreRegistry, seed: u64) -> TemplateStore {
    let (num_classes, n_features, _) = reg.geometry();
    let per_class = 4;
    let n = per_class * num_classes;
    let labels: Vec<usize> = (0..n).map(|i| i % num_classes).collect();
    let mut rng = hec::rng::Rng::new(seed);
    let mut feats = vec![0.0f32; n * n_features];
    for (i, l) in labels.iter().enumerate() {
        for j in 0..n_features {
            feats[i * n_features + j] = (*l as f32) * 0.3
                + rng.u01() as f32
                + if j % num_classes == *l { 1.5 } else { 0.0 };
        }
    }
    TemplateStore::from_features(&feats, &labels, n_features, num_classes, seed).unwrap()
}

/// Everything hit-vs-miss parity compares bitwise.
#[derive(Debug, PartialEq)]
struct Outcome {
    predictions: Vec<(usize, f64)>,
    back_end_nj: f64,
}

fn outcome(r: &ClassifyResponse) -> Outcome {
    Outcome {
        predictions: r.predictions.iter().map(|p| (p.class, p.score)).collect(),
        back_end_nj: r.energy.back_end_nj,
    }
}

/// Property 1: cache-on serving is bitwise identical to cache-off serving
/// on the same request sequence — across both interpreter engines, the
/// deterministic feature-count backend, and the RNG-consuming ACAM
/// simulator at full variability.  Hits additionally charge a zero
/// front-end; first occurrences charge exactly the cold figure.
#[test]
fn hit_serving_is_bitwise_identical_to_cold_serving() {
    let scenarios = [
        (Backend::FeatureCount, Engine::Interp, 0.0),
        (Backend::FeatureCount, Engine::InterpFast, 0.0),
        (Backend::AcamSim, Engine::Interp, 1.0),
    ];
    let (images, img_len) = workload(3, 9_901);
    let seq = [0usize, 1, 0, 2, 1, 0];
    for (backend, engine, variability) in scenarios {
        let mut on = cached_cfg(backend, 8);
        on.engine = engine;
        on.acam.variability_level = variability;
        let mut off = cfg(backend);
        off.engine = engine;
        off.acam.variability_level = variability;
        let hot_srv = Server::start(on).unwrap();
        let cold_srv = Server::start(off).unwrap();

        let mut seen = std::collections::BTreeSet::new();
        for (i, &img) in seq.iter().enumerate() {
            let mut req = ClassifyRequest::new(images[img * img_len..(img + 1) * img_len].to_vec());
            req.top_k = 3;
            let hot = hot_srv.handle.submit_blocking(req.clone()).unwrap();
            let cold = cold_srv.handle.submit_blocking(req).unwrap();
            assert_eq!(
                outcome(&hot),
                outcome(&cold),
                "request {i} (image {img}, {backend:?}/{engine:?}): \
                 cached serving diverged from cold serving"
            );
            assert_eq!(cold.cache, None, "cache-off responses must not carry the flag");
            if seen.insert(img) {
                assert_eq!(hot.cache, Some(false), "request {i}: first sight is a miss");
                assert_eq!(
                    hot.energy.front_end_nj, cold.energy.front_end_nj,
                    "request {i}: a miss pays the full front-end"
                );
                assert!(hot.energy.front_end_nj > 0.0);
            } else {
                assert_eq!(hot.cache, Some(true), "request {i}: repeat must hit");
                assert_eq!(
                    hot.energy.front_end_nj, 0.0,
                    "request {i}: a hit skips the CNN front-end entirely"
                );
            }
        }
        hot_srv.shutdown();
        cold_srv.shutdown();
    }
}

/// Property 2: cache-off is bitwise invisible — no `cache` key on the wire,
/// no `hec_cache_*` series on `/metrics`, and responses equal to a direct
/// registry-free [`Pipeline`] run on the same images.
#[test]
fn cache_off_is_bitwise_invisible() {
    let c = cfg(Backend::FeatureCount);
    let (images, img_len) = workload(2, 555);
    let srv = Server::start(c.clone()).unwrap();
    let mut p = Pipeline::new(&c).unwrap();
    for i in [0usize, 1, 0] {
        let chunk = &images[i * img_len..(i + 1) * img_len];
        let resp = srv.handle.classify_blocking(chunk.to_vec()).unwrap();
        assert_eq!(resp.cache, None);
        let wire = resp.to_value().to_json();
        assert!(
            !wire.contains("\"cache\""),
            "cache-off wire bytes changed: {wire}"
        );
        let want = p.classify_batch(chunk, 1).unwrap().remove(0);
        assert_eq!(resp.top1().class, want.top1().class);
        assert_eq!(resp.top1().score, want.top1().score);
        assert_eq!(resp.energy.front_end_nj, want.energy.front_end_nj);
        assert_eq!(resp.energy.back_end_nj, want.energy.back_end_nj);
    }
    let text = srv.handle.prometheus_text();
    assert!(
        !text.contains("hec_cache_"),
        "cache-off /metrics must not render cache series:\n{text}"
    );
    srv.shutdown();
}

/// Property 3: the `/metrics` counters are exact under a deterministic
/// sequence — capacity 2, three distinct images: a, b, a(hit), c(evicts a
/// seeded victim), and the entries gauge holds at capacity.
#[test]
fn cache_metrics_count_hits_misses_evictions_and_entries() {
    let (images, img_len) = workload(3, 77_001);
    let srv = Server::start(cached_cfg(Backend::FeatureCount, 2)).unwrap();
    let img = |i: usize| images[i * img_len..(i + 1) * img_len].to_vec();
    assert_eq!(srv.handle.classify_blocking(img(0)).unwrap().cache, Some(false));
    assert_eq!(srv.handle.classify_blocking(img(1)).unwrap().cache, Some(false));
    assert_eq!(srv.handle.classify_blocking(img(0)).unwrap().cache, Some(true));
    assert_eq!(srv.handle.classify_blocking(img(2)).unwrap().cache, Some(false));
    let text = srv.handle.prometheus_text();
    for needle in [
        "# TYPE hec_cache_hits_total counter",
        "# TYPE hec_cache_entries gauge",
        "hec_cache_hits_total 1",
        "hec_cache_misses_total 3",
        "hec_cache_evictions_total 1",
        "hec_cache_entries 2",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    srv.shutdown();
}

/// Property 4: a default-store hot-swap flushes the cache.  Cached bits are
/// binarised under the **old** store's thresholds; serving them against the
/// published version would silently answer from the wrong store.  The first
/// post-swap repeat must therefore be a miss, and the refilled hit must
/// again be bitwise-parity with the post-swap miss.
#[test]
fn default_store_swap_flushes_the_cache() {
    let mut c = cached_cfg(Backend::FeatureCount, 8);
    c.shards.count = 1;
    let set = ShardSet::start(&c).unwrap();
    let (images, img_len) = workload(1, 31_337);
    let img = images[..img_len].to_vec();

    assert_eq!(
        set.handle.submit_blocking(ClassifyRequest::new(img.clone())).unwrap().cache,
        Some(false)
    );
    assert_eq!(
        set.handle.submit_blocking(ClassifyRequest::new(img.clone())).unwrap().cache,
        Some(true)
    );

    let admin = set.handle.store_admin().expect("sharded surface carries the admin");
    let reg = admin.registry();
    let snap = reg
        .publish("default", publishable_store(reg, 4242), "put")
        .unwrap();
    assert_eq!(snap.version, 1);

    // The very next batch adopts v1 AND re-misses: the swap flushed the
    // entry cached under the bootstrap store's thresholds.
    let miss = set.handle.submit_blocking(ClassifyRequest::new(img.clone())).unwrap();
    assert_eq!(miss.store_version, Some(1), "post-publish batch must serve v1");
    assert_eq!(
        miss.cache,
        Some(false),
        "stale bits must never answer for a freshly published store"
    );
    let hit = set.handle.submit_blocking(ClassifyRequest::new(img)).unwrap();
    assert_eq!(hit.store_version, Some(1));
    assert_eq!(hit.cache, Some(true));
    assert_eq!(hit.energy.front_end_nj, 0.0);
    assert_eq!(outcome(&hit), outcome(&miss), "post-swap hit diverged from post-swap miss");

    // Flush keeps the totals monotone; the gauge re-counts the refill.
    let text = set.handle.prometheus_text();
    for needle in [
        "hec_cache_hits_total{shard=\"0\"} 2",
        "hec_cache_misses_total{shard=\"0\"} 2",
        "hec_cache_entries{shard=\"0\"} 1",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    set.shutdown();
}

/// Property 5: under `digital_fallback` (the degradation ladder's terminal
/// rung) a hit feeds the cached bits to the **digital** matcher — bitwise
/// identical to a cold run with fallback engaged, zero front-end charge.
/// Driven at the [`Pipeline`] level through the public fallback switch, so
/// no canary machinery is needed.
#[test]
fn hits_stay_bitwise_identical_under_digital_fallback() {
    let mut c = cfg(Backend::AcamSim);
    c.acam.variability_level = 1.0;
    let mut hot = Pipeline::new(&c).unwrap();
    let mut cold = Pipeline::new(&c).unwrap();
    hot.set_digital_fallback(true);
    cold.set_digital_fallback(true);
    assert!(hot.digital_fallback());

    let mut cache = FeatureCache::new(8, 0xF0CA);
    let (images, img_len) = workload(2, 123_457);
    let opts = [ClassifyOptions { top_k: 3, ..Default::default() }];
    let mut seen = std::collections::BTreeSet::new();
    for (i, &img) in [0usize, 1, 0, 0, 1].iter().enumerate() {
        let chunk = &images[img * img_len..(img + 1) * img_len];
        let h = hot
            .classify_batch_cached(chunk, 1, &opts, &[], &mut cache)
            .unwrap()
            .remove(0);
        let w = cold.classify_batch_routed(chunk, 1, &opts, &[]).unwrap().remove(0);
        let pick = |r: &hec::api::ClassifyResult| {
            (
                r.predictions.iter().map(|p| (p.class, p.score)).collect::<Vec<_>>(),
                r.energy.back_end_nj,
            )
        };
        assert_eq!(pick(&h), pick(&w), "request {i}: fallback hit diverged from cold");
        if seen.insert(img) {
            assert_eq!(h.cache, Some(false), "request {i}");
        } else {
            assert_eq!(h.cache, Some(true), "request {i}");
            assert_eq!(h.energy.front_end_nj, 0.0, "request {i}");
        }
    }
}

/// Property 6: a shard panic-restart rebuilds the engine — which
/// invalidates every cached bit-vector — so the entries gauge flushes to
/// zero while the hit/miss totals stay monotone (the cache object outlives
/// the rebuild).  The injected panic fires before the cache is consulted,
/// so the boom batch moves no counter.
#[test]
fn panic_restart_keeps_totals_monotone_and_resets_entries() {
    let gate = Gate::new();
    let mut c = cached_cfg(Backend::FeatureCount, 8);
    c.shards.count = 1;
    c.batch.queue_depth = 8;
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            panic_on: Some("boom".into()),
            restart_gate: Some(Arc::clone(&gate)),
            ..Default::default()
        },
    )
    .unwrap();
    let (images, img_len) = workload(1, 2_024);
    let img = images[..img_len].to_vec();

    assert_eq!(
        set.handle.submit_blocking(ClassifyRequest::new(img.clone())).unwrap().cache,
        Some(false)
    );
    assert_eq!(
        set.handle.submit_blocking(ClassifyRequest::new(img.clone())).unwrap().cache,
        Some(true)
    );

    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("boom".into());
    assert!(set.handle.submit_blocking(req).is_err(), "panic fails the request");
    gate.await_arrivals(1);
    gate.release();
    gate.await_arrivals(2); // rebuild done: flush + re-publish already ran

    let text = set.handle.prometheus_text();
    for needle in [
        "hec_cache_hits_total{shard=\"0\"} 1",
        "hec_cache_misses_total{shard=\"0\"} 1",
        "hec_cache_entries{shard=\"0\"} 0",
    ] {
        assert!(text.contains(needle), "missing {needle:?} post-restart in:\n{text}");
    }

    // Same pixels re-miss against the rebuilt engine, then hit again; the
    // totals only ever go up.
    assert_eq!(
        set.handle.submit_blocking(ClassifyRequest::new(img.clone())).unwrap().cache,
        Some(false)
    );
    assert_eq!(
        set.handle.submit_blocking(ClassifyRequest::new(img)).unwrap().cache,
        Some(true)
    );
    let text = set.handle.prometheus_text();
    for needle in [
        "hec_cache_hits_total{shard=\"0\"} 2",
        "hec_cache_misses_total{shard=\"0\"} 2",
        "hec_cache_entries{shard=\"0\"} 1",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    set.shutdown();
}

//! Integration tests over the real artifacts (`make artifacts` must have
//! run; tests skip with a message when the artifacts directory is absent so
//! `cargo test` stays green on a fresh checkout).

use hec::config::{Backend, ServeConfig};
use hec::coordinator::{Pipeline, Server};
use hec::dataset::SyntheticDataset;
use hec::jsonlite;
use hec::runtime::Meta;

const ARTIFACTS: &str = "artifacts";

fn have_artifacts() -> bool {
    let ok = std::path::Path::new(ARTIFACTS).join("meta.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn cfg(backend: Backend) -> ServeConfig {
    ServeConfig {
        artifacts_dir: ARTIFACTS.into(),
        backend,
        ..Default::default()
    }
}

fn golden() -> jsonlite::Value {
    let text = std::fs::read_to_string("artifacts/meta.json").unwrap();
    jsonlite::parse(&text).unwrap().get("golden").unwrap().clone()
}

fn workload(meta: &Meta, n: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
    SyntheticDataset::new(seed, n, meta.norm.mean as f32, meta.norm.std as f32).batch(0, n)
}

/// The deployed Rust pipeline must reproduce the Python pipeline's
/// predictions bit-for-bit on the golden samples (same generator, same HLO,
/// same thresholds, same matcher).
#[test]
fn golden_predictions_match_python() {
    if !have_artifacts() {
        return;
    }
    let g = golden();
    let seed = g.get("test_seed").unwrap().as_u64().unwrap();
    let n = g.get("n").unwrap().as_usize().unwrap();
    let want: Vec<usize> = g
        .get("pred_fc_k1")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();

    let mut pipeline = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let (images, _) = workload(&pipeline.meta, n, seed);
    let got: Vec<usize> = pipeline
        .classify_batch(&images, n)
        .unwrap()
        .into_iter()
        .map(|c| c.top1().class)
        .collect();
    assert_eq!(got, want, "Rust FC predictions diverge from Python golden");
}

/// Feature values produced through PJRT must match the Python-side export
/// (catches constant corruption / layout mismatches).
#[test]
fn golden_features_match_python() {
    if !have_artifacts() {
        return;
    }
    let g = golden();
    let seed = g.get("test_seed").unwrap().as_u64().unwrap();
    let want: Vec<f32> = g
        .get("features_row0_first8")
        .unwrap()
        .as_f32_vec()
        .unwrap();
    let ones = g.get("binary_row0_ones").unwrap().as_usize().unwrap();

    let mut pipeline = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let (images, _) = workload(&pipeline.meta, 1, seed);
    let feats = pipeline.extract_features(&images, 1).unwrap();
    for (i, (g, w)) in feats.iter().zip(want.iter()).enumerate() {
        assert!(
            (g - w).abs() < 1e-3 + 1e-3 * w.abs(),
            "feature {i}: got {g}, want {w}"
        );
    }
    let bits = pipeline.store.binarize(&feats);
    let got_ones: usize = bits.iter().map(|&b| b as usize).sum();
    assert_eq!(got_ones, ones);
}

/// Ideal ACAM simulation must classify identically to the digital
/// feature-count path (the §III fidelity contract).
#[test]
fn ideal_acam_equals_feature_count() {
    if !have_artifacts() {
        return;
    }
    let mut fc = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let mut acam = Pipeline::new(&cfg(Backend::AcamSim)).unwrap();
    let (images, _) = workload(&fc.meta, 64, 1_000_003);
    let p_fc: Vec<usize> = fc
        .classify_batch(&images, 64)
        .unwrap()
        .into_iter()
        .map(|c| c.top1().class)
        .collect();
    let p_acam: Vec<usize> = acam
        .classify_batch(&images, 64)
        .unwrap()
        .into_iter()
        .map(|c| c.top1().class)
        .collect();
    assert_eq!(p_fc, p_acam);
}

/// §V.B: binary-domain similarity matching agrees with feature count.
#[test]
fn similarity_agrees_with_feature_count() {
    if !have_artifacts() {
        return;
    }
    let mut fc = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let mut sim = Pipeline::new(&cfg(Backend::Similarity)).unwrap();
    let (images, _) = workload(&fc.meta, 64, 1_000_003);
    let p_fc: Vec<usize> = fc
        .classify_batch(&images, 64)
        .unwrap()
        .iter()
        .map(|c| c.top1().class)
        .collect();
    let p_sim: Vec<usize> = sim
        .classify_batch(&images, 64)
        .unwrap()
        .iter()
        .map(|c| c.top1().class)
        .collect();
    let agree = p_fc.iter().zip(&p_sim).filter(|(a, b)| a == b).count();
    assert!(agree >= 62, "agreement {agree}/64"); // ties may split
}

/// Accuracy ordering from the paper: softmax head >= binary matching, and
/// both clearly above chance.
#[test]
fn accuracy_ordering_softmax_vs_matching() {
    if !have_artifacts() {
        return;
    }
    let mut soft = Pipeline::new(&cfg(Backend::Softmax)).unwrap();
    let mut fc = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let (images, labels) = workload(&soft.meta, 200, 1_000_003);
    let e_soft = soft.evaluate(&images, &labels, 32).unwrap();
    let e_fc = fc.evaluate(&images, &labels, 32).unwrap();
    assert!(e_soft.accuracy > 0.5, "softmax {:.3}", e_soft.accuracy);
    assert!(e_fc.accuracy > 0.5, "fc {:.3}", e_fc.accuracy);
    assert!(
        e_soft.accuracy >= e_fc.accuracy - 0.02,
        "softmax {:.3} vs fc {:.3}",
        e_soft.accuracy,
        e_fc.accuracy
    );
    // Energy: under the paper's published (fJ-effective) arithmetic the
    // dense head costs only ~0.16 nJ, *less* than the 1.45 nJ ACAM search —
    // the "ACAM beats the digital head" claim only holds under strict-pJ
    // units (where the head costs ~159 nJ).  Assert that strict-pJ ordering.
    let em = hec::energy::EnergyModel::default();
    let head_strict_nj = em.frontend_strict_pj_nj(soft.meta.macs.as_built.head_ops);
    let acam_nj = em.backend_nj(10, 784);
    assert!(
        head_strict_nj > acam_nj,
        "strict-pJ head {head_strict_nj} nJ must exceed ACAM {acam_nj} nJ"
    );
    // And the two deployments must report different energy ledgers.
    assert!((e_fc.total_energy_nj - e_soft.total_energy_nj).abs() > 1e-6);
}

/// All three Table II template sets load, validate, and classify.
#[test]
fn multi_template_sets_work() {
    if !have_artifacts() {
        return;
    }
    for k in 1..=3 {
        let mut c = cfg(Backend::FeatureCount);
        c.templates_per_class = k;
        let mut p = Pipeline::new(&c).unwrap();
        let (images, labels) = workload(&p.meta, 100, 1_000_003);
        let e = p.evaluate(&images, &labels, 32).unwrap();
        assert!(e.accuracy > 0.4, "k={k}: {:.3}", e.accuracy);
        let set = p.store.set(k).unwrap();
        assert_eq!(set.num_templates(), k * p.store.num_classes);
    }
}

/// The match_fc HLO artifact computes the same scores as the Rust matcher
/// (PJRT-only: executes an HLO artifact directly).
#[cfg(feature = "pjrt")]
#[test]
fn match_artifact_equals_rust_matcher() {
    use hec::runtime::Runtime;
    use hec::templates::TemplateStore;
    if !have_artifacts() {
        return;
    }
    let meta = Meta::load(ARTIFACTS).unwrap();
    let store = TemplateStore::load("artifacts/templates.json").unwrap();
    let set = store.set(1).unwrap();
    let mut rt = Runtime::new(ARTIFACTS).unwrap();
    let b = 8usize;
    let nf = meta.artifacts.n_features;
    let m = set.num_templates();

    // Build a batch of binary queries.
    let mut rng = hec::rng::Rng::new(11);
    let mut q = vec![0f32; b * nf];
    for v in q.iter_mut() {
        *v = f32::from(rng.u01() < 0.5);
    }
    let t: Vec<f32> = set
        .templates
        .iter()
        .flat_map(|row| row.iter().map(|&x| x as f32))
        .collect();

    let exe = rt.load(&format!("match_fc_b{b}")).unwrap();
    let scores = exe
        .run_f32(&[
            (&q, &[b as i64, nf as i64]),
            (&t, &[m as i64, nf as i64]),
        ])
        .unwrap();
    for i in 0..b {
        let bits: Vec<u8> = q[i * nf..(i + 1) * nf].iter().map(|&v| v as u8).collect();
        let want = hec::matching::feature_count_all_dense(&bits, set);
        for (j, &w) in want.iter().enumerate() {
            assert_eq!(scores[i * m + j] as u32, w, "query {i} template {j}");
        }
    }
}

/// The Pallas-lowered artifact and the jnp-lowered fast variant are
/// numerically identical (the L2 perf optimisation must not change math).
/// PJRT-only: the interp engine has no fast/pallas split, so comparing the
/// two configs under it would be vacuous.
#[cfg(feature = "pjrt")]
#[test]
fn pallas_and_fast_frontends_agree() {
    if !have_artifacts() {
        return;
    }
    let mut fast_cfg = cfg(Backend::FeatureCount);
    fast_cfg.engine = hec::config::Engine::Pjrt;
    fast_cfg.use_fast_frontend = true;
    let mut pallas_cfg = cfg(Backend::FeatureCount);
    pallas_cfg.engine = hec::config::Engine::Pjrt;
    pallas_cfg.use_fast_frontend = false;
    let mut fast = Pipeline::new(&fast_cfg).unwrap();
    let mut pallas = Pipeline::new(&pallas_cfg).unwrap();
    let (images, _) = workload(&fast.meta, 4, 1_000_003);
    let ff = fast.extract_features(&images, 4).unwrap();
    let fp = pallas.extract_features(&images, 4).unwrap();
    for (i, (a, b)) in ff.iter().zip(fp.iter()).enumerate() {
        assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs(), "feature {i}: {a} vs {b}");
    }
}

/// Front-end batch variants all produce consistent features for the same
/// image (padding must not leak into real rows).
#[test]
fn batch_variants_are_consistent() {
    if !have_artifacts() {
        return;
    }
    let mut pipeline = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let (images, _) = workload(&pipeline.meta, 1, 1_000_003);
    let nf = pipeline.meta.artifacts.n_features;
    // n=1 -> b1 artifact; duplicate the image 9x -> b32 artifact.
    let f1 = pipeline.extract_features(&images, 1).unwrap();
    let mut many = Vec::new();
    for _ in 0..9 {
        many.extend_from_slice(&images);
    }
    let f9 = pipeline.extract_features(&many, 9).unwrap();
    for i in 0..9 {
        for j in 0..nf {
            let a = f1[j];
            let b = f9[i * nf + j];
            assert!((a - b).abs() < 1e-4, "row {i} feat {j}: {a} vs {b}");
        }
    }
}

/// End-to-end serving: submit through the dynamic batcher, all responses
/// arrive, metrics add up.
#[test]
fn server_round_trip() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg(Backend::FeatureCount);
    c.batch.max_batch = 8;
    c.batch.max_wait_us = 500;
    let server = Server::start(c).unwrap();
    let handle = server.handle.clone();
    let meta = Meta::load(ARTIFACTS).unwrap();
    let (images, _) = workload(&meta, 16, 77);
    let img_len = meta.artifacts.image_size * meta.artifacts.image_size;

    let rxs: Vec<_> = (0..16)
        .map(|i| {
            handle
                .submit(hec::api::ClassifyRequest::new(
                    images[i * img_len..(i + 1) * img_len].to_vec(),
                ))
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let res = rx.recv().unwrap().unwrap();
        assert!(res.top1().class < 10);
        assert!(res.energy.total_nj() > 0.0);
    }
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.responses, 16);
    assert_eq!(snap.errors, 0);
    assert!(snap.batches >= 2); // 16 items with max_batch 8
    drop(handle);
    server.shutdown();
}

/// Bad image size is rejected before it reaches the queue.
#[test]
fn server_rejects_bad_shapes() {
    if !have_artifacts() {
        return;
    }
    let server = Server::start(cfg(Backend::FeatureCount)).unwrap();
    let err = server
        .handle
        .submit(hec::api::ClassifyRequest::new(vec![0.0; 17]))
        .err()
        .expect("bad shape must be rejected");
    assert_eq!(err.code, hec::api::ErrorCode::InvalidShape);
    server.shutdown();
}

/// Evaluation confusion matrix is consistent with its accuracy.
#[test]
fn evaluation_confusion_consistency() {
    if !have_artifacts() {
        return;
    }
    let mut p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let (images, labels) = workload(&p.meta, 100, 1_000_003);
    let e = p.evaluate(&images, &labels, 32).unwrap();
    let total: u64 = e.confusion.iter().flatten().sum();
    assert_eq!(total as usize, e.n);
    let diag: u64 = (0..10).map(|i| e.confusion[i][i]).sum();
    assert!((e.accuracy - diag as f64 / e.n as f64).abs() < 1e-9);
}

/// ACAM variability ablation: ideal accuracy >= heavily-degraded accuracy.
#[test]
fn acam_variability_degrades_gracefully() {
    if !have_artifacts() {
        return;
    }
    let run = |level: f64| {
        let mut c = cfg(Backend::AcamSim);
        c.acam.variability_level = level;
        let mut p = Pipeline::new(&c).unwrap();
        let (images, labels) = workload(&p.meta, 100, 1_000_003);
        p.evaluate(&images, &labels, 32).unwrap().accuracy
    };
    let ideal = run(0.0);
    let noisy = run(8.0);
    assert!(
        ideal >= noisy - 0.05,
        "ideal {ideal:.3} should not lose to heavily degraded {noisy:.3}"
    );
}

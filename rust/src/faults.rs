//! Deterministic fault injection and the back-end degradation ladder.
//!
//! RRAM conductances drift, stick, and get noisier with age; the 792x
//! energy win of the analogue back-end is only real while the array still
//! classifies correctly.  This module gives the serving stack a seeded,
//! replayable way to *inject* those failures while traffic is flowing, and
//! names the degradation states the coordinator walks through when its
//! canary probes detect them:
//!
//! * [`FaultPlan`] — a schedule of [`FaultEvent`]s keyed on the shard's
//!   served-request counter (a deterministic, sleep-free clock), parsed
//!   from a compact spec string (`HEC_FAULT_PLAN` / `faults.plan`);
//! * [`FaultInjector`] — the per-shard cursor over the plan: pops due
//!   events, owns its own RNG stream (never the array's — with faults
//!   disabled every existing RNG stream stays bitwise identical), and
//!   remembers stuck-cell sets so they survive re-programming (a stuck
//!   filament does not heal because you re-programmed the row);
//! * [`BackendState`] — the three-state ladder `Healthy` →
//!   `Reprogramming` → `DigitalFallback` driven by the canary state
//!   machine in `coordinator/shard.rs`.
//!
//! The module is deliberately free of coordinator/pipeline dependencies:
//! fault *application* (mutating the array, charging re-programming
//! energy) lives with the owners of that state.

use crate::acam::rram::{G_MAX, G_MIN};
use crate::rng::Rng;

/// One injectable failure mode.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Age the device corner: set the array + periphery variability to
    /// `Variability::at_level(level)` (retention drift, read noise, sense
    /// and WTA offsets all scale together; level 0 = ideal, 1 = typical).
    Drift { level: f64 },
    /// Escalate only the multiplicative conductance read noise to `sigma`
    /// (relative), leaving the rest of the corner untouched.
    ReadNoise { sigma: f64 },
    /// Stick `fraction` of all cells (drawn from the injector's RNG) at
    /// conductance `g`.  Sticky: re-applied after every re-programming.
    StuckCells { fraction: f64, g: f64 },
    /// Cooperative worker stall of `millis` before the next batch — the
    /// "wedged shard" scenario for deadline / spill testing.
    Stall { millis: u64 },
}

/// A [`FaultKind`] that fires once the shard has served `at_request`
/// requests.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub at_request: u64,
    pub kind: FaultKind,
}

/// A seeded, ordered schedule of fault events.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// Base seed for stuck-cell coordinate draws (mixed per shard).
    pub seed: u64,
    /// Events sorted by `at_request` (stable for equal keys).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a compact spec string: comma-separated `kind@request[=args]`
    /// events.
    ///
    /// * `drift@N=LEVEL` — variability corner to `at_level(LEVEL)`;
    /// * `noise@N=SIGMA` — read noise escalation;
    /// * `stuck@N=FRACTION[:G]` — stick cells (G in siemens, default
    ///   `G_MIN`, the high-resistance stuck state);
    /// * `stall@N=MILLIS` — worker stall.
    ///
    /// Whitespace around tokens is ignored; an all-whitespace spec is an
    /// empty plan.  Errors name the offending token.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            events.push(parse_event(tok)?);
        }
        events.sort_by_key(|e| e.at_request);
        Ok(FaultPlan { seed, events })
    }
}

fn parse_event(tok: &str) -> Result<FaultEvent, String> {
    let err = |why: &str| format!("fault event '{tok}': {why}");
    let (kind_s, rest) = tok
        .split_once('@')
        .ok_or_else(|| err("expected kind@request[=args]"))?;
    let (at_s, args) = match rest.split_once('=') {
        Some((a, b)) => (a, Some(b.trim())),
        None => (rest, None),
    };
    let at_request: u64 = at_s
        .trim()
        .parse()
        .map_err(|_| err("request index must be a non-negative integer"))?;
    let num = |name: &str| -> Result<f64, String> {
        let v: f64 = args
            .ok_or_else(|| err("missing '=args'"))?
            .parse()
            .map_err(|_| err("argument must be a number"))?;
        if !v.is_finite() || v < 0.0 {
            return Err(err(&format!("{name} must be finite and >= 0")));
        }
        Ok(v)
    };
    let kind = match kind_s.trim() {
        "drift" => FaultKind::Drift { level: num("level")? },
        "noise" => FaultKind::ReadNoise { sigma: num("sigma")? },
        "stuck" => {
            let args = args.ok_or_else(|| err("missing '=fraction[:g]'"))?;
            let (frac_s, g_s) = match args.split_once(':') {
                Some((f, g)) => (f, Some(g)),
                None => (args, None),
            };
            let fraction: f64 = frac_s
                .trim()
                .parse()
                .map_err(|_| err("fraction must be a number"))?;
            if !(0.0..=1.0).contains(&fraction) {
                return Err(err("fraction must be in [0, 1]"));
            }
            let g = match g_s {
                Some(g_s) => {
                    let g: f64 = g_s
                        .trim()
                        .parse()
                        .map_err(|_| err("conductance must be a number"))?;
                    if !(G_MIN..=G_MAX).contains(&g) {
                        return Err(err("conductance must be within the device window"));
                    }
                    g
                }
                None => G_MIN,
            };
            FaultKind::StuckCells { fraction, g }
        }
        "stall" => {
            let millis: u64 = args
                .ok_or_else(|| err("missing '=millis'"))?
                .parse()
                .map_err(|_| err("millis must be a non-negative integer"))?;
            FaultKind::Stall { millis }
        }
        other => return Err(err(&format!("unknown fault kind '{other}'"))),
    };
    Ok(FaultEvent { at_request, kind })
}

/// A stuck-cell set that has fired: coordinates plus the stuck conductance,
/// re-applied after every re-programming attempt.
#[derive(Debug, Clone)]
pub struct StuckSet {
    pub cells: Vec<(usize, usize)>,
    pub g: f64,
}

/// Per-shard cursor over a [`FaultPlan`].
///
/// Owns an RNG stream derived from `(plan.seed, shard)` so coordinate
/// draws never touch the array's search RNG.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    next: usize,
    rng: Rng,
    sticky: Vec<StuckSet>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan, shard: usize) -> Self {
        let seed = plan
            .seed
            .wrapping_add(0x9E37_79B9_7F4A_7C15)
            .wrapping_mul(2 * shard as u64 + 1);
        FaultInjector {
            plan,
            next: 0,
            rng: Rng::new(seed),
            sticky: Vec::new(),
        }
    }

    /// Pop every event whose `at_request` has been reached.
    pub fn due(&mut self, served: u64) -> Vec<FaultKind> {
        let mut fired = Vec::new();
        while let Some(e) = self.plan.events.get(self.next) {
            if e.at_request > served {
                break;
            }
            fired.push(e.kind.clone());
            self.next += 1;
        }
        fired
    }

    /// True once every event has fired.
    pub fn exhausted(&self) -> bool {
        self.next >= self.plan.events.len()
    }

    /// Draw the coordinate set for a `StuckCells` event over an
    /// `n_rows x width` array, record it as sticky, and return it.
    pub fn materialize_stuck(
        &mut self,
        n_rows: usize,
        width: usize,
        fraction: f64,
        g: f64,
    ) -> StuckSet {
        let mut cells = Vec::new();
        for r in 0..n_rows {
            for c in 0..width {
                if self.rng.u01() < fraction {
                    cells.push((r, c));
                }
            }
        }
        let set = StuckSet { cells, g };
        self.sticky.push(set.clone());
        set
    }

    /// Stuck-cell sets that must be re-applied after a re-programming.
    pub fn sticky_sets(&self) -> &[StuckSet] {
        &self.sticky
    }
}

/// The per-shard back-end degradation ladder.
///
/// `Healthy` serves through the configured analogue back-end.  When the
/// canary probe drops below threshold the shard enters `Reprogramming`
/// (re-fits the array, charging re-programming energy); a successful
/// verify promotes it back to `Healthy`, a failed one demotes it to
/// `DigitalFallback`, where ACAM-backed requests are served by the digital
/// matching reference — correct, but without the 1.45 nJ back-end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum BackendState {
    Healthy = 0,
    Reprogramming = 1,
    DigitalFallback = 2,
}

impl BackendState {
    /// Stable wire / metrics spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            BackendState::Healthy => "healthy",
            BackendState::Reprogramming => "reprogramming",
            BackendState::DigitalFallback => "digital_fallback",
        }
    }

    /// Inverse of the `repr(u8)` discriminant (atomics store the state as
    /// a `u8`); out-of-range values clamp to `DigitalFallback`, the most
    /// conservative reading.
    pub fn from_u8(v: u8) -> Self {
        match v {
            0 => BackendState::Healthy,
            1 => BackendState::Reprogramming,
            _ => BackendState::DigitalFallback,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind_and_sorts() {
        let p = FaultPlan::parse(" noise@40=0.2, drift@10=2.5 ,stuck@20=0.25:1e-5,stall@5=7 ", 9)
            .unwrap();
        assert_eq!(p.seed, 9);
        let at: Vec<u64> = p.events.iter().map(|e| e.at_request).collect();
        assert_eq!(at, vec![5, 10, 20, 40]);
        assert_eq!(p.events[0].kind, FaultKind::Stall { millis: 7 });
        assert_eq!(p.events[1].kind, FaultKind::Drift { level: 2.5 });
        assert_eq!(
            p.events[2].kind,
            FaultKind::StuckCells { fraction: 0.25, g: 1e-5 }
        );
        assert_eq!(p.events[3].kind, FaultKind::ReadNoise { sigma: 0.2 });
    }

    #[test]
    fn stuck_conductance_defaults_to_g_min() {
        let p = FaultPlan::parse("stuck@3=0.5", 0).unwrap();
        assert_eq!(
            p.events[0].kind,
            FaultKind::StuckCells { fraction: 0.5, g: G_MIN }
        );
    }

    #[test]
    fn empty_spec_is_empty_plan() {
        let p = FaultPlan::parse("  ", 1).unwrap();
        assert!(p.events.is_empty());
    }

    #[test]
    fn rejects_malformed_events() {
        for bad in [
            "drift",
            "drift@x=1",
            "drift@5",
            "drift@5=abc",
            "drift@5=-1",
            "noise@5=inf",
            "stuck@5=1.5",
            "stuck@5=0.5:1.0",
            "stall@5=-2",
            "melt@5=1",
        ] {
            let e = FaultPlan::parse(bad, 0).unwrap_err();
            assert!(e.contains("fault event"), "{bad}: {e}");
        }
    }

    #[test]
    fn injector_pops_due_events_once() {
        let p = FaultPlan::parse("drift@10=2.0,noise@10=0.1,stall@30=1", 3).unwrap();
        let mut inj = FaultInjector::new(p, 0);
        assert!(inj.due(9).is_empty());
        let fired = inj.due(10);
        assert_eq!(fired.len(), 2);
        assert!(inj.due(10).is_empty(), "events fire exactly once");
        assert!(!inj.exhausted());
        assert_eq!(inj.due(1000).len(), 1);
        assert!(inj.exhausted());
    }

    #[test]
    fn stuck_draws_are_deterministic_per_shard_and_sticky() {
        let p = FaultPlan::parse("stuck@1=0.3", 42).unwrap();
        let mut a = FaultInjector::new(p.clone(), 0);
        let mut b = FaultInjector::new(p.clone(), 0);
        let sa = a.materialize_stuck(10, 64, 0.3, G_MIN);
        let sb = b.materialize_stuck(10, 64, 0.3, G_MIN);
        assert_eq!(sa.cells, sb.cells, "same shard, same coordinates");
        assert!(!sa.cells.is_empty() && sa.cells.len() < 640);
        let mut c = FaultInjector::new(p, 1);
        let sc = c.materialize_stuck(10, 64, 0.3, G_MIN);
        assert_ne!(sa.cells, sc.cells, "different shards draw differently");
        assert_eq!(a.sticky_sets().len(), 1, "stuck sets are remembered");
    }

    #[test]
    fn backend_state_roundtrip() {
        for s in [
            BackendState::Healthy,
            BackendState::Reprogramming,
            BackendState::DigitalFallback,
        ] {
            assert_eq!(BackendState::from_u8(s as u8), s);
        }
        assert_eq!(BackendState::from_u8(7), BackendState::DigitalFallback);
        assert_eq!(BackendState::Healthy.as_str(), "healthy");
        assert_eq!(BackendState::DigitalFallback.as_str(), "digital_fallback");
    }
}

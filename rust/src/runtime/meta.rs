//! meta.json — the build-time pipeline's record of shapes, normalisation,
//! metrics and experiment data, consumed by the coordinator and benches.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::jsonlite::{self, Value};

#[derive(Debug, Clone)]
pub struct Norm {
    pub mean: f64,
    pub std: f64,
}

#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub train: usize,
    pub test: usize,
    pub source: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactsInfo {
    pub batch_sizes: Vec<usize>,
    pub n_features: usize,
    pub n_templates: usize,
    pub image_size: usize,
    pub use_pallas: bool,
}

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub accuracy: f64,
    pub f1: f64,
    pub precision: f64,
    pub recall: f64,
    pub params: u64,
    pub macs: u64,
}

#[derive(Debug, Clone)]
pub struct MatchingModes {
    pub feature_count_acc: f64,
    pub similarity_binary_acc: f64,
    pub agreement: f64,
}

#[derive(Debug, Clone)]
pub struct Experiments {
    pub table1: HashMap<String, Table1Row>,
    /// templates-per-class -> feature-count accuracy (Table II).
    pub table2_multi_template: HashMap<usize, f64>,
    /// "mean"/"median" -> downstream matching accuracy (Fig. 1).
    pub fig1_threshold_accuracy: HashMap<String, f64>,
    pub fig6_confusion: Vec<Vec<u64>>,
    pub fig7_per_class_accuracy: Vec<f64>,
    pub matching_modes: MatchingModes,
}

#[derive(Debug, Clone)]
pub struct ModelSummary {
    pub macs: u64,
    pub params: u64,
}

#[derive(Debug, Clone)]
pub struct AsBuilt {
    pub student: ModelSummary,
    pub teacher_gray: ModelSummary,
    pub teacher_color: ModelSummary,
    /// Sparsity-skipped MACs of the pruned conv stack (head excluded).
    pub student_effective: u64,
    /// Dense-head ops (removed by the ACAM; paid by the softmax baseline).
    pub head_ops: u64,
    pub student_params_actual: u64,
    pub achieved_sparsity: f64,
}

#[derive(Debug, Clone)]
pub struct MacsInfo {
    pub as_built: AsBuilt,
}

/// Parsed meta.json (the fields the runtime needs; the raw document keeps
/// the training log and config for humans).
#[derive(Debug, Clone)]
pub struct Meta {
    pub norm: Norm,
    pub dataset: DatasetInfo,
    pub artifacts: ArtifactsInfo,
    pub experiments: Experiments,
    pub macs: MacsInfo,
}

fn need<'a>(v: Option<&'a Value>, what: &str) -> Result<&'a Value> {
    v.ok_or_else(|| Error::Schema(format!("meta.json: missing {what}")))
}

fn num(v: &Value, what: &str) -> Result<f64> {
    v.as_f64()
        .ok_or_else(|| Error::Schema(format!("meta.json: {what} must be a number")))
}

fn summary(v: &Value, what: &str) -> Result<ModelSummary> {
    Ok(ModelSummary {
        macs: num(need(v.get("macs"), what)?, what)? as u64,
        params: num(need(v.get("params"), what)?, what)? as u64,
    })
}

impl Meta {
    /// Load `meta.json` when the artifacts directory has one, or fall back
    /// to the built-in [`Meta::synthetic`] record so the serving stack runs
    /// on a clean checkout with no artifacts at all.
    pub fn load_or_synthetic<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        if artifacts_dir.as_ref().join("meta.json").is_file() {
            Self::load(artifacts_dir)
        } else {
            Ok(Self::synthetic())
        }
    }

    /// Metadata for artifact-free serving: the synthetic-fallback student
    /// (see [`crate::runtime::backend::interp::SYNTH_FILTERS`]) against the
    /// synthetic dataset, with paper-scale teacher constants so the energy
    /// ledger stays meaningful.  Experiment tables are empty — they record
    /// build-time measurements that do not exist without `make artifacts`.
    pub fn synthetic() -> Self {
        use crate::energy::constants as ec;
        use crate::runtime::backend::interp::SYNTH_FILTERS;
        let [f1, f2, f3, f4] = SYNTH_FILTERS.map(|f| f as u64);
        // Eq. 13 over the synthetic stack at image size 32 (SAME convs at
        // 32/16/8 px, then the 2x2 VALID conv at 7 px).
        let conv_macs =
            32 * 32 * 9 * f1 + 16 * 16 * 9 * f1 * f2 + 8 * 8 * 9 * f2 * f3 + 7 * 7 * 4 * f3 * f4;
        let conv_params =
            9 * f1 + f1 + 9 * f1 * f2 + f2 + 9 * f2 * f3 + f3 + 4 * f3 * f4 + f4;
        let n_features = 7 * 7 * f4;
        let head_ops = n_features * 10 + 10;
        Meta {
            norm: Norm {
                mean: 0.5,
                std: 0.25,
            },
            dataset: DatasetInfo {
                train: 0,
                test: 0,
                source: "synthetic-fallback".into(),
            },
            artifacts: ArtifactsInfo {
                batch_sizes: vec![1, 8, 32],
                n_features: n_features as usize,
                n_templates: 10,
                image_size: 32,
                use_pallas: false,
            },
            experiments: Experiments {
                table1: HashMap::new(),
                table2_multi_template: HashMap::new(),
                fig1_threshold_accuracy: HashMap::new(),
                fig6_confusion: Vec::new(),
                fig7_per_class_accuracy: Vec::new(),
                matching_modes: MatchingModes {
                    feature_count_acc: 0.0,
                    similarity_binary_acc: 0.0,
                    agreement: 0.0,
                },
            },
            macs: MacsInfo {
                as_built: AsBuilt {
                    student: ModelSummary {
                        macs: conv_macs + head_ops,
                        params: conv_params + head_ops,
                    },
                    teacher_gray: ModelSummary {
                        macs: ec::TEACHER_GRAY.macs,
                        params: ec::TEACHER_GRAY.params,
                    },
                    teacher_color: ModelSummary {
                        macs: ec::TEACHER_COLOR.macs,
                        params: ec::TEACHER_COLOR.params,
                    },
                    // Synthetic weights are dense (nothing pruned): every
                    // conv MAC is effective.
                    student_effective: conv_macs,
                    head_ops,
                    student_params_actual: conv_params + head_ops,
                    achieved_sparsity: 0.0,
                },
            },
        }
    }

    pub fn load<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let path = artifacts_dir.as_ref().join("meta.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Artifact(format!("cannot read {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    /// Parse meta.json text (exposed for tests).
    pub fn parse(text: &str) -> Result<Self> {
        let doc = jsonlite::parse(text)?;

        let norm_v = need(doc.get("norm"), "norm")?;
        let norm = Norm {
            mean: num(need(norm_v.get("mean"), "norm.mean")?, "norm.mean")?,
            std: num(need(norm_v.get("std"), "norm.std")?, "norm.std")?,
        };

        let ds = need(doc.get("dataset"), "dataset")?;
        let dataset = DatasetInfo {
            train: num(need(ds.get("train"), "dataset.train")?, "train")? as usize,
            test: num(need(ds.get("test"), "dataset.test")?, "test")? as usize,
            source: need(ds.get("source"), "dataset.source")?
                .as_str()
                .unwrap_or("unknown")
                .to_string(),
        };

        let art = need(doc.get("artifacts"), "artifacts")?;
        let batch_sizes: Vec<usize> = need(art.get("batch_sizes"), "batch_sizes")?
            .as_array()
            .ok_or_else(|| Error::Schema("batch_sizes must be an array".into()))?
            .iter()
            .filter_map(Value::as_usize)
            .collect();
        if batch_sizes.is_empty() {
            return Err(Error::Artifact("meta.json has no batch sizes".into()));
        }
        let artifacts = ArtifactsInfo {
            batch_sizes,
            n_features: num(need(art.get("n_features"), "n_features")?, "n_features")? as usize,
            n_templates: num(need(art.get("n_templates"), "n_templates")?, "n_templates")?
                as usize,
            image_size: num(need(art.get("image_size"), "image_size")?, "image_size")? as usize,
            use_pallas: need(art.get("use_pallas"), "use_pallas")?
                .as_bool()
                .unwrap_or(false),
        };

        let exp = need(doc.get("experiments"), "experiments")?;
        let mut table1 = HashMap::new();
        for (name, row) in need(exp.get("table1"), "table1")?
            .as_object()
            .ok_or_else(|| Error::Schema("table1 must be an object".into()))?
        {
            table1.insert(
                name.clone(),
                Table1Row {
                    accuracy: num(need(row.get("accuracy"), "accuracy")?, "accuracy")?,
                    f1: num(need(row.get("f1"), "f1")?, "f1")?,
                    precision: num(need(row.get("precision"), "precision")?, "precision")?,
                    recall: num(need(row.get("recall"), "recall")?, "recall")?,
                    params: num(need(row.get("params"), "params")?, "params")? as u64,
                    macs: num(need(row.get("macs"), "macs")?, "macs")? as u64,
                },
            );
        }
        let mut table2 = HashMap::new();
        for (k, v) in need(exp.get("table2_multi_template"), "table2")?
            .as_object()
            .ok_or_else(|| Error::Schema("table2 must be an object".into()))?
        {
            if let (Ok(kk), Some(acc)) = (k.parse::<usize>(), v.as_f64()) {
                table2.insert(kk, acc);
            }
        }
        let mut fig1 = HashMap::new();
        for (k, v) in need(exp.get("fig1_threshold_accuracy"), "fig1")?
            .as_object()
            .ok_or_else(|| Error::Schema("fig1 must be an object".into()))?
        {
            if let Some(acc) = v.as_f64() {
                fig1.insert(k.clone(), acc);
            }
        }
        let fig6: Vec<Vec<u64>> = need(exp.get("fig6_confusion"), "fig6")?
            .as_array()
            .ok_or_else(|| Error::Schema("fig6 must be a matrix".into()))?
            .iter()
            .map(|row| {
                row.as_array()
                    .map(|r| r.iter().filter_map(Value::as_u64).collect())
                    .unwrap_or_default()
            })
            .collect();
        let fig7: Vec<f64> = need(exp.get("fig7_per_class_accuracy"), "fig7")?
            .as_array()
            .ok_or_else(|| Error::Schema("fig7 must be an array".into()))?
            .iter()
            .filter_map(Value::as_f64)
            .collect();
        let mm = need(exp.get("matching_modes"), "matching_modes")?;
        let matching_modes = MatchingModes {
            feature_count_acc: num(need(mm.get("feature_count_acc"), "fc acc")?, "fc")?,
            similarity_binary_acc: num(need(mm.get("similarity_binary_acc"), "sim acc")?, "sim")?,
            agreement: num(need(mm.get("agreement"), "agreement")?, "agreement")?,
        };

        let ab = need(doc.at(&["macs", "as_built"]), "macs.as_built")?;
        let as_built = AsBuilt {
            student: summary(need(ab.get("student"), "student")?, "student")?,
            teacher_gray: summary(need(ab.get("teacher_gray"), "teacher_gray")?, "teacher_gray")?,
            teacher_color: summary(
                need(ab.get("teacher_color"), "teacher_color")?,
                "teacher_color",
            )?,
            student_effective: num(
                need(ab.get("student_effective"), "student_effective")?,
                "student_effective",
            )? as u64,
            head_ops: ab
                .get("head_ops")
                .and_then(Value::as_u64)
                .unwrap_or(7_850),
            student_params_actual: num(
                need(ab.get("student_params_actual"), "student_params_actual")?,
                "student_params_actual",
            )? as u64,
            achieved_sparsity: num(
                need(ab.get("achieved_sparsity"), "achieved_sparsity")?,
                "achieved_sparsity",
            )?,
        };

        Ok(Meta {
            norm,
            dataset,
            artifacts,
            experiments: Experiments {
                table1,
                table2_multi_template: table2,
                fig1_threshold_accuracy: fig1,
                fig6_confusion: fig6,
                fig7_per_class_accuracy: fig7,
                matching_modes,
            },
            macs: MacsInfo { as_built },
        })
    }

    /// Smallest exported batch size >= n (or the largest available).
    pub fn batch_for(&self, n: usize) -> usize {
        let mut sizes = self.artifacts.batch_sizes.clone();
        sizes.sort_unstable();
        for &b in &sizes {
            if b >= n {
                return b;
            }
        }
        *sizes.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOY: &str = r#"{
        "norm": {"mean": 0.5, "std": 0.25},
        "dataset": {"train": 100, "test": 50, "source": "synthetic"},
        "artifacts": {"batch_sizes": [1, 8, 32], "n_features": 784,
                      "n_templates": 10, "image_size": 32, "use_pallas": true},
        "experiments": {
            "table1": {"teacher_gray": {"accuracy": 0.9, "f1": 0.9,
                "precision": 0.9, "recall": 0.9, "params": 100, "macs": 1000}},
            "table2_multi_template": {"1": 0.7, "2": 0.71, "3": 0.715},
            "fig1_threshold_accuracy": {"mean": 0.7, "median": 0.68},
            "fig6_confusion": [[5, 1], [2, 4]],
            "fig7_per_class_accuracy": [0.83, 0.66],
            "matching_modes": {"feature_count_acc": 0.7,
                "similarity_binary_acc": 0.7, "agreement": 1.0}
        },
        "macs": {"as_built": {
            "student": {"macs": 200, "params": 20, "layers": []},
            "teacher_gray": {"macs": 2000, "params": 200},
            "teacher_color": {"macs": 2100, "params": 210},
            "student_effective": 40,
            "student_params_actual": 20,
            "achieved_sparsity": 0.8
        }}
    }"#;

    #[test]
    fn parses_toy_meta() {
        let m = Meta::parse(TOY).unwrap();
        assert_eq!(m.norm.mean, 0.5);
        assert_eq!(m.artifacts.batch_sizes, vec![1, 8, 32]);
        assert_eq!(m.experiments.table2_multi_template[&2], 0.71);
        assert_eq!(m.experiments.fig6_confusion[1][0], 2);
        assert_eq!(m.macs.as_built.student_effective, 40);
        assert_eq!(m.experiments.table1["teacher_gray"].macs, 1000);
    }

    #[test]
    fn batch_for_picks_smallest_fit() {
        let m = Meta::parse(TOY).unwrap();
        assert_eq!(m.batch_for(1), 1);
        assert_eq!(m.batch_for(2), 8);
        assert_eq!(m.batch_for(9), 32);
        assert_eq!(m.batch_for(100), 32);
    }

    #[test]
    fn missing_field_is_schema_error() {
        let r = Meta::parse(r#"{"norm": {"mean": 1.0}}"#);
        assert!(r.is_err());
    }

    #[test]
    fn synthetic_meta_is_self_consistent() {
        use crate::runtime::backend::interp::SYNTH_FILTERS;
        let m = Meta::synthetic();
        assert_eq!(m.artifacts.n_features, 7 * 7 * SYNTH_FILTERS[3]);
        assert_eq!(m.artifacts.image_size, 32);
        assert_eq!(m.dataset.source, "synthetic-fallback");
        assert!(m.macs.as_built.student_effective > 0);
        assert!(m.norm.std > 0.0);
        assert_eq!(m.batch_for(1), 1);
        assert_eq!(m.batch_for(9), 32);
    }

    #[test]
    fn load_or_synthetic_falls_back_on_missing_dir() {
        let m = Meta::load_or_synthetic("/nonexistent-hec-artifacts").unwrap();
        assert_eq!(m.dataset.source, "synthetic-fallback");
    }
}

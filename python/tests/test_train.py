"""Distillation losses (Eq. 1-3), curriculum ordering (Eq. 4), Adam, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.train import (
    adam_init,
    adam_update,
    composite_loss,
    confusion_metrics,
    cross_entropy,
    curriculum_order,
    kd_loss,
)

RNG = np.random.default_rng(2)


def test_cross_entropy_perfect_prediction_near_zero():
    logits = jnp.asarray([[20.0, 0.0, 0.0], [0.0, 20.0, 0.0]])
    labels = jnp.asarray([0, 1])
    assert float(cross_entropy(logits, labels)) < 1e-6


def test_kd_loss_zero_when_student_equals_teacher():
    z = jnp.asarray(RNG.normal(size=(8, 10)).astype(np.float32))
    assert abs(float(kd_loss(z, z, temperature=4.0))) < 1e-5


def test_kd_loss_positive_when_different():
    zs = jnp.asarray(RNG.normal(size=(8, 10)).astype(np.float32))
    zt = jnp.asarray(RNG.normal(size=(8, 10)).astype(np.float32))
    assert float(kd_loss(zs, zt, temperature=4.0)) > 0


def test_kd_t2_scaling_keeps_gradients_comparable():
    """Hinton's T^2 factor: gradient magnitude should be O(1) across T."""
    zs = jnp.asarray(RNG.normal(size=(16, 10)).astype(np.float32))
    zt = jnp.asarray(RNG.normal(size=(16, 10)).astype(np.float32))
    g2 = jnp.abs(jax.grad(lambda z: kd_loss(z, zt, 2.0))(zs)).mean()
    g8 = jnp.abs(jax.grad(lambda z: kd_loss(z, zt, 8.0))(zs)).mean()
    # Without T^2 these differ by ~(8/2)^2 = 16x; with it, well within 4x.
    assert float(g2) / float(g8) < 4.0 and float(g8) / float(g2) < 4.0


def test_composite_loss_alpha_extremes():
    """Eq. 1: alpha=0 -> pure CE, alpha=1 -> pure KD."""
    zs = jnp.asarray(RNG.normal(size=(8, 10)).astype(np.float32))
    zt = jnp.asarray(RNG.normal(size=(8, 10)).astype(np.float32))
    y = jnp.asarray(RNG.integers(0, 10, size=8))
    assert_allclose(
        float(composite_loss(zs, zt, y, 0.0, 4.0)), float(cross_entropy(zs, y)), rtol=1e-6
    )
    assert_allclose(
        float(composite_loss(zs, zt, y, 1.0, 4.0)), float(kd_loss(zs, zt, 4.0)), rtol=1e-6
    )


def test_curriculum_orders_easy_first():
    """Eq. 4: samples the teacher nails come before ones it misses."""
    # Teacher confident-correct on sample 0, confident-wrong on sample 1.
    t_logits = np.array([[10.0, 0.0], [10.0, 0.0], [2.0, 0.0]], np.float32)
    labels = np.array([0, 1, 0])
    order = curriculum_order(t_logits, labels)
    assert order[0] == 0 and order[-1] == 1


def test_adam_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam_init(params)
    for _ in range(400):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adam_update(params, g, opt, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adam_bias_correction_first_step():
    """After one step with unit gradient, |delta| ~ lr (bias-corrected)."""
    params = {"w": jnp.asarray([0.0])}
    opt = adam_init(params)
    g = {"w": jnp.asarray([1.0])}
    new_params, _ = adam_update(params, g, opt, lr=0.1)
    assert_allclose(float(new_params["w"][0]), -0.1, rtol=1e-3)


def test_confusion_metrics_identity():
    cm = np.diag([5, 5, 5])
    m = confusion_metrics(cm)
    assert m["accuracy"] == 1.0 and m["f1"] == 1.0
    assert m["per_class_accuracy"] == [1.0, 1.0, 1.0]


def test_confusion_metrics_known_case():
    cm = np.array([[8, 2], [4, 6]])
    m = confusion_metrics(cm)
    assert_allclose(m["accuracy"], 0.7)
    assert_allclose(m["precision"], ((8 / 12) + (6 / 8)) / 2, rtol=1e-9)
    assert_allclose(m["recall"], (0.8 + 0.6) / 2, rtol=1e-9)

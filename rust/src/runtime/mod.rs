//! Execution runtime: build metadata, parameter sidecars, and the pluggable
//! front-end execution backends.
//!
//! * [`meta`] — `meta.json` (shapes, normalisation, experiment data) with a
//!   synthetic default for artifact-free serving;
//! * [`params`] — the `<name>.params.{json,bin}` weight-sidecar loader
//!   shared by every engine;
//! * [`backend`] — the [`FrontEnd`] trait and its implementations: the
//!   pure-Rust [`backend::interp::InterpBackend`] (default) and the
//!   HLO/PJRT [`backend::pjrt::PjrtBackend`] (cargo feature `pjrt`).
//!
//! The coordinator constructs an engine through [`backend::create`] and
//! only ever talks to the trait; swapping engines is a config change.

pub mod backend;
pub mod meta;
pub mod params;

pub use backend::{create as create_backend, FrontEnd};
pub use meta::Meta;
pub use params::ParamArray;

#[cfg(feature = "pjrt")]
pub use backend::pjrt::{Executable, Runtime};

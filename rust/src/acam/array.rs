//! ACAM array: rows of cells sharing a matchline, simulated with explicit
//! RC timesteps, sensed by per-row amplifiers (Fig. 3's first layer).
//!
//! The search is the paper's "massively parallel compare": every cell of
//! every row evaluates the query simultaneously; each row's matchline
//! integrates its cells' currents; the sense amplifier converts time-to-
//! charge into the row's analogue similarity.  With the 6T4R charging cell
//! the matchline voltage after the evaluation window is monotone in the
//! number of matching cells, so the downstream WTA computes exactly
//! Eq. 8 + Eq. 12.


use super::cell::{AcamCell, CellKind, I_LIMIT};
use super::variability::Variability;
use super::VDD;

/// Electrical configuration of the array periphery.
#[derive(Debug, Clone)]
pub struct ArrayConfig {
    pub kind: CellKind,
    /// Matchline capacitance per attached cell (F). 5 fF/cell is typical
    /// for a 180 nm metal line plus drain loading.
    pub c_ml_per_cell: f64,
    /// Evaluation window (s).
    pub t_eval: f64,
    /// Simulation timestep (s).
    pub dt: f64,
    /// Matchline leakage resistance (ohm) — bounds the voltage at long t.
    pub r_leak: f64,
    /// Sense-amp reference as a fraction of VDD (match/mismatch decision).
    pub sense_ref: f64,
    /// Per-search per-cell energy (fJ) — the Section III-B figure.
    pub cell_energy_fj: f64,
}

impl Default for ArrayConfig {
    fn default() -> Self {
        ArrayConfig {
            kind: CellKind::Charging6T4R,
            c_ml_per_cell: 5e-15,
            t_eval: 20e-9,
            dt: 0.5e-9,
            r_leak: 5e8,
            sense_ref: 0.5,
            cell_energy_fj: 185.0,
        }
    }
}

/// Result of one parallel search.
#[derive(Debug, Clone)]
pub struct SearchOutput {
    /// Per-row analogue similarity in [0, 1] (matchline voltage / VDD for
    /// the charging cell; min of the two precharged lines for 3T1R).
    pub similarity: Vec<f64>,
    /// Per-row sense-amp digital match flags.
    pub matched: Vec<bool>,
    /// Per-row count of matching cells (diagnostic; what Eq. 8 counts).
    pub match_counts: Vec<u32>,
    /// Energy consumed by this search (nJ): cells x 185 fJ.
    pub energy_nj: f64,
}

/// The array: `rows x width` cells (one row per stored template).
pub struct AcamArray {
    pub config: ArrayConfig,
    pub variability: Variability,
    rows: Vec<Vec<AcamCell>>,
    rng: crate::rng::Rng,
}

impl AcamArray {
    /// Build from per-row windows: `windows[r] = (lo[], hi[])` in volts.
    pub fn from_windows(
        config: ArrayConfig,
        variability: Variability,
        windows: &[(Vec<f64>, Vec<f64>)],
        seed: u64,
    ) -> Self {
        let mut rng = crate::rng::Rng::new(seed);
        let rows = windows
            .iter()
            .map(|(lo, hi)| {
                lo.iter()
                    .zip(hi.iter())
                    .map(|(&l, &h)| AcamCell::program(config.kind, l, h, &variability, &mut rng))
                    .collect()
            })
            .collect();
        AcamArray {
            config,
            variability,
            rows,
            rng,
        }
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn width(&self) -> usize {
        self.rows.first().map_or(0, Vec::len)
    }

    /// One massively-parallel search of `query_v` (volts, one per column).
    ///
    /// Timestepped matchline integration:
    /// * 6T4R: `C dV/dt = I_match - V / R_leak`, V(0) = 0 (discharged init);
    /// * 3T1R: both lines precharged to VDD, mismatch currents pull down:
    ///   `C dV/dt = -I_dis - (V - VDD) / R_leak`.
    pub fn search(&mut self, query_v: &[f64]) -> SearchOutput {
        assert_eq!(query_v.len(), self.width(), "query width mismatch");
        let n_rows = self.num_rows();
        let width = self.width();
        let c_ml = self.config.c_ml_per_cell * width as f64;
        let steps = (self.config.t_eval / self.config.dt).ceil() as usize;

        let mut similarity = Vec::with_capacity(n_rows);
        let mut matched = Vec::with_capacity(n_rows);
        let mut match_counts = Vec::with_capacity(n_rows);

        let sense_sigma = self.variability.sense_offset_sigma * VDD;

        for row in &self.rows {
            // Evaluate every cell once (the physical compare is static
            // during the evaluation window).
            let mut i_charge = 0f64;
            let mut i_dis_low = 0f64;
            let mut i_dis_high = 0f64;
            let mut count = 0u32;
            for (cell, &v) in row.iter().zip(query_v.iter()) {
                let r = cell.response(v, &self.variability, &mut self.rng);
                i_charge += r.i_charge;
                i_dis_low += r.i_dis_low;
                i_dis_high += r.i_dis_high;
                count += u32::from(r.matched);
            }

            let sim = match self.config.kind {
                // The 9T4R cell grades `i_charge` per cell but still drives
                // one matchline from 0 V, so it shares the charging
                // integration with the 6T4R design.
                CellKind::Charging6T4R | CellKind::Analogue9T4R => {
                    // Integrate the single matchline from 0 V.
                    let mut v_ml = 0f64;
                    for _ in 0..steps {
                        let dv = (i_charge - v_ml / self.config.r_leak) / c_ml;
                        v_ml = (v_ml + dv * self.config.dt).clamp(0.0, VDD);
                    }
                    v_ml / VDD
                }
                CellKind::Precharging3T1R => {
                    // Integrate both precharged lines downward.
                    let mut v_lo = VDD;
                    let mut v_hi = VDD;
                    for _ in 0..steps {
                        let dvl = (-i_dis_low - (v_lo - VDD) / self.config.r_leak) / c_ml;
                        let dvh = (-i_dis_high - (v_hi - VDD) / self.config.r_leak) / c_ml;
                        v_lo = (v_lo + dvl * self.config.dt).clamp(0.0, VDD);
                        v_hi = (v_hi + dvh * self.config.dt).clamp(0.0, VDD);
                    }
                    // A template matches to the degree *neither* line dropped.
                    v_lo.min(v_hi) / VDD
                }
            };

            let sense_ref = if sense_sigma > 0.0 {
                self.config.sense_ref + self.rng.normal(0.0, sense_sigma) / VDD
            } else {
                self.config.sense_ref
            };
            similarity.push(sim);
            matched.push(sim >= sense_ref);
            match_counts.push(count);
        }

        SearchOutput {
            energy_nj: (n_rows * width) as f64 * self.config.cell_energy_fj * 1e-6,
            similarity,
            matched,
            match_counts,
        }
    }

    /// Stuck-at-G fault injection: freeze each listed `(row, col)` cell's
    /// RRAM devices at conductance `g` (see [`AcamCell::stick_at`]).
    /// Out-of-range coordinates are ignored; returns the number of cells
    /// actually stuck.  Selection is the caller's job (the fault injector
    /// draws coordinates from its own RNG so the array's search stream is
    /// untouched).
    pub fn stick_cells(&mut self, cells: &[(usize, usize)], g: f64) -> usize {
        let mut stuck = 0;
        for &(r, c) in cells {
            if let Some(cell) = self.rows.get_mut(r).and_then(|row| row.get_mut(c)) {
                cell.stick_at(g);
                stuck += 1;
            }
        }
        stuck
    }

    /// Full-row charge saturation check: with all `width` cells matching and
    /// the default periphery, the matchline must reach the sense reference
    /// within the evaluation window (design-point sanity, used in tests and
    /// calibration).
    pub fn full_match_headroom(&self) -> f64 {
        let width = self.width().max(1);
        let c_ml = self.config.c_ml_per_cell * width as f64;
        // Linear-charge estimate: V = I_total * t / C.
        let v = I_LIMIT * width as f64 * self.config.t_eval / c_ml;
        v.min(VDD) / (self.config.sense_ref * VDD)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Binary-template helper: windows [V(b-0.5), V(b+0.5)].
    fn binary_windows(templates: &[Vec<u8>]) -> Vec<(Vec<f64>, Vec<f64>)> {
        use super::super::feature_to_voltage as v;
        templates
            .iter()
            .map(|t| {
                let lo = t.iter().map(|&b| v(b as f32 - 0.5)).collect();
                let hi = t.iter().map(|&b| v(b as f32 + 0.5)).collect();
                (lo, hi)
            })
            .collect()
    }

    fn ideal_array(templates: &[Vec<u8>], kind: CellKind) -> AcamArray {
        let cfg = ArrayConfig {
            kind,
            ..Default::default()
        };
        AcamArray::from_windows(cfg, Variability::ideal(), &binary_windows(templates), 7)
    }

    #[test]
    fn similarity_monotone_in_match_count_6t4r() {
        // Rows engineered to match 64, 32, 0 of 64 query bits.
        let q: Vec<u8> = vec![1; 64];
        let t_full = vec![1u8; 64];
        let mut t_half = vec![1u8; 64];
        for b in t_half.iter_mut().take(32) {
            *b = 0;
        }
        let t_none = vec![0u8; 64];
        let mut arr = ideal_array(&[t_full, t_half, t_none], CellKind::Charging6T4R);
        let qv: Vec<f64> = q.iter().map(|&b| super::super::feature_to_voltage(b as f32)).collect();
        let out = arr.search(&qv);
        assert_eq!(out.match_counts, vec![64, 32, 0]);
        assert!(out.similarity[0] > out.similarity[1]);
        assert!(out.similarity[1] > out.similarity[2]);
    }

    #[test]
    fn ideal_match_counts_equal_eq8() {
        let templates: Vec<Vec<u8>> = (0..4)
            .map(|r| (0..32).map(|i| ((i + r) % 3 == 0) as u8).collect())
            .collect();
        let q: Vec<u8> = (0..32).map(|i| (i % 2 == 0) as u8).collect();
        let mut arr = ideal_array(&templates, CellKind::Charging6T4R);
        let qv: Vec<f64> = q.iter().map(|&b| super::super::feature_to_voltage(b as f32)).collect();
        let out = arr.search(&qv);
        for (r, t) in templates.iter().enumerate() {
            let eq8: u32 = q.iter().zip(t.iter()).map(|(a, b)| u32::from(a == b)).sum();
            assert_eq!(out.match_counts[r], eq8, "row {r}");
        }
    }

    #[test]
    fn precharging_3t1r_full_match_stays_high() {
        let t = vec![1u8, 0, 1, 0, 1, 0, 1, 0];
        let mut arr = ideal_array(&[t.clone()], CellKind::Precharging3T1R);
        let qv: Vec<f64> = t.iter().map(|&b| super::super::feature_to_voltage(b as f32)).collect();
        let out = arr.search(&qv);
        assert!(out.similarity[0] > 0.95, "{}", out.similarity[0]);
        assert!(out.matched[0]);
    }

    #[test]
    fn precharging_3t1r_mismatch_drops() {
        let t = vec![1u8; 8];
        let mut arr = ideal_array(&[t], CellKind::Precharging3T1R);
        let qv = vec![super::super::feature_to_voltage(0.0); 8]; // all bits wrong
        let out = arr.search(&qv);
        assert!(out.similarity[0] < 0.5, "{}", out.similarity[0]);
        assert!(!out.matched[0]);
    }

    #[test]
    fn energy_is_cells_times_185fj() {
        let templates = vec![vec![0u8; 784]; 10];
        let mut arr = ideal_array(&templates, CellKind::Charging6T4R);
        let out = arr.search(&vec![super::super::feature_to_voltage(0.0); 784]);
        // 10 x 784 x 185 fJ = 1.4504 nJ (Eq. 14)
        assert!((out.energy_nj - 1.4504).abs() < 0.001, "{}", out.energy_nj);
    }

    #[test]
    fn analogue_9t4r_matches_eq8_on_binary_queries() {
        // Binary query voltages sit 1 V from the wrong window — far past
        // the 9T4R roll-off — so ideal match counts and the monotone
        // similarity ordering both survive the graded cell.
        let q: Vec<u8> = vec![1; 64];
        let t_full = vec![1u8; 64];
        let mut t_half = vec![1u8; 64];
        for b in t_half.iter_mut().take(32) {
            *b = 0;
        }
        let t_none = vec![0u8; 64];
        let mut arr = ideal_array(&[t_full, t_half, t_none], CellKind::Analogue9T4R);
        let qv: Vec<f64> = q.iter().map(|&b| super::super::feature_to_voltage(b as f32)).collect();
        let out = arr.search(&qv);
        assert_eq!(out.match_counts, vec![64, 32, 0]);
        assert!(out.similarity[0] > out.similarity[1]);
        assert!(out.similarity[1] > out.similarity[2]);
    }

    #[test]
    fn full_match_headroom_at_design_point() {
        let templates = vec![vec![1u8; 784]];
        let arr = ideal_array(&templates, CellKind::Charging6T4R);
        assert!(arr.full_match_headroom() >= 1.0);
    }

    #[test]
    fn stuck_cells_stop_matching_either_bit() {
        let t = vec![1u8; 16];
        let mut arr = ideal_array(&[t.clone()], CellKind::Charging6T4R);
        let qv: Vec<f64> = t.iter().map(|&b| super::super::feature_to_voltage(b as f32)).collect();
        assert_eq!(arr.search(&qv).match_counts, vec![16]);
        let coords: Vec<(usize, usize)> = (0..8).map(|c| (0, c)).collect();
        assert_eq!(arr.stick_cells(&coords, super::super::rram::G_MIN), 8);
        let out = arr.search(&qv);
        assert_eq!(out.match_counts, vec![8], "stuck cells must reject the query bit");
        // Out-of-range coordinates are ignored, not a panic.
        assert_eq!(arr.stick_cells(&[(5, 0), (0, 99)], super::super::rram::G_MIN), 0);
    }

    #[test]
    #[should_panic]
    fn wrong_query_width_panics() {
        let mut arr = ideal_array(&[vec![1u8; 8]], CellKind::Charging6T4R);
        arr.search(&[0.0; 4]);
    }
}

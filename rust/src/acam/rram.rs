//! RRAM device model: programmable non-volatile conductance with
//! programming variability, read noise and retention drift.
//!
//! Calibrated to the in-house devices referenced by the paper [26]:
//! HRS/LRS window of roughly 1 µS – 100 µS, log-normal programming spread.


use super::variability::Variability;

/// Low-conductance bound (high-resistance state), siemens.
pub const G_MIN: f64 = 1e-6;
/// High-conductance bound (low-resistance state), siemens.
pub const G_MAX: f64 = 1e-4;

/// One two-terminal RRAM device.
#[derive(Debug, Clone)]
pub struct RramDevice {
    /// Programmed conductance (S), fixed after programming
    /// (program-once-read-many).
    g: f64,
    /// Target the programming aimed at (kept for diagnostics).
    target: f64,
}

impl RramDevice {
    /// Program the device toward `target` conductance through the
    /// variability model (log-normal multiplicative error — the standard
    /// empirical model for filamentary RRAM programming spread).
    pub fn program(target: f64, var: &Variability, rng: &mut crate::rng::Rng) -> Self {
        let target = target.clamp(G_MIN, G_MAX);
        let g = if var.program_sigma > 0.0 {
            (target * rng.normal(0.0, var.program_sigma).exp()).clamp(G_MIN, G_MAX)
        } else {
            target
        };
        RramDevice { g, target }
    }

    /// Ideal programming (zero spread) — the software-calibration reference.
    pub fn ideal(target: f64) -> Self {
        let target = target.clamp(G_MIN, G_MAX);
        RramDevice { g: target, target }
    }

    /// Read the conductance with read noise and retention drift applied.
    ///
    /// Drift: G(t) = G0 * (t / t0)^(-nu) for t > t0 (power-law retention
    /// loss); `age_hours` selects the read time.
    pub fn read(&self, var: &Variability, rng: &mut crate::rng::Rng) -> f64 {
        let mut g = self.g;
        if var.drift_nu > 0.0 && var.age_hours > 1.0 {
            g *= var.age_hours.powf(-var.drift_nu);
        }
        if var.read_sigma > 0.0 {
            g *= 1.0 + rng.normal(0.0, var.read_sigma);
        }
        g.clamp(G_MIN, G_MAX)
    }

    /// Force the stored conductance to `g` (clamped to the device window),
    /// leaving the programming target untouched.  Models a stuck-at fault:
    /// the filament is frozen at `g` and subsequent re-programming cannot
    /// move it (fault injection re-applies this after every re-program).
    pub fn force_conductance(&mut self, g: f64) {
        self.g = g.clamp(G_MIN, G_MAX);
    }

    /// Programmed conductance without noise (diagnostics).
    pub fn conductance(&self) -> f64 {
        self.g
    }

    /// Absolute programming error relative to target (diagnostics).
    pub fn program_error(&self) -> f64 {
        (self.g - self.target).abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
        
    #[test]
    fn ideal_program_is_exact() {
        let d = RramDevice::ideal(5e-5);
        assert_eq!(d.conductance(), 5e-5);
        assert_eq!(d.program_error(), 0.0);
    }

    #[test]
    fn program_clamps_to_device_window() {
        assert_eq!(RramDevice::ideal(1.0).conductance(), G_MAX);
        assert_eq!(RramDevice::ideal(0.0).conductance(), G_MIN);
    }

    #[test]
    fn programming_spread_scales_with_sigma() {
        let mut rng = crate::rng::Rng::new(0);
        let var_lo = Variability { program_sigma: 0.01, ..Default::default() };
        let var_hi = Variability { program_sigma: 0.3, ..Default::default() };
        let spread = |v: &Variability, rng: &mut crate::rng::Rng| {
            let errs: Vec<f64> = (0..200)
                .map(|_| RramDevice::program(1e-5, v, rng).program_error())
                .collect();
            errs.iter().sum::<f64>() / errs.len() as f64
        };
        let lo = spread(&var_lo, &mut rng);
        let hi = spread(&var_hi, &mut rng);
        assert!(hi > lo * 5.0, "lo={lo} hi={hi}");
    }

    #[test]
    fn read_noise_zero_is_deterministic() {
        let mut rng = crate::rng::Rng::new(1);
        let d = RramDevice::ideal(2e-5);
        let v = Variability::default();
        assert_eq!(d.read(&v, &mut rng), 2e-5);
    }

    #[test]
    fn drift_reduces_conductance() {
        let mut rng = crate::rng::Rng::new(2);
        let d = RramDevice::ideal(5e-5);
        let aged = Variability { drift_nu: 0.05, age_hours: 1000.0, ..Default::default() };
        let g_aged = d.read(&aged, &mut rng);
        assert!(g_aged < 5e-5);
        assert!(g_aged > G_MIN);
    }

    #[test]
    fn read_respects_device_window() {
        let mut rng = crate::rng::Rng::new(3);
        let d = RramDevice::ideal(G_MAX);
        let noisy = Variability { read_sigma: 0.5, ..Default::default() };
        for _ in 0..100 {
            let g = d.read(&noisy, &mut rng);
            assert!((G_MIN..=G_MAX).contains(&g));
        }
    }
}

"""The python suite exercises the jax training/compile stack; skip the whole
directory when jax is absent (CI's python job runs without the training
stack installed — the Rust serving stack is verified independently)."""

import pytest

pytest.importorskip("jax", reason="python test suite requires jax")

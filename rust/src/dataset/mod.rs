//! Dataset substrate: the synthetic CIFAR-like workload generator.
//!
//! The serving benches and examples need an input distribution identical to
//! what the Python pipeline trained on.  [`synthetic`] is a line-for-line
//! mirror of `python/compile/data.py` — the same SplitMix64-seeded LCG, the
//! same ten class recipes — so sample *i* of class *c* is the same image in
//! both languages (pinned by golden-value tests on both sides).

pub mod synthetic;

pub use synthetic::{render, Lcg, SyntheticDataset, IMAGE_SIZE, NUM_CLASSES};

/// Paper Section IV-A grayscale weights: Y = 0.2989 R + 0.5870 G + 0.1140 B.
pub const GRAY_WEIGHTS: [f32; 3] = [0.2989, 0.5870, 0.1140];

/// Convert an interleaved RGB image (HWC, values in [0,1]) to grayscale.
pub fn to_grayscale(rgb: &[f32], pixels: usize) -> Vec<f32> {
    assert_eq!(rgb.len(), pixels * 3);
    (0..pixels)
        .map(|i| {
            GRAY_WEIGHTS[0] * rgb[3 * i]
                + GRAY_WEIGHTS[1] * rgb[3 * i + 1]
                + GRAY_WEIGHTS[2] * rgb[3 * i + 2]
        })
        .collect()
}

/// CIFAR-10 class names (the labels the paper classifies).
pub const CLASS_NAMES: [&str; 10] = [
    "airplane",
    "automobile",
    "bird",
    "cat",
    "deer",
    "dog",
    "frog",
    "horse",
    "ship",
    "truck",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grayscale_formula() {
        let rgb = [1.0f32, 1.0, 1.0, 0.5, 0.0, 0.0];
        let g = to_grayscale(&rgb, 2);
        assert!((g[0] - 0.9999).abs() < 1e-4);
        assert!((g[1] - 0.2989 * 0.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn grayscale_wrong_len_panics() {
        to_grayscale(&[0.0; 5], 2);
    }
}

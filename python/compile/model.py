"""L2 — JAX model definitions: teacher ResNet and Fig.-5 student CNN.

Models are pure functional pytrees: ``init_*`` builds the parameter dict,
``*_apply`` runs the forward pass.  BatchNorm keeps a separate *state* pytree
(running mean/var) threaded through training and frozen at export.

The student forward has a ``use_pallas`` switch: the training loop uses the
pure-jnp reference path (interpret-mode Pallas is orders of magnitude slower
than XLA on CPU), while the AOT export (aot.py) lowers the Pallas path so the
kernel's tiling structure lands in the shipped HLO.  Both paths are asserted
numerically identical in python/tests/test_model.py.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import StudentConfig, TeacherConfig
from .kernels import conv2d as pallas_conv2d
from .kernels import matmul as pallas_matmul
from .kernels import ref

Params = Dict
State = Dict

# ---------------------------------------------------------------------------
# Initialisers / primitive layers
# ---------------------------------------------------------------------------


def _he_conv(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = np.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def _he_dense(key, din, dout):
    std = np.sqrt(2.0 / din)
    return jax.random.normal(key, (din, dout), jnp.float32) * std


def init_conv(key, kh, kw, cin, cout) -> Params:
    return {"w": _he_conv(key, kh, kw, cin, cout), "b": jnp.zeros((cout,), jnp.float32)}


def init_bn(c) -> Tuple[Params, State]:
    return (
        {"gamma": jnp.ones((c,), jnp.float32), "beta": jnp.zeros((c,), jnp.float32)},
        {"mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)},
    )


def init_dense(key, din, dout) -> Params:
    return {"w": _he_dense(key, din, dout), "b": jnp.zeros((dout,), jnp.float32)}


def conv_apply(p: Params, x, padding="SAME", stride=1, use_pallas=False):
    """Conv + bias.  Stride handled by slicing the SAME output (stride only
    appears in the teacher, which always runs the jnp path)."""
    if use_pallas:
        y = pallas_conv2d(x, p["w"], padding)
    else:
        y = jax.lax.conv_general_dilated(
            x,
            p["w"],
            window_strides=(stride, stride),
            padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return y + p["b"]
    if stride != 1:
        y = y[:, ::stride, ::stride, :]
    return y + p["b"]


BN_MOMENTUM = 0.9
BN_EPS = 1e-5


def bn_apply(p: Params, s: State, x, training: bool):
    """BatchNorm over NHW; returns (y, new_state)."""
    if training:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_s = {
            "mean": BN_MOMENTUM * s["mean"] + (1 - BN_MOMENTUM) * mean,
            "var": BN_MOMENTUM * s["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var, new_s = s["mean"], s["var"], s
    y = (x - mean) * jax.lax.rsqrt(var + BN_EPS) * p["gamma"] + p["beta"]
    return y, new_s


def dense_apply(p: Params, x, use_pallas=False):
    y = pallas_matmul(x, p["w"]) if use_pallas else jnp.dot(x, p["w"])
    return y + p["b"]


# ---------------------------------------------------------------------------
# Student CNN (Fig. 5)
# ---------------------------------------------------------------------------
#
#   conv 3x3x32 SAME - BN - ReLU - maxpool2   -> 16x16x32
#   conv 3x3x128 SAME - BN - ReLU - maxpool2  -> 8x8x128
#   conv 3x3x256 SAME - ReLU                  -> 8x8x256
#   conv 2x2x16 VALID - ReLU                  -> 7x7x16 -> flatten 784
#   [softmax head: dense 784 -> 10]           (baseline classifier only)


def init_student(cfg: StudentConfig, key, in_channels=1, num_classes=10):
    f1, f2, f3, f4 = cfg.filters
    k = jax.random.split(key, 5)
    bn1_p, bn1_s = init_bn(f1)
    bn2_p, bn2_s = init_bn(f2)
    params = {
        "conv1": init_conv(k[0], 3, 3, in_channels, f1),
        "bn1": bn1_p,
        "conv2": init_conv(k[1], 3, 3, f1, f2),
        "bn2": bn2_p,
        "conv3": init_conv(k[2], 3, 3, f2, f3),
        "conv4": init_conv(k[3], 2, 2, f3, f4),
        "head": init_dense(k[4], cfg.feature_dim, num_classes),
    }
    state = {"bn1": bn1_s, "bn2": bn2_s}
    return params, state


def student_features(params, state, x, training=False, use_pallas=False):
    """Front-end feature extractor: x [B,32,32,1] -> features [B,784].

    This is exactly the tensor the ACAM back-end consumes (the paper's
    "flattened feature map used as a query key").
    """
    h = conv_apply(params["conv1"], x, "SAME", use_pallas=use_pallas)
    h, s1 = bn_apply(params["bn1"], state["bn1"], h, training)
    h = ref.maxpool2(jax.nn.relu(h))
    h = conv_apply(params["conv2"], h, "SAME", use_pallas=use_pallas)
    h, s2 = bn_apply(params["bn2"], state["bn2"], h, training)
    h = ref.maxpool2(jax.nn.relu(h))
    h = jax.nn.relu(conv_apply(params["conv3"], h, "SAME", use_pallas=use_pallas))
    h = jax.nn.relu(conv_apply(params["conv4"], h, "VALID", use_pallas=use_pallas))
    feats = h.reshape(h.shape[0], -1)
    return feats, {"bn1": s1, "bn2": s2}


def student_logits(params, state, x, training=False, use_pallas=False):
    """Full student with the baseline softmax head: -> logits [B,10]."""
    feats, new_s = student_features(params, state, x, training, use_pallas)
    return dense_apply(params["head"], feats, use_pallas=use_pallas), new_s


def student_param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Teacher ResNet (Section IV-B: 3 stages, 2x3x3 convs per block, identity /
# 1x1-projection shortcuts, GAP + dense head)
# ---------------------------------------------------------------------------


def init_teacher(cfg: TeacherConfig, key, in_channels=1, num_classes=10):
    widths = (cfg.width, cfg.width * 2, cfg.width * 4)
    keys = iter(jax.random.split(key, 4 + 6 * 3 * cfg.blocks_per_stage))
    bn0_p, bn0_s = init_bn(widths[0])
    params = {"stem": init_conv(next(keys), 3, 3, in_channels, widths[0]), "bn0": bn0_p}
    state = {"bn0": bn0_s}
    cin = widths[0]
    for si, w in enumerate(widths):
        for bi in range(cfg.blocks_per_stage):
            name = f"s{si}b{bi}"
            bna_p, bna_s = init_bn(w)
            bnb_p, bnb_s = init_bn(w)
            blk = {
                "conv_a": init_conv(next(keys), 3, 3, cin, w),
                "bn_a": bna_p,
                "conv_b": init_conv(next(keys), 3, 3, w, w),
                "bn_b": bnb_p,
            }
            if cin != w:
                blk["proj"] = init_conv(next(keys), 1, 1, cin, w)
            params[name] = blk
            state[name] = {"bn_a": bna_s, "bn_b": bnb_s}
            cin = w
    params["head"] = init_dense(next(keys), widths[-1], num_classes)
    return params, state


def _teacher_block(blk, bst, x, stride, training):
    h = conv_apply(blk["conv_a"], x, "SAME", stride=stride)
    h, sa = bn_apply(blk["bn_a"], bst["bn_a"], h, training)
    h = jax.nn.relu(h)
    h = conv_apply(blk["conv_b"], h, "SAME")
    h, sb = bn_apply(blk["bn_b"], bst["bn_b"], h, training)
    if "proj" in blk:
        x = conv_apply(blk["proj"], x, "SAME", stride=stride)
    elif stride != 1:
        x = x[:, ::stride, ::stride, :]
    return jax.nn.relu(h + x), {"bn_a": sa, "bn_b": sb}


def teacher_logits(params, state, x, cfg: TeacherConfig, training=False):
    """Teacher forward: x [B,32,32,C] -> logits [B,10]."""
    h = conv_apply(params["stem"], x, "SAME")
    h, s0 = bn_apply(params["bn0"], state["bn0"], h, training)
    h = jax.nn.relu(h)
    new_state = {"bn0": s0}
    for si in range(3):
        for bi in range(cfg.blocks_per_stage):
            name = f"s{si}b{bi}"
            stride = 2 if (si > 0 and bi == 0) else 1
            h, new_state[name] = _teacher_block(
                params[name], state[name], h, stride, training
            )
    h = jnp.mean(h, axis=(1, 2))  # global average pooling
    return dense_apply(params["head"], h), new_state


def l2_penalty(params) -> jnp.ndarray:
    """Sum of squared conv/dense weights (teacher regulariser)."""
    return sum(
        jnp.sum(p ** 2)
        for path, p in jax.tree_util.tree_leaves_with_path(params)
        if path[-1].key == "w"
    )

//! The request pipeline: image batch -> front-end engine (pure-Rust
//! interpreter or PJRT) -> feature binarisation -> back-end classification
//! (simulated ACAM, digital matcher, or softmax baseline) -> prediction +
//! energy estimate.
//!
//! This is the paper's Fig. 2 as executable structure.  The front-end is a
//! [`FrontEnd`] trait object selected by `ServeConfig::engine`, so the
//! pipeline never knows which engine is running.  Everything here runs on
//! the serving thread.
//!
//! Artifact-free serving: when the configured artifacts directory does not
//! exist, [`Pipeline::new`] falls back to synthetic metadata
//! ([`Meta::synthetic`]), synthetic interpreter weights, and a template
//! store bootstrapped from the synthetic dataset through the same engine —
//! a fully self-consistent deployment that needs zero build-time steps.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::acam::Variability;
use crate::api::{ClassifyOptions, ClassifyResult, EnergyBreakdown, Prediction};
use crate::backend::{build_unit, BackendVariant, MatchingBackend};
use crate::config::{Backend, ServeConfig};
use crate::energy::{EnergyModel, Scale};
use crate::error::{Error, Result};
use crate::faults::{FaultInjector, FaultKind};
use crate::matching;
use crate::runtime::{backend, FrontEnd, Meta};
use crate::store::{StoreRegistry, DEFAULT_STORE_ID};
use crate::templates::TemplateStore;

/// Samples drawn per class when bootstrapping templates without artifacts
/// (public so tests can regenerate the bootstrap workload and assert its
/// classification accuracy).
pub const BOOTSTRAP_PER_CLASS: usize = 8;

/// Synthetic-dataset seed for the bootstrap workload (distinct from the
/// evaluation seeds the benches and tests use, so bootstrapped templates
/// are never graded on their own training samples).
pub const BOOTSTRAP_DATA_SEED: u64 = 0xB007_5EED;

/// The assembled serving pipeline.
pub struct Pipeline {
    engine: Box<dyn FrontEnd>,
    pub meta: Meta,
    pub store: TemplateStore,
    backend: Backend,
    k: usize,
    /// The deployed back-end variant (what hardware `acam`-routed requests
    /// land on); fixed at construction, invariant across panic-restart,
    /// re-programming, and store hot-swap.
    variant: BackendVariant,
    /// The matching unit behind the [`MatchingBackend`] seam; `Some` only
    /// when the deployment backend is `acam`.
    unit: Option<Box<dyn MatchingBackend>>,
    acam_var: Variability,
    /// The configured (baseline) variability corner — what fault injection
    /// escalates away from and re-programming restores.
    base_var: Variability,
    /// Seed the array was programmed with (re-programming derives fresh,
    /// deterministic per-attempt seeds from it).
    acam_seed: u64,
    /// Completed re-programming attempts (salts the re-program seed).
    reprograms: u32,
    /// Degradation-ladder override: when set, ACAM-routed requests are
    /// served by the digital matching reference instead of the array.
    digital_fallback: bool,
    energy: EnergyModel,
    /// Per-inference front-end energy (nJ), precomputed from the as-built
    /// effective MAC count.
    e_frontend_nj: f64,
    rng: crate::rng::Rng,
    /// Template-store registry (see `crate::store`); `None` outside the
    /// serving coordinator (CLI eval paths, unit tests).
    registry: Option<Arc<StoreRegistry>>,
    /// Registry epoch this pipeline last synchronised against
    /// (`u64::MAX` forces the first [`Pipeline::sync_stores`] to run).
    registry_epoch: u64,
    /// Whether responses advertise store tags (mirrors
    /// [`StoreRegistry::advertises`]; false keeps wire bytes identical to a
    /// registry-free build).
    advertise: bool,
    /// `(id, version)` of the default binding; version 0 until a publish
    /// replaces the shard's bootstrap store.
    default_tag: (Arc<str>, u64),
    /// Non-default store bindings (tenant-pinned stores), each with its own
    /// programmed array when the deployment backend is `acam`.
    extras: BTreeMap<Arc<str>, StoreBinding>,
}

/// One adopted non-default store: the immutable snapshot plus the matching
/// unit programmed from it (mirroring the default binding's unit
/// availability — always the same variant as the deployment).
struct StoreBinding {
    version: u64,
    store: Arc<TemplateStore>,
    unit: Option<Box<dyn MatchingBackend>>,
}

/// One canary sweep's health evidence (see [`Pipeline::canary_probe`]).
#[derive(Debug, Clone)]
pub struct CanaryReport {
    /// Probes evaluated.
    pub probes: usize,
    /// Probes where the analogue top-1 agreed with the digital reference.
    pub agree: usize,
    /// `agree / probes` (1.0 for an empty probe set).
    pub accuracy: f64,
    /// Mean top-1 matchline similarity scaled by the array's full-match
    /// headroom — the analogue match margin; decays as devices drift.
    pub margin: f64,
    /// The array's static full-match headroom at its design point.
    pub headroom: f64,
    /// Analogue search energy spent probing (nJ) — charged to the shard.
    pub energy_nj: f64,
}

impl Pipeline {
    /// Build from a serving config: loads (or synthesises) meta.json and
    /// templates.json, constructs the configured engine, programs the ACAM
    /// array.
    pub fn new(cfg: &ServeConfig) -> Result<Self> {
        cfg.validate()?;
        // One probe decides real-vs-synthetic for the WHOLE deployment:
        // meta, weights (InterpBackend uses the same meta.json probe), and
        // templates must come from the same side, or a partially-written
        // artifacts directory could silently mix trained templates with
        // synthetic weights.
        let have_artifacts = cfg.artifacts_dir.join("meta.json").is_file();
        let meta = if have_artifacts {
            Meta::load(&cfg.artifacts_dir)?
        } else {
            Meta::synthetic()
        };
        let mut engine = backend::create(cfg, &meta)?;
        let store = if have_artifacts {
            TemplateStore::load(cfg.artifacts_dir.join("templates.json"))?
        } else {
            bootstrap_store(engine.as_mut(), &meta, cfg.acam.seed)?
        };

        let set = store.set(cfg.templates_per_class)?;
        let variant = cfg.resolve_backend_variant()?;
        let unit = if cfg.backend == Backend::AcamSim {
            Some(build_unit(
                variant,
                cfg.acam.cell_kind,
                set,
                &Variability::at_level(cfg.acam.variability_level),
                cfg.acam.seed,
            ))
        } else {
            None
        };

        let frontend_ops = meta.macs.as_built.student_effective;
        let energy = EnergyModel::default();
        let e_frontend_nj = energy.frontend_nj(frontend_ops);

        Ok(Pipeline {
            engine,
            backend: cfg.backend,
            k: cfg.templates_per_class,
            variant,
            unit,
            acam_var: Variability::at_level(cfg.acam.variability_level),
            base_var: Variability::at_level(cfg.acam.variability_level),
            acam_seed: cfg.acam.seed,
            reprograms: 0,
            digital_fallback: false,
            energy,
            e_frontend_nj,
            rng: crate::rng::Rng::new(cfg.acam.seed ^ 0x5EED),
            meta,
            store,
            registry: None,
            registry_epoch: u64::MAX,
            advertise: false,
            default_tag: (Arc::from(DEFAULT_STORE_ID), 0),
            extras: BTreeMap::new(),
        })
    }

    /// Attach the shared template-store registry.  Until the first publish
    /// the registry is inert: the pipeline keeps serving the store it built
    /// at construction and responses carry no store tags.
    pub fn attach_registry(&mut self, registry: Arc<StoreRegistry>) {
        self.registry = Some(registry);
        self.registry_epoch = u64::MAX;
    }

    /// Synchronise against the registry's publish epoch.  Called once per
    /// batch by the serving workers — a single atomic load when nothing
    /// changed, so in-flight batches finish on the version they resolved
    /// and the next batch sees the new one (the hot-swap barrier).
    ///
    /// Adopting a publish re-programs the affected matching unit from the
    /// new store at the variant's per-cell programming cost (80 pJ/cell on
    /// the ACAM variants); the returned energy (nJ) is charged to the
    /// worker's meter.  Digital backends adopt stores without a
    /// re-programming charge.
    pub fn sync_stores(&mut self) -> Result<f64> {
        let Some(reg) = self.registry.clone() else {
            return Ok(0.0);
        };
        let epoch = reg.epoch();
        self.advertise = reg.advertises();
        if epoch == self.registry_epoch {
            return Ok(0.0);
        }
        self.registry_epoch = epoch;
        let mut charged = 0.0;
        let serving = reg.serving_set();
        for snap in &serving {
            if &*snap.id == DEFAULT_STORE_ID {
                if snap.version != self.default_tag.1 {
                    if let Some(new_store) = &snap.store {
                        self.store = (**new_store).clone();
                        if self.unit.is_some() {
                            charged += self.reprogram()?;
                        }
                        self.default_tag = (Arc::clone(&snap.id), snap.version);
                    }
                }
                continue;
            }
            let fresh = match self.extras.get(&*snap.id) {
                Some(b) => b.version != snap.version,
                None => true,
            };
            if !fresh {
                continue;
            }
            match &snap.store {
                None => {
                    self.extras.remove(&*snap.id);
                }
                Some(new_store) => {
                    let unit = match self.unit.as_ref() {
                        Some(u) => {
                            let set = new_store.set(self.k)?;
                            charged += u.reprogram_nj(
                                set.num_templates() as u64,
                                set.num_features() as u64,
                            );
                            // Per-(store, version) deterministic seed, in
                            // the same stream family as the default unit.
                            let seed = self.acam_seed
                                ^ crate::coordinator::shard::fnv1a(&snap.id)
                                ^ (snap.version << 32);
                            Some(u.spawn(set, &self.base_var, seed))
                        }
                        None => None,
                    };
                    self.extras.insert(
                        Arc::clone(&snap.id),
                        StoreBinding {
                            version: snap.version,
                            store: Arc::clone(new_store),
                            unit,
                        },
                    );
                }
            }
        }
        // Drop bindings whose store id left the serving set entirely.
        self.extras
            .retain(|id, _| serving.iter().any(|s| s.id == *id));
        Ok(charged)
    }

    /// Pixels per image.
    pub fn image_len(&self) -> usize {
        let s = self.meta.artifacts.image_size;
        s * s
    }

    /// Name of the deployed execution engine (diagnostics).
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Extract (real-valued) feature maps for `n` images (public for the
    /// benches and template-refresh example).  Buffer-length validation is
    /// the engine's contract (see [`FrontEnd`]).
    pub fn extract_features(&mut self, images: &[f32], n: usize) -> Result<Vec<f32>> {
        let feats = self.engine.extract_features(images, n)?;
        let nf = self.meta.artifacts.n_features;
        if feats.len() != n * nf {
            return Err(Error::Backend(format!(
                "{} front-end returned {} floats, expected {}",
                self.engine.name(),
                feats.len(),
                n * nf
            )));
        }
        Ok(feats)
    }

    /// Modelled padding overhead for a batch of `n` (engine-specific: the
    /// interpreter never pads; PJRT pads up to the exported artifact size).
    pub fn padding_for(&self, n: usize) -> usize {
        self.engine.padding_for(n)
    }

    /// Deployment backend (the default when a request carries no override).
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The deployed back-end variant serving `acam`-routed requests.
    pub fn backend_variant(&self) -> BackendVariant {
        self.variant
    }

    /// Whether this deployment can serve a per-request `backend` override.
    /// Digital matchers and the softmax head are always available (they run
    /// on the always-loaded template store / engine head); the simulated
    /// ACAM needs the matching unit that is only programmed when the
    /// deployment backend is `acam`.
    pub fn backend_available(&self, b: Backend) -> bool {
        match b {
            Backend::AcamSim => self.unit.is_some(),
            Backend::FeatureCount | Backend::Similarity | Backend::Softmax => true,
        }
    }

    /// Classify a batch of `n` images with the default options (top-1 on
    /// the deployment backend).  Engines accept arbitrary batch sizes
    /// (PJRT chunks internally).
    pub fn classify_batch(&mut self, images: &[f32], n: usize) -> Result<Vec<ClassifyResult>> {
        self.classify_batch_with(images, n, &vec![ClassifyOptions::default(); n])
    }

    /// Classify a batch with per-item options (the v1 API path): each item
    /// resolves its own backend override, `top_k`, and `return_features`.
    ///
    /// The engine runs at most twice for the whole batch — one feature pass
    /// if any item needs the matching path (or raw features), one head pass
    /// if any item resolved to softmax — so mixed batches still amortise
    /// dispatch like uniform ones.
    pub fn classify_batch_with(
        &mut self,
        images: &[f32],
        n: usize,
        opts: &[ClassifyOptions],
    ) -> Result<Vec<ClassifyResult>> {
        self.classify_batch_routed(images, n, opts, &[])
    }

    /// [`Pipeline::classify_batch_with`] with per-item store routing: item
    /// `i` serves from the binding named by `routes[i]` (`None`, a missing
    /// entry, or an empty `routes` means the default store).  A routed id
    /// whose store has not been published yet (version 0) falls back to the
    /// default binding — the tenant simply shares the deployment store
    /// until its own is uploaded.
    pub fn classify_batch_routed(
        &mut self,
        images: &[f32],
        n: usize,
        opts: &[ClassifyOptions],
        routes: &[Option<Arc<str>>],
    ) -> Result<Vec<ClassifyResult>> {
        if opts.len() != n {
            return Err(Error::Request(format!(
                "{} option sets for a batch of {n}",
                opts.len()
            )));
        }
        let num_classes = self.store.num_classes;
        let resolved: Vec<Backend> = opts
            .iter()
            .map(|o| o.backend.unwrap_or(self.backend))
            .collect();
        for &b in &resolved {
            if !self.backend_available(b) {
                return Err(Error::Config(format!(
                    "backend '{}' is not provisioned in this deployment",
                    b.name()
                )));
            }
        }
        let needs_features = opts
            .iter()
            .zip(&resolved)
            .any(|(o, &b)| o.return_features || b != Backend::Softmax);
        let needs_logits = resolved.iter().any(|&b| b == Backend::Softmax);

        let feats = if needs_features {
            Some(self.extract_features(images, n)?)
        } else {
            None
        };
        let logits = if needs_logits {
            let l = self.engine.logits(images, n, num_classes)?;
            if l.len() != n * num_classes {
                return Err(Error::Backend(format!(
                    "{} head returned {} floats, expected {}",
                    self.engine.name(),
                    l.len(),
                    n * num_classes
                )));
            }
            Some(l)
        } else {
            None
        };

        let nf = self.meta.artifacts.n_features;
        let mut out = Vec::with_capacity(n);
        let this = &mut *self;
        for (i, (o, &backend)) in opts.iter().zip(&resolved).enumerate() {
            let k = o.top_k.clamp(1, num_classes);
            let route = routes.get(i).and_then(|r| r.as_ref());
            let (predictions, energy) = match backend {
                Backend::Softmax => {
                    let row = &logits.as_ref().expect("logits computed")
                        [i * num_classes..(i + 1) * num_classes];
                    let ranked = matching::rank_scores(row);
                    let predictions = ranked
                        .into_iter()
                        .take(k)
                        .map(|(class, score)| Prediction {
                            class,
                            score: score as f64,
                        })
                        .collect();
                    // Softmax baseline pays for the dense head: no back-end
                    // term, head ops not removed (they are excluded from
                    // student_effective, which covers the pruned conv stack).
                    let e = this.energy.frontend_nj(
                        this.meta.macs.as_built.student_effective
                            + this.meta.macs.as_built.head_ops,
                    );
                    (
                        predictions,
                        EnergyBreakdown {
                            front_end_nj: e,
                            back_end_nj: 0.0,
                        },
                    )
                }
                _ => {
                    let row =
                        &feats.as_ref().expect("features computed")[i * nf..(i + 1) * nf];
                    match route.and_then(|id| this.extras.get_mut(&**id)) {
                        Some(b) => score_binding(
                            &b.store,
                            this.k,
                            &mut b.unit,
                            this.digital_fallback,
                            &this.energy,
                            this.e_frontend_nj,
                            &this.acam_var,
                            &mut this.rng,
                            row,
                            backend,
                            k,
                        )?,
                        None => score_binding(
                            &this.store,
                            this.k,
                            &mut this.unit,
                            this.digital_fallback,
                            &this.energy,
                            this.e_frontend_nj,
                            &this.acam_var,
                            &mut this.rng,
                            row,
                            backend,
                            k,
                        )?,
                    }
                }
            };
            let store_tag = if !this.advertise {
                None
            } else {
                match route {
                    None => Some(this.default_tag.clone()),
                    Some(id) => match this.extras.get(&**id) {
                        Some(b) => Some((Arc::clone(id), b.version)),
                        // Unpublished tenant store: serving the default
                        // binding, tagged version 0 (bootstrap).
                        None => Some((Arc::clone(id), 0)),
                    },
                }
            };
            out.push(ClassifyResult {
                predictions,
                energy,
                backend,
                store: store_tag,
                features: if o.return_features {
                    Some(
                        feats.as_ref().expect("features computed")[i * nf..(i + 1) * nf]
                            .to_vec(),
                    )
                } else {
                    None
                },
                cache: None,
            });
        }
        Ok(out)
    }

    /// [`Pipeline::classify_batch_routed`] through a content-hash feature
    /// cache (see [`crate::coordinator::cache`]): a hit skips the CNN
    /// front-end entirely — the cached **binarised** feature vector goes
    /// straight to the live matcher, `front_end_nj` is charged as 0, and
    /// the result carries `cache: Some(true)`.  Misses run the cold path,
    /// populate the cache, and carry `Some(false)`.
    ///
    /// Cache-eligible items are feature-path requests on the **default**
    /// store with no raw-feature echo: softmax requests never touch the
    /// matcher, `return_features` needs the real-valued maps a hit does not
    /// retain, and tenant-routed stores binarise under their own thresholds
    /// (all three bypass with `cache: None`).  The back-end consumes the
    /// shard RNG in the same per-item order on hits as on misses, so
    /// hit-vs-miss predictions are bitwise identical; only the engine
    /// invocation (and its 96.23 nJ) disappears.
    ///
    /// The cache-off serving path never calls this method — it stays on
    /// [`Pipeline::classify_batch_routed`], bitwise identical to a build
    /// without the cache.
    pub fn classify_batch_cached(
        &mut self,
        images: &[f32],
        n: usize,
        opts: &[ClassifyOptions],
        routes: &[Option<Arc<str>>],
        cache: &mut crate::coordinator::cache::FeatureCache,
    ) -> Result<Vec<ClassifyResult>> {
        if opts.len() != n {
            return Err(Error::Request(format!(
                "{} option sets for a batch of {n}",
                opts.len()
            )));
        }
        let num_classes = self.store.num_classes;
        let resolved: Vec<Backend> = opts
            .iter()
            .map(|o| o.backend.unwrap_or(self.backend))
            .collect();
        for &b in &resolved {
            if !self.backend_available(b) {
                return Err(Error::Config(format!(
                    "backend '{}' is not provisioned in this deployment",
                    b.name()
                )));
            }
        }
        let img_len = self.image_len();

        // Per-item cache consult (hits and misses both counted here, in
        // item order, so the counters are deterministic too).
        let mut keys: Vec<Option<u64>> = Vec::with_capacity(n);
        let mut cached: Vec<Option<Vec<u8>>> = Vec::with_capacity(n);
        for (i, (o, &b)) in opts.iter().zip(&resolved).enumerate() {
            let route = routes.get(i).and_then(|r| r.as_ref());
            let eligible = b != Backend::Softmax && !o.return_features && route.is_none();
            if !eligible {
                keys.push(None);
                cached.push(None);
                continue;
            }
            let key =
                crate::coordinator::cache::content_hash(&images[i * img_len..(i + 1) * img_len]);
            cached.push(cache.lookup(key));
            keys.push(Some(key));
        }

        // Cold sub-batch: items still needing the engine's feature pass.
        let cold: Vec<usize> = (0..n)
            .filter(|&i| {
                cached[i].is_none()
                    && (opts[i].return_features || resolved[i] != Backend::Softmax)
            })
            .collect();
        let cold_feats = if cold.is_empty() {
            None
        } else {
            let mut buf = Vec::with_capacity(cold.len() * img_len);
            for &i in &cold {
                buf.extend_from_slice(&images[i * img_len..(i + 1) * img_len]);
            }
            Some(self.extract_features(&buf, cold.len())?)
        };
        // Engine column of item i inside the cold feature block.
        let cold_col = |i: usize| cold.iter().position(|&c| c == i).expect("cold item");

        let needs_logits = resolved.iter().any(|&b| b == Backend::Softmax);
        let logits = if needs_logits {
            let l = self.engine.logits(images, n, num_classes)?;
            if l.len() != n * num_classes {
                return Err(Error::Backend(format!(
                    "{} head returned {} floats, expected {}",
                    self.engine.name(),
                    l.len(),
                    n * num_classes
                )));
            }
            Some(l)
        } else {
            None
        };

        let nf = self.meta.artifacts.n_features;
        let mut out = Vec::with_capacity(n);
        let this = &mut *self;
        for (i, (o, &backend)) in opts.iter().zip(&resolved).enumerate() {
            let k = o.top_k.clamp(1, num_classes);
            let route = routes.get(i).and_then(|r| r.as_ref());
            let (predictions, energy, was_hit) = match backend {
                Backend::Softmax => {
                    let row = &logits.as_ref().expect("logits computed")
                        [i * num_classes..(i + 1) * num_classes];
                    let ranked = matching::rank_scores(row);
                    let predictions: Vec<Prediction> = ranked
                        .into_iter()
                        .take(k)
                        .map(|(class, score)| Prediction {
                            class,
                            score: score as f64,
                        })
                        .collect();
                    let e = this.energy.frontend_nj(
                        this.meta.macs.as_built.student_effective
                            + this.meta.macs.as_built.head_ops,
                    );
                    (
                        predictions,
                        EnergyBreakdown {
                            front_end_nj: e,
                            back_end_nj: 0.0,
                        },
                        None,
                    )
                }
                _ => match &cached[i] {
                    Some(bits) => {
                        // Hit: front-end skipped, zero front-end charge,
                        // live matcher on the cached bits.
                        let (p, e) = score_bits(
                            &this.store,
                            this.k,
                            &mut this.unit,
                            this.digital_fallback,
                            &this.energy,
                            0.0,
                            &this.acam_var,
                            &mut this.rng,
                            bits,
                            backend,
                            k,
                        )?;
                        (p, e, Some(true))
                    }
                    None => {
                        let col = cold_col(i);
                        let row = &cold_feats.as_ref().expect("features computed")
                            [col * nf..(col + 1) * nf];
                        match route.and_then(|id| this.extras.get_mut(&**id)) {
                            Some(b) => {
                                let (p, e) = score_binding(
                                    &b.store,
                                    this.k,
                                    &mut b.unit,
                                    this.digital_fallback,
                                    &this.energy,
                                    this.e_frontend_nj,
                                    &this.acam_var,
                                    &mut this.rng,
                                    row,
                                    backend,
                                    k,
                                )?;
                                (p, e, None)
                            }
                            None => {
                                let bits = this.store.binarize(row);
                                let hit_flag = match keys[i] {
                                    Some(key) => {
                                        cache.insert(key, bits.clone());
                                        Some(false)
                                    }
                                    None => None, // return_features bypass
                                };
                                let (p, e) = score_bits(
                                    &this.store,
                                    this.k,
                                    &mut this.unit,
                                    this.digital_fallback,
                                    &this.energy,
                                    this.e_frontend_nj,
                                    &this.acam_var,
                                    &mut this.rng,
                                    &bits,
                                    backend,
                                    k,
                                )?;
                                (p, e, hit_flag)
                            }
                        }
                    }
                },
            };
            let store_tag = if !this.advertise {
                None
            } else {
                match route {
                    None => Some(this.default_tag.clone()),
                    Some(id) => match this.extras.get(&**id) {
                        Some(b) => Some((Arc::clone(id), b.version)),
                        None => Some((Arc::clone(id), 0)),
                    },
                }
            };
            out.push(ClassifyResult {
                predictions,
                energy,
                backend,
                store: store_tag,
                features: if o.return_features {
                    let col = cold_col(i);
                    Some(
                        cold_feats.as_ref().expect("features computed")
                            [col * nf..(col + 1) * nf]
                            .to_vec(),
                    )
                } else {
                    None
                },
                cache: was_hit,
            });
        }
        Ok(out)
    }

    /// Version of the default store binding (0 until the first publish
    /// replaces the bootstrap store).  The serving workers compare this
    /// across [`Pipeline::sync_stores`] to flush the feature cache on a
    /// hot-swap — cached bits are a function of the store's binarisation
    /// thresholds.
    pub fn default_store_version(&self) -> u64 {
        self.default_tag.1
    }

    /// Score one already-extracted feature map on a feature-domain backend
    /// against the default store binding: ranked top-k predictions plus the
    /// back-end energy term.
    fn score_features(
        &mut self,
        features: &[f32],
        backend: Backend,
        k: usize,
    ) -> Result<(Vec<Prediction>, EnergyBreakdown)> {
        score_binding(
            &self.store,
            self.k,
            &mut self.unit,
            self.digital_fallback,
            &self.energy,
            self.e_frontend_nj,
            &self.acam_var,
            &mut self.rng,
            features,
            backend,
            k,
        )
    }

    /// Evaluate accuracy + confusion matrix over a labelled workload.
    pub fn evaluate(
        &mut self,
        images: &[f32],
        labels: &[usize],
        batch: usize,
    ) -> Result<Evaluation> {
        let img_len = self.image_len();
        let n = labels.len();
        let num_classes = self.store.num_classes;
        let mut confusion = vec![vec![0u64; num_classes]; num_classes];
        let mut correct = 0usize;
        let mut energy_nj = 0f64;
        let t0 = Instant::now();
        let mut i = 0;
        while i < n {
            let m = batch.min(n - i);
            let chunk = &images[i * img_len..(i + m) * img_len];
            for (j, c) in self.classify_batch(chunk, m)?.into_iter().enumerate() {
                let truth = labels[i + j];
                let class = c.top1().class;
                confusion[truth][class] += 1;
                correct += usize::from(class == truth);
                energy_nj += c.energy.total_nj();
            }
            i += m;
        }
        Ok(Evaluation {
            accuracy: correct as f64 / n as f64,
            confusion,
            total_energy_nj: energy_nj,
            wall_secs: t0.elapsed().as_secs_f64(),
            n,
        })
    }

    /// Whether ACAM-routed requests are currently served by the digital
    /// fallback (the ladder's `DigitalFallback` state).
    pub fn digital_fallback(&self) -> bool {
        self.digital_fallback
    }

    /// Enter/leave the digital-fallback routing (set by the degradation
    /// ladder in `coordinator/shard.rs`; a no-op for non-ACAM deployments).
    pub fn set_digital_fallback(&mut self, on: bool) {
        self.digital_fallback = on;
    }

    /// Build the canary probe set: the first `per_class * NUM_CLASSES`
    /// bootstrap samples (labels interleave `i % NUM_CLASSES`, so the set
    /// is exactly class-balanced), pushed through the front-end and
    /// binarised once.  Returns `(bit_vectors, labels)`.  Runs only the
    /// deterministic engine — no RNG stream is touched, so building the
    /// probe set never perturbs served predictions.
    pub fn canary_bits(&mut self, per_class: usize) -> Result<(Vec<Vec<u8>>, Vec<usize>)> {
        let classes = crate::dataset::NUM_CLASSES;
        let n = (per_class * classes).max(1);
        let ds = crate::dataset::SyntheticDataset::new(
            BOOTSTRAP_DATA_SEED,
            n,
            self.meta.norm.mean as f32,
            self.meta.norm.std as f32,
        );
        let (images, labels) = ds.batch(0, n);
        let feats = self.extract_features(&images, n)?;
        let nf = self.meta.artifacts.n_features;
        let bits = (0..n)
            .map(|i| self.store.binarize(&feats[i * nf..(i + 1) * nf]))
            .collect();
        Ok((bits, labels))
    }

    /// Probe the matching unit's health against the digital reference.
    ///
    /// For each probe bit-vector the unit is searched for real (the probe
    /// consumes the unit's RNG stream and search energy — the ladder only
    /// runs probes when canary scoring is enabled, keeping the default
    /// deployment bitwise identical to a canary-free one) and the unit's
    /// top-1 is compared with the digital Eq. 8 top-1 on the same bits —
    /// the calibration contract says they agree exactly on ideal devices,
    /// so disagreement is direct evidence of device decay.
    pub fn canary_probe(&mut self, probes: &[Vec<u8>]) -> Result<CanaryReport> {
        let num_classes = self.store.num_classes;
        let set = self.store.set(self.k)?;
        let unit = self
            .unit
            .as_mut()
            .ok_or_else(|| Error::Config("ACAM array not programmed".into()))?;
        let mut agree = 0usize;
        let mut margin_sum = 0f64;
        let mut energy_nj = 0f64;
        for bits in probes {
            let digital = matching::classify_feature_count_topk(bits, set, num_classes, 1)[0].0;
            let p = unit.probe(bits, set, num_classes, &self.energy, &self.acam_var, &mut self.rng);
            energy_nj += p.energy_nj;
            agree += usize::from(p.top_class == digital);
            margin_sum += p.top_similarity;
        }
        let headroom = unit.headroom();
        let n = probes.len();
        Ok(CanaryReport {
            probes: n,
            agree,
            accuracy: if n == 0 { 1.0 } else { agree as f64 / n as f64 },
            margin: if n == 0 {
                headroom
            } else {
                (margin_sum / n as f64) * headroom
            },
            headroom,
            energy_nj,
        })
    }

    /// Re-fit the matching unit: re-program every cell from the template
    /// store at the baseline variability corner (clearing injected drift
    /// and read-noise escalations — but NOT stuck cells, which the caller
    /// re-applies via [`Pipeline::apply_sticky`]).  Each attempt programs
    /// with a fresh deterministic seed.  Returns the programming energy
    /// charged (nJ) at the variant's per-cell cost.
    pub fn reprogram(&mut self) -> Result<f64> {
        let set = self.store.set(self.k)?;
        let unit = self
            .unit
            .as_mut()
            .ok_or_else(|| Error::Config("ACAM array not programmed".into()))?;
        let energy_nj =
            unit.reprogram_nj(set.num_templates() as u64, set.num_features() as u64);
        self.reprograms += 1;
        let seed = self.acam_seed.wrapping_add((self.reprograms as u64) << 32);
        unit.reprogram(set, &self.base_var, seed);
        self.acam_var = self.base_var.clone();
        Ok(energy_nj)
    }

    /// Completed re-programming attempts.
    pub fn reprogram_count(&self) -> u32 {
        self.reprograms
    }

    /// Apply one injected fault to this pipeline's matching state.  Stall
    /// faults are the worker loop's business and are ignored here; every
    /// fault kind is a no-op on deployments without a programmed unit
    /// (except the WTA-corner half of drift, which the pipeline owns).
    pub fn apply_fault(&mut self, kind: &FaultKind, inj: &mut FaultInjector) {
        if let FaultKind::Drift { level } = kind {
            // The periphery (sense/WTA) half of the drift corner lives in
            // the pipeline; the unit absorbs the array half below.
            self.acam_var = Variability::at_level(*level);
        }
        if let Some(unit) = self.unit.as_mut() {
            unit.apply_fault(kind, inj);
        }
    }

    /// Re-apply sticky stuck-cell sets (after a re-programming).  Returns
    /// the number of cells stuck.
    pub fn apply_sticky(&mut self, sets: &[crate::faults::StuckSet]) -> usize {
        match self.unit.as_mut() {
            Some(unit) => unit.apply_sticky(sets),
            None => 0,
        }
    }

    /// The §V.D report for this deployment (as-built scale).
    pub fn energy_report(&self) -> crate::energy::EnergyReport {
        let set = self.store.set(self.k).expect("validated at construction");
        self.energy.report(Scale::AsBuilt {
            frontend_ops: self.meta.macs.as_built.student_effective,
            teacher_macs: self.meta.macs.as_built.teacher_gray.macs,
            n_templates: set.num_templates() as u64,
            n_features: set.num_features() as u64,
        })
    }
}

/// Score one already-extracted feature map against an arbitrary store
/// binding.  Free function over the binding's disjoint parts so the routed
/// batch loop can borrow a tenant binding out of `Pipeline::extras` while
/// still passing the pipeline's shared energy model and RNG stream.
#[allow(clippy::too_many_arguments)]
fn score_binding(
    store: &TemplateStore,
    k_templates: usize,
    unit: &mut Option<Box<dyn MatchingBackend>>,
    digital_fallback: bool,
    energy: &EnergyModel,
    e_frontend_nj: f64,
    acam_var: &Variability,
    rng: &mut crate::rng::Rng,
    features: &[f32],
    backend: Backend,
    k: usize,
) -> Result<(Vec<Prediction>, EnergyBreakdown)> {
    let bits = store.binarize(features);
    score_bits(
        store,
        k_templates,
        unit,
        digital_fallback,
        energy,
        e_frontend_nj,
        acam_var,
        rng,
        &bits,
        backend,
        k,
    )
}

/// The back half of [`score_binding`]: score an **already-binarised**
/// feature vector.  Split out so the feature cache can inject cached bits
/// (with `e_frontend_nj = 0`) while the cold path keeps binarising inline —
/// both paths share every instruction from here down, including the RNG
/// draw order, which is what makes hit-vs-miss predictions bitwise equal.
#[allow(clippy::too_many_arguments)]
fn score_bits(
    store: &TemplateStore,
    k_templates: usize,
    unit: &mut Option<Box<dyn MatchingBackend>>,
    digital_fallback: bool,
    energy: &EnergyModel,
    e_frontend_nj: f64,
    acam_var: &Variability,
    rng: &mut crate::rng::Rng,
    bits: &[u8],
    backend: Backend,
    k: usize,
) -> Result<(Vec<Prediction>, EnergyBreakdown)> {
    let num_classes = store.num_classes;
    let set = store.set(k_templates)?;
    let (ranked, e_backend): (Vec<(usize, f64)>, f64) = match backend {
        Backend::FeatureCount => {
            let top = matching::classify_feature_count_topk(bits, set, num_classes, k);
            // Digital matcher modelled at the same ACAM energy envelope
            // (it replaces the same head); report the Eq. 14 figure.
            (
                top.into_iter().map(|(c, s)| (c, s as f64)).collect(),
                energy.backend_nj(set.num_templates() as u64, set.num_features() as u64),
            )
        }
        Backend::Similarity => {
            let qf: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
            let top = matching::classify_similarity_topk(
                &qf,
                set,
                store.similarity_alpha,
                num_classes,
                true,
                k,
            );
            (
                top.into_iter().map(|(c, s)| (c, s as f64)).collect(),
                energy.backend_nj(set.num_templates() as u64, set.num_features() as u64),
            )
        }
        Backend::AcamSim if digital_fallback => {
            // Degradation-ladder fallback: the array is untrustworthy,
            // so ACAM-routed requests are answered by the digital Eq. 8
            // reference.  Correct, and costed at the digital matcher's
            // envelope — the analogue array contributes nothing.
            let top = matching::classify_feature_count_topk(bits, set, num_classes, k);
            (
                top.into_iter().map(|(c, s)| (c, s as f64)).collect(),
                energy.backend_nj(set.num_templates() as u64, set.num_features() as u64),
            )
        }
        Backend::AcamSim => {
            let u = unit
                .as_mut()
                .ok_or_else(|| Error::Config("ACAM array not programmed".into()))?;
            let out = u.score(bits, set, num_classes, k, energy, acam_var, rng);
            (out.ranked, out.energy_nj)
        }
        Backend::Softmax => unreachable!("handled in classify_batch_with"),
    };
    Ok((
        ranked
            .into_iter()
            .map(|(class, score)| Prediction { class, score })
            .collect(),
        EnergyBreakdown {
            front_end_nj: e_frontend_nj,
            back_end_nj: e_backend,
        },
    ))
}

/// Bootstrap a template store from the synthetic dataset through the
/// deployed engine (the artifact-free path): render a labelled workload,
/// extract features, and hand them to [`TemplateStore::from_features`].
fn bootstrap_store(engine: &mut dyn FrontEnd, meta: &Meta, seed: u64) -> Result<TemplateStore> {
    bootstrap_store_with(engine, meta, seed, BOOTSTRAP_PER_CLASS)
}

/// [`bootstrap_store`] with an explicit samples-per-class budget — public
/// so the ROADMAP's bootstrap sweep (`rust/tests/interp_backend.rs`) can
/// grade template quality at 1/2/4/8 samples per class.  The synthetic
/// dataset interleaves labels (`label(i) = i % NUM_CLASSES`), so the first
/// `per_class * NUM_CLASSES` samples are exactly class-balanced.
pub fn bootstrap_store_with(
    engine: &mut dyn FrontEnd,
    meta: &Meta,
    seed: u64,
    per_class: usize,
) -> Result<TemplateStore> {
    let classes = crate::dataset::NUM_CLASSES;
    let n = per_class * classes;
    let ds = crate::dataset::SyntheticDataset::new(
        BOOTSTRAP_DATA_SEED,
        n,
        meta.norm.mean as f32,
        meta.norm.std as f32,
    );
    let (images, labels) = ds.batch(0, n);
    let feats = engine.extract_features(&images, n)?;
    TemplateStore::from_features(&feats, &labels, meta.artifacts.n_features, classes, seed)
}

/// Accuracy/confusion summary of an evaluation run.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub accuracy: f64,
    pub confusion: Vec<Vec<u64>>,
    pub total_energy_nj: f64,
    pub wall_secs: f64,
    pub n: usize,
}

impl Evaluation {
    /// Per-class accuracy (Fig. 7).
    pub fn per_class_accuracy(&self) -> Vec<f64> {
        self.confusion
            .iter()
            .enumerate()
            .map(|(c, row)| {
                let total: u64 = row.iter().sum();
                if total == 0 {
                    0.0
                } else {
                    row[c] as f64 / total as f64
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_are_top1_deployment_backend() {
        let o = ClassifyOptions::default();
        assert_eq!(o.top_k, 1);
        assert!(o.backend.is_none());
        assert!(!o.return_features);
    }
}

//! Layer-3 coordinator: the serving system around the hybrid classifier.
//!
//! * [`batcher`] — dynamic batching policy (size + deadline, artifact-size
//!   padding);
//! * [`pipeline`] — image -> front-end engine (pure-Rust interpreter or
//!   PJRT, via the [`crate::runtime::FrontEnd`] trait) -> binarise ->
//!   back-end (ACAM sim / digital matcher / softmax baseline) -> class +
//!   energy;
//! * [`cache`] — per-worker content-hash feature cache: a hit skips the
//!   CNN front-end (96.23 nJ) and reuses the cached binarised feature
//!   vector, while the cheap back-end (1.45 nJ) always re-runs against the
//!   live template store so hot-swaps and the degradation ladder stay
//!   correct;
//! * [`server`] — the event loop: bounded request queue with backpressure, a
//!   dedicated worker thread owning the engine state, async-friendly
//!   handles speaking the v1 [`crate::api`] types;
//! * [`shard`] — the scaled-out variant: N independent pipeline workers
//!   (each with its own engine, ACAM array, RNG stream and bounded queue)
//!   behind one routed submit surface with spill backpressure and
//!   panic-restart shard health;
//! * [`metrics`] — lock-free counters, gauges, latency histograms, energy
//!   ledger, Prometheus rendering (aggregate + `shard`-labelled series).
//!
//! The [`ClassifySurface`] trait is the seam between front doors and
//! deployments: the HTTP gateway (and any future transport) serves
//! whichever surface it is handed — a single-pipeline [`Handle`] or a
//! sharded [`shard::ShardHandle`] — without knowing which.

pub mod batcher;
pub mod cache;
pub mod metrics;
pub mod oneshot;
pub mod pipeline;
pub mod server;
pub mod shard;

pub use metrics::{Metrics, Snapshot};
pub use pipeline::{Evaluation, Pipeline};
pub use server::{Caps, Handle, Server};
pub use shard::{ShardHandle, ShardSet};

use crate::api::{ApiError, ClassifyRequest, ClassifyResponse, ErrorCode};

/// Health of one worker shard, as reported by `/healthz`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardStatus {
    pub index: usize,
    /// `false` while the shard is draining/restarting after a worker panic.
    pub healthy: bool,
    /// Panic-restarts of this shard's worker since startup.
    pub restarts: u64,
    pub queue_depth: u64,
    pub in_flight: u64,
    /// Degradation-ladder state (`"healthy"`, `"reprogramming"`,
    /// `"digital_fallback"`); `None` when the canary ladder is inactive,
    /// in which case `/healthz` omits the key entirely (additive v1).
    pub backend_state: Option<&'static str>,
    /// The deployed [`MatchingBackend`] variant serving this shard's
    /// `acam`-routed requests (`"acam"`, `"acam-9t4r"`, `"rbf"`,
    /// `"digital"`).  Always present — `/healthz` is not part of the wire
    /// parity gate.
    ///
    /// [`MatchingBackend`]: crate::backend::MatchingBackend
    pub backend_variant: &'static str,
}

/// Deployment health: degraded while any shard is down **or** any shard's
/// degradation ladder has left `Healthy`.  Un-sharded deployments report an
/// empty shard list and are never degraded (a dead single worker is
/// `SERVER_STOPPED` at submit time, not a health state).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HealthReport {
    pub degraded: bool,
    pub shards: Vec<ShardStatus>,
}

/// A submit surface the gateway (or any front door) can serve: caps for
/// request validation, non-blocking submit into a bounded queue, health,
/// and a Prometheus metrics payload.  Implemented by the single-pipeline
/// [`Handle`] and the sharded [`shard::ShardHandle`].
pub trait ClassifySurface {
    /// What the deployment can serve (image shape, engine, backends).
    fn caps(&self) -> &Caps;

    /// Submit a request; await the returned receiver for the response.
    #[allow(clippy::type_complexity)]
    fn submit(
        &self,
        req: ClassifyRequest,
    ) -> std::result::Result<
        oneshot::Receiver<std::result::Result<ClassifyResponse, ApiError>>,
        ApiError,
    >;

    /// Deployment health (degraded + per-shard statuses).
    fn health(&self) -> HealthReport;

    /// The `/metrics` payload (Prometheus text exposition format).
    fn prometheus_text(&self) -> String;

    /// The template-store admin surface behind `/v1/stores`, when this
    /// deployment carries a [`crate::store::StoreRegistry`].  Defaults to
    /// `None` so transport-only test doubles keep compiling and the gateway
    /// answers 404 for store routes on registry-less surfaces.
    fn store_admin(&self) -> Option<crate::store::StoreAdmin> {
        None
    }

    /// Submit and block for the response.
    fn submit_blocking(
        &self,
        req: ClassifyRequest,
    ) -> std::result::Result<ClassifyResponse, ApiError> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| ApiError::new(ErrorCode::Internal, "worker dropped response"))?
    }
}

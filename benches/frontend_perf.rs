//! Front-end perf trajectory: scalar interpreter vs the blocked/threaded
//! `interp-fast` engine (vs PJRT when compiled + artifacts exist) on the
//! Fig.-5 student shapes (32x32 input, paper channel widths 32/128/256/16),
//! batch 1 and batch 8.
//!
//! Emits a machine-readable `BENCH_frontend.json` (override the path with
//! `HEC_BENCH_OUT`) so subsequent PRs can track the speedup over time, and
//! asserts the PR-2 acceptance bar: `interp-fast` >= 4x scalar throughput
//! on the batch-8 forward pass.  `HEC_BENCH_SMOKE=1` shrinks the timing
//! budget for CI; `HEC_BENCH_NO_ASSERT=1` reports without gating.

use std::time::Duration;

use hec::benchkit::{self, bench_for, section, BenchResult};
use hec::dataset::SyntheticDataset;
use hec::jsonlite::Value;
use hec::runtime::backend::fast::FastBackend;
use hec::runtime::backend::interp::{InterpBackend, StudentParams, PAPER_FILTERS};
use hec::runtime::FrontEnd;

const IMAGE_SIZE: usize = 32;
const WEIGHT_SEED: u64 = 0xF16_5EED;

fn workload(n: usize) -> Vec<f32> {
    // Pixel statistics are irrelevant to timing; a synthetic batch keeps
    // the inputs deterministic and denormal-free.
    SyntheticDataset::new(7, n, 0.1307, 0.3081).batch(0, n).0
}

fn time_engine(
    name: &str,
    engine: &mut dyn FrontEnd,
    images: &[f32],
    n: usize,
    warmup: usize,
    budget: Duration,
) -> BenchResult {
    bench_for(&format!("{name} b{n}"), warmup, 3, budget, || {
        let feats = engine.extract_features(images, n).unwrap();
        assert_eq!(feats.len(), n * 784);
    })
}

fn main() {
    let smoke = std::env::var("HEC_BENCH_SMOKE").is_ok();
    let budget = if smoke {
        Duration::from_millis(250)
    } else {
        Duration::from_millis(1500)
    };
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);

    let params = StudentParams::synthetic_with_filters(WEIGHT_SEED, PAPER_FILTERS);
    let mut scalar = InterpBackend::from_params(params.clone(), IMAGE_SIZE);
    let mut fast1 = FastBackend::from_params(params.clone(), IMAGE_SIZE, 1);
    let mut fastn = FastBackend::from_params(params.clone(), IMAGE_SIZE, threads);

    // The fast paths must agree with the scalar oracle before being timed.
    let probe = workload(2);
    let want = scalar.extract_features(&probe, 2).unwrap();
    for engine in [&mut fast1 as &mut dyn FrontEnd, &mut fastn] {
        let got = engine.extract_features(&probe, 2).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() <= 1e-5 + 1e-5 * w.abs(), "fast != scalar");
        }
    }

    let mut results: Vec<BenchResult> = Vec::new();
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    for n in [1usize, 8] {
        section(&format!("Fig.-5 student forward, batch {n}"));
        let images = workload(n);
        let s = time_engine("interp", &mut scalar, &images, n, 1, budget);
        let f1 = time_engine("interp-fast t1", &mut fast1, &images, n, 1, budget);
        let fnn = time_engine(
            &format!("interp-fast t{threads}"),
            &mut fastn,
            &images,
            n,
            1,
            budget,
        );
        let speedup = s.mean.as_secs_f64() / fnn.mean.as_secs_f64();
        let serial = s.mean.as_secs_f64() / f1.mean.as_secs_f64();
        println!("speedup vs scalar: {serial:.2}x single-thread, {speedup:.2}x threaded");
        let key = if n == 1 { "speedup_b1" } else { "speedup_b8" };
        speedups.push((key, speedup));
        results.extend([s, f1, fnn]);
    }

    #[cfg(feature = "pjrt")]
    {
        use hec::config::{Engine, ServeConfig};
        use hec::runtime::Meta;
        if std::path::Path::new("artifacts/meta.json").is_file() {
            section("PJRT CPU client (artifacts)");
            let cfg = ServeConfig {
                engine: Engine::Pjrt,
                ..Default::default()
            };
            let meta = Meta::load("artifacts").unwrap();
            let mut pjrt = hec::runtime::create_backend(&cfg, &meta).unwrap();
            for n in [1usize, 8] {
                let images = workload(n);
                results.push(bench_for(&format!("pjrt b{n}"), 1, 3, budget, || {
                    pjrt.extract_features(&images, n).unwrap();
                }));
            }
        } else {
            println!("\npjrt: skipped (run `make artifacts` first)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("\npjrt: skipped (build with --features pjrt)");

    let out = std::env::var("HEC_BENCH_OUT").unwrap_or_else(|_| "BENCH_frontend.json".into());
    let mut extra = vec![
        ("image_size", Value::Num(IMAGE_SIZE as f64)),
        ("filters", Value::Arr(PAPER_FILTERS.iter().map(|&f| Value::Num(f as f64)).collect())),
        ("threads", Value::Num(threads as f64)),
        ("smoke", Value::Bool(smoke)),
    ];
    for &(k, v) in &speedups {
        extra.push((k, Value::Num(v)));
    }
    let rows: Vec<&BenchResult> = results.iter().collect();
    benchkit::write_json_report(&out, "hec/frontend-perf/v1", &extra, &rows)
        .expect("write bench report");
    println!("\nwrote {out}");

    let b8 = speedups.iter().find(|(k, _)| *k == "speedup_b8").unwrap().1;
    // The 4x acceptance bar assumes a multi-core host (batch sharding is
    // roughly half the win); a single-core machine only gets the blocked
    // microkernel + folding share, so it gates at 2x instead.
    let bar = if threads >= 2 { 4.0 } else { 2.0 };
    if smoke || std::env::var("HEC_BENCH_NO_ASSERT").is_ok() {
        // Smoke runs exist to exercise the path and publish the JSON; their
        // short budgets make ratios too noisy to gate on.
        println!("frontend_perf: speedup_b8 = {b8:.2}x (assertion disabled)");
    } else {
        assert!(
            b8 >= bar,
            "interp-fast must be >= {bar}x scalar interp at batch 8 \
             ({threads} threads), measured {b8:.2}x"
        );
        println!("frontend_perf: PASS ({b8:.2}x >= {bar}x at batch 8)");
    }
}

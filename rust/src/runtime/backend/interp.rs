//! `InterpBackend` — the pure-Rust student-CNN inference engine.
//!
//! Ports the Fig.-5 student forward pass (`python/compile/model.py::
//! student_features` / `student_logits`, inference mode) on top of the
//! reference kernels in [`super::kernels`]:
//!
//! ```text
//! conv1 SAME -> BN -> ReLU -> maxpool2
//! conv2 SAME -> BN -> ReLU -> maxpool2
//! conv3 SAME -> ReLU
//! conv4 VALID -> ReLU -> flatten (the ACAM query features)
//! [dense head -> logits]            (softmax baseline only)
//! ```
//!
//! The frozen batch-norms are folded into conv1/conv2's weights and biases
//! at parameter-load time ([`fold_conv_bn`] / [`FoldedStudent`]), so the
//! executed chain is pure conv -> ReLU [-> pool]; a unit test pins the
//! folded output to the explicit-BN reference chain.
//!
//! Weights come from the existing `<name>.params.{json,bin}` sidecars
//! (loaded through [`crate::runtime::params`]) when an artifacts directory
//! is present — `student_softmax_b*` first because it carries the dense
//! head, then `student_fwd_b*` — or from a deterministic He-initialised
//! synthetic student when serving without artifacts (the zero-setup
//! quickstart path: templates are bootstrapped from the same weights, so
//! the whole stack stays self-consistent).

use std::path::Path;

use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::runtime::meta::Meta;
use crate::runtime::params::{self, ParamArray};

use super::kernels::{self, Padding};
use super::FrontEnd;

/// Filter widths (conv1..conv4 output channels) of the synthetic fallback
/// student.  Slimmer than the Fig.-5 deployment so the interpreter stays
/// fast in debug builds; the trailing 16 keeps the 7x7x16 = 784 feature
/// contract at image size 32.
pub const SYNTH_FILTERS: [usize; 4] = [8, 16, 32, 16];

/// The paper's Fig.-5 deployment filter widths (conv1..conv4 output
/// channels) — what `benches/frontend_perf.rs` times, and what
/// artifacts-trained weights use.
pub const PAPER_FILTERS: [usize; 4] = [32, 128, 256, 16];

/// Seed for the synthetic He-initialised weights (fixed so every pipeline
/// in a process — and across processes — sees the same fallback model).
pub const SYNTH_WEIGHT_SEED: u64 = 0x5EED_F00D;

/// One convolution layer: HWIO weights (`[kh, kw, cin, cout]` row-major)
/// plus bias.
#[derive(Debug, Clone)]
pub struct Conv {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
}

/// Frozen batch-norm statistics and affine parameters.
#[derive(Debug, Clone)]
pub struct BatchNorm {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

/// The dense softmax head: `[din, dout]` weights plus bias.
#[derive(Debug, Clone)]
pub struct Dense {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub din: usize,
    pub dout: usize,
}

/// The full student parameter set.
#[derive(Debug, Clone)]
pub struct StudentParams {
    pub conv1: Conv,
    pub bn1: BatchNorm,
    pub conv2: Conv,
    pub bn2: BatchNorm,
    pub conv3: Conv,
    pub conv4: Conv,
    /// Absent in the feature-extractor-only sidecars (`student_fwd_b*`).
    pub head: Option<Dense>,
}

fn conv_from(b: &ParamArray, w: &ParamArray, name: &str) -> Result<Conv> {
    if w.shape.len() != 4 {
        return Err(Error::Artifact(format!(
            "{name}: conv weight must be rank-4 HWIO, got shape {:?}",
            w.shape
        )));
    }
    let (kh, kw, cin, cout) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    if b.shape.len() != 1 || b.shape[0] != cout {
        return Err(Error::Artifact(format!(
            "{name}: bias shape {:?} does not match cout {cout}",
            b.shape
        )));
    }
    Ok(Conv {
        w: w.data.clone(),
        b: b.data.clone(),
        kh,
        kw,
        cin,
        cout,
    })
}

fn bn_from(
    beta: &ParamArray,
    gamma: &ParamArray,
    mean: &ParamArray,
    var: &ParamArray,
    c: usize,
    name: &str,
) -> Result<BatchNorm> {
    for (what, a) in [("beta", beta), ("gamma", gamma), ("mean", mean), ("var", var)] {
        if a.data.len() != c {
            return Err(Error::Artifact(format!(
                "{name}.{what}: expected {c} values, got {}",
                a.data.len()
            )));
        }
    }
    Ok(BatchNorm {
        gamma: gamma.data.clone(),
        beta: beta.data.clone(),
        mean: mean.data.clone(),
        var: var.data.clone(),
    })
}

impl StudentParams {
    /// Assemble from a parameter sidecar in the AOT export's argument order
    /// (`aot.py` flattens `({bn1, bn2, conv1..4[, head]}, {bn1, bn2})` with
    /// jax `tree_flatten`, which sorts dict keys):
    ///
    /// ```text
    ///  0 bn1.beta    1 bn1.gamma   2 bn2.beta    3 bn2.gamma
    ///  4 conv1.b     5 conv1.w     6 conv2.b     7 conv2.w
    ///  8 conv3.b     9 conv3.w    10 conv4.b    11 conv4.w
    /// [12 head.b    13 head.w]     then bn1.mean, bn1.var, bn2.mean, bn2.var
    /// ```
    pub fn from_sidecar(arrays: &[ParamArray], with_head: bool) -> Result<StudentParams> {
        let want = if with_head { 18 } else { 16 };
        if arrays.len() != want {
            return Err(Error::Artifact(format!(
                "parameter sidecar has {} arrays, expected {want}",
                arrays.len()
            )));
        }
        let conv1 = conv_from(&arrays[4], &arrays[5], "conv1")?;
        let conv2 = conv_from(&arrays[6], &arrays[7], "conv2")?;
        let conv3 = conv_from(&arrays[8], &arrays[9], "conv3")?;
        let conv4 = conv_from(&arrays[10], &arrays[11], "conv4")?;
        let state = if with_head { 14 } else { 12 };
        let bn1 = bn_from(
            &arrays[0],
            &arrays[1],
            &arrays[state],
            &arrays[state + 1],
            conv1.cout,
            "bn1",
        )?;
        let bn2 = bn_from(
            &arrays[2],
            &arrays[3],
            &arrays[state + 2],
            &arrays[state + 3],
            conv2.cout,
            "bn2",
        )?;
        let head = if with_head {
            let (hb, hw) = (&arrays[12], &arrays[13]);
            if hw.shape.len() != 2 {
                return Err(Error::Artifact(format!(
                    "head weight must be rank-2, got shape {:?}",
                    hw.shape
                )));
            }
            Some(Dense {
                w: hw.data.clone(),
                b: hb.data.clone(),
                din: hw.shape[0],
                dout: hw.shape[1],
            })
        } else {
            None
        };
        Ok(StudentParams {
            conv1,
            bn1,
            conv2,
            bn2,
            conv3,
            conv4,
            head,
        })
    }

    /// Deterministic He-initialised synthetic student ([`SYNTH_FILTERS`]
    /// channel widths, identity batch-norm, zero biases).
    pub fn synthetic(seed: u64) -> StudentParams {
        Self::synthetic_with_filters(seed, SYNTH_FILTERS)
    }

    /// Synthetic student with explicit conv1..conv4 channel widths (the
    /// perf bench uses [`PAPER_FILTERS`] to time the Fig.-5 shapes).
    pub fn synthetic_with_filters(seed: u64, filters: [usize; 4]) -> StudentParams {
        let [f1, f2, f3, f4] = filters;
        let mut rng = Rng::new(seed);
        let conv1 = he_conv(&mut rng, 3, 3, 1, f1);
        let conv2 = he_conv(&mut rng, 3, 3, f1, f2);
        let conv3 = he_conv(&mut rng, 3, 3, f2, f3);
        let conv4 = he_conv(&mut rng, 2, 2, f3, f4);
        let head = he_dense(&mut rng, 7 * 7 * f4, crate::dataset::NUM_CLASSES);
        StudentParams {
            conv1,
            bn1: identity_bn(f1),
            conv2,
            bn2: identity_bn(f2),
            conv3,
            conv4,
            head: Some(head),
        }
    }
}

fn he_conv(rng: &mut Rng, kh: usize, kw: usize, cin: usize, cout: usize) -> Conv {
    let std = (2.0 / (kh * kw * cin) as f64).sqrt();
    let w = (0..kh * kw * cin * cout)
        .map(|_| (rng.gauss() * std) as f32)
        .collect();
    Conv {
        w,
        b: vec![0.0; cout],
        kh,
        kw,
        cin,
        cout,
    }
}

fn he_dense(rng: &mut Rng, din: usize, dout: usize) -> Dense {
    let std = (2.0 / din as f64).sqrt();
    let w = (0..din * dout).map(|_| (rng.gauss() * std) as f32).collect();
    Dense {
        w,
        b: vec![0.0; dout],
        din,
        dout,
    }
}

fn identity_bn(c: usize) -> BatchNorm {
    BatchNorm {
        gamma: vec![1.0; c],
        beta: vec![0.0; c],
        mean: vec![0.0; c],
        var: vec![1.0; c],
    }
}

fn conv(x: &[f32], h: usize, w: usize, layer: &Conv, pad: Padding) -> (Vec<f32>, usize, usize) {
    kernels::conv2d(
        x, h, w, layer.cin, &layer.w, layer.kh, layer.kw, layer.cout, &layer.b, pad,
    )
}

/// Fold a frozen batch-norm into the preceding conv: with
/// `s_c = gamma_c / sqrt(var_c + eps)`,
/// `bn(conv(x)) = conv'(x)` where `w'[.., c] = w[.., c] * s_c` and
/// `b'_c = (b_c - mean_c) * s_c + beta_c`.  Removes two full per-pixel
/// passes (bn1, bn2) from every inference.
pub fn fold_conv_bn(conv: &Conv, bn: &BatchNorm) -> Conv {
    let cout = conv.cout;
    let scale: Vec<f32> = (0..cout)
        .map(|c| bn.gamma[c] / (bn.var[c] + kernels::BN_EPS).sqrt())
        .collect();
    let w = conv
        .w
        .iter()
        .enumerate()
        .map(|(i, &v)| v * scale[i % cout])
        .collect();
    let b = (0..cout)
        .map(|c| (conv.b[c] - bn.mean[c]) * scale[c] + bn.beta[c])
        .collect();
    Conv {
        w,
        b,
        kh: conv.kh,
        kw: conv.kw,
        cin: conv.cin,
        cout: conv.cout,
    }
}

/// The student with batch-norms folded away — what both interpreter
/// engines actually execute: four conv layers (ReLU after each, pools
/// after the first two) plus the optional dense head.
#[derive(Debug, Clone)]
pub struct FoldedStudent {
    pub conv1: Conv,
    pub conv2: Conv,
    pub conv3: Conv,
    pub conv4: Conv,
    pub head: Option<Dense>,
}

impl FoldedStudent {
    pub fn from_params(p: &StudentParams) -> FoldedStudent {
        FoldedStudent {
            conv1: fold_conv_bn(&p.conv1, &p.bn1),
            conv2: fold_conv_bn(&p.conv2, &p.bn2),
            conv3: p.conv3.clone(),
            conv4: p.conv4.clone(),
            head: p.head.clone(),
        }
    }

    /// Feature width implied by the layer stack at `image_size`: two 2x2
    /// pools, then the VALID conv4 shrink (per-axis — conv4 need not be
    /// square).
    pub fn feature_len(&self, image_size: usize) -> usize {
        let sh = image_size / 4 + 1 - self.conv4.kh;
        let sw = image_size / 4 + 1 - self.conv4.kw;
        sh * sw * self.conv4.cout
    }
}

/// Resolve the student parameter set for `cfg`: weight sidecars when the
/// artifacts directory exists (detected by `meta.json`, the same probe
/// [`Meta::load_or_synthetic`] uses), synthetic weights otherwise.  Shared
/// by [`InterpBackend`] and [`super::fast::FastBackend`] so both engines
/// always serve the same model.
pub fn load_student_params(cfg: &ServeConfig, meta: &Meta) -> Result<StudentParams> {
    if cfg.artifacts_dir.join("meta.json").is_file() {
        load_sidecars(&cfg.artifacts_dir, meta)
    } else {
        Ok(StudentParams::synthetic(SYNTH_WEIGHT_SEED))
    }
}

fn load_sidecars(dir: &Path, meta: &Meta) -> Result<StudentParams> {
    let b = meta.artifacts.batch_sizes.iter().min().copied().unwrap_or(1);
    let full = params::load_params(dir, &format!("student_softmax_b{b}"))?;
    if !full.is_empty() {
        return StudentParams::from_sidecar(&full, true);
    }
    let fe = params::load_params(dir, &format!("student_fwd_b{b}"))?;
    if !fe.is_empty() {
        return StudentParams::from_sidecar(&fe, false);
    }
    Err(Error::Artifact(format!(
        "no interp-loadable parameter sidecar (student_softmax_b{b}.params.json or \
         student_fwd_b{b}.params.json) in {}",
        dir.display()
    )))
}

/// The pure-Rust scalar execution engine (the numeric oracle the blocked
/// [`super::fast::FastBackend`] is property-tested against).
pub struct InterpBackend {
    folded: FoldedStudent,
    image_size: usize,
    n_features: usize,
}

impl InterpBackend {
    /// Load weights from the artifacts directory when one exists, or fall
    /// back to the synthetic student; batch-norms are folded into conv1/2
    /// at load time.
    pub fn new(cfg: &ServeConfig, meta: &Meta) -> Result<InterpBackend> {
        let backend = Self::from_params(load_student_params(cfg, meta)?, meta.artifacts.image_size);
        if backend.n_features != meta.artifacts.n_features {
            return Err(Error::Artifact(format!(
                "interp front-end produces {} features, meta.json says {}",
                backend.n_features, meta.artifacts.n_features
            )));
        }
        Ok(backend)
    }

    /// Build directly from a parameter set (benches and tests).
    pub fn from_params(params: StudentParams, image_size: usize) -> InterpBackend {
        let folded = FoldedStudent::from_params(&params);
        let n_features = folded.feature_len(image_size);
        InterpBackend {
            folded,
            image_size,
            n_features,
        }
    }

    /// The full `student_features` forward pass for one `[s, s, 1]` image
    /// (batch-norm already folded into the conv weights).
    fn forward_one(&self, img: &[f32]) -> Vec<f32> {
        let p = &self.folded;
        let s = self.image_size;
        let (mut h, hh, ww) = conv(img, s, s, &p.conv1, Padding::Same);
        kernels::relu(&mut h);
        let (h, hh, ww) = kernels::maxpool2(&h, hh, ww, p.conv1.cout);
        let (mut h, hh, ww) = conv(&h, hh, ww, &p.conv2, Padding::Same);
        kernels::relu(&mut h);
        let (h, hh, ww) = kernels::maxpool2(&h, hh, ww, p.conv2.cout);
        let (mut h, hh, ww) = conv(&h, hh, ww, &p.conv3, Padding::Same);
        kernels::relu(&mut h);
        let (mut h, _hh, _ww) = conv(&h, hh, ww, &p.conv4, Padding::Valid);
        kernels::relu(&mut h);
        h
    }
}

impl FrontEnd for InterpBackend {
    fn name(&self) -> &'static str {
        "interp"
    }

    fn extract_features(&mut self, images: &[f32], n: usize) -> Result<Vec<f32>> {
        let img_len = self.image_size * self.image_size;
        if images.len() != n * img_len {
            return Err(Error::Request(format!(
                "batch buffer has {} floats, expected {} ({n} images)",
                images.len(),
                n * img_len
            )));
        }
        let mut out = Vec::with_capacity(n * self.n_features);
        for img in images.chunks_exact(img_len) {
            out.extend(self.forward_one(img));
        }
        Ok(out)
    }

    fn logits(&mut self, images: &[f32], n: usize, num_classes: usize) -> Result<Vec<f32>> {
        let feats = self.extract_features(images, n)?;
        let head = self.folded.head.as_ref().ok_or_else(|| {
            Error::Artifact(
                "softmax head unavailable (feature-extractor-only parameter set)".into(),
            )
        })?;
        if head.dout != num_classes {
            return Err(Error::Config(format!(
                "head emits {} classes, pipeline expects {num_classes}",
                head.dout
            )));
        }
        if head.din != self.n_features {
            return Err(Error::Artifact(format!(
                "head expects {} features, front-end produces {}",
                head.din, self.n_features
            )));
        }
        Ok(kernels::dense(&feats, n, head.din, &head.w, &head.b, head.dout))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, scale: f64, off: f64) -> Vec<f32> {
        (0..n).map(|i| (i as f64 * scale + off) as f32).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len(), "length mismatch");
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() <= tol + tol * w.abs(),
                "element {i}: got {g}, want {w}"
            );
        }
    }

    /// A tiny student (8x8 input, channels 2/3/4/5) with deterministic
    /// weights; goldens generated by running the identical layer chain
    /// through python/compile/kernels/ref.py (see the PR's golden
    /// generator: conv1 SAME -> bn -> relu -> pool -> conv2 SAME -> bn ->
    /// relu -> pool -> conv3 SAME -> relu -> conv4 VALID -> relu).
    fn mini_student() -> InterpBackend {
        InterpBackend::from_params(mini_params(), 8)
    }

    fn mini_params() -> StudentParams {
        StudentParams {
            conv1: Conv {
                w: seq(18, 0.11, -0.9),
                b: vec![0.05, -0.1],
                kh: 3,
                kw: 3,
                cin: 1,
                cout: 2,
            },
            bn1: BatchNorm {
                gamma: vec![1.1, 0.9],
                beta: vec![0.02, -0.03],
                mean: vec![0.3, -0.2],
                var: vec![0.8, 1.3],
            },
            conv2: Conv {
                w: seq(54, 0.04, -1.0),
                b: vec![0.0, 0.1, -0.05],
                kh: 3,
                kw: 3,
                cin: 2,
                cout: 3,
            },
            bn2: BatchNorm {
                gamma: vec![0.95, 1.05, 1.0],
                beta: vec![0.0, 0.05, -0.02],
                mean: vec![0.1, 0.0, -0.1],
                var: vec![1.1, 0.9, 1.0],
            },
            conv3: Conv {
                w: seq(108, 0.02, -0.3),
                b: vec![0.01, -0.01, 0.02, 0.0],
                kh: 3,
                kw: 3,
                cin: 3,
                cout: 4,
            },
            conv4: Conv {
                w: seq(80, 0.01, -0.15),
                b: vec![0.0, 0.02, -0.02, 0.01, -0.01],
                kh: 2,
                kw: 2,
                cin: 4,
                cout: 5,
            },
            head: Some(Dense {
                w: seq(50, 0.017, -0.4),
                b: seq(10, 0.01, -0.04),
                din: 5,
                dout: 10,
            }),
        }
    }

    /// The folded forward pass must reproduce the explicit
    /// conv -> batchnorm -> relu reference chain (the two per-pixel BN
    /// passes that folding eliminates) to fp-noise tolerance.
    #[test]
    fn folded_forward_matches_unfolded_reference() {
        let p = mini_params();
        let img = seq(64, 0.03, -0.9);
        // Unfolded reference: explicit BN passes after conv1 and conv2.
        let (mut h, hh, ww) = conv(&img, 8, 8, &p.conv1, Padding::Same);
        let bn1 = &p.bn1;
        kernels::batchnorm(&mut h, p.conv1.cout, &bn1.gamma, &bn1.beta, &bn1.mean, &bn1.var);
        kernels::relu(&mut h);
        let (h, hh, ww) = kernels::maxpool2(&h, hh, ww, p.conv1.cout);
        let (mut h, hh, ww) = conv(&h, hh, ww, &p.conv2, Padding::Same);
        let bn2 = &p.bn2;
        kernels::batchnorm(&mut h, p.conv2.cout, &bn2.gamma, &bn2.beta, &bn2.mean, &bn2.var);
        kernels::relu(&mut h);
        let (h, hh, ww) = kernels::maxpool2(&h, hh, ww, p.conv2.cout);
        let (mut h, hh, ww) = conv(&h, hh, ww, &p.conv3, Padding::Same);
        kernels::relu(&mut h);
        let (mut want, _, _) = conv(&h, hh, ww, &p.conv4, Padding::Valid);
        kernels::relu(&mut want);

        let mut be = InterpBackend::from_params(p, 8);
        let got = be.extract_features(&img, 1).unwrap();
        assert_close(&got, &want, 1e-5);
    }

    #[test]
    fn mini_student_features_match_ref_chain() {
        let mut be = mini_student();
        let img = seq(64, 0.03, -0.9);
        let feats = be.extract_features(&img, 1).unwrap();
        let want = [40.4683, 44.6168, 48.7053, 52.8638, 56.9724];
        assert_close(&feats, &want, 1e-3);
    }

    #[test]
    fn mini_student_logits_match_ref_chain() {
        let mut be = mini_student();
        let img = seq(64, 0.03, -0.9);
        let logits = be.logits(&img, 1, 10).unwrap();
        let want = [
            -7.64424, -3.49259, 0.659067, 4.81072, 8.96237, 13.114, 17.2657, 21.4173, 25.569,
            29.7206,
        ];
        assert_close(&logits, &want, 1e-3);
    }

    #[test]
    fn synthetic_params_are_deterministic_and_shaped() {
        let a = StudentParams::synthetic(7);
        let b = StudentParams::synthetic(7);
        assert_eq!(a.conv1.w, b.conv1.w);
        assert_eq!(a.head.as_ref().unwrap().w, b.head.as_ref().unwrap().w);
        let [f1, f2, f3, f4] = SYNTH_FILTERS;
        assert_eq!(a.conv1.w.len(), 9 * f1);
        assert_eq!(a.conv2.w.len(), 9 * f1 * f2);
        assert_eq!(a.conv3.w.len(), 9 * f2 * f3);
        assert_eq!(a.conv4.w.len(), 4 * f3 * f4);
        assert_eq!(a.head.as_ref().unwrap().din, 7 * 7 * f4);
    }

    #[test]
    fn batch_and_single_extraction_agree() {
        let mut be = mini_student();
        let one = seq(64, 0.03, -0.9);
        let mut three = Vec::new();
        for _ in 0..3 {
            three.extend_from_slice(&one);
        }
        let f1 = be.extract_features(&one, 1).unwrap();
        let f3 = be.extract_features(&three, 3).unwrap();
        for i in 0..3 {
            assert_eq!(&f3[i * 5..(i + 1) * 5], &f1[..]);
        }
    }

    #[test]
    fn wrong_buffer_size_is_request_error() {
        let mut be = mini_student();
        match be.extract_features(&[0.0; 10], 1) {
            Err(Error::Request(_)) => {}
            other => panic!("expected request error, got {:?}", other.map(|v| v.len())),
        }
    }

    #[test]
    fn sidecar_roundtrip_reconstructs_params() {
        // Build an 18-array sidecar in the export order from a synthetic
        // student, then reload it and compare.
        let sp = StudentParams::synthetic(3);
        let head = sp.head.clone().unwrap();
        let arr = |shape: Vec<usize>, data: &[f32]| ParamArray {
            shape,
            data: data.to_vec(),
        };
        let conv_arrays = |c: &Conv| {
            (
                arr(vec![c.cout], &c.b),
                arr(vec![c.kh, c.kw, c.cin, c.cout], &c.w),
            )
        };
        let (c1b, c1w) = conv_arrays(&sp.conv1);
        let (c2b, c2w) = conv_arrays(&sp.conv2);
        let (c3b, c3w) = conv_arrays(&sp.conv3);
        let (c4b, c4w) = conv_arrays(&sp.conv4);
        let arrays = vec![
            arr(vec![sp.conv1.cout], &sp.bn1.beta),
            arr(vec![sp.conv1.cout], &sp.bn1.gamma),
            arr(vec![sp.conv2.cout], &sp.bn2.beta),
            arr(vec![sp.conv2.cout], &sp.bn2.gamma),
            c1b,
            c1w,
            c2b,
            c2w,
            c3b,
            c3w,
            c4b,
            c4w,
            arr(vec![head.dout], &head.b),
            arr(vec![head.din, head.dout], &head.w),
            arr(vec![sp.conv1.cout], &sp.bn1.mean),
            arr(vec![sp.conv1.cout], &sp.bn1.var),
            arr(vec![sp.conv2.cout], &sp.bn2.mean),
            arr(vec![sp.conv2.cout], &sp.bn2.var),
        ];
        let re = StudentParams::from_sidecar(&arrays, true).unwrap();
        assert_eq!(re.conv1.w, sp.conv1.w);
        assert_eq!(re.conv4.cout, sp.conv4.cout);
        assert_eq!(re.head.unwrap().w, head.w);

        // Wrong array count is rejected.
        assert!(StudentParams::from_sidecar(&arrays[..16], true).is_err());
    }
}

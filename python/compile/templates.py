"""Template generation for ACAM deployment (Section II-D1).

Turns the student's 784-feature maps into the back-end's stored patterns:

* per-feature **thresholds** (mean- or median-based, Fig. 1) binarise feature
  maps;
* one or more **templates per class** (Table II): k-means centroids over the
  class's binary feature maps, quality-checked with silhouette scores;
* per-template **matching windows** [lo, hi] for the similarity model
  (Eq. 9-11) and for programming the ACAM cells' RRAM conductance pairs.

k-means and silhouette are hand-rolled (no sklearn in this environment) and
mirrored in ``rust/src/kmeans/`` for on-device template refresh.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Thresholding (Section II-C / Fig. 1)
# ---------------------------------------------------------------------------


def feature_thresholds(features: np.ndarray, mode: str = "mean") -> np.ndarray:
    """Per-feature binarisation threshold over the training set.

    mean mode: ReLU sparsity drags the mean *below* the median, so low-
    magnitude informative activations survive binarisation (the paper's
    argument for mean over median).
    """
    if mode == "mean":
        return features.mean(axis=0)
    if mode == "median":
        return np.median(features, axis=0)
    raise ValueError(f"unknown threshold mode: {mode}")


def binarize(features: np.ndarray, thresholds: np.ndarray) -> np.ndarray:
    return (features > thresholds[None, :]).astype(np.float32)


# ---------------------------------------------------------------------------
# k-means + silhouette (hand-rolled; mirrored in rust/src/kmeans)
# ---------------------------------------------------------------------------


def kmeans(
    x: np.ndarray, k: int, iters: int, restarts: int, rng: np.random.Generator
) -> Tuple[np.ndarray, np.ndarray, float]:
    """Lloyd's algorithm with k-means++ seeding and restarts.

    Returns (centroids [k,N], assignment [n], inertia).  Empty clusters are
    re-seeded from the point farthest from its centroid.
    """
    n = len(x)
    best = None
    for _ in range(max(restarts, 1)):
        cents = _kmeanspp(x, k, rng)
        assign = np.zeros(n, dtype=np.int64)
        for _ in range(iters):
            d = ((x[:, None, :] - cents[None, :, :]) ** 2).sum(-1)  # [n,k]
            new_assign = d.argmin(1)
            for c in range(k):
                sel = new_assign == c
                if sel.any():
                    cents[c] = x[sel].mean(0)
                else:  # re-seed empty cluster at the worst-fit point
                    cents[c] = x[d.min(1).argmax()]
            if (new_assign == assign).all():
                assign = new_assign
                break
            assign = new_assign
        inertia = float(((x - cents[assign]) ** 2).sum())
        if best is None or inertia < best[2]:
            best = (cents.copy(), assign.copy(), inertia)
    return best


def _kmeanspp(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    n = len(x)
    cents = [x[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(((x[:, None, :] - np.asarray(cents)[None]) ** 2).sum(-1), axis=1)
        if d2.sum() <= 0:
            cents.append(x[rng.integers(n)])
            continue
        probs = d2 / d2.sum()
        cents.append(x[rng.choice(n, p=probs)])
    return np.asarray(cents, dtype=np.float64)


def silhouette_score(x: np.ndarray, assign: np.ndarray, sample_cap: int = 256, seed: int = 0) -> float:
    """Mean silhouette over (a capped subsample of) x; single-cluster -> 0."""
    ks = np.unique(assign)
    if len(ks) < 2:
        return 0.0
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))[: min(sample_cap, len(x))]
    xs, as_ = x[idx], assign[idx]
    d = np.sqrt(((xs[:, None, :] - x[None, :, :]) ** 2).sum(-1))  # [s,n]
    scores = []
    for i in range(len(xs)):
        own = assign == as_[i]
        own_d = d[i][own]
        a = own_d.sum() / max(own.sum() - 1, 1)  # exclude self via sum/(n-1)
        b = np.inf
        for c in ks:
            if c == as_[i]:
                continue
            sel = assign == c
            if sel.any():
                b = min(b, d[i][sel].mean())
        denom = max(a, b)
        scores.append(0.0 if denom == 0 else (b - a) / denom)
    return float(np.mean(scores))


# ---------------------------------------------------------------------------
# Template set generation
# ---------------------------------------------------------------------------


def generate_templates(
    bin_features: np.ndarray,
    real_features: np.ndarray,
    labels: np.ndarray,
    num_classes: int,
    templates_per_class: int,
    kmeans_iters: int = 50,
    kmeans_restarts: int = 4,
    window_margin: float = 0.0,
    seed: int = 0,
) -> Dict:
    """Build the template store.

    Per class: k-means (k = templates_per_class) on the class's *binary*
    feature maps; centroid > 0.5 gives the binary template (k = 1 degenerates
    to the majority-vote template).  Matching windows for the similarity model
    and ACAM programming come from the *real-valued* features of the cluster
    members: [p10, p90] per feature, widened by ``window_margin``.

    Returns a dict ready to serialise as templates.json:
      templates   [M][N] 0/1 ints
      lo, hi      [M][N] floats (real-feature windows)
      bin_lo/hi   [M][N] floats (binary-domain windows: t +/- 0.5)
      class_of    [M] ints
      silhouette  per-class scores (the Table II clustering diagnostic)
    """
    rng = np.random.default_rng(seed)
    templates, los, his, blos, bhis, class_of, silhouettes = [], [], [], [], [], [], []
    for c in range(num_classes):
        sel = labels == c
        xb, xr = bin_features[sel], real_features[sel]
        k = min(templates_per_class, max(len(xb), 1))
        if k == 1:
            cents = xb.mean(0, keepdims=True)
            assign = np.zeros(len(xb), dtype=np.int64)
            sil = 0.0
        else:
            cents, assign, _ = kmeans(xb.astype(np.float64), k, kmeans_iters, kmeans_restarts, rng)
            sil = silhouette_score(xb.astype(np.float64), assign, seed=seed + c)
        for ci in range(len(cents)):
            t = (cents[ci] > 0.5).astype(np.int8)
            members = xr[assign == ci] if (assign == ci).any() else xr
            lo = np.percentile(members, 10, axis=0) - window_margin
            hi = np.percentile(members, 90, axis=0) + window_margin
            templates.append(t)
            los.append(lo.astype(np.float32))
            his.append(np.maximum(hi, lo).astype(np.float32))
            blos.append(t.astype(np.float32) - 0.5)
            bhis.append(t.astype(np.float32) + 0.5)
            class_of.append(c)
        silhouettes.append(sil)
    return {
        "templates": np.asarray(templates),
        "lo": np.asarray(los),
        "hi": np.asarray(his),
        "bin_lo": np.asarray(blos),
        "bin_hi": np.asarray(bhis),
        "class_of": np.asarray(class_of, dtype=np.int32),
        "silhouette": silhouettes,
        "templates_per_class": templates_per_class,
    }


# ---------------------------------------------------------------------------
# Matching-based evaluation (numpy reference used by run_experiments)
# ---------------------------------------------------------------------------


def match_predict_fc(binq: np.ndarray, store: Dict, num_classes: int) -> np.ndarray:
    """Eq. 8 + Eq. 12 (per-class max over that class's templates)."""
    t = store["templates"].astype(np.float32)
    scores = (binq[:, None, :] == t[None, :, :]).sum(-1)  # [B,M]
    return _argmax_per_class(scores, store["class_of"], num_classes)


def match_predict_sim(
    q: np.ndarray, store: Dict, num_classes: int, alpha: float, binary: bool = True
) -> np.ndarray:
    """Eq. 9-12 against the binary-domain (or real-domain) windows."""
    lo = store["bin_lo"] if binary else store["lo"]
    hi = store["bin_hi"] if binary else store["hi"]
    qb = q[:, None, :]
    over = np.maximum(qb - hi[None], 0.0)
    under = np.maximum(lo[None] - qb, 0.0)
    d = (over * over + under * under).sum(-1)
    h = ((qb >= lo[None]) & (qb <= hi[None])).mean(-1)
    scores = h / (1.0 + alpha * d)
    return _argmax_per_class(scores, store["class_of"], num_classes)


def _argmax_per_class(scores: np.ndarray, class_of: np.ndarray, num_classes: int) -> np.ndarray:
    best = np.full((len(scores), num_classes), -np.inf)
    for m, c in enumerate(class_of):
        best[:, c] = np.maximum(best[:, c], scores[:, m])
    return best.argmax(1)

//! Back-end variant trade-off bench: accuracy vs per-op energy for every
//! [`hec::backend::BackendVariant`], through the same `Pipeline` serving
//! path the coordinator uses.
//!
//! Emits `BENCH_backends.json` (override the path with `HEC_BENCH_OUT`)
//! with one row per variant — classification accuracy on a labelled
//! synthetic workload, per-op back-end energy, re-program energy, and
//! serve-loop latency — and replays the paper's E_back-end = 1.45 nJ
//! point: the default TXL variant's measured per-cell search energy,
//! scaled to the published 10x784 array, must land on Eq. 14's figure.
//! `HEC_BENCH_SMOKE=1` shrinks the request count for CI.

use std::time::Instant;

use hec::backend::BackendVariant;
use hec::benchkit::{section, BenchResult};
use hec::config::{Backend, Engine, ServeConfig};
use hec::coordinator::Pipeline;
use hec::dataset::SyntheticDataset;
use hec::energy::constants as c;
use hec::jsonlite::Value;
use hec::runtime::Meta;

struct VariantOutcome {
    variant: &'static str,
    accuracy: f64,
    per_op_backend_nj: f64,
    reprogram_nj: f64,
    result: BenchResult,
}

fn run_variant(variant: BackendVariant, images: &[Vec<f32>], labels: &[usize]) -> VariantOutcome {
    let mut cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::AcamSim,
        engine: Engine::Interp,
        ..Default::default()
    };
    cfg.backend_variant = Some(variant);
    let mut p = Pipeline::new(&cfg).unwrap();

    let mut correct = 0usize;
    let mut per_op = 0f64;
    let mut lat_us: Vec<u64> = Vec::with_capacity(images.len());
    let t0 = Instant::now();
    for (img, &label) in images.iter().zip(labels.iter()) {
        let t = Instant::now();
        let out = p.classify_batch(img, 1).unwrap().remove(0);
        lat_us.push(t.elapsed().as_micros() as u64);
        if out.top1().class == label {
            correct += 1;
        }
        per_op = out.energy.back_end_nj;
    }
    let secs = t0.elapsed().as_secs_f64();
    let accuracy = correct as f64 / images.len() as f64;

    let set = p.store.set(1).unwrap();
    let (rows, width) = (set.num_templates() as u64, set.num_features() as u64);
    let ideal = hec::acam::Variability::ideal();
    let unit = hec::backend::build_unit(variant, cfg.acam.cell_kind, set, &ideal, cfg.acam.seed);
    let reprogram_nj = unit.reprogram_nj(rows, width);

    lat_us.sort_unstable();
    let pct = |p: f64| lat_us[((lat_us.len() - 1) as f64 * p) as usize];
    let result = BenchResult {
        name: format!("serve_{}", variant.name()),
        iters: images.len(),
        mean: std::time::Duration::from_secs_f64(secs / images.len() as f64),
        p50: std::time::Duration::from_micros(pct(0.50)),
        p99: std::time::Duration::from_micros(pct(0.99)),
        min: std::time::Duration::from_micros(lat_us[0]),
    };
    println!(
        "  {:<10} accuracy {:.3}  per-op {:.4} nJ  re-program {:.1} nJ  ({} requests)",
        variant.name(),
        accuracy,
        per_op,
        reprogram_nj,
        images.len()
    );
    VariantOutcome {
        variant: variant.name(),
        accuracy,
        per_op_backend_nj: per_op,
        reprogram_nj,
        result,
    }
}

fn main() {
    let smoke = std::env::var("HEC_BENCH_SMOKE").is_ok();
    let requests = if smoke { 60 } else { 300 };
    let have_artifacts = std::path::Path::new("artifacts/meta.json").is_file();
    if !have_artifacts {
        println!("backend_tradeoff: no artifacts/ — serving the synthetic fallback deployment");
    }
    let meta = Meta::load_or_synthetic("artifacts").unwrap();
    let ds = SyntheticDataset::new(2_718_281, requests, meta.norm.mean as f32, meta.norm.std as f32);
    let images: Vec<Vec<f32>> = (0..requests).map(|i| ds.image(i)).collect();
    let labels: Vec<usize> = (0..requests).map(|i| ds.label(i)).collect();

    section("accuracy vs per-op energy, all variants");
    let outcomes: Vec<VariantOutcome> = BackendVariant::ALL
        .iter()
        .map(|&v| run_variant(v, &images, &labels))
        .collect();
    let by_name = |n: &str| outcomes.iter().find(|o| o.variant == n).unwrap();

    // The deployed geometry may be synthetic; scale the measured per-op
    // figure back to per-cell and forward to the published 10x784 array.
    // For the default TXL variant that replays Eq. 14's E_back-end.
    let p = Pipeline::new(&ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::AcamSim,
        engine: Engine::Interp,
        ..Default::default()
    })
    .unwrap();
    let set = p.store.set(1).unwrap();
    let cells = (set.num_templates() * set.num_features()) as f64;
    let paper_cells = (c::N_TEMPLATES * c::N_FEATURES) as f64;
    let acam_paper_nj = by_name("acam").per_op_backend_nj / cells * paper_cells;

    section("paper replay: E_back-end at 10x784");
    println!(
        "  acam per-op at paper geometry: {acam_paper_nj:.4} nJ (published {} nJ)",
        c::E_BACKEND_NJ
    );
    assert!(
        (acam_paper_nj - c::E_BACKEND_NJ).abs() < 0.01,
        "default variant must replay the paper's E_back-end: got {acam_paper_nj} nJ"
    );

    // Trade-off sanity: energy follows the per-cell constants; the exact
    // digital reference is never *less* accurate than an analogue variant
    // at the ideal corner, where acam agrees with it bit for bit.
    assert!(by_name("acam-9t4r").per_op_backend_nj > by_name("acam").per_op_backend_nj);
    assert!(by_name("acam").per_op_backend_nj > by_name("rbf").per_op_backend_nj);
    assert_eq!(by_name("acam").accuracy, by_name("digital").accuracy);
    for o in &outcomes {
        assert!(o.accuracy > 0.5, "{} accuracy collapsed: {}", o.variant, o.accuracy);
    }

    let keyed: Vec<(String, Value)> = outcomes
        .iter()
        .flat_map(|o| {
            [
                (format!("{}_accuracy", o.variant), Value::Num(o.accuracy)),
                (
                    format!("{}_per_op_backend_nj", o.variant),
                    Value::Num(o.per_op_backend_nj),
                ),
                (
                    format!("{}_reprogram_nj", o.variant),
                    Value::Num(o.reprogram_nj),
                ),
            ]
        })
        .collect();
    let mut extra: Vec<(&str, Value)> = vec![
        ("requests", Value::Num(requests as f64)),
        ("smoke", Value::Bool(smoke)),
        ("artifacts", Value::Bool(have_artifacts)),
        ("acam_paper_geometry_nj", Value::Num(acam_paper_nj)),
        ("published_e_backend_nj", Value::Num(c::E_BACKEND_NJ)),
    ];
    extra.extend(keyed.iter().map(|(k, v)| (k.as_str(), v.clone())));

    let rows: Vec<&BenchResult> = outcomes.iter().map(|o| &o.result).collect();
    let out = std::env::var("HEC_BENCH_OUT").unwrap_or_else(|_| "BENCH_backends.json".into());
    hec::benchkit::write_json_report(&out, "hec/backend_tradeoff/v1", &extra, &rows)
        .expect("write BENCH_backends.json");
    println!("\nwrote {out} ({} rows)", rows.len());
    println!("backend_tradeoff: PASS");
}

//! HTTP/JSON gateway — the network front door over the v1 [`crate::api`].
//!
//! Built on `std::net` + [`crate::jsonlite`] only (the offline build carries
//! zero external dependencies; SNIPPETS ADR-002 is the prior art for a pure
//! wire stack).  Every HTTP request funnels into the same bounded-channel
//! [`crate::coordinator::Handle`] the in-process callers use, so network
//! load shares the queue semantics, dynamic batching, and backpressure of
//! the rest of the system — a full queue is an HTTP 429, not a new code
//! path.
//!
//! Routes:
//!
//! | Method + path          | Body                      | Response |
//! |------------------------|---------------------------|----------|
//! | `POST /v1/classify`    | [`ClassifyRequest`] JSON  | [`ClassifyResponse`] JSON |
//! | `POST /v1/classify/batch` | `{"requests": [...]}`  | `{"responses": [...]}` (per-item response or error envelope) |
//! | `GET /healthz`         | —                         | deployment facts (engine, backend, image_len, ...) |
//! | `GET /metrics`         | —                         | Prometheus text ([`crate::coordinator::Snapshot::prometheus`]) |
//! | `GET /v1/stores`       | —                         | registered template stores (id, version, origin) |
//! | `GET /v1/stores/{id}`  | —                         | one store snapshot |
//! | `PUT /v1/stores/{id}`  | templates JSON, or labelled features as `application/x-hec-f32` | published snapshot (new version) |
//! | `POST /v1/stores/{id}/refit` | —                   | re-fit outcome (accuracy, published version, re-programming energy) |
//!
//! Store routes 404 on surfaces without a registry (see
//! [`ClassifySurface::store_admin`]).
//!
//! Concurrency model: a dedicated accept thread plus one thread per live
//! connection (keep-alive), capped at `max_connections`; connections over
//! the cap receive an immediate 429 (`QUEUE_FULL` — the cap is
//! backpressure, like the bounded queue).  Thread-per-connection is the right
//! size here because per-connection state is one 8 KiB buffer and the real
//! bottleneck is the serving queue behind the handle.

pub mod http;

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::{binary, stream, ApiError, ClassifyResponse, ErrorCode, API_VERSION};
use crate::config::HttpConfig;
use crate::coordinator::ClassifySurface;
use crate::error::Result;
use crate::jsonlite::Value;

use http::{read_request_with_deadline, write_response, ReadError, Request};

/// Per-connection socket read timeout: bounds how long an idle keep-alive
/// connection pins its thread.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Wall-clock budget for one request *body* transfer.  The socket timeout
/// alone cannot bound a slow-drip upload (a byte every 29 s keeps resetting
/// it); this deadline caps total body time so a wedged client cannot pin a
/// connection thread indefinitely.  Tripping it is a 408 carrying the
/// stable `DEADLINE_EXCEEDED` code, then close.
const BODY_READ_DEADLINE: Duration = Duration::from_secs(30);

/// The running gateway (accept thread + connection threads).
pub struct Gateway {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl Gateway {
    /// Bind `cfg.addr` and start accepting.  Port 0 binds an OS-assigned
    /// free port; [`Gateway::local_addr`] reports the resolved address.
    ///
    /// The gateway serves any [`ClassifySurface`] — a single-pipeline
    /// [`crate::coordinator::Handle`] or a sharded
    /// [`crate::coordinator::ShardHandle`] — the same way: the surface
    /// owns validation, routing and backpressure; the gateway owns HTTP.
    pub fn start<S>(handle: S, cfg: &HttpConfig) -> Result<Gateway>
    where
        S: ClassifySurface + Clone + Send + 'static,
    {
        let addr = cfg.addr.as_deref().unwrap_or("127.0.0.1:0");
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let max_connections = cfg.max_connections;

        let stop_accept = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("hec-gateway".into())
            .spawn(move || {
                let live = Arc::new(AtomicUsize::new(0));
                for stream in listener.incoming() {
                    if stop_accept.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    if live.load(Ordering::Relaxed) >= max_connections {
                        let mut s = stream;
                        let err = ApiError::new(
                            ErrorCode::QueueFull,
                            "connection limit reached, retry later",
                        );
                        // Same status the code maps to everywhere else (429):
                        // the cap is backpressure, not an outage.
                        let _ = write_response(
                            &mut s,
                            err.code.http_status(),
                            "application/json",
                            err.to_value().to_json().as_bytes(),
                            true,
                        );
                        continue;
                    }
                    live.fetch_add(1, Ordering::Relaxed);
                    let conn_live = Arc::clone(&live);
                    let handle = handle.clone();
                    let spawned = std::thread::Builder::new()
                        .name("hec-gateway-conn".into())
                        .spawn(move || {
                            serve_connection(stream, &handle);
                            conn_live.fetch_sub(1, Ordering::Relaxed);
                        });
                    if spawned.is_err() {
                        // Thread spawn failed (resource exhaustion): the
                        // closure never ran, so give the slot back instead
                        // of leaking it until the cap locks the gateway up.
                        live.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            })
            .expect("spawn gateway accept thread");

        Ok(Gateway {
            local_addr,
            stop,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting and join the accept thread.  Live connection threads
    /// finish their current exchange and exit on their own (bounded by the
    /// read timeout).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop with a wake-up connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

/// Serve one keep-alive connection until EOF / `Connection: close` /
/// protocol error.
fn serve_connection<S: ClassifySurface>(stream: TcpStream, handle: &S) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        match read_request_with_deadline(&mut reader, Some(BODY_READ_DEADLINE)) {
            Err(ReadError::Eof) => return,
            Err(ReadError::Bad(status, msg)) => {
                // 408 is the body-read deadline tripping (a stalled upload
                // pinning the connection thread), not a malformed request —
                // it carries the deadline code so clients can distinguish
                // "send faster" from "fix the request".  411 is a bodied
                // request with no framing header at all: its own stable
                // code, because the fix (add Content-Length) differs from
                // every other malformed-request repair.
                let code = if status == 408 {
                    ErrorCode::DeadlineExceeded
                } else if status == 411 {
                    ErrorCode::LengthRequired
                } else {
                    ErrorCode::MalformedRequest
                };
                let err = ApiError::new(code, msg);
                let _ = write_response(
                    &mut writer,
                    status,
                    "application/json",
                    err.to_value().to_json().as_bytes(),
                    true,
                );
                return;
            }
            Ok(req) => {
                let close = req.close;
                if !respond(&mut writer, &req, handle, close) {
                    return;
                }
                if close {
                    return;
                }
            }
        }
    }
}

/// Route one request and write the response; returns false when the
/// connection should drop (write failure).
fn respond<W: Write, S: ClassifySurface>(
    out: &mut W,
    req: &Request,
    handle: &S,
    close: bool,
) -> bool {
    let (status, content_type, body) = route(req, handle);
    write_response(out, status, content_type, body.as_bytes(), close).is_ok()
}

/// The routing table: returns (status, content type, body).
fn route<S: ClassifySurface>(req: &Request, handle: &S) -> (u16, &'static str, String) {
    if req.path == "/v1/stores" || req.path.starts_with("/v1/stores/") {
        return store_route(req, handle);
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/classify") => match classify_one(req, handle) {
            Ok(resp) => (200, "application/json", resp.to_value().to_json()),
            Err(e) => (e.code.http_status(), "application/json", e.to_value().to_json()),
        },
        ("POST", "/v1/classify/batch") => match classify_batch(req, handle) {
            Ok(v) => (200, "application/json", v.to_json()),
            Err(e) => (e.code.http_status(), "application/json", e.to_value().to_json()),
        },
        ("GET", "/healthz") => (200, "application/json", healthz(handle).to_json()),
        ("GET", "/metrics") => (
            200,
            "text/plain; version=0.0.4",
            handle.prometheus_text(),
        ),
        (_, "/v1/classify") | (_, "/v1/classify/batch") | (_, "/healthz") | (_, "/metrics") => {
            let e = ApiError::new(
                ErrorCode::MethodNotAllowed,
                format!("method {} not allowed on {}", req.method, req.path),
            );
            (405, "application/json", e.to_value().to_json())
        }
        _ => {
            let e = ApiError::new(
                ErrorCode::NotFound,
                format!("no route for {}", req.path),
            );
            (404, "application/json", e.to_value().to_json())
        }
    }
}

/// `/v1/stores` admin routes: list / inspect / upload / re-fit template
/// stores on the surface's [`crate::store::StoreRegistry`].  Surfaces
/// without a registry (`store_admin() == None`, e.g. transport-only test
/// doubles) answer 404 for the whole subtree, exactly as if the routes did
/// not exist.
fn store_route<S: ClassifySurface>(req: &Request, handle: &S) -> (u16, &'static str, String) {
    let json = "application/json";
    let fail = |e: ApiError| (e.code.http_status(), json, e.to_value().to_json());
    let Some(admin) = handle.store_admin() else {
        return fail(ApiError::new(
            ErrorCode::NotFound,
            format!("no route for {}", req.path),
        ));
    };
    // Split "/v1/stores[/{id}[/refit]]" into its (id, action) tail.
    let tail = req.path.strip_prefix("/v1/stores").unwrap_or("");
    let (id, action) = match tail.strip_prefix('/') {
        None => ("", ""),
        Some(rest) => match rest.split_once('/') {
            None => (rest, ""),
            Some((id, action)) => (id, action),
        },
    };
    let wrap = |mut fields: BTreeMap<String, Value>| {
        fields.insert("api".to_string(), Value::Str(API_VERSION.to_string()));
        Value::Obj(fields)
    };
    // Stamp the API version onto an object-shaped payload (snapshots and
    // re-fit outcomes always render as objects).
    let stamped = |v: Value| match v {
        Value::Obj(fields) => wrap(fields).to_json(),
        other => other.to_json(),
    };
    match (req.method.as_str(), id, action) {
        ("GET", "", "") => {
            let stores: Vec<Value> = admin.list().iter().map(|s| s.to_value()).collect();
            (
                200,
                json,
                wrap(BTreeMap::from([(
                    "stores".to_string(),
                    Value::Arr(stores),
                )]))
                .to_json(),
            )
        }
        ("GET", id, "") => match admin.get(id) {
            Some(snap) => (200, json, stamped(snap.to_value())),
            None => fail(ApiError::new(
                ErrorCode::NotFound,
                format!("no store '{id}'"),
            )),
        },
        ("PUT", id, "") => {
            let published = if is_binary(req) {
                admin.put_binary(id, &req.body)
            } else {
                match body_text(&req.body) {
                    Ok(text) => admin.put_json(id, text),
                    Err(e) => Err(e),
                }
            };
            match published {
                Ok(snap) => (200, json, stamped(snap.to_value())),
                Err(e) => fail(e),
            }
        }
        ("POST", id, "refit") => match admin.refit(id) {
            Ok(outcome) => (200, json, stamped(outcome.to_value())),
            Err(e) => fail(e),
        },
        (_, _, "") | (_, _, "refit") => fail(ApiError::new(
            ErrorCode::MethodNotAllowed,
            format!("method {} not allowed on {}", req.method, req.path),
        )),
        _ => fail(ApiError::new(
            ErrorCode::NotFound,
            format!("no route for {}", req.path),
        )),
    }
}

/// Is this request's `Content-Type` the raw-binary image encoding
/// ([`binary::CONTENT_TYPE`])?  Media-type parameters after `;` are
/// tolerated; everything else (including absent) means JSON.
fn is_binary(req: &Request) -> bool {
    req.header("content-type")
        .map(|ct| ct.split(';').next().unwrap_or("").trim())
        .is_some_and(|mt| mt.eq_ignore_ascii_case(binary::CONTENT_TYPE))
}

fn body_text(body: &[u8]) -> std::result::Result<&str, ApiError> {
    std::str::from_utf8(body)
        .map_err(|_| ApiError::new(ErrorCode::MalformedRequest, "body is not UTF-8"))
}

/// `POST /v1/classify`: decode (streaming JSON or raw binary, no
/// intermediate `Value` tree), submit through the bounded queue, block for
/// the response (the connection thread is the waiter, mirroring an
/// in-process `submit_blocking` caller).
fn classify_one<S: ClassifySurface>(
    req: &Request,
    handle: &S,
) -> std::result::Result<ClassifyResponse, ApiError> {
    let decoded = if is_binary(req) {
        binary::decode_single(&req.body)?
    } else {
        stream::decode_classify_request(body_text(&req.body)?, handle.caps().image_len)?
    };
    handle.submit_blocking(decoded)
}

/// `POST /v1/classify/batch`: submit every item before collecting any
/// response, so one HTTP batch becomes co-batchable work for the dynamic
/// batcher instead of a serial request chain — with the streaming decoders,
/// each item is submitted *while later items are still being parsed*.  Item
/// failures (shape, queue full) become per-item error envelopes; the call
/// itself is 200.
fn classify_batch<S: ClassifySurface>(
    req: &Request,
    handle: &S,
) -> std::result::Result<Value, ApiError> {
    let submit = |item: std::result::Result<_, ApiError>| item.and_then(|r| handle.submit(r));
    let pending = if is_binary(req) {
        binary::decode_batch_with(&req.body, submit)?
    } else {
        stream::decode_batch_envelope(
            body_text(&req.body)?,
            handle.caps().image_len,
            submit,
        )?
    };
    let responses: Vec<Value> = pending
        .into_iter()
        .map(|p| match p {
            Ok(rx) => match rx.recv() {
                Ok(Ok(resp)) => resp.to_value(),
                Ok(Err(e)) => e.to_value(),
                Err(_) => ApiError::new(ErrorCode::Internal, "worker dropped response")
                    .to_value(),
            },
            Err(e) => e.to_value(),
        })
        .collect();
    Ok(Value::Obj(BTreeMap::from([(
        "responses".to_string(),
        Value::Arr(responses),
    )])))
}

/// `GET /healthz`: liveness + the deployment facts a client needs to build
/// valid requests.  Sharded deployments additionally report per-shard
/// health, and `status` becomes `"degraded"` while any shard is down —
/// the deployment still serves (healthy shards absorb the traffic), but an
/// operator's probe sees the reduced capacity.
fn healthz<S: ClassifySurface>(handle: &S) -> Value {
    let caps = handle.caps();
    let health = handle.health();
    let mut m = BTreeMap::from([
        (
            "status".to_string(),
            Value::Str(if health.degraded { "degraded" } else { "ok" }.to_string()),
        ),
        ("api".to_string(), Value::Str(API_VERSION.to_string())),
        (
            "engine".to_string(),
            Value::Str(caps.engine.to_string()),
        ),
        (
            "backend".to_string(),
            Value::Str(caps.backend.name().to_string()),
        ),
        (
            "image_len".to_string(),
            Value::Num(caps.image_len as f64),
        ),
        (
            "num_classes".to_string(),
            Value::Num(caps.num_classes as f64),
        ),
        (
            "acam_available".to_string(),
            Value::Bool(caps.acam_available),
        ),
        (
            "backend_variant".to_string(),
            Value::Str(caps.backend_variant.name().to_string()),
        ),
    ]);
    // Registry-backed deployments additionally publish the template-store
    // geometry, so a `PUT /v1/stores/{id}` client can build a valid HECT
    // frame (n_features rows) from `/healthz` alone.
    if let Some(admin) = handle.store_admin() {
        let (_, n_features, k) = admin.registry().geometry();
        m.insert("n_features".to_string(), Value::Num(n_features as f64));
        m.insert(
            "templates_per_class".to_string(),
            Value::Num(k as f64),
        );
    }
    if !health.shards.is_empty() {
        m.insert(
            "shards".to_string(),
            Value::Arr(
                health
                    .shards
                    .iter()
                    .map(|s| {
                        let mut fields = BTreeMap::from([
                            ("index".to_string(), Value::Num(s.index as f64)),
                            ("healthy".to_string(), Value::Bool(s.healthy)),
                            ("restarts".to_string(), Value::Num(s.restarts as f64)),
                            (
                                "queue_depth".to_string(),
                                Value::Num(s.queue_depth as f64),
                            ),
                            ("in_flight".to_string(), Value::Num(s.in_flight as f64)),
                            (
                                "backend_variant".to_string(),
                                Value::Str(s.backend_variant.to_string()),
                            ),
                        ]);
                        if let Some(state) = s.backend_state {
                            fields.insert(
                                "backend_state".to_string(),
                                Value::Str(state.to_string()),
                            );
                        }
                        Value::Obj(fields)
                    })
                    .collect(),
            ),
        );
    }
    Value::Obj(m)
}

//! Template store: loads, validates and packs `artifacts/templates.json`.
//!
//! The store carries, per k in {1, 2, 3} (Table II):
//! * binary templates (the patterns programmed into the ACAM),
//! * real-feature matching windows `[lo, hi]` (Eq. 9 bounds / RRAM targets),
//! * binary-domain windows (`t ± 0.5`) for the similarity model on binary
//!   queries,
//! * the owning class of each template (Eq. 12 per-class max).
//!
//! Binary templates are additionally packed into u64 words (64 features per
//! word) for the popcount fast path in [`crate::matching`].

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::jsonlite::{self, Value};

/// One template set (a fixed `templates_per_class`).
#[derive(Debug, Clone)]
pub struct TemplateSet {
    /// Binary templates, row-major `[m][n]` with values 0/1.
    pub templates: Vec<Vec<u8>>,
    /// Packed rows: `words_per_row` u64s per template, LSB-first bit order.
    pub packed: Vec<u64>,
    pub words_per_row: usize,
    /// Real-feature windows (Eq. 9 bounds).
    pub lo: Vec<Vec<f32>>,
    pub hi: Vec<Vec<f32>>,
    /// Binary-domain windows (t ± 0.5).
    pub bin_lo: Vec<Vec<f32>>,
    pub bin_hi: Vec<Vec<f32>>,
    /// Owning class per template.
    pub class_of: Vec<usize>,
    /// Per-class silhouette scores from the build-time clustering.
    pub silhouette: Vec<f64>,
}

impl TemplateSet {
    /// Number of stored templates (rows).
    pub fn num_templates(&self) -> usize {
        self.templates.len()
    }

    /// Feature width.
    pub fn num_features(&self) -> usize {
        self.templates.first().map_or(0, |t| t.len())
    }

    /// Pack a binary query the same way the templates are packed.
    pub fn pack_query(&self, q: &[u8]) -> Vec<u64> {
        pack_bits(q, self.words_per_row)
    }

    fn validate(&self, n_features: usize, num_classes: usize) -> Result<()> {
        if self.templates.is_empty() {
            return Err(Error::Template("empty template set".into()));
        }
        for (i, t) in self.templates.iter().enumerate() {
            if t.len() != n_features {
                return Err(Error::Template(format!(
                    "template {i} has {} features, expected {n_features}",
                    t.len()
                )));
            }
            if t.iter().any(|&b| b > 1) {
                return Err(Error::Template(format!("template {i} is not binary")));
            }
        }
        if self.class_of.len() != self.templates.len() {
            return Err(Error::Template("class_of length mismatch".into()));
        }
        if self.class_of.iter().any(|&c| c >= num_classes) {
            return Err(Error::Template("class id out of range".into()));
        }
        let mut seen = vec![false; num_classes];
        for &c in &self.class_of {
            seen[c] = true;
        }
        if !seen.iter().all(|&s| s) {
            return Err(Error::Template("some class has no template".into()));
        }
        for (lo, hi) in self.lo.iter().zip(self.hi.iter()) {
            if lo.len() != n_features || hi.len() != n_features {
                return Err(Error::Template("window width mismatch".into()));
            }
            if lo.iter().zip(hi.iter()).any(|(l, h)| l > h) {
                return Err(Error::Template("window lo > hi".into()));
            }
        }
        // NaN slips past the ordering checks above (all comparisons are
        // false), and a non-finite window would silently never match once
        // programmed into cells — reject it at the validation boundary so
        // uploads fail with INVALID_ARGUMENT instead.
        for w in [&self.lo, &self.hi, &self.bin_lo, &self.bin_hi] {
            if w.iter().flatten().any(|v| !v.is_finite()) {
                return Err(Error::Template("non-finite window value".into()));
            }
        }
        Ok(())
    }
}

/// `[p10, p90]` per-feature windows over the selected member rows, with
/// numpy-style linear interpolation between order statistics.
fn percentile_windows(feats: &[f32], n_features: usize, members: &[usize]) -> (Vec<f32>, Vec<f32>) {
    let mut lo = vec![0f32; n_features];
    let mut hi = vec![0f32; n_features];
    let mut col: Vec<f32> = Vec::with_capacity(members.len());
    for j in 0..n_features {
        col.clear();
        for &i in members {
            col.push(feats[i * n_features + j]);
        }
        col.sort_by(f32::total_cmp);
        let l = percentile_sorted(&col, 10.0);
        let h = percentile_sorted(&col, 90.0);
        lo[j] = l;
        hi[j] = h.max(l);
    }
    (lo, hi)
}

/// Linear-interpolated percentile of a sorted slice (`np.percentile`).
fn percentile_sorted(sorted: &[f32], p: f64) -> f32 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = (n - 1) as f64 * p / 100.0;
    let base = pos.floor() as usize;
    let frac = (pos - base as f64) as f32;
    if base + 1 >= n {
        sorted[n - 1]
    } else {
        sorted[base] + frac * (sorted[base + 1] - sorted[base])
    }
}

/// Pack 0/1 bytes into u64 words, LSB-first.
pub fn pack_bits(bits: &[u8], words_per_row: usize) -> Vec<u64> {
    let mut out = vec![0u64; words_per_row];
    for (i, &b) in bits.iter().enumerate() {
        if b != 0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
    out
}

/// The full store: thresholds + one [`TemplateSet`] per templates-per-class.
#[derive(Debug, Clone)]
pub struct TemplateStore {
    pub num_classes: usize,
    pub n_features: usize,
    /// Per-feature binarisation thresholds (the deployed mode from training).
    pub thresholds: Vec<f32>,
    /// Both threshold variants, kept for the Fig. 1 bench.
    pub thresholds_mean: Vec<f32>,
    pub thresholds_median: Vec<f32>,
    pub threshold_mode: String,
    pub similarity_alpha: f32,
    /// Keyed by templates-per-class (1, 2, 3).
    pub sets: BTreeMap<usize, TemplateSet>,
}

struct RawSet {
    templates: Vec<Vec<u8>>,
    lo: Vec<Vec<f32>>,
    hi: Vec<Vec<f32>>,
    bin_lo: Vec<Vec<f32>>,
    bin_hi: Vec<Vec<f32>>,
    class_of: Vec<usize>,
    silhouette: Vec<f64>,
}

struct RawStore {
    num_classes: usize,
    n_features: usize,
    threshold_mode: String,
    thresholds: Vec<f32>,
    thresholds_mean: Vec<f32>,
    thresholds_median: Vec<f32>,
    similarity_alpha: f32,
    stores: BTreeMap<String, RawSet>,
}

/// Schema-error helper: `field(v.get("x"), "x")?`.
fn field<'a>(v: Option<&'a Value>, name: &str) -> Result<&'a Value> {
    v.ok_or_else(|| Error::Schema(format!("templates.json: missing field '{name}'")))
}

fn f32_matrix(v: &Value, name: &str) -> Result<Vec<Vec<f32>>> {
    v.as_f32_matrix()
        .ok_or_else(|| Error::Schema(format!("templates.json: '{name}' must be a numeric matrix")))
}

fn parse_raw_set(v: &Value) -> Result<RawSet> {
    let templates: Vec<Vec<u8>> = f32_matrix(field(v.get("templates"), "templates")?, "templates")?
        .into_iter()
        .map(|row| row.into_iter().map(|f| f as u8).collect())
        .collect();
    let class_of = field(v.get("class_of"), "class_of")?
        .as_array()
        .ok_or_else(|| Error::Schema("class_of must be an array".into()))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| Error::Schema("class_of must be ints".into())))
        .collect::<Result<Vec<usize>>>()?;
    let silhouette = field(v.get("silhouette"), "silhouette")?
        .as_array()
        .ok_or_else(|| Error::Schema("silhouette must be an array".into()))?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0))
        .collect();
    Ok(RawSet {
        templates,
        lo: f32_matrix(field(v.get("lo"), "lo")?, "lo")?,
        hi: f32_matrix(field(v.get("hi"), "hi")?, "hi")?,
        bin_lo: f32_matrix(field(v.get("bin_lo"), "bin_lo")?, "bin_lo")?,
        bin_hi: f32_matrix(field(v.get("bin_hi"), "bin_hi")?, "bin_hi")?,
        class_of,
        silhouette,
    })
}

impl TemplateStore {
    /// Load and validate `templates.json`.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        Self::from_json_str(&std::fs::read_to_string(path)?)
    }

    /// Parse and validate a store from JSON text in the `templates.json`
    /// schema.  Shared by [`TemplateStore::load`] and the store-registry
    /// admin upload path, which receives the same document over HTTP.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let doc = jsonlite::parse(text)?;
        let f32_vec = |name: &str| -> Result<Vec<f32>> {
            field(doc.get(name), name)?
                .as_f32_vec()
                .ok_or_else(|| Error::Schema(format!("'{name}' must be a numeric array")))
        };
        let mut stores = BTreeMap::new();
        for (k, v) in field(doc.get("stores"), "stores")?
            .as_object()
            .ok_or_else(|| Error::Schema("'stores' must be an object".into()))?
        {
            stores.insert(k.clone(), parse_raw_set(v)?);
        }
        let raw = RawStore {
            num_classes: field(doc.get("num_classes"), "num_classes")?
                .as_usize()
                .ok_or_else(|| Error::Schema("num_classes must be an int".into()))?,
            n_features: field(doc.get("n_features"), "n_features")?
                .as_usize()
                .ok_or_else(|| Error::Schema("n_features must be an int".into()))?,
            threshold_mode: field(doc.get("threshold_mode"), "threshold_mode")?
                .as_str()
                .unwrap_or("mean")
                .to_string(),
            thresholds: f32_vec("thresholds")?,
            thresholds_mean: f32_vec("thresholds_mean")?,
            thresholds_median: f32_vec("thresholds_median")?,
            similarity_alpha: field(doc.get("similarity_alpha"), "similarity_alpha")?
                .as_f64()
                .ok_or_else(|| Error::Schema("similarity_alpha must be a number".into()))?
                as f32,
            stores,
        };
        Self::from_raw(raw)
    }

    fn from_raw(raw: RawStore) -> Result<Self> {
        if raw.thresholds.len() != raw.n_features {
            return Err(Error::Template("threshold width mismatch".into()));
        }
        if raw
            .thresholds
            .iter()
            .chain(raw.thresholds_mean.iter())
            .chain(raw.thresholds_median.iter())
            .any(|v| !v.is_finite())
            || !raw.similarity_alpha.is_finite()
        {
            return Err(Error::Template(
                "non-finite threshold or similarity_alpha".into(),
            ));
        }
        let words_per_row = raw.n_features.div_ceil(64);
        let mut sets = BTreeMap::new();
        for (k, rs) in raw.stores {
            let k: usize = k
                .parse()
                .map_err(|_| Error::Template(format!("bad store key {k}")))?;
            let packed = rs
                .templates
                .iter()
                .flat_map(|t| pack_bits(t, words_per_row))
                .collect();
            let set = TemplateSet {
                templates: rs.templates,
                packed,
                words_per_row,
                lo: rs.lo,
                hi: rs.hi,
                bin_lo: rs.bin_lo,
                bin_hi: rs.bin_hi,
                class_of: rs.class_of,
                silhouette: rs.silhouette,
            };
            set.validate(raw.n_features, raw.num_classes)?;
            sets.insert(k, set);
        }
        if sets.is_empty() {
            return Err(Error::Template("no template sets".into()));
        }
        Ok(TemplateStore {
            num_classes: raw.num_classes,
            n_features: raw.n_features,
            thresholds: raw.thresholds,
            thresholds_mean: raw.thresholds_mean,
            thresholds_median: raw.thresholds_median,
            threshold_mode: raw.threshold_mode,
            similarity_alpha: raw.similarity_alpha,
            sets,
        })
    }

    /// Bootstrap a store from served feature maps — the artifact-free path.
    ///
    /// Mirrors `python/compile/templates.py::generate_templates`: per-feature
    /// mean/median thresholds over the rows, per-class k-means templates for
    /// k = 1..=3 (k = 1 degenerates to the majority-vote template), and
    /// `[p10, p90]` real-feature matching windows over each cluster's
    /// members.  `feats` is `labels.len() x n_features`, row-major.
    pub fn from_features(
        feats: &[f32],
        labels: &[usize],
        n_features: usize,
        num_classes: usize,
        seed: u64,
    ) -> Result<TemplateStore> {
        let n = labels.len();
        if n == 0 || feats.len() != n * n_features {
            return Err(Error::Template(format!(
                "feature matrix has {} floats, expected {n} rows x {n_features}",
                feats.len()
            )));
        }
        // HECT uploads land here with raw little-endian floats; a NaN row
        // would poison thresholds and windows downstream, so reject early.
        if feats.iter().any(|v| !v.is_finite()) {
            return Err(Error::Template("non-finite feature value".into()));
        }
        // Per-feature mean and median thresholds (Fig. 1's two modes).
        let mut thresholds_mean = vec![0f32; n_features];
        for row in feats.chunks_exact(n_features) {
            for (t, v) in thresholds_mean.iter_mut().zip(row.iter()) {
                *t += v;
            }
        }
        for t in thresholds_mean.iter_mut() {
            *t /= n as f32;
        }
        let mut thresholds_median = vec![0f32; n_features];
        let mut col = vec![0f32; n];
        for (j, tm) in thresholds_median.iter_mut().enumerate() {
            for (i, c) in col.iter_mut().enumerate() {
                *c = feats[i * n_features + j];
            }
            col.sort_by(f32::total_cmp);
            *tm = if n % 2 == 1 {
                col[n / 2]
            } else {
                0.5 * (col[n / 2 - 1] + col[n / 2])
            };
        }
        // Binarise every row with the deployed (mean) thresholds.
        let mut bits = vec![0u8; n * n_features];
        for (i, row) in feats.chunks_exact(n_features).enumerate() {
            for (j, (f, t)) in row.iter().zip(thresholds_mean.iter()).enumerate() {
                bits[i * n_features + j] = u8::from(f > t);
            }
        }

        let words_per_row = n_features.div_ceil(64);
        let mut sets = BTreeMap::new();
        for k in 1..=3usize {
            let mut templates: Vec<Vec<u8>> = Vec::new();
            let mut lo: Vec<Vec<f32>> = Vec::new();
            let mut hi: Vec<Vec<f32>> = Vec::new();
            let mut class_of: Vec<usize> = Vec::new();
            let mut silhouette: Vec<f64> = Vec::new();
            for c in 0..num_classes {
                let rows: Vec<usize> = (0..n).filter(|&i| labels[i] == c).collect();
                if rows.is_empty() {
                    return Err(Error::Template(format!("class {c} has no feature rows")));
                }
                let xb: Vec<Vec<f64>> = rows
                    .iter()
                    .map(|&i| {
                        bits[i * n_features..(i + 1) * n_features]
                            .iter()
                            .map(|&b| b as f64)
                            .collect()
                    })
                    .collect();
                let (centroids, assign, sil) = if k == 1 {
                    let mut cent = vec![0f64; n_features];
                    for row in &xb {
                        for (s, v) in cent.iter_mut().zip(row.iter()) {
                            *s += v;
                        }
                    }
                    for s in cent.iter_mut() {
                        *s /= xb.len() as f64;
                    }
                    (vec![cent], vec![0usize; xb.len()], 0.0)
                } else {
                    let cl = crate::kmeans::kmeans(&xb, k, 30, 2, seed.wrapping_add(c as u64));
                    let sil =
                        crate::kmeans::silhouette(&xb, &cl.assignment, 256, seed.wrapping_add(c as u64));
                    (cl.centroids, cl.assignment, sil)
                };
                for (ci, cent) in centroids.iter().enumerate() {
                    let t: Vec<u8> = cent.iter().map(|&v| u8::from(v > 0.5)).collect();
                    // Window members: the cluster's real-feature rows
                    // (whole class when a cluster came back empty).
                    let mut members: Vec<usize> = rows
                        .iter()
                        .enumerate()
                        .filter(|(ri, _)| assign[*ri] == ci)
                        .map(|(_, &i)| i)
                        .collect();
                    if members.is_empty() {
                        members = rows.clone();
                    }
                    let (wlo, whi) = percentile_windows(feats, n_features, &members);
                    templates.push(t);
                    lo.push(wlo);
                    hi.push(whi);
                    class_of.push(c);
                }
                silhouette.push(sil);
            }
            let packed = templates
                .iter()
                .flat_map(|t| pack_bits(t, words_per_row))
                .collect();
            let bin_lo: Vec<Vec<f32>> = templates
                .iter()
                .map(|t| t.iter().map(|&b| b as f32 - 0.5).collect())
                .collect();
            let bin_hi: Vec<Vec<f32>> = templates
                .iter()
                .map(|t| t.iter().map(|&b| b as f32 + 0.5).collect())
                .collect();
            let set = TemplateSet {
                templates,
                packed,
                words_per_row,
                lo,
                hi,
                bin_lo,
                bin_hi,
                class_of,
                silhouette,
            };
            set.validate(n_features, num_classes)?;
            sets.insert(k, set);
        }
        Ok(TemplateStore {
            num_classes,
            n_features,
            thresholds: thresholds_mean.clone(),
            thresholds_mean,
            thresholds_median,
            threshold_mode: "mean".into(),
            similarity_alpha: 0.05,
            sets,
        })
    }

    /// The template set for `k` templates per class.
    pub fn set(&self, k: usize) -> Result<&TemplateSet> {
        self.sets
            .get(&k)
            .ok_or_else(|| Error::Template(format!("no set with {k} templates/class")))
    }

    /// Binarise a real-valued feature vector with the deployed thresholds
    /// (strict `>`, matching the Python/Pallas kernels).
    pub fn binarize(&self, features: &[f32]) -> Vec<u8> {
        features
            .iter()
            .zip(self.thresholds.iter())
            .map(|(f, t)| u8::from(f > t))
            .collect()
    }

    /// Serialise to the `templates.json` schema [`Self::from_json_str`]
    /// parses, so accepted registry publishes can be persisted to the
    /// stores directory and reloaded verbatim on restart.  The packed rows
    /// and `words_per_row` are derived state and are rebuilt at parse time.
    pub fn to_json(&self) -> String {
        let f32_arr = |v: &[f32]| Value::Arr(v.iter().map(|&f| Value::Num(f as f64)).collect());
        let f32_mat =
            |m: &[Vec<f32>]| Value::Arr(m.iter().map(|row| f32_arr(row)).collect());
        let mut stores = BTreeMap::new();
        for (k, set) in &self.sets {
            let templates = Value::Arr(
                set.templates
                    .iter()
                    .map(|t| Value::Arr(t.iter().map(|&b| Value::Num(b as f64)).collect()))
                    .collect(),
            );
            let class_of = Value::Arr(
                set.class_of.iter().map(|&c| Value::Num(c as f64)).collect(),
            );
            let silhouette =
                Value::Arr(set.silhouette.iter().map(|&s| Value::Num(s)).collect());
            let obj = BTreeMap::from([
                ("templates".to_string(), templates),
                ("lo".to_string(), f32_mat(&set.lo)),
                ("hi".to_string(), f32_mat(&set.hi)),
                ("bin_lo".to_string(), f32_mat(&set.bin_lo)),
                ("bin_hi".to_string(), f32_mat(&set.bin_hi)),
                ("class_of".to_string(), class_of),
                ("silhouette".to_string(), silhouette),
            ]);
            stores.insert(k.to_string(), Value::Obj(obj));
        }
        let doc = BTreeMap::from([
            ("num_classes".to_string(), Value::Num(self.num_classes as f64)),
            ("n_features".to_string(), Value::Num(self.n_features as f64)),
            (
                "threshold_mode".to_string(),
                Value::Str(self.threshold_mode.clone()),
            ),
            ("thresholds".to_string(), f32_arr(&self.thresholds)),
            ("thresholds_mean".to_string(), f32_arr(&self.thresholds_mean)),
            (
                "thresholds_median".to_string(),
                f32_arr(&self.thresholds_median),
            ),
            (
                "similarity_alpha".to_string(),
                Value::Num(self.similarity_alpha as f64),
            ),
            ("stores".to_string(), Value::Obj(stores)),
        ]);
        Value::Obj(doc).to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_raw(n_features: usize) -> RawStore {
        let t0 = vec![1u8; n_features];
        let t1 = vec![0u8; n_features];
        let mk = |t: &Vec<u8>| RawSet {
            templates: vec![t.clone(), t.iter().map(|b| 1 - b).collect()],
            lo: vec![vec![0.0; n_features]; 2],
            hi: vec![vec![1.0; n_features]; 2],
            bin_lo: vec![vec![-0.5; n_features]; 2],
            bin_hi: vec![vec![0.5; n_features]; 2],
            class_of: vec![0, 1],
            silhouette: vec![0.0, 0.0],
        };
        RawStore {
            num_classes: 2,
            n_features,
            threshold_mode: "mean".into(),
            thresholds: vec![0.5; n_features],
            thresholds_mean: vec![0.5; n_features],
            thresholds_median: vec![0.6; n_features],
            similarity_alpha: 0.05,
            stores: BTreeMap::from([("1".to_string(), mk(&t0)), ("2".to_string(), mk(&t1))]),
        }
    }

    #[test]
    fn pack_bits_lsb_first() {
        let bits = [1u8, 0, 1, 1];
        let packed = pack_bits(&bits, 1);
        assert_eq!(packed[0], 0b1101);
    }

    #[test]
    fn pack_bits_multiword() {
        let mut bits = vec![0u8; 70];
        bits[0] = 1;
        bits[64] = 1;
        bits[69] = 1;
        let packed = pack_bits(&bits, 2);
        assert_eq!(packed[0], 1);
        assert_eq!(packed[1], 0b100001);
    }

    #[test]
    fn load_roundtrip_and_binarize() {
        let store = TemplateStore::from_raw(toy_raw(8)).unwrap();
        assert_eq!(store.set(1).unwrap().num_templates(), 2);
        let b = store.binarize(&[0.4, 0.6, 0.5, 0.9, 0.0, 1.0, 0.51, 0.49]);
        assert_eq!(b, vec![0, 1, 0, 1, 0, 1, 1, 0]); // strict >
    }

    #[test]
    fn validate_rejects_nonbinary() {
        let mut raw = toy_raw(4);
        raw.stores.get_mut("1").unwrap().templates[0][0] = 2;
        assert!(TemplateStore::from_raw(raw).is_err());
    }

    #[test]
    fn validate_rejects_missing_class() {
        let mut raw = toy_raw(4);
        raw.stores.get_mut("1").unwrap().class_of = vec![0, 0];
        assert!(TemplateStore::from_raw(raw).is_err());
    }

    #[test]
    fn validate_rejects_bad_window() {
        let mut raw = toy_raw(4);
        raw.stores.get_mut("2").unwrap().lo[0][2] = 5.0;
        assert!(TemplateStore::from_raw(raw).is_err());
    }

    #[test]
    fn validate_rejects_non_finite_window() {
        // NaN compares false against everything, so the lo > hi check alone
        // would let it through.
        let mut raw = toy_raw(4);
        raw.stores.get_mut("2").unwrap().lo[0][2] = f32::NAN;
        assert!(TemplateStore::from_raw(raw).is_err());
        let mut raw = toy_raw(4);
        raw.stores.get_mut("1").unwrap().bin_hi[0][1] = f32::INFINITY;
        assert!(TemplateStore::from_raw(raw).is_err());
    }

    #[test]
    fn from_raw_rejects_non_finite_thresholds() {
        let mut raw = toy_raw(4);
        raw.thresholds[0] = f32::NAN;
        assert!(TemplateStore::from_raw(raw).is_err());
        let mut raw = toy_raw(4);
        raw.similarity_alpha = f32::INFINITY;
        assert!(TemplateStore::from_raw(raw).is_err());
    }

    #[test]
    fn missing_set_is_error() {
        let store = TemplateStore::from_raw(toy_raw(4)).unwrap();
        assert!(store.set(3).is_err());
    }

    /// Synthetic per-class feature clusters for the bootstrap tests: class c
    /// concentrates around c with a small deterministic wobble.
    fn clustered_features(per_class: usize, classes: usize, nf: usize) -> (Vec<f32>, Vec<usize>) {
        let mut rng = crate::rng::Rng::new(9);
        let mut feats = Vec::with_capacity(per_class * classes * nf);
        let mut labels = Vec::with_capacity(per_class * classes);
        for i in 0..per_class * classes {
            let c = i % classes;
            labels.push(c);
            for j in 0..nf {
                let base = if j % classes == c { 1.0 } else { 0.0 };
                feats.push((base + rng.range(-0.1, 0.1)) as f32);
            }
        }
        (feats, labels)
    }

    #[test]
    fn from_features_builds_valid_store() {
        let (feats, labels) = clustered_features(8, 4, 20);
        let store = TemplateStore::from_features(&feats, &labels, 20, 4, 42).unwrap();
        assert_eq!(store.num_classes, 4);
        assert_eq!(store.n_features, 20);
        for k in 1..=3 {
            let set = store.set(k).unwrap();
            assert!(set.num_templates() >= 4, "k={k}");
            assert_eq!(set.num_features(), 20);
        }
        // k = 1 gives exactly one (majority-vote) template per class, and
        // that template marks the class's hot features.
        let set1 = store.set(1).unwrap();
        assert_eq!(set1.num_templates(), 4);
        for (t, &c) in set1.templates.iter().zip(set1.class_of.iter()) {
            for (j, &b) in t.iter().enumerate() {
                assert_eq!(b, u8::from(j % 4 == c), "class {c} feature {j}");
            }
        }
    }

    #[test]
    fn from_features_is_deterministic() {
        let (feats, labels) = clustered_features(6, 3, 12);
        let a = TemplateStore::from_features(&feats, &labels, 12, 3, 7).unwrap();
        let b = TemplateStore::from_features(&feats, &labels, 12, 3, 7).unwrap();
        assert_eq!(a.thresholds, b.thresholds);
        assert_eq!(a.set(2).unwrap().templates, b.set(2).unwrap().templates);
    }

    #[test]
    fn from_features_median_of_even_rows_interpolates() {
        // 4 rows, 1 feature: values 0, 1, 2, 3 -> mean 1.5, median 1.5.
        let feats = vec![0.0f32, 1.0, 2.0, 3.0];
        let labels = vec![0usize, 1, 0, 1];
        let store = TemplateStore::from_features(&feats, &labels, 1, 2, 0).unwrap();
        assert!((store.thresholds_mean[0] - 1.5).abs() < 1e-6);
        assert!((store.thresholds_median[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn to_json_roundtrips_through_from_json_str() {
        let (feats, labels) = clustered_features(8, 4, 20);
        let store = TemplateStore::from_features(&feats, &labels, 20, 4, 42).unwrap();
        let back = TemplateStore::from_json_str(&store.to_json()).unwrap();
        assert_eq!(back.num_classes, store.num_classes);
        assert_eq!(back.n_features, store.n_features);
        assert_eq!(back.threshold_mode, store.threshold_mode);
        assert_eq!(back.thresholds, store.thresholds);
        assert_eq!(back.thresholds_mean, store.thresholds_mean);
        assert_eq!(back.thresholds_median, store.thresholds_median);
        assert_eq!(back.similarity_alpha, store.similarity_alpha);
        assert_eq!(
            back.sets.keys().collect::<Vec<_>>(),
            store.sets.keys().collect::<Vec<_>>()
        );
        for (k, set) in &store.sets {
            let bset = &back.sets[k];
            assert_eq!(bset.templates, set.templates, "k={k} templates");
            assert_eq!(bset.packed, set.packed, "k={k} packed (rebuilt)");
            assert_eq!(bset.words_per_row, set.words_per_row);
            assert_eq!(bset.lo, set.lo, "k={k} lo");
            assert_eq!(bset.hi, set.hi, "k={k} hi");
            assert_eq!(bset.bin_lo, set.bin_lo, "k={k} bin_lo");
            assert_eq!(bset.bin_hi, set.bin_hi, "k={k} bin_hi");
            assert_eq!(bset.class_of, set.class_of, "k={k} class_of");
            assert_eq!(bset.silhouette, set.silhouette, "k={k} silhouette");
        }
    }

    #[test]
    fn from_features_rejects_bad_shapes() {
        assert!(TemplateStore::from_features(&[0.0; 10], &[0, 1], 4, 2, 0).is_err());
        // A class with no rows is rejected.
        assert!(TemplateStore::from_features(&[0.0; 8], &[0, 0], 4, 2, 0).is_err());
    }

    #[test]
    fn from_features_rejects_non_finite_rows() {
        let (mut feats, labels) = clustered_features(8, 4, 20);
        feats[5] = f32::NAN;
        assert!(TemplateStore::from_features(&feats, &labels, 20, 4, 42).is_err());
        let (mut feats, labels) = clustered_features(8, 4, 20);
        feats[33] = f32::NEG_INFINITY;
        assert!(TemplateStore::from_features(&feats, &labels, 20, 4, 42).is_err());
    }
}

"""Pallas kernels vs the pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes (and the dtypes the pipeline feeds: f32 features,
{0,1}-valued binaries) and asserts allclose against ref.py, per the repo
contract that every kernel behaviour is pinned by its oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    binary_quantize,
    conv2d,
    match_feature_count,
    match_similarity,
    matmul,
    ref,
)

RNG = np.random.default_rng(0)
HYP = dict(max_examples=12, deadline=None)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**HYP)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 70),
)
def test_matmul_matches_ref(m, k, n):
    a = RNG.normal(size=(m, k)).astype(np.float32)
    b = RNG.normal(size=(k, n)).astype(np.float32)
    assert_allclose(np.asarray(matmul(a, b)), a @ b, rtol=1e-4, atol=1e-4)


def test_matmul_exact_tile_multiple():
    a = RNG.normal(size=(256, 128)).astype(np.float32)
    b = RNG.normal(size=(128, 256)).astype(np.float32)
    assert_allclose(np.asarray(matmul(a, b)), a @ b, rtol=1e-4, atol=1e-4)


def test_matmul_small_tiles():
    a = RNG.normal(size=(20, 30)).astype(np.float32)
    b = RNG.normal(size=(30, 10)).astype(np.float32)
    out = matmul(a, b, bm=8, bk=8, bn=8)
    assert_allclose(np.asarray(out), a @ b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(**HYP)
@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([4, 7, 8, 16]),
    cin=st.sampled_from([1, 3, 8]),
    cout=st.sampled_from([4, 16]),
    kh=st.sampled_from([1, 2, 3]),
    padding=st.sampled_from(["SAME", "VALID"]),
)
def test_conv2d_matches_ref(b, hw, cin, cout, kh, padding):
    if padding == "VALID" and kh > hw:
        return
    x = RNG.normal(size=(b, hw, hw, cin)).astype(np.float32)
    w = RNG.normal(size=(kh, kh, cin, cout)).astype(np.float32)
    got = np.asarray(conv2d(x, w, padding))
    want = np.asarray(ref.conv2d(jnp.asarray(x), jnp.asarray(w), padding))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ref_conv2d_matches_lax():
    """The oracle itself is validated against XLA's convolution."""
    x = RNG.normal(size=(2, 12, 12, 5)).astype(np.float32)
    w = RNG.normal(size=(3, 3, 5, 7)).astype(np.float32)
    want = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    assert_allclose(np.asarray(ref.conv2d(jnp.asarray(x), jnp.asarray(w), "SAME")),
                    np.asarray(want), rtol=1e-4, atol=1e-4)


def test_conv2d_valid_2x2_gives_fig5_feature_dim():
    """Fig. 5: 8x8x256 --conv 2x2x16 VALID--> 7x7x16 = 784 features."""
    x = RNG.normal(size=(1, 8, 8, 256)).astype(np.float32)
    w = RNG.normal(size=(2, 2, 256, 16)).astype(np.float32)
    out = conv2d(x, w, "VALID")
    assert out.shape == (1, 7, 7, 16)
    assert int(np.prod(out.shape[1:])) == 784


# ---------------------------------------------------------------------------
# pattern matching
# ---------------------------------------------------------------------------


@settings(**HYP)
@given(
    b=st.integers(1, 40),
    m=st.integers(1, 35),
    n=st.integers(1, 300),
)
def test_feature_count_matches_ref(b, m, n):
    q = (RNG.random((b, n)) > 0.5).astype(np.float32)
    t = (RNG.random((m, n)) > 0.5).astype(np.float32)
    got = np.asarray(match_feature_count(q, t))
    want = np.asarray(ref.match_feature_count(jnp.asarray(q), jnp.asarray(t)))
    assert_allclose(got, want)


def test_feature_count_extremes():
    q = np.ones((2, 64), np.float32)
    t = np.vstack([np.ones((1, 64), np.float32), np.zeros((1, 64), np.float32)])
    s = np.asarray(match_feature_count(q, t))
    assert s[0, 0] == 64.0 and s[0, 1] == 0.0


@settings(**HYP)
@given(
    b=st.integers(1, 40),
    m=st.integers(1, 35),
    n=st.integers(1, 300),
    alpha=st.floats(0.0, 1.0),
)
def test_similarity_matches_ref(b, m, n, alpha):
    q = RNG.normal(size=(b, n)).astype(np.float32)
    lo = (RNG.normal(size=(m, n)) - 0.5).astype(np.float32)
    hi = lo + RNG.random((m, n)).astype(np.float32)
    got = np.asarray(match_similarity(q, lo, hi, alpha))
    want = np.asarray(ref.match_similarity(jnp.asarray(q), jnp.asarray(lo), jnp.asarray(hi), alpha))
    assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_similarity_in_window_is_one():
    """A query inside every window has D=0, H=1 -> similarity exactly 1."""
    q = np.zeros((1, 50), np.float32)
    lo, hi = -np.ones((3, 50), np.float32), np.ones((3, 50), np.float32)
    s = np.asarray(match_similarity(q, lo, hi, 0.5))
    assert_allclose(s, np.ones((1, 3)))


# ---------------------------------------------------------------------------
# binary quantize
# ---------------------------------------------------------------------------


@settings(**HYP)
@given(b=st.integers(1, 50), n=st.integers(1, 900))
def test_binary_quantize_matches_ref(b, n):
    x = RNG.normal(size=(b, n)).astype(np.float32)
    th = RNG.normal(size=(n,)).astype(np.float32)
    got = np.asarray(binary_quantize(x, th))
    want = np.asarray(ref.binary_quantize(jnp.asarray(x), jnp.asarray(th)))
    assert_allclose(got, want)
    assert set(np.unique(got)).issubset({0.0, 1.0})


def test_binary_quantize_strict_inequality():
    """Threshold equality binarises to 0 (strict >), matching Rust."""
    x = np.array([[1.0, 2.0, 3.0]], np.float32)
    th = np.array([1.0, 1.5, 3.0], np.float32)
    assert np.asarray(binary_quantize(x, th)).tolist() == [[0.0, 1.0, 0.0]]


# ---------------------------------------------------------------------------
# classify (Eq. 12 multi-template argmax)
# ---------------------------------------------------------------------------


def test_classify_picks_best_template_class():
    scores = jnp.asarray([[1.0, 5.0, 3.0, 4.0]])
    class_of = jnp.asarray([0, 0, 1, 1])
    pred = ref.classify(scores, class_of, 2)
    assert int(pred[0]) == 0  # max over class 0 templates (5) beats class 1 (4)


def test_fc_and_sim_agree_on_binary_inputs():
    """§V.B: with binary features and unit windows the two matching modes
    produce the same argmax (scores are monotone transforms of each other)."""
    q = (RNG.random((30, 100)) > 0.5).astype(np.float32)
    t = (RNG.random((10, 100)) > 0.5).astype(np.float32)
    fc = np.asarray(ref.match_feature_count(jnp.asarray(q), jnp.asarray(t)))
    sim = np.asarray(ref.match_similarity(
        jnp.asarray(q), jnp.asarray(t) - 0.5, jnp.asarray(t) + 0.5, 0.05))
    assert (fc.argmax(1) == sim.argmax(1)).all()

//! Device non-ideality model shared by the RRAM devices and the analogue
//! periphery (sense amplifiers, WTA).


/// Stochastic non-idealities injected into the simulation.
///
/// All sigmas are *relative* (fraction of the nominal value) except
/// `wta_offset_v`, which is an input-referred offset voltage.
#[derive(Debug, Clone)]
pub struct Variability {
    /// Log-normal programming spread of RRAM conductance.
    pub program_sigma: f64,
    /// Gaussian multiplicative read noise on RRAM conductance.
    pub read_sigma: f64,
    /// Retention drift exponent: G(t) = G0 * t^-nu (t in hours).
    pub drift_nu: f64,
    /// Device age at read time (hours); drift applies when > 1.
    pub age_hours: f64,
    /// Sense-amp threshold offset (relative to VDD).
    pub sense_offset_sigma: f64,
    /// WTA comparator input-referred offset (volts).
    pub wta_offset_v: f64,
}

impl Default for Variability {
    /// Ideal devices — the calibration reference: with this setting the
    /// simulated ACAM must agree exactly with the digital matcher.
    fn default() -> Self {
        Variability {
            program_sigma: 0.0,
            read_sigma: 0.0,
            drift_nu: 0.0,
            age_hours: 0.0,
            sense_offset_sigma: 0.0,
            wta_offset_v: 0.0,
        }
    }
}

impl Variability {
    /// Ideal devices (alias for `Default`).
    pub fn ideal() -> Self {
        Self::default()
    }

    /// A representative fabricated-device corner: moderate programming
    /// spread and read noise, light drift (values in line with published
    /// RRAM characterisation, e.g. the paper's ref. [26]).
    pub fn typical() -> Self {
        Variability {
            program_sigma: 0.05,
            read_sigma: 0.02,
            drift_nu: 0.01,
            age_hours: 24.0,
            sense_offset_sigma: 0.01,
            wta_offset_v: 0.005,
        }
    }

    /// Scale all non-idealities by `level` (0 = ideal, 1 = typical,
    /// >1 = worst-case sweeps for the variability ablation).
    ///
    /// Endpoint contract (pinned by the property tests below, relied on
    /// by the fault-injection drift schedule): `at_level(0.0)` equals
    /// [`Variability::ideal`] field-for-field — including `age_hours`,
    /// which previously stayed at the typical corner's 24 h and made
    /// "level 0" carry latent drift state — and `at_level(1.0)` equals
    /// [`Variability::typical`].  Every field is monotone non-decreasing
    /// in `level`, so a drift schedule stepping the level upward can
    /// never make the device corner *less* severe.
    pub fn at_level(level: f64) -> Self {
        let t = Self::typical();
        Variability {
            program_sigma: t.program_sigma * level,
            read_sigma: t.read_sigma * level,
            drift_nu: t.drift_nu * level,
            age_hours: t.age_hours * level,
            sense_offset_sigma: t.sense_offset_sigma * level,
            wta_offset_v: t.wta_offset_v * level,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_ideal() {
        let v = Variability::default();
        assert_eq!(v.program_sigma, 0.0);
        assert_eq!(v.wta_offset_v, 0.0);
    }

    #[test]
    fn level_zero_is_ideal() {
        let v = Variability::at_level(0.0);
        assert_eq!(v.program_sigma, 0.0);
        assert_eq!(v.read_sigma, 0.0);
    }

    #[test]
    fn level_scales_linearly() {
        let v1 = Variability::at_level(1.0);
        let v2 = Variability::at_level(2.0);
        assert!((v2.program_sigma - 2.0 * v1.program_sigma).abs() < 1e-12);
    }

    fn fields(v: &Variability) -> [f64; 6] {
        [
            v.program_sigma,
            v.read_sigma,
            v.drift_nu,
            v.age_hours,
            v.sense_offset_sigma,
            v.wta_offset_v,
        ]
    }

    #[test]
    fn level_zero_equals_ideal_every_field() {
        assert_eq!(fields(&Variability::at_level(0.0)), fields(&Variability::ideal()));
    }

    #[test]
    fn level_one_equals_typical_every_field() {
        assert_eq!(fields(&Variability::at_level(1.0)), fields(&Variability::typical()));
    }

    #[test]
    fn every_field_is_monotone_in_level() {
        let sweep: Vec<f64> = (0..=32).map(|i| i as f64 * 0.125).collect();
        for pair in sweep.windows(2) {
            let lo = fields(&Variability::at_level(pair[0]));
            let hi = fields(&Variability::at_level(pair[1]));
            for (a, b) in lo.iter().zip(hi.iter()) {
                assert!(b >= a, "field regressed between levels {} and {}", pair[0], pair[1]);
            }
        }
    }

    #[test]
    fn drift_severity_is_monotone_in_level() {
        // The retention factor G(t)/G0 = age^-nu must only shrink (more
        // drift) as the level rises past the point where drift engages
        // (age_hours > 1, i.e. level > 1/24).
        let factor = |level: f64| {
            let v = Variability::at_level(level);
            if v.drift_nu > 0.0 && v.age_hours > 1.0 {
                v.age_hours.powf(-v.drift_nu)
            } else {
                1.0
            }
        };
        let sweep: Vec<f64> = (0..=40).map(|i| i as f64 * 0.1).collect();
        for pair in sweep.windows(2) {
            assert!(
                factor(pair[1]) <= factor(pair[0]) + 1e-15,
                "drift factor rose between levels {} and {}",
                pair[0],
                pair[1]
            );
        }
    }
}

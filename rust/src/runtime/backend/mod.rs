//! Pluggable front-end execution backends.
//!
//! The student CNN front-end executes through one of two engines behind the
//! [`FrontEnd`] trait:
//!
//! * [`interp::InterpBackend`] — a dependency-free pure-Rust inference
//!   engine that ports the reference kernels in `python/compile/kernels/`
//!   (see [`kernels`]).  The default everywhere: it builds and serves on a
//!   clean offline checkout, loading exported weight sidecars when an
//!   artifacts directory exists and falling back to deterministic synthetic
//!   weights when it does not.  Scalar and single-threaded by design: it is
//!   the numeric oracle.
//! * [`fast::FastBackend`] — the same model on the interpreter fast-path:
//!   im2col lowering, a cache-blocked unroll-by-8 matmul microkernel,
//!   scratch-buffer arenas, and `std::thread::scope` batch/row-band
//!   parallelism (`--threads`).  Property-tested against the scalar
//!   oracle; still dependency-free.
//! * [`pjrt::PjrtBackend`] — the HLO/PJRT path (cargo feature `pjrt`),
//!   which compiles the AOT-exported HLO text artifacts onto the PJRT CPU
//!   client.  Unavailable in offline builds because the `xla` crate cannot
//!   be vendored there.
//!
//! [`FrontEnd`] is the dispatch seam: the coordinator pipeline only sees
//! the trait, so engine selection is a configuration knob
//! (`engine = "interp" | "interp-fast" | "pjrt"` / `hec --engine`), not a
//! build fork.

pub mod fast;
pub mod interp;
pub mod kernels;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::config::{Engine, ServeConfig};
use crate::error::Result;

use super::meta::Meta;

/// A front-end execution engine: runs the student CNN on image batches.
///
/// Images are packed contiguously, `image_size^2` floats each (NHWC with
/// C = 1); outputs are row-major matrices.  Engines accept any batch size
/// `n` — batching constraints (e.g. PJRT's exported artifact sizes) are an
/// implementation detail handled inside the engine — and validate the
/// input buffer length, returning `Error::Request` on a mismatch.
pub trait FrontEnd {
    /// Engine name for diagnostics and metrics labels.
    fn name(&self) -> &'static str;

    /// Padding slots this engine would add to dispatch a batch of `n`
    /// (metrics only).  Engines that run any batch size natively pad
    /// nothing.
    fn padding_for(&self, _n: usize) -> usize {
        0
    }

    /// Extract real-valued feature maps for `n` images: returns
    /// `n * n_features` floats.
    fn extract_features(&mut self, images: &[f32], n: usize) -> Result<Vec<f32>>;

    /// Run the softmax-head variant for `n` images: returns
    /// `n * num_classes` logits.
    fn logits(&mut self, images: &[f32], n: usize, num_classes: usize) -> Result<Vec<f32>>;
}

/// Build the engine selected by `cfg.engine`.
pub fn create(cfg: &ServeConfig, meta: &Meta) -> Result<Box<dyn FrontEnd>> {
    match cfg.engine {
        Engine::Interp => Ok(Box::new(interp::InterpBackend::new(cfg, meta)?)),
        Engine::InterpFast => Ok(Box::new(fast::FastBackend::new(cfg, meta)?)),
        #[cfg(feature = "pjrt")]
        Engine::Pjrt => Ok(Box::new(pjrt::PjrtBackend::new(cfg, meta)?)),
        #[cfg(not(feature = "pjrt"))]
        Engine::Pjrt => Err(crate::error::Error::Config(
            "engine 'pjrt' requires a build with `--features pjrt` \
             (and the vendored xla crate — see Cargo.toml)"
                .into(),
        )),
    }
}

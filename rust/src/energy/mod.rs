//! Energy ledger — the §V.D accounting, implemented as a first-class runtime
//! subsystem so every served classification carries its energy estimate.
//!
//! Two accounting scales (DESIGN.md §Substitutions):
//! * **paper scale** — the constants the paper reports (ResNet-50 teacher,
//!   Fig.-5 student); reproduces the published 792x reduction;
//! * **as-built** — Eq. 13 walked over the models actually trained by
//!   `make artifacts` (read from meta.json), for the serving metrics.
//!
//! ### Unit-slip note (reproduction fidelity)
//!
//! The paper quotes Horowitz per-op energies in **pJ** (0.2 pJ mul + 0.03 pJ
//! add + 20 pJ memory = 20.23 pJ/MAC) but its published totals only follow
//! if that per-MAC figure is applied as **fJ**: 4,749,174 MACs x 20.23 fJ =
//! 96.07 nJ (the published front-end figure) and 3,858,551,808 MACs x
//! 20.23 fJ = 78.06 uJ (the published teacher figure).  With strict pJ the
//! totals would be 1000x larger.  We reproduce the *published arithmetic*
//! (fJ-effective, [`EnergyModel::report`]) because the paper's headline
//! 792x is a *ratio* and is unit-slip invariant; [`EnergyModel::frontend_strict_pj_nj`]
//! exposes the strict-pJ variant for comparison.  See EXPERIMENTS.md §V.D.

pub mod constants;


use constants::*;

/// Energy model parameters (Horowitz constants by default; configurable so
/// ablations can model other process nodes).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// 8-bit multiply energy (pJ, Horowitz).
    pub mul8_pj: f64,
    /// 8-bit add energy (pJ, Horowitz).
    pub add8_pj: f64,
    /// Memory access energy per MAC (pJ; the paper's 32 KB cache figure).
    pub mem_pj: f64,
    /// ACAM energy per cell per search (fJ, Section III-B).
    pub acam_cell_fj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            mul8_pj: MUL8_PJ,
            add8_pj: ADD8_PJ,
            mem_pj: MEM_32K_PJ,
            acam_cell_fj: ACAM_CELL_ENERGY_FJ,
        }
    }
}

impl EnergyModel {
    /// Per-MAC energy in the paper's stated units (pJ): mul + add + memory.
    pub fn per_mac_pj(&self) -> f64 {
        self.mul8_pj + self.add8_pj + self.mem_pj
    }

    /// Eq. 14: E_back-end = N_templates x N_features x E_cell, in nJ.
    /// (This term is unit-consistent in the paper: 10 x 784 x 185 fJ = 1.45 nJ.)
    pub fn backend_nj(&self, n_templates: u64, n_features: u64) -> f64 {
        (n_templates * n_features) as f64 * self.acam_cell_fj * 1e-6
    }

    /// Energy to (re-)program the whole array, in nJ: one program-and-verify
    /// sequence for every cell's RRAM devices (see
    /// [`RRAM_PROGRAM_CELL_PJ`]).  Charged by the degradation ladder when a
    /// shard re-fits its array after canary evidence of drift.
    pub fn reprogram_nj(&self, n_templates: u64, n_features: u64) -> f64 {
        (n_templates * n_features) as f64 * RRAM_PROGRAM_CELL_PJ * 1e-3
    }

    /// §V.D front-end total in nJ, following the paper's published
    /// arithmetic (per-MAC figure applied as fJ — see the unit-slip note).
    pub fn frontend_nj(&self, ops: u64) -> f64 {
        ops as f64 * self.per_mac_pj() * 1e-6
    }

    /// Strict-pJ front-end total in nJ (1000x the published arithmetic).
    pub fn frontend_strict_pj_nj(&self, ops: u64) -> f64 {
        ops as f64 * self.per_mac_pj() * 1e-3
    }

    /// Teacher energy in µJ (paper arithmetic; colour-teacher MACs x
    /// 20.23 fJ = 78.06 µJ matches the published figure).
    pub fn teacher_uj(&self, macs: u64) -> f64 {
        macs as f64 * self.per_mac_pj() * 1e-9
    }

    /// §V.D composite: the full hybrid-vs-teacher comparison.
    pub fn report(&self, scale: Scale) -> EnergyReport {
        let (frontend_ops, teacher_macs, n_templates, n_features) = match scale {
            Scale::Paper => (
                FRONTEND_OPS_ACAM,
                TEACHER_COLOR.macs,
                N_TEMPLATES,
                N_FEATURES,
            ),
            Scale::AsBuilt {
                frontend_ops,
                teacher_macs,
                n_templates,
                n_features,
            } => (frontend_ops, teacher_macs, n_templates, n_features),
        };
        let e_backend_nj = self.backend_nj(n_templates, n_features);
        let e_frontend_nj = self.frontend_nj(frontend_ops);
        let e_total_nj = e_backend_nj + e_frontend_nj;
        let e_teacher_uj = self.teacher_uj(teacher_macs);
        EnergyReport {
            frontend_ops,
            teacher_macs,
            n_templates,
            n_features,
            e_backend_nj,
            e_frontend_nj,
            e_total_nj,
            e_teacher_uj,
            reduction: e_teacher_uj * 1e3 / e_total_nj,
        }
    }
}

/// Which model scale the report uses.
#[derive(Debug, Clone, Copy)]
pub enum Scale {
    /// Paper-reported constants (reproduces §V.D's published numbers).
    Paper,
    /// The models this repo actually trained (from meta.json).
    AsBuilt {
        frontend_ops: u64,
        teacher_macs: u64,
        n_templates: u64,
        n_features: u64,
    },
}

/// The §V.D table: per-classification energy, front and back, vs teacher.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub frontend_ops: u64,
    pub teacher_macs: u64,
    pub n_templates: u64,
    pub n_features: u64,
    pub e_backend_nj: f64,
    pub e_frontend_nj: f64,
    pub e_total_nj: f64,
    pub e_teacher_uj: f64,
    /// Teacher energy / hybrid energy (the paper's 792x headline).
    pub reduction: f64,
}

impl std::fmt::Display for EnergyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "E_front-end = {:>9.2} nJ  ({} effective 8-bit MACs)",
            self.e_frontend_nj, self.frontend_ops
        )?;
        writeln!(
            f,
            "E_back-end  = {:>9.2} nJ  ({} templates x {} features)",
            self.e_backend_nj, self.n_templates, self.n_features
        )?;
        writeln!(f, "E_total     = {:>9.2} nJ", self.e_total_nj)?;
        writeln!(
            f,
            "E_teacher   = {:>9.2} uJ  ({} MACs)",
            self.e_teacher_uj, self.teacher_macs
        )?;
        write!(f, "reduction   = {:>9.0}x", self.reduction)
    }
}

// ---------------------------------------------------------------------------
// Eq. 13 MAC ledger (mirrors python/compile/macs.py)
// ---------------------------------------------------------------------------

/// One accountable layer.
#[derive(Debug, Clone)]
pub enum Layer {
    Conv {
        name: String,
        h_out: u64,
        w_out: u64,
        kh: u64,
        kw: u64,
        cin: u64,
        cout: u64,
    },
    Dense {
        name: String,
        din: u64,
        dout: u64,
    },
}

impl Layer {
    /// Eq. 13: MACs = Ho*Wo*Kh*Kw*Cin*Cout (dense: din*dout).
    pub fn macs(&self) -> u64 {
        match self {
            Layer::Conv {
                h_out,
                w_out,
                kh,
                kw,
                cin,
                cout,
                ..
            } => h_out * w_out * kh * kw * cin * cout,
            Layer::Dense { din, dout, .. } => din * dout,
        }
    }

    pub fn params(&self) -> u64 {
        match self {
            Layer::Conv {
                kh, kw, cin, cout, ..
            } => kh * kw * cin * cout + cout,
            Layer::Dense { din, dout, .. } => din * dout + dout,
        }
    }

    pub fn name(&self) -> &str {
        match self {
            Layer::Conv { name, .. } | Layer::Dense { name, .. } => name,
        }
    }
}

/// The Fig.-5 student layer stack (mirrors `macs.student_layers`).
pub fn student_layers() -> Vec<Layer> {
    vec![
        Layer::Conv { name: "conv1".into(), h_out: 32, w_out: 32, kh: 3, kw: 3, cin: 1, cout: 32 },
        Layer::Conv { name: "conv2".into(), h_out: 16, w_out: 16, kh: 3, kw: 3, cin: 32, cout: 128 },
        Layer::Conv { name: "conv3".into(), h_out: 8, w_out: 8, kh: 3, kw: 3, cin: 128, cout: 256 },
        Layer::Conv { name: "conv4".into(), h_out: 7, w_out: 7, kh: 2, kw: 2, cin: 256, cout: 16 },
        Layer::Dense { name: "head".into(), din: 784, dout: 10 },
    ]
}

/// Total MACs over a stack.
pub fn total_macs(layers: &[Layer]) -> u64 {
    layers.iter().map(Layer::macs).sum()
}

/// Sparsity-skipped effective MACs (§V.A's 80%-sparsity argument).
pub fn effective_macs(macs: u64, sparsity: f64) -> u64 {
    (macs as f64 * (1.0 - sparsity)).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq14_backend_energy_matches_paper() {
        let m = EnergyModel::default();
        // 10 x 784 x 185 fJ = 1.4504 nJ
        assert!((m.backend_nj(N_TEMPLATES, N_FEATURES) - E_BACKEND_NJ).abs() < 0.01);
    }

    #[test]
    fn frontend_energy_matches_published_arithmetic() {
        let m = EnergyModel::default();
        let e = m.frontend_nj(FRONTEND_OPS_ACAM);
        assert!((e - E_FRONTEND_NJ).abs() / E_FRONTEND_NJ < 0.005, "{e}");
        // ... and the strict-pJ variant is exactly 1000x that.
        let strict = m.frontend_strict_pj_nj(FRONTEND_OPS_ACAM);
        assert!((strict / e - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn teacher_energy_matches_published() {
        let m = EnergyModel::default();
        let e = m.teacher_uj(TEACHER_COLOR.macs);
        assert!((e - E_TEACHER_UJ).abs() / E_TEACHER_UJ < 0.005, "{e}");
    }

    #[test]
    fn reduction_matches_paper_headline() {
        let r = EnergyModel::default().report(Scale::Paper);
        // Published: 792x (78.06 uJ vs 97.52 nJ; exact division gives ~800 —
        // the paper rounds). Assert within 2% of 800 and above 780.
        assert!(r.reduction > 780.0 && r.reduction < 820.0, "{}", r.reduction);
        assert!((r.e_total_nj - E_TOTAL_NJ).abs() / E_TOTAL_NJ < 0.005);
    }

    #[test]
    fn softmax_head_constant() {
        let head = &student_layers()[4];
        assert_eq!(head.params(), SOFTMAX_HEAD_OPS);
        assert_eq!(FRONTEND_OPS_ACAM, STUDENT_OPT.macs - SOFTMAX_HEAD_OPS);
    }

    #[test]
    fn eq13_layer_macs() {
        let layers = student_layers();
        assert_eq!(layers[0].macs(), 32 * 32 * 9 * 32);
        assert_eq!(layers[1].macs(), 16 * 16 * 9 * 32 * 128);
        assert_eq!(layers[3].macs(), 49 * 4 * 256 * 16);
    }

    #[test]
    fn effective_macs_rounds() {
        assert_eq!(effective_macs(23_785_120, 0.80), 4_757_024);
        assert_eq!(effective_macs(1000, 0.8), 200);
    }

    #[test]
    fn student_sparsity_relation() {
        // Paper: optimised student MACs = 20% of base MACs.
        assert_eq!(
            effective_macs(STUDENT_BASE.macs, SPARSITY),
            STUDENT_OPT.macs
        );
    }

    #[test]
    fn as_built_scale_plumbs_through() {
        let m = EnergyModel::default();
        let r = m.report(Scale::AsBuilt {
            frontend_ops: 1000,
            teacher_macs: 1_000_000,
            n_templates: 10,
            n_features: 784,
        });
        assert_eq!(r.frontend_ops, 1000);
        assert!(r.e_total_nj > r.e_backend_nj);
        assert!(r.reduction > 1.0);
    }
}

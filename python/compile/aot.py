"""AOT export — the single build-time entry point (`make artifacts`).

Runs the full Section-II pipeline (teacher -> distill -> prune -> QAT ->
templates), evaluates every experiment the paper reports (Table I, Table II,
Fig. 1, Fig. 6, Fig. 7, §V.D inputs), and emits the artifacts/ contract
described in DESIGN.md:

  *.hlo.txt        — HLO *text* modules for the Rust PJRT runtime (text, not
                     serialized proto: jax>=0.5 emits 64-bit instruction ids
                     that xla_extension 0.5.1 rejects; the text parser
                     reassigns ids).
  templates.json   — binary templates + matching windows, k = 1, 2, 3.
  meta.json        — shapes, norm stats, metrics, MAC ledger, experiment data.
  train_log.json   — per-epoch loss/accuracy for every phase.

Python never runs again after this: the Rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, macs, templates as tpl
from .config import PipelineConfig
from .model import (
    init_student,
    init_teacher,
    student_features,
    student_logits,
    student_param_count,
    teacher_logits,
)
from .prune import prune_student, sparsity_of
from .qat import qat_student
from .train import (
    distill_student,
    eval_metrics,
    train_student_baseline,
    train_teacher,
)
from .kernels import (
    binary_quantize,
    match_feature_count,
    match_similarity,
    ref,
)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the Rust
    side unwraps with to_tuple1/tuple elements)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# The xla_client bundled with this jaxlib corrupts *large* dense constants on
# the mlir->XlaComputation conversion (values come back as iota bit
# patterns), so weights must NEVER be baked into the graph: every exported
# entry point takes them as runtime parameters and ships them in a binary
# sidecar (<name>.params.bin + <name>.params.json) that the Rust runtime
# uploads once as PJRT buffers.  This guard catches any regression.
_CONST_RE = re.compile(r"constant\(\{")


def check_no_large_constants(text: str, name: str) -> None:
    for line in text.splitlines():
        if "constant(" not in line:
            continue
        if _CONST_RE.search(line) and line.count(",") > 16:
            raise RuntimeError(
                f"{name}: exported HLO contains a large baked constant — "
                f"these are corrupted by the mlir->XLA conversion; pass the "
                f"array as a runtime parameter instead:\n{line[:200]}"
            )


def export_hlo(fn, example_args, path: str) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*example_args))
    check_no_large_constants(text, os.path.basename(path))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def export_parameterized(fn_flat, x_specs, flat_arrays, out_dir: str, name: str) -> int:
    """Export `fn_flat(*x_specs, *flat) -> (out,)` plus its parameter sidecar.

    The weights travel in `<name>.params.bin` (raw little-endian f32) with a
    `<name>.params.json` manifest (shape per array, in argument order); the
    Rust runtime uploads them once and appends them to every execute call.
    """
    flat_specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in flat_arrays]
    text = to_hlo_text(jax.jit(fn_flat).lower(*x_specs, *flat_specs))
    check_no_large_constants(text, name)
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    manifest = {"arrays": []}
    with open(os.path.join(out_dir, f"{name}.params.bin"), "wb") as f:
        offset = 0
        for a in flat_arrays:
            arr = np.asarray(a, dtype=np.float32)
            f.write(arr.tobytes())  # little-endian on every supported host
            manifest["arrays"].append({"shape": list(arr.shape), "offset": offset})
            offset += arr.size
        manifest["total"] = offset
    with open(os.path.join(out_dir, f"{name}.params.json"), "w") as f:
        json.dump(manifest, f)
    return len(text)


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


def run_pipeline(cfg: PipelineConfig, out_dir: str, use_pallas_export: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    t_start = time.time()
    log: list = []
    meta: dict = {"config": json.loads(cfg.to_json())}

    # ---- data -------------------------------------------------------------
    tx, ty, vx, vy, norm = data.load(cfg.data)
    txc, tyc, vxc, vyc, norm_c = data.load(cfg.data, color=True)
    meta["norm"] = norm
    meta["norm_color"] = norm_c
    meta["dataset"] = {
        "train": len(tx),
        "test": len(vx),
        "source": "cifar10" if (cfg.data.cifar_dir or os.environ.get("CIFAR10_DIR")) else "synthetic",
    }
    print(f"[data] train={len(tx)} test={len(vx)} source={meta['dataset']['source']}")

    # ---- teacher (colour + greyscale, Table I rows 1-2) ---------------------
    key = jax.random.PRNGKey(cfg.teacher.seed)
    tparams_c, tstate_c = init_teacher(cfg.teacher, key, in_channels=3)
    tparams_c, tstate_c, log = train_teacher(cfg.teacher, tparams_c, tstate_c, txc, tyc, vxc, vyc, log)
    teacher_c_apply = jax.jit(
        lambda p, s, xb: teacher_logits(p, s, xb, cfg.teacher, training=False)[0]
    )
    m_teacher_c = eval_metrics(teacher_c_apply, tparams_c, tstate_c, vxc, vyc)
    print(f"[teacher colour] acc={m_teacher_c['accuracy']:.4f}")

    tparams, tstate = init_teacher(cfg.teacher, jax.random.PRNGKey(cfg.teacher.seed + 1), in_channels=1)
    tparams, tstate, log = train_teacher(cfg.teacher, tparams, tstate, tx, ty, vx, vy, log)
    teacher_apply = jax.jit(
        lambda p, s, xb: teacher_logits(p, s, xb, cfg.teacher, training=False)[0]
    )
    m_teacher_g = eval_metrics(teacher_apply, tparams, tstate, vx, vy)
    print(f"[teacher grey]   acc={m_teacher_g['accuracy']:.4f}")

    # ---- student baseline (Table I row 3) -----------------------------------
    sparams_b, sstate_b = init_student(cfg.student, jax.random.PRNGKey(cfg.student.seed))
    sparams_b, sstate_b, log = train_student_baseline(
        cfg.student, sparams_b, sstate_b, tx, ty, vx, vy, log
    )
    student_apply = jax.jit(lambda p, s, xb: student_logits(p, s, xb, training=False)[0])
    m_student_b = eval_metrics(student_apply, sparams_b, sstate_b, vx, vy)
    print(f"[student base]   acc={m_student_b['accuracy']:.4f}")

    # ---- student optimised: distill -> prune -> QAT (Table I row 4) ---------
    sparams, sstate = init_student(cfg.student, jax.random.PRNGKey(cfg.student.seed + 1))
    frozen_teacher = lambda xb: teacher_apply(tparams, tstate, xb)
    sparams, sstate, log = distill_student(
        cfg.distill, cfg.student, sparams, sstate, frozen_teacher, tx, ty, vx, vy, log
    )
    sparams, sstate, masks, log = prune_student(
        cfg.prune, cfg.student, sparams, sstate, tx, ty, vx, vy, log
    )
    sparams, sstate, log = qat_student(
        cfg.quant, cfg.student, sparams, sstate, masks, tx, ty, vx, vy, log
    )
    m_student_o = eval_metrics(student_apply, sparams, sstate, vx, vy)
    achieved_sparsity = sparsity_of(sparams, masks)
    print(f"[student opt]    acc={m_student_o['accuracy']:.4f} sparsity={achieved_sparsity:.3f}")

    # ---- MAC / parameter ledger (Eq. 13; as-built + paper-scale) ------------
    s_layers = macs.student_layers(cfg.student.filters)
    t_layers = macs.teacher_layers(cfg.teacher.width, cfg.teacher.blocks_per_stage)
    tc_layers = macs.teacher_layers(cfg.teacher.width, cfg.teacher.blocks_per_stage, in_ch=3)
    # Effective (sparsity-skipped) MACs cover the pruned conv stack only;
    # the dense head is unpruned and accounted separately — the ACAM removes
    # it entirely (§V.D), the softmax baseline pays it in full.
    head_macs = s_layers[-1].macs
    head_ops = s_layers[-1].params  # 784*10 + 10 = the paper's 7,850
    conv_macs = macs.total_macs(s_layers) - head_macs
    meta["macs"] = {
        "as_built": {
            "student": macs.model_summary(s_layers),
            "teacher_gray": macs.model_summary(t_layers),
            "teacher_color": macs.model_summary(tc_layers),
            "student_effective": macs.effective_macs(conv_macs, achieved_sparsity),
            "head_ops": head_ops,
            "student_params_actual": student_param_count(sparams),
            "achieved_sparsity": achieved_sparsity,
        },
        "paper_scale": macs.PAPER,
    }

    # ---- feature extraction for templates -----------------------------------
    feat_apply = jax.jit(lambda p, s, xb: student_features(p, s, xb, training=False)[0])
    def features_of(x):
        out = [np.asarray(feat_apply(sparams, sstate, jnp.asarray(x[i : i + 256])))
               for i in range(0, len(x), 256)]
        return np.concatenate(out)

    feats_train = features_of(tx)
    feats_test = features_of(vx)

    th_mean = tpl.feature_thresholds(feats_train, "mean")
    th_median = tpl.feature_thresholds(feats_train, "median")
    thresholds = th_mean if cfg.quant.threshold_mode == "mean" else th_median
    bin_train = tpl.binarize(feats_train, thresholds)
    bin_test = tpl.binarize(feats_test, thresholds)

    # ---- experiments: Fig. 1, Table II, Fig. 6/7, matching modes ------------
    experiments: dict = {}
    experiments["fig1_thresholds"] = {
        "mean": th_mean.tolist(),
        "median": th_median.tolist(),
    }

    stores = {}
    multi_template_acc = {}
    for k in (1, 2, 3):
        store = tpl.generate_templates(
            bin_train,
            feats_train,
            ty,
            cfg.data.num_classes,
            k,
            cfg.template.kmeans_iters,
            cfg.template.kmeans_restarts,
            cfg.template.window_margin,
            cfg.template.seed,
        )
        stores[k] = store
        pred = tpl.match_predict_fc(bin_test, store, cfg.data.num_classes)
        multi_template_acc[k] = float((pred == vy).mean())
        print(f"[match k={k}] feature-count acc={multi_template_acc[k]:.4f} "
              f"silhouette={['%.3f' % s for s in store['silhouette']]}")
    experiments["table2_multi_template"] = multi_template_acc

    # Mean vs median thresholding accuracy (Fig. 1's downstream consequence).
    store_mean = stores[1]
    bin_train_med = tpl.binarize(feats_train, th_median)
    bin_test_med = tpl.binarize(feats_test, th_median)
    store_med = tpl.generate_templates(
        bin_train_med, feats_train, ty, cfg.data.num_classes, 1,
        cfg.template.kmeans_iters, cfg.template.kmeans_restarts,
        cfg.template.window_margin, cfg.template.seed,
    )
    acc_mean_th = multi_template_acc[1]
    acc_med_th = float(
        (tpl.match_predict_fc(bin_test_med, store_med, cfg.data.num_classes) == vy).mean()
    )
    experiments["fig1_threshold_accuracy"] = {"mean": acc_mean_th, "median": acc_med_th}
    print(f"[fig1] mean-threshold acc={acc_mean_th:.4f} median-threshold acc={acc_med_th:.4f}")

    # Fig. 6/7: confusion + per-class accuracy of feature-count matching (k=1).
    pred_fc = tpl.match_predict_fc(bin_test, store_mean, cfg.data.num_classes)
    cm = np.zeros((cfg.data.num_classes, cfg.data.num_classes), dtype=np.int64)
    for t, p in zip(vy, pred_fc):
        cm[int(t), int(p)] += 1
    from .train import confusion_metrics

    m_match = confusion_metrics(cm)
    experiments["fig6_confusion"] = m_match["confusion"]
    experiments["fig7_per_class_accuracy"] = m_match["per_class_accuracy"]

    # §V.B: binary-domain equivalence of feature-count and similarity matching.
    pred_sim = tpl.match_predict_sim(
        bin_test, store_mean, cfg.data.num_classes, cfg.template.similarity_alpha
    )
    experiments["matching_modes"] = {
        "feature_count_acc": float((pred_fc == vy).mean()),
        "similarity_binary_acc": float((pred_sim == vy).mean()),
        "agreement": float((pred_fc == pred_sim).mean()),
    }

    # Table I assembly (as-measured).
    experiments["table1"] = {
        "teacher_color": {**{k: m_teacher_c[k] for k in ("accuracy", "f1", "precision", "recall")},
                          "params": macs.total_params(tc_layers), "macs": macs.total_macs(tc_layers)},
        "teacher_gray": {**{k: m_teacher_g[k] for k in ("accuracy", "f1", "precision", "recall")},
                         "params": macs.total_params(t_layers), "macs": macs.total_macs(t_layers)},
        "student_base": {**{k: m_student_b[k] for k in ("accuracy", "f1", "precision", "recall")},
                         "params": student_param_count(sparams_b), "macs": macs.total_macs(s_layers)},
        "student_opt": {**{k: m_student_o[k] for k in ("accuracy", "f1", "precision", "recall")},
                        "params": student_param_count(sparams),
                        "macs": meta["macs"]["as_built"]["student_effective"]},
    }
    meta["experiments"] = experiments

    # Golden record for the Rust integration tests: expected behaviour of the
    # deployed artifacts on the first test samples (same generator seed the
    # Rust synthetic workload uses).
    meta["golden"] = {
        "test_seed": cfg.data.seed + 1_000_003,
        "n": 32,
        "labels": [int(v) for v in vy[:32]],
        "pred_fc_k1": [int(p) for p in pred_fc[:32]],
        "features_row0_first8": [float(v) for v in feats_test[0][:8]],
        "binary_row0_ones": int(bin_test[0].sum()),
    }

    # ---- templates.json ------------------------------------------------------
    tjson = {
        "num_classes": cfg.data.num_classes,
        "n_features": int(bin_train.shape[1]),
        "threshold_mode": cfg.quant.threshold_mode,
        "thresholds": thresholds.tolist(),
        "thresholds_mean": th_mean.tolist(),
        "thresholds_median": th_median.tolist(),
        "similarity_alpha": cfg.template.similarity_alpha,
        "stores": {
            str(k): {
                "templates": stores[k]["templates"].astype(int).tolist(),
                "lo": stores[k]["lo"].tolist(),
                "hi": stores[k]["hi"].tolist(),
                "bin_lo": stores[k]["bin_lo"].tolist(),
                "bin_hi": stores[k]["bin_hi"].tolist(),
                "class_of": stores[k]["class_of"].tolist(),
                "silhouette": stores[k]["silhouette"],
            }
            for k in stores
        },
    }
    with open(os.path.join(out_dir, "templates.json"), "w") as f:
        json.dump(tjson, f)

    # ---- HLO export -----------------------------------------------------------
    # Weights are runtime parameters (see export_parameterized): flatten the
    # student/teacher pytrees once and close over the treedefs.
    n_feat = int(bin_train.shape[1])
    n_templ = len(store_mean["class_of"])
    s_flat, s_treedef = jax.tree_util.tree_flatten((sparams, sstate))
    # The feature-extractor exports must not carry the (unused) softmax head:
    # XLA drops unused parameters during conversion, which would desynchronise
    # the sidecar's argument order from the compiled program.
    sparams_fe = {k: v for k, v in sparams.items() if k != "head"}
    fe_flat, fe_treedef = jax.tree_util.tree_flatten((sparams_fe, sstate))
    t_flat, t_treedef = jax.tree_util.tree_flatten((tparams, tstate))
    th_arr = np.asarray(thresholds, np.float32)

    def fwd_flat(x, *flat):
        p, s = jax.tree_util.tree_unflatten(fe_treedef, flat)
        return (student_features(p, s, x, training=False, use_pallas=use_pallas_export)[0],)

    def fwd_fast_flat(x, *flat):
        # CPU-serving variant: identical math through the pure-jnp path
        # (XLA's native convolutions), numerically equal to the Pallas
        # artifact (pinned by tests).  The Pallas artifact remains the
        # TPU-shaped deliverable; the coordinator picks this one on CPU.
        p, s = jax.tree_util.tree_unflatten(fe_treedef, flat)
        return (student_features(p, s, x, training=False, use_pallas=False)[0],)

    def fwd_softmax_flat(x, *flat):
        p, s = jax.tree_util.tree_unflatten(s_treedef, flat)
        return (student_logits(p, s, x, training=False, use_pallas=use_pallas_export)[0],)

    def fwd_binary_flat(x, *flat):
        th = flat[-1]
        p, s = jax.tree_util.tree_unflatten(fe_treedef, flat[:-1])
        f = student_features(p, s, x, training=False, use_pallas=use_pallas_export)[0]
        return (binary_quantize(f, th),)

    def teacher_flat(x, *flat):
        p, s = jax.tree_util.tree_unflatten(t_treedef, flat)
        return (teacher_logits(p, s, x, cfg.teacher, training=False)[0],)

    sizes = {}
    for b in cfg.export_batch_sizes:
        x_spec = jax.ShapeDtypeStruct((b, cfg.data.image_size, cfg.data.image_size, 1), jnp.float32)
        q_spec = jax.ShapeDtypeStruct((b, n_feat), jnp.float32)
        t_spec = jax.ShapeDtypeStruct((n_templ, n_feat), jnp.float32)

        def mfc(q, t):
            return (match_feature_count(q, t),)

        def msim(q, lo, hi):
            return (match_similarity(q, lo, hi, cfg.template.similarity_alpha),)

        sizes[f"student_fwd_b{b}"] = export_parameterized(
            fwd_flat, (x_spec,), fe_flat, out_dir, f"student_fwd_b{b}")
        sizes[f"student_fwd_fast_b{b}"] = export_parameterized(
            fwd_fast_flat, (x_spec,), fe_flat, out_dir, f"student_fwd_fast_b{b}")
        sizes[f"student_softmax_b{b}"] = export_parameterized(
            fwd_softmax_flat, (x_spec,), s_flat, out_dir, f"student_softmax_b{b}")
        sizes[f"student_binary_b{b}"] = export_parameterized(
            fwd_binary_flat, (x_spec,), fe_flat + [th_arr], out_dir, f"student_binary_b{b}")
        # The matchers take queries and templates as runtime args already.
        sizes[f"match_fc_b{b}"] = export_hlo(
            mfc, (q_spec, t_spec), os.path.join(out_dir, f"match_fc_b{b}.hlo.txt"))
        sizes[f"match_sim_b{b}"] = export_hlo(
            msim, (q_spec, t_spec, t_spec), os.path.join(out_dir, f"match_sim_b{b}.hlo.txt"))

    # Teacher (greyscale) at batch 8 for the energy/latency comparison bench.
    xt_spec = jax.ShapeDtypeStruct((8, cfg.data.image_size, cfg.data.image_size, 1), jnp.float32)
    sizes["teacher_fwd_b8"] = export_parameterized(
        teacher_flat, (xt_spec,), t_flat, out_dir, "teacher_fwd_b8")

    meta["artifacts"] = {
        "hlo_sizes": sizes,
        "batch_sizes": list(cfg.export_batch_sizes),
        "n_features": n_feat,
        "n_templates": n_templ,
        "image_size": cfg.data.image_size,
        "use_pallas": use_pallas_export,
    }
    meta["wallclock_secs"] = time.time() - t_start

    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)
    print(f"[done] {len(sizes)} HLO artifacts -> {out_dir} in {meta['wallclock_secs']:.1f}s")
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--full", action="store_true",
                    help="paper-leaning config (slower) instead of the fast CPU config")
    ap.add_argument("--no-pallas", action="store_true",
                    help="export jnp-path HLO (debug aid)")
    args = ap.parse_args()
    cfg = PipelineConfig() if args.full else PipelineConfig.fast()
    run_pipeline(cfg, args.out, use_pallas_export=not args.no_pallas)


if __name__ == "__main__":
    main()

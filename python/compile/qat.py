"""8-bit quantisation-aware training (Section II-C, first stage).

Weights are fake-quantised to signed 8-bit integers (symmetric, per-tensor)
on the forward pass with a straight-through estimator on the backward pass,
so the student adapts to the reduced precision during fine-tuning.  The
exported artifacts carry both the float weights and the integer scales so the
Rust energy model can account 8-bit MACs (Horowitz constants).
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import QuantConfig, StudentConfig
from .model import student_logits
from .train import adam_init, adam_update, cross_entropy, evaluate, _batches


def fake_quant(w: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Symmetric per-tensor fake quantisation with a straight-through estimator.

    q = round(w / s) clipped to [-2^{b-1}+1, 2^{b-1}-1], dequantised by s.
    The STE (``stop_gradient`` of the rounding residual) passes gradients
    through the rounding unchanged.
    """
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax) * scale
    return w + jax.lax.stop_gradient(q - w)


def quantize_params(params, bits: int = 8):
    """Hard-quantise every conv/dense kernel (the deployment snapshot)."""

    def q(path, leaf):
        if path[-1].key == "w":
            qmax = 2 ** (bits - 1) - 1
            scale = max(float(jnp.max(jnp.abs(leaf))), 1e-8) / qmax
            return jnp.clip(jnp.round(leaf / scale), -qmax, qmax) * scale
        return leaf

    return jax.tree_util.tree_map_with_path(q, params)


def _fq_params(params, bits):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fake_quant(leaf, bits) if path[-1].key == "w" else leaf,
        params,
    )


def qat_student(
    cfg: QuantConfig, scfg: StudentConfig, params, state, masks, tx, ty, vx, vy, log=None
):
    """QAT fine-tune with pruning masks kept in force."""
    log = log if log is not None else []

    @jax.jit
    def step(params, state, opt, xb, yb):
        def loss_fn(p):
            pq = _fq_params(p, cfg.weight_bits)
            logits, new_s = student_logits(pq, state, xb, training=True)
            return cross_entropy(logits, yb), new_s

        (loss, new_s), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads = jax.tree_util.tree_map(lambda g, m: g * m, grads, masks)
        params, opt = adam_update(params, grads, opt, scfg.lr * 0.1)
        params = jax.tree_util.tree_map(lambda p, m: p * m, params, masks)
        return params, new_s, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(scfg.seed + 47)
    infer = jax.jit(
        lambda p, s, xb: student_logits(
            _fq_params(p, cfg.weight_bits), s, xb, training=False
        )[0]
    )
    for epoch in range(cfg.qat_epochs):
        t0 = time.time()
        losses = []
        for bidx in _batches(len(tx), scfg.batch_size, rng):
            params, state, opt, loss = step(
                params, state, opt, jnp.asarray(tx[bidx]), jnp.asarray(ty[bidx])
            )
            losses.append(float(loss))
        log.append(
            {
                "phase": "qat",
                "epoch": epoch,
                "loss": float(np.mean(losses)),
                "val_acc": evaluate(infer, params, state, vx, vy),
                "secs": time.time() - t0,
            }
        )
    # Deployment snapshot: hard-quantised weights (masks already zero where pruned).
    return quantize_params(params, cfg.weight_bits), state, log

//! Minimal JSON parser + writer substrate (no serde available offline).
//!
//! Supports the full JSON value grammar the artifact files use: objects,
//! arrays, strings (with escapes), numbers, booleans, null.  The accessor
//! API (`get`, `as_f64`, `as_array`, …) returns `Option`s so callers layer
//! their own schema errors on top.

use std::collections::BTreeMap;
use std::fmt::Write as _;

pub mod stream;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `at(&["macs", "as_built", "student_effective"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut v = self;
        for k in path {
            v = v.get(k)?;
        }
        Some(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f.round() as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f.round() as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a numeric array into f32s (the bulk template payloads).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_array()?
            .iter()
            .map(|v| v.as_f64().map(|f| f as f32))
            .collect()
    }

    /// Nested numeric matrix `[[...], ...]` into row vectors.
    pub fn as_f32_matrix(&self) -> Option<Vec<Vec<f32>>> {
        self.as_array()?.iter().map(Value::as_f32_vec).collect()
    }

    // ---- writer ----------------------------------------------------------

    /// Serialise (compact).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; null is the least-bad
                    // wire encoding (and what serde_json's default does too).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Parse failure with byte offset.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (artifact files are ASCII); surrogate
                            // pairs map to the replacement character.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).unwrap().as_array().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn f32_matrix_helper() {
        let v = parse("[[1, 2], [3, 4.5]]").unwrap();
        assert_eq!(
            v.as_f32_matrix().unwrap(),
            vec![vec![1.0, 2.0], vec![3.0, 4.5]]
        );
        assert!(parse("[[1], [\"x\"]]").unwrap().as_f32_matrix().is_none());
    }

    #[test]
    fn writer_roundtrip() {
        let text = r#"{"arr":[1,2.5,true,null],"s":"a\"b"}"#;
        let v = parse(text).unwrap();
        let v2 = parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn writer_emits_null_for_non_finite() {
        assert_eq!(Value::Num(f64::NAN).to_json(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_json(), "null");
        assert_eq!(Value::Num(f64::NEG_INFINITY).to_json(), "null");
        // And inside containers the document stays parseable.
        let v = Value::Arr(vec![Value::Num(1.5), Value::Num(f64::NAN)]);
        let back = parse(&v.to_json()).unwrap();
        assert_eq!(
            back,
            Value::Arr(vec![Value::Num(1.5), Value::Null])
        );
    }

    #[test]
    fn writer_string_escape_roundtrip() {
        let cases = [
            "plain",
            "quote \" backslash \\ slash /",
            "ctrl \n \r \t \u{8} \u{c} \u{1} \u{1f}",
            "unicode: caf\u{e9} \u{2603} \u{1F600}",
            "",
        ];
        for s in cases {
            let v = Value::Str(s.to_string());
            let back = parse(&v.to_json()).unwrap();
            assert_eq!(back.as_str(), Some(s), "round-trip of {s:?}");
        }
    }

    #[test]
    fn writer_number_edge_case_roundtrip() {
        let cases = [
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.1,
            -3.5e2,
            1e15,          // integer-formatting boundary
            1e15 + 2.0,    // just above it (still exactly representable)
            -1e15,
            1.23e300,      // near f64 max
            5e-324,        // smallest subnormal
            2.2250738585072014e-308, // smallest normal
            9007199254740991.0,      // 2^53 - 1
            f64::MAX,
            f64::MIN_POSITIVE,
        ];
        for n in cases {
            let v = Value::Num(n);
            let back = parse(&v.to_json()).unwrap();
            let got = back.as_f64().unwrap();
            assert!(
                got == n || (got == 0.0 && n == 0.0),
                "round-trip of {n:e}: got {got:e} from {}",
                v.to_json()
            );
        }
    }

    #[test]
    fn object_with_escaped_keys_roundtrips() {
        let mut m = BTreeMap::new();
        m.insert("a\"b\\c".to_string(), Value::Num(1.0));
        m.insert("tab\tkey".to_string(), Value::Bool(true));
        let v = Value::Obj(m);
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn large_flat_array() {
        let text = format!("[{}]", (0..1000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let v = parse(&text).unwrap();
        assert_eq!(v.as_array().unwrap().len(), 1000);
        assert_eq!(v.as_array().unwrap()[999].as_u64(), Some(999));
    }
}

//! Serving metrics: counters, log-bucketed latency histogram, energy ledger.
//!
//! Lock-free on the hot path (atomics only); `snapshot()` gives a consistent
//! read for the CLI / benches.

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram with power-of-two microsecond buckets:
/// bucket i covers [2^i, 2^(i+1)) µs; bucket 0 covers [0, 2) µs.
const BUCKETS: usize = 24; // up to ~8.4 s

#[derive(Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize).min(BUCKETS) - 1;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate percentile from the bucket histogram (upper bucket edge).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    /// Render as Prometheus `_bucket`/`_sum`/`_count` series (cumulative
    /// fixed buckets).  Observations are integer microseconds, so bucket i
    /// — which covers `[2^i, 2^(i+1))` — has the inclusive upper bound
    /// `le="2^(i+1)-1"`.  `labels` is a pre-formatted label list without
    /// braces (`""`, `shard="0"`, `backend="fc",shard="0"`); the caller
    /// emits the one `# HELP`/`# TYPE histogram` header per family.  The
    /// `_count` line repeats the `+Inf` bucket so the rendered series is
    /// self-consistent even against concurrent recording.
    pub fn render_prometheus(&self, name: &str, labels: &str, out: &mut String) {
        use std::fmt::Write as _;
        let with = |extra: &str| {
            if labels.is_empty() {
                format!("{{{extra}}}")
            } else {
                format!("{{{labels},{extra}}}")
            }
        };
        let plain = if labels.is_empty() {
            String::new()
        } else {
            format!("{{{labels}}}")
        };
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            let le = (1u64 << (i + 1)) - 1;
            let _ = writeln!(out, "{name}_bucket{} {cum}", with(&format!("le=\"{le}\"")));
        }
        let _ = writeln!(out, "{name}_bucket{} {cum}", with("le=\"+Inf\""));
        let _ = writeln!(
            out,
            "{name}_sum{plain} {}",
            self.sum_us.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "{name}_count{plain} {cum}");
    }
}

/// Label values for the per-backend latency histograms, indexed like
/// [`Metrics::latency_for`] (the canonical v1 wire names).
pub const BACKEND_LABELS: [&str; 4] = ["acam", "fc", "sim", "softmax"];

fn backend_index(b: crate::config::Backend) -> usize {
    match b {
        crate::config::Backend::AcamSim => 0,
        crate::config::Backend::FeatureCount => 1,
        crate::config::Backend::Similarity => 2,
        crate::config::Backend::Softmax => 3,
    }
}

/// All serving counters.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Worker panic-restarts (shard deployments; always 0 for the plain
    /// single-pipeline server).
    pub restarts: AtomicU64,
    /// Gauge: requests accepted into the bounded queue but not yet pulled
    /// into a batch by the worker.
    pub queue_depth: AtomicU64,
    /// Gauge: requests accepted but not yet answered (queued + computing).
    pub in_flight: AtomicU64,
    /// End-to-end request latency.
    pub latency: Histogram,
    /// PJRT execute() time per batch.
    pub execute: Histogram,
    /// Back-end (ACAM / matcher) time per batch.
    pub backend: Histogram,
    /// End-to-end request latency split by serving backend (indexed by
    /// [`backend_index`]; see [`BACKEND_LABELS`]).
    latency_by_backend: [Histogram; 4],
    /// Feature-cache hit/miss/eviction counters and the resident-entry
    /// gauge.  Only rendered into `/metrics` when the cache is enabled
    /// ([`prometheus_cache`]), so cache-off exposition text stays
    /// byte-identical to a build without the cache.
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub cache_evictions: AtomicU64,
    pub cache_entries: AtomicU64,
    /// Modelled energy, micro-nJ integer (nJ * 1e3) to stay in atomics.
    energy_mnj: AtomicU64,
    /// Back-end energy attributed to the deployed [`MatchingBackend`]
    /// variant (micro-nJ), and end-to-end latency of requests served by
    /// it.  Only rendered into `/metrics` for a non-default variant
    /// ([`prometheus_variant`]), so a default `acam` deployment's
    /// exposition text stays byte-identical to pre-seam builds.
    ///
    /// [`MatchingBackend`]: crate::backend::MatchingBackend
    variant_energy_mnj: AtomicU64,
    pub variant_latency: Histogram,
}

impl Metrics {
    /// The per-backend end-to-end latency histogram for `b`.
    pub fn latency_for(&self, b: crate::config::Backend) -> &Histogram {
        &self.latency_by_backend[backend_index(b)]
    }

    pub fn add_energy_nj(&self, nj: f64) {
        self.energy_mnj
            .fetch_add((nj * 1e3).round() as u64, Ordering::Relaxed);
    }

    pub fn energy_nj(&self) -> f64 {
        self.energy_mnj.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn add_variant_energy_nj(&self, nj: f64) {
        self.variant_energy_mnj
            .fetch_add((nj * 1e3).round() as u64, Ordering::Relaxed);
    }

    pub fn variant_energy_nj(&self) -> f64 {
        self.variant_energy_mnj.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Saturating gauge decrement (gauges never wrap below zero even if a
    /// racing snapshot observes an intermediate state).
    pub fn gauge_dec(gauge: &AtomicU64, by: u64) {
        let mut cur = gauge.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(by);
            match gauge.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            restarts: self.restarts.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            pad_fraction: if items > 0 {
                self.padded_slots.load(Ordering::Relaxed) as f64
                    / (items + self.padded_slots.load(Ordering::Relaxed)) as f64
            } else {
                0.0
            },
            latency_mean_us: self.latency.mean_us(),
            latency_p50_us: self.latency.percentile_us(0.50),
            latency_p99_us: self.latency.percentile_us(0.99),
            execute_mean_us: self.execute.mean_us(),
            backend_mean_us: self.backend.mean_us(),
            energy_nj: self.energy_nj(),
        }
    }
}

/// A consistent point-in-time read of the metrics.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub queue_depth: u64,
    pub in_flight: u64,
    pub restarts: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub pad_fraction: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    pub execute_mean_us: f64,
    pub backend_mean_us: f64,
    pub energy_nj: f64,
}

impl Snapshot {
    /// Render as Prometheus text exposition format (version 0.0.4) — the
    /// payload of the gateway's `GET /metrics`.
    pub fn prometheus(&self) -> String {
        fn push(out: &mut String, kind: &str, name: &str, help: &str, v: f64) {
            use std::fmt::Write as _;
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
            let _ = writeln!(out, "{name} {v}");
        }
        let mut out = String::new();
        let counters: [(&str, &str, f64); 6] = [
            (
                "hec_requests_total",
                "Requests accepted by the handle",
                self.requests as f64,
            ),
            (
                "hec_responses_total",
                "Successful classifications",
                self.responses as f64,
            ),
            (
                "hec_errors_total",
                "Failed or rejected requests",
                self.errors as f64,
            ),
            (
                "hec_batches_total",
                "Batches dispatched to the engine",
                self.batches as f64,
            ),
            (
                "hec_energy_nanojoules_total",
                "Modelled inference energy (nJ)",
                self.energy_nj,
            ),
            (
                "hec_restarts_total",
                "Worker panic-restarts across all shards",
                self.restarts as f64,
            ),
        ];
        for (name, help, v) in counters {
            push(&mut out, "counter", name, help, v);
        }
        let gauges: [(&str, &str, f64); 6] = [
            (
                "hec_queue_depth",
                "Requests queued but not yet batched",
                self.queue_depth as f64,
            ),
            (
                "hec_in_flight",
                "Requests accepted but not yet answered",
                self.in_flight as f64,
            ),
            (
                "hec_batch_size_mean",
                "Mean dispatched batch size",
                self.mean_batch,
            ),
            (
                "hec_latency_mean_microseconds",
                "Mean end-to-end request latency (us)",
                self.latency_mean_us,
            ),
            (
                "hec_latency_p50_microseconds",
                "p50 end-to-end latency upper bound (us)",
                self.latency_p50_us as f64,
            ),
            (
                "hec_latency_p99_microseconds",
                "p99 end-to-end latency upper bound (us)",
                self.latency_p99_us as f64,
            ),
        ];
        for (name, help, v) in gauges {
            push(&mut out, "gauge", name, help, v);
        }
        out
    }

    /// Aggregate per-shard snapshots into one deployment-wide view: counters
    /// and gauges sum exactly; latency/execute means are weighted by each
    /// shard's traffic; the percentile upper bounds take the worst shard
    /// (a conservative deployment-wide bound, since per-shard histograms
    /// cannot be re-bucketed from a snapshot).
    pub fn merge(snaps: &[Snapshot]) -> Snapshot {
        let mut out = Snapshot {
            requests: 0,
            responses: 0,
            errors: 0,
            queue_depth: 0,
            in_flight: 0,
            restarts: 0,
            batches: 0,
            mean_batch: 0.0,
            pad_fraction: 0.0,
            latency_mean_us: 0.0,
            latency_p50_us: 0,
            latency_p99_us: 0,
            execute_mean_us: 0.0,
            backend_mean_us: 0.0,
            energy_nj: 0.0,
        };
        let mut items = 0f64;
        let mut padded = 0f64;
        for s in snaps {
            out.requests += s.requests;
            out.responses += s.responses;
            out.errors += s.errors;
            out.queue_depth += s.queue_depth;
            out.in_flight += s.in_flight;
            out.restarts += s.restarts;
            out.batches += s.batches;
            out.energy_nj += s.energy_nj;
            out.latency_mean_us += s.latency_mean_us * s.responses as f64;
            out.execute_mean_us += s.execute_mean_us * s.batches as f64;
            out.backend_mean_us += s.backend_mean_us * s.batches as f64;
            out.latency_p50_us = out.latency_p50_us.max(s.latency_p50_us);
            out.latency_p99_us = out.latency_p99_us.max(s.latency_p99_us);
            let shard_items = s.mean_batch * s.batches as f64;
            items += shard_items;
            // pad_fraction = padded / (items + padded)  =>  invert per shard.
            if s.pad_fraction > 0.0 && s.pad_fraction < 1.0 {
                padded += shard_items * s.pad_fraction / (1.0 - s.pad_fraction);
            }
        }
        if out.responses > 0 {
            out.latency_mean_us /= out.responses as f64;
        }
        if out.batches > 0 {
            out.execute_mean_us /= out.batches as f64;
            out.backend_mean_us /= out.batches as f64;
            out.mean_batch = items / out.batches as f64;
        }
        if items + padded > 0.0 {
            out.pad_fraction = padded / (items + padded);
        }
        out
    }
}

/// Render the per-shard Prometheus series block (`shard`-labelled samples,
/// one HELP/TYPE header per metric name) — appended after the aggregate
/// [`Snapshot::prometheus`] payload by the sharded gateway's `/metrics`.
pub fn prometheus_shards(shards: &[(Snapshot, bool)]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    type Pick = fn(&Snapshot, bool) -> f64;
    let series: [(&str, &str, &str, Pick); 6] = [
        (
            "hec_shard_queue_depth",
            "gauge",
            "Requests queued on this shard but not yet batched",
            |s, _| s.queue_depth as f64,
        ),
        (
            "hec_shard_in_flight",
            "gauge",
            "Requests accepted by this shard but not yet answered",
            |s, _| s.in_flight as f64,
        ),
        (
            "hec_shard_served_total",
            "counter",
            "Successful classifications served by this shard",
            |s, _| s.responses as f64,
        ),
        (
            "hec_shard_errors_total",
            "counter",
            "Failed or rejected requests on this shard",
            |s, _| s.errors as f64,
        ),
        (
            "hec_shard_restarts_total",
            "counter",
            "Panic-restarts of this shard's worker",
            |s, _| s.restarts as f64,
        ),
        (
            "hec_shard_healthy",
            "gauge",
            "1 when the shard worker is serving, 0 while draining/restarting",
            |_, healthy| f64::from(u8::from(healthy)),
        ),
    ];
    for (name, kind, help, pick) in series {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        for (i, (snap, healthy)) in shards.iter().enumerate() {
            let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {}", pick(snap, *healthy));
        }
    }
    out
}

/// Render the fixed-bucket latency histogram families for `GET /metrics`:
/// end-to-end request latency, per-batch engine execute time, and
/// end-to-end latency split by serving backend.  `labeled` adds a
/// `shard="i"` label per entry (the sharded surface); `false` renders the
/// single-pipeline surface unlabeled.  One `HELP`/`TYPE` header per family.
pub fn prometheus_histograms(
    shards: &[std::sync::Arc<Metrics>],
    labeled: bool,
    out: &mut String,
) {
    use std::fmt::Write as _;
    fn fam(out: &mut String, name: &str, help: &str) {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
    }
    let shard_label = |i: usize| {
        if labeled {
            format!("shard=\"{i}\"")
        } else {
            String::new()
        }
    };
    fam(
        out,
        "hec_latency_microseconds",
        "End-to-end request latency (us), power-of-two buckets",
    );
    for (i, m) in shards.iter().enumerate() {
        m.latency
            .render_prometheus("hec_latency_microseconds", &shard_label(i), out);
    }
    fam(
        out,
        "hec_execute_microseconds",
        "Per-batch engine execute time (us), power-of-two buckets",
    );
    for (i, m) in shards.iter().enumerate() {
        m.execute
            .render_prometheus("hec_execute_microseconds", &shard_label(i), out);
    }
    fam(
        out,
        "hec_backend_latency_microseconds",
        "End-to-end request latency by serving backend (us), power-of-two buckets",
    );
    for (i, m) in shards.iter().enumerate() {
        for (bi, backend) in BACKEND_LABELS.iter().enumerate() {
            let labels = if labeled {
                format!("backend=\"{backend}\",shard=\"{i}\"")
            } else {
                format!("backend=\"{backend}\"")
            };
            m.latency_by_backend[bi].render_prometheus(
                "hec_backend_latency_microseconds",
                &labels,
                out,
            );
        }
    }
}

/// Render the feature-cache Prometheus series: hit/miss/eviction counters
/// plus the resident-entry gauge.  `labeled` adds a `shard="i"` label per
/// entry (the sharded surface); `false` renders the single-pipeline surface
/// unlabeled.  Appended by `/metrics` **only when the cache is enabled** so
/// a cache-off deployment's exposition text stays byte-identical to a build
/// without the cache.
pub fn prometheus_cache(
    shards: &[std::sync::Arc<Metrics>],
    labeled: bool,
    out: &mut String,
) {
    use std::fmt::Write as _;
    type Pick = fn(&Metrics) -> u64;
    let series: [(&str, &str, &str, Pick); 4] = [
        (
            "hec_cache_hits_total",
            "counter",
            "Feature-cache hits (CNN front-end skipped, front_end_nj charged 0)",
            |m| m.cache_hits.load(Ordering::Relaxed),
        ),
        (
            "hec_cache_misses_total",
            "counter",
            "Feature-cache misses (full front-end run, result inserted)",
            |m| m.cache_misses.load(Ordering::Relaxed),
        ),
        (
            "hec_cache_evictions_total",
            "counter",
            "Feature-cache evictions (capacity reached, seeded-random victim)",
            |m| m.cache_evictions.load(Ordering::Relaxed),
        ),
        (
            "hec_cache_entries",
            "gauge",
            "Feature-cache entries currently resident",
            |m| m.cache_entries.load(Ordering::Relaxed),
        ),
    ];
    for (name, kind, help, pick) in series {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        for (i, m) in shards.iter().enumerate() {
            if labeled {
                let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {}", pick(m));
            } else {
                let _ = writeln!(out, "{name} {}", pick(m));
            }
        }
    }
}

/// Render the per-variant back-end Prometheus series: the modelled
/// back-end energy attributed to the deployed `MatchingBackend` variant
/// and the end-to-end latency of the requests it served, both carrying a
/// `variant` label.  `labeled` adds a `shard="i"` label per entry (the
/// sharded surface); `false` renders the single-pipeline surface without
/// it.  Appended by `/metrics` **only when the deployed variant is not
/// the default `acam`**, so a default deployment's exposition text stays
/// byte-identical to pre-seam builds.
pub fn prometheus_variant(
    variant: &'static str,
    shards: &[std::sync::Arc<Metrics>],
    labeled: bool,
    out: &mut String,
) {
    use std::fmt::Write as _;
    let name = "hec_variant_energy_nanojoules_total";
    let _ = writeln!(
        out,
        "# HELP {name} Modelled back-end energy by MatchingBackend variant (nJ)"
    );
    let _ = writeln!(out, "# TYPE {name} counter");
    for (i, m) in shards.iter().enumerate() {
        if labeled {
            let _ = writeln!(
                out,
                "{name}{{variant=\"{variant}\",shard=\"{i}\"}} {}",
                m.variant_energy_nj()
            );
        } else {
            let _ = writeln!(out, "{name}{{variant=\"{variant}\"}} {}", m.variant_energy_nj());
        }
    }
    let name = "hec_variant_latency_microseconds";
    let _ = writeln!(
        out,
        "# HELP {name} End-to-end request latency by MatchingBackend variant (us), power-of-two buckets"
    );
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (i, m) in shards.iter().enumerate() {
        let labels = if labeled {
            format!("variant=\"{variant}\",shard=\"{i}\"")
        } else {
            format!("variant=\"{variant}\"")
        };
        m.variant_latency.render_prometheus(name, &labels, out);
    }
}

/// Render the degradation-ladder Prometheus series (`shard`-labelled), one
/// tuple per shard: `(backend_state, last canary accuracy, re-programs)`.
/// Appended after [`prometheus_shards`] by the sharded `/metrics` — but
/// **only when the canary ladder is active**, so a faults-off deployment's
/// exposition text stays byte-identical to pre-faults builds.  Accuracy is
/// NaN until a shard's first probe (the Prometheus convention for
/// "no data yet").
pub fn prometheus_ladder(shards: &[(crate::faults::BackendState, f64, u64)]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let name = "hec_shard_backend_state";
    let _ = writeln!(
        out,
        "# HELP {name} Degradation ladder state (0=healthy, 1=reprogramming, 2=digital_fallback)"
    );
    let _ = writeln!(out, "# TYPE {name} gauge");
    for (i, (state, _, _)) in shards.iter().enumerate() {
        let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {}", *state as u8);
    }
    let name = "hec_canary_accuracy";
    let _ = writeln!(
        out,
        "# HELP {name} Latest canary-probe accuracy vs the digital reference (NaN before the first probe)"
    );
    let _ = writeln!(out, "# TYPE {name} gauge");
    for (i, (_, accuracy, _)) in shards.iter().enumerate() {
        let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {accuracy}");
    }
    let name = "hec_reprogram_total";
    let _ = writeln!(
        out,
        "# HELP {name} Completed ACAM array re-programs on this shard"
    );
    let _ = writeln!(out, "# TYPE {name} counter");
    for (i, (_, _, reprograms)) in shards.iter().enumerate() {
        let _ = writeln!(out, "{name}{{shard=\"{i}\"}} {reprograms}");
    }
    out
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests={} responses={} errors={} queued={} in_flight={} batches={} \
             mean_batch={:.2} pad={:.1}%",
            self.requests,
            self.responses,
            self.errors,
            self.queue_depth,
            self.in_flight,
            self.batches,
            self.mean_batch,
            self.pad_fraction * 100.0
        )?;
        writeln!(
            f,
            "latency mean={:.0}us p50<{}us p99<{}us  (execute {:.0}us, backend {:.0}us per batch)",
            self.latency_mean_us,
            self.latency_p50_us,
            self.latency_p99_us,
            self.execute_mean_us,
            self.backend_mean_us
        )?;
        write!(f, "modelled energy total={:.2} nJ", self.energy_nj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_percentiles() {
        let h = Histogram::default();
        for us in [1u64, 2, 4, 8, 1000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 203.0).abs() < 1.0);
        assert!(h.percentile_us(0.5) <= 8);
        assert!(h.percentile_us(0.99) >= 1000);
    }

    #[test]
    fn histogram_zero_is_safe() {
        let h = Histogram::default();
        h.record_us(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile_us(1.0), 2);
    }

    #[test]
    fn energy_accumulates_in_millinj() {
        let m = Metrics::default();
        m.add_energy_nj(1.45);
        m.add_energy_nj(1.45);
        assert!((m.energy_nj() - 2.9).abs() < 1e-9);
    }

    #[test]
    fn gauges_track_and_saturate() {
        let m = Metrics::default();
        m.queue_depth.fetch_add(3, Ordering::Relaxed);
        m.in_flight.fetch_add(5, Ordering::Relaxed);
        Metrics::gauge_dec(&m.queue_depth, 2);
        let s = m.snapshot();
        assert_eq!(s.queue_depth, 1);
        assert_eq!(s.in_flight, 5);
        // Saturating: decrementing past zero pins at zero, never wraps.
        Metrics::gauge_dec(&m.queue_depth, 100);
        assert_eq!(m.snapshot().queue_depth, 0);
    }

    #[test]
    fn prometheus_rendering_exposes_counters_and_gauges() {
        let m = Metrics::default();
        m.requests.fetch_add(7, Ordering::Relaxed);
        m.responses.fetch_add(6, Ordering::Relaxed);
        m.errors.fetch_add(1, Ordering::Relaxed);
        m.queue_depth.fetch_add(2, Ordering::Relaxed);
        m.in_flight.fetch_add(4, Ordering::Relaxed);
        m.add_energy_nj(1.5);
        let text = m.snapshot().prometheus();
        for line in [
            "hec_requests_total 7",
            "hec_responses_total 6",
            "hec_errors_total 1",
            "hec_queue_depth 2",
            "hec_in_flight 4",
            "hec_energy_nanojoules_total 1.5",
            "# TYPE hec_queue_depth gauge",
            "# TYPE hec_requests_total counter",
        ] {
            assert!(text.contains(line), "missing {line:?} in:\n{text}");
        }
        // Every sample line is "name value" with a parseable float.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.split_whitespace();
            let name = parts.next().unwrap();
            let value = parts.next().unwrap();
            assert!(name.starts_with("hec_"), "bad metric name in {line:?}");
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            assert!(parts.next().is_none(), "trailing tokens in {line:?}");
        }
    }

    #[test]
    fn merge_sums_counters_and_weights_means() {
        let a = Metrics::default();
        a.requests.fetch_add(4, Ordering::Relaxed);
        a.responses.fetch_add(4, Ordering::Relaxed);
        a.batches.fetch_add(2, Ordering::Relaxed);
        a.batched_items.fetch_add(4, Ordering::Relaxed);
        a.latency.record_us(100);
        a.latency.record_us(100);
        a.latency.record_us(100);
        a.latency.record_us(100);
        a.add_energy_nj(2.0);
        let b = Metrics::default();
        b.requests.fetch_add(1, Ordering::Relaxed);
        b.responses.fetch_add(1, Ordering::Relaxed);
        b.errors.fetch_add(3, Ordering::Relaxed);
        b.restarts.fetch_add(1, Ordering::Relaxed);
        b.batches.fetch_add(1, Ordering::Relaxed);
        b.batched_items.fetch_add(1, Ordering::Relaxed);
        b.latency.record_us(600);
        b.add_energy_nj(0.5);
        let m = Snapshot::merge(&[a.snapshot(), b.snapshot()]);
        assert_eq!(m.requests, 5);
        assert_eq!(m.responses, 5);
        assert_eq!(m.errors, 3);
        assert_eq!(m.restarts, 1);
        assert_eq!(m.batches, 3);
        assert!((m.energy_nj - 2.5).abs() < 1e-9);
        // Weighted latency mean: (4*100 + 1*600) / 5 = 200.
        assert!((m.latency_mean_us - 200.0).abs() < 1e-6, "{}", m.latency_mean_us);
        // Mean batch: 5 items over 3 batches.
        assert!((m.mean_batch - 5.0 / 3.0).abs() < 1e-9);
        // Worst-shard percentile bound.
        assert!(m.latency_p99_us >= 600);
        // Merging nothing is all-zero and finite.
        let z = Snapshot::merge(&[]);
        assert_eq!(z.requests, 0);
        assert_eq!(z.latency_mean_us, 0.0);
    }

    #[test]
    fn merge_reconstructs_pad_fraction() {
        let a = Metrics::default();
        a.batches.fetch_add(1, Ordering::Relaxed);
        a.batched_items.fetch_add(10, Ordering::Relaxed);
        a.padded_slots.fetch_add(6, Ordering::Relaxed);
        let b = Metrics::default();
        b.batches.fetch_add(1, Ordering::Relaxed);
        b.batched_items.fetch_add(10, Ordering::Relaxed);
        let m = Snapshot::merge(&[a.snapshot(), b.snapshot()]);
        // 6 padded slots over 20 items total.
        assert!((m.pad_fraction - 6.0 / 26.0).abs() < 1e-6, "{}", m.pad_fraction);
    }

    #[test]
    fn prometheus_shard_block_labels_every_shard() {
        let a = Metrics::default();
        a.queue_depth.fetch_add(2, Ordering::Relaxed);
        a.in_flight.fetch_add(3, Ordering::Relaxed);
        a.responses.fetch_add(9, Ordering::Relaxed);
        let b = Metrics::default();
        b.restarts.fetch_add(1, Ordering::Relaxed);
        let text = prometheus_shards(&[(a.snapshot(), true), (b.snapshot(), false)]);
        for needle in [
            "hec_shard_queue_depth{shard=\"0\"} 2",
            "hec_shard_in_flight{shard=\"0\"} 3",
            "hec_shard_served_total{shard=\"0\"} 9",
            "hec_shard_restarts_total{shard=\"1\"} 1",
            "hec_shard_healthy{shard=\"0\"} 1",
            "hec_shard_healthy{shard=\"1\"} 0",
            "# TYPE hec_shard_queue_depth gauge",
            "# TYPE hec_shard_restarts_total counter",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // One HELP header per metric name, not per shard.
        assert_eq!(text.matches("# HELP hec_shard_queue_depth").count(), 1);
        // Every sample line is "name{shard=\"i\"} value" with a parseable
        // float value.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.split_once(' ').unwrap();
            assert!(name.starts_with("hec_shard_"), "bad name in {line:?}");
            assert!(name.contains("{shard=\""), "unlabelled sample {line:?}");
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
        }
    }

    #[test]
    fn prometheus_ladder_block_labels_states_and_counters() {
        use crate::faults::BackendState;
        let text = prometheus_ladder(&[
            (BackendState::Healthy, 1.0, 0),
            (BackendState::DigitalFallback, 0.55, 2),
            (BackendState::Reprogramming, f64::NAN, 1),
        ]);
        for needle in [
            "hec_shard_backend_state{shard=\"0\"} 0",
            "hec_shard_backend_state{shard=\"1\"} 2",
            "hec_shard_backend_state{shard=\"2\"} 1",
            "hec_canary_accuracy{shard=\"0\"} 1",
            "hec_canary_accuracy{shard=\"1\"} 0.55",
            "hec_canary_accuracy{shard=\"2\"} NaN",
            "hec_reprogram_total{shard=\"1\"} 2",
            "# TYPE hec_shard_backend_state gauge",
            "# TYPE hec_reprogram_total counter",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Every sample line stays machine-parseable (NaN included).
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.split_once(' ').unwrap();
            assert!(name.contains("{shard=\""), "unlabelled sample {line:?}");
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
        }
    }

    #[test]
    fn histogram_prometheus_block_is_cumulative_and_consistent() {
        let h = Histogram::default();
        for us in [1u64, 2, 4, 8, 1000] {
            h.record_us(us);
        }
        let mut out = String::new();
        h.render_prometheus("hec_latency_microseconds", "", &mut out);
        for needle in [
            "hec_latency_microseconds_bucket{le=\"1\"} 1",
            "hec_latency_microseconds_bucket{le=\"3\"} 2",
            "hec_latency_microseconds_bucket{le=\"7\"} 3",
            "hec_latency_microseconds_bucket{le=\"15\"} 4",
            "hec_latency_microseconds_bucket{le=\"+Inf\"} 5",
            "hec_latency_microseconds_sum 1015",
            "hec_latency_microseconds_count 5",
        ] {
            assert!(out.contains(needle), "missing {needle:?} in:\n{out}");
        }
        // Cumulative counts never decrease down the bucket ladder.
        let mut prev = 0u64;
        for line in out.lines().filter(|l| l.contains("_bucket")) {
            let (_, value) = line.split_once(' ').unwrap();
            let v: u64 = value.parse().unwrap();
            assert!(v >= prev, "non-monotone bucket in {line:?}");
            prev = v;
        }
        // Labelled rendering nests le inside the existing label set.
        let mut labelled = String::new();
        h.render_prometheus("hec_x", "shard=\"3\"", &mut labelled);
        assert!(labelled.contains("hec_x_bucket{shard=\"3\",le=\"+Inf\"} 5"), "{labelled}");
        assert!(labelled.contains("hec_x_sum{shard=\"3\"} 1015"), "{labelled}");
    }

    #[test]
    fn prometheus_histograms_cover_backends_and_shards() {
        use crate::config::Backend;
        let a = std::sync::Arc::new(Metrics::default());
        a.latency.record_us(10);
        a.execute.record_us(5);
        a.latency_for(Backend::AcamSim).record_us(10);
        let b = std::sync::Arc::new(Metrics::default());
        b.latency_for(Backend::FeatureCount).record_us(100);
        let mut out = String::new();
        prometheus_histograms(&[a.clone(), b.clone()], true, &mut out);
        for needle in [
            "# TYPE hec_latency_microseconds histogram",
            "# TYPE hec_execute_microseconds histogram",
            "# TYPE hec_backend_latency_microseconds histogram",
            "hec_latency_microseconds_count{shard=\"0\"} 1",
            "hec_latency_microseconds_count{shard=\"1\"} 0",
            "hec_backend_latency_microseconds_count{backend=\"acam\",shard=\"0\"} 1",
            "hec_backend_latency_microseconds_count{backend=\"fc\",shard=\"1\"} 1",
            "hec_backend_latency_microseconds_count{backend=\"sim\",shard=\"0\"} 0",
        ] {
            assert!(out.contains(needle), "missing {needle:?} in:\n{out}");
        }
        // One TYPE header per family, not per shard or backend.
        assert_eq!(out.matches("# TYPE hec_backend_latency_microseconds").count(), 1);
        // Unlabelled single-shard rendering drops the shard label entirely.
        let mut single = String::new();
        prometheus_histograms(&[a], false, &mut single);
        assert!(single.contains("hec_latency_microseconds_count 1"), "{single}");
        assert!(
            single.contains("hec_backend_latency_microseconds_count{backend=\"acam\"} 1"),
            "{single}"
        );
        assert!(!single.contains("shard="), "{single}");
    }

    #[test]
    fn prometheus_cache_block_renders_both_shapes() {
        let a = std::sync::Arc::new(Metrics::default());
        a.cache_hits.fetch_add(7, Ordering::Relaxed);
        a.cache_misses.fetch_add(3, Ordering::Relaxed);
        a.cache_entries.fetch_add(3, Ordering::Relaxed);
        let b = std::sync::Arc::new(Metrics::default());
        b.cache_evictions.fetch_add(1, Ordering::Relaxed);
        let mut out = String::new();
        prometheus_cache(&[a.clone(), b], true, &mut out);
        for needle in [
            "hec_cache_hits_total{shard=\"0\"} 7",
            "hec_cache_misses_total{shard=\"0\"} 3",
            "hec_cache_evictions_total{shard=\"1\"} 1",
            "hec_cache_entries{shard=\"0\"} 3",
            "# TYPE hec_cache_hits_total counter",
            "# TYPE hec_cache_entries gauge",
        ] {
            assert!(out.contains(needle), "missing {needle:?} in:\n{out}");
        }
        // One HELP header per metric name, not per shard.
        assert_eq!(out.matches("# HELP hec_cache_hits_total").count(), 1);
        // Unlabelled single-pipeline rendering drops the shard label.
        let mut single = String::new();
        prometheus_cache(&[a], false, &mut single);
        assert!(single.contains("hec_cache_hits_total 7"), "{single}");
        assert!(!single.contains("shard="), "{single}");
    }

    #[test]
    fn prometheus_variant_block_labels_energy_and_latency() {
        let a = std::sync::Arc::new(Metrics::default());
        a.add_variant_energy_nj(2.5);
        a.variant_latency.record_us(10);
        let b = std::sync::Arc::new(Metrics::default());
        let mut out = String::new();
        prometheus_variant("rbf", &[a.clone(), b], true, &mut out);
        for needle in [
            "hec_variant_energy_nanojoules_total{variant=\"rbf\",shard=\"0\"} 2.5",
            "hec_variant_energy_nanojoules_total{variant=\"rbf\",shard=\"1\"} 0",
            "hec_variant_latency_microseconds_count{variant=\"rbf\",shard=\"0\"} 1",
            "# TYPE hec_variant_energy_nanojoules_total counter",
            "# TYPE hec_variant_latency_microseconds histogram",
        ] {
            assert!(out.contains(needle), "missing {needle:?} in:\n{out}");
        }
        // One HELP header per family, not per shard.
        assert_eq!(out.matches("# HELP hec_variant_energy_nanojoules_total").count(), 1);
        // Unlabelled single-pipeline rendering keeps the variant label only.
        let mut single = String::new();
        prometheus_variant("acam-9t4r", &[a], false, &mut single);
        assert!(
            single.contains("hec_variant_energy_nanojoules_total{variant=\"acam-9t4r\"} 2.5"),
            "{single}"
        );
        assert!(!single.contains("shard="), "{single}");
    }

    #[test]
    fn restarts_render_in_aggregate_prometheus() {
        let m = Metrics::default();
        m.restarts.fetch_add(2, Ordering::Relaxed);
        let text = m.snapshot().prometheus();
        assert!(text.contains("hec_restarts_total 2"), "{text}");
    }

    #[test]
    fn snapshot_batch_stats() {
        let m = Metrics::default();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batched_items.fetch_add(10, Ordering::Relaxed);
        m.padded_slots.fetch_add(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.mean_batch - 5.0).abs() < 1e-9);
        assert!((s.pad_fraction - 6.0 / 16.0).abs() < 1e-9);
    }
}

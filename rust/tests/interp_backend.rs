//! Artifact-free integration tests: the default interp engine + synthetic
//! fallback must serve end-to-end on a clean checkout (no `make artifacts`),
//! and `Pipeline::classify` must agree exactly with the digital matching
//! reference path on the synthetic dataset.
//!
//! Every test points at a directory that cannot exist, so the fallback path
//! is exercised deterministically even on machines that have built real
//! artifacts.

use hec::config::{Backend, Engine, ServeConfig};
use hec::coordinator::{Pipeline, Server};
use hec::dataset::SyntheticDataset;
use hec::matching;

/// An artifacts directory that never exists -> synthetic fallback.
const NO_ARTIFACTS: &str = "/nonexistent-hec-artifacts";

fn cfg(backend: Backend) -> ServeConfig {
    ServeConfig {
        artifacts_dir: NO_ARTIFACTS.into(),
        backend,
        ..Default::default()
    }
}

fn workload(p: &Pipeline, n: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
    SyntheticDataset::new(seed, n, p.meta.norm.mean as f32, p.meta.norm.std as f32).batch(0, n)
}

/// The full stack (synthetic weights, bootstrapped templates, similarity
/// back-end — `--backend sim`) classifies a synthetic batch end-to-end.
#[test]
fn synthetic_pipeline_runs_end_to_end() {
    let mut p = Pipeline::new(&cfg(Backend::Similarity)).unwrap();
    assert_eq!(p.engine_name(), "interp");
    assert_eq!(p.meta.dataset.source, "synthetic-fallback");
    let n = 12;
    let (images, _) = workload(&p, n, 1_000_003);
    let results = p.classify_batch(&images, n).unwrap();
    assert_eq!(results.len(), n);
    for r in &results {
        assert!(r.top1().class < p.store.num_classes);
        assert!(r.energy.total_nj() > 0.0);
        assert!(r.energy.front_end_nj > 0.0 && r.energy.back_end_nj > 0.0);
    }
}

/// Predictions through the pipeline's feature-count back-end are identical
/// to running the digital Eq. 8 + Eq. 12 reference directly on the
/// binarised features.
#[test]
fn pipeline_matches_digital_reference_feature_count() {
    let mut p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let n = 16;
    let (images, _) = workload(&p, n, 1_000_003);
    let feats = p.extract_features(&images, n).unwrap();
    let nf = p.meta.artifacts.n_features;
    let got: Vec<usize> = p
        .classify_batch(&images, n)
        .unwrap()
        .into_iter()
        .map(|c| c.top1().class)
        .collect();
    let set = p.store.set(1).unwrap();
    let want: Vec<usize> = feats
        .chunks_exact(nf)
        .map(|row| {
            let bits = p.store.binarize(row);
            matching::classify_feature_count(&bits, set, p.store.num_classes)
        })
        .collect();
    assert_eq!(got, want);
}

/// Same identity for the similarity back-end (`--backend sim`).
#[test]
fn pipeline_matches_digital_reference_similarity() {
    let mut p = Pipeline::new(&cfg(Backend::Similarity)).unwrap();
    let n = 16;
    let (images, _) = workload(&p, n, 1_000_003);
    let feats = p.extract_features(&images, n).unwrap();
    let nf = p.meta.artifacts.n_features;
    let got: Vec<usize> = p
        .classify_batch(&images, n)
        .unwrap()
        .into_iter()
        .map(|c| c.top1().class)
        .collect();
    let set = p.store.set(1).unwrap();
    let want: Vec<usize> = feats
        .chunks_exact(nf)
        .map(|row| {
            let bits = p.store.binarize(row);
            let qf: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
            matching::classify_similarity(
                &qf,
                set,
                p.store.similarity_alpha,
                p.store.num_classes,
                true,
            )
        })
        .collect();
    assert_eq!(got, want);
}

/// The §III fidelity contract holds without artifacts too: an ideal
/// simulated ACAM classifies identically to the digital feature count.
#[test]
fn ideal_acam_equals_feature_count() {
    let mut fc = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let mut acam = Pipeline::new(&cfg(Backend::AcamSim)).unwrap();
    let n = 16;
    let (images, _) = workload(&fc, n, 1_000_003);
    let p_fc: Vec<usize> = fc
        .classify_batch(&images, n)
        .unwrap()
        .into_iter()
        .map(|c| c.top1().class)
        .collect();
    let p_acam: Vec<usize> = acam
        .classify_batch(&images, n)
        .unwrap()
        .into_iter()
        .map(|c| c.top1().class)
        .collect();
    assert_eq!(p_fc, p_acam);
}

/// The softmax baseline runs through the synthetic head.
#[test]
fn softmax_backend_runs_on_synthetic_head() {
    let mut p = Pipeline::new(&cfg(Backend::Softmax)).unwrap();
    let n = 8;
    let (images, _) = workload(&p, n, 999);
    let results = p.classify_batch(&images, n).unwrap();
    assert_eq!(results.len(), n);
    for r in &results {
        assert!(r.top1().class < p.store.num_classes);
    }
}

/// Feature extraction is deterministic and batch-size invariant.
#[test]
fn features_are_deterministic_and_batch_invariant() {
    let mut p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let (images, _) = workload(&p, 4, 77);
    let nf = p.meta.artifacts.n_features;
    let all = p.extract_features(&images, 4).unwrap();
    let again = p.extract_features(&images, 4).unwrap();
    assert_eq!(all, again);
    let img_len = p.image_len();
    for i in 0..4 {
        let one = p
            .extract_features(&images[i * img_len..(i + 1) * img_len], 1)
            .unwrap();
        assert_eq!(&all[i * nf..(i + 1) * nf], &one[..], "row {i}");
    }
}

/// Two pipelines built from the same config see the same bootstrapped
/// store and produce the same predictions (the bootstrap is deterministic).
#[test]
fn bootstrap_is_deterministic_across_pipelines() {
    let a = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let b = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    assert_eq!(a.store.thresholds, b.store.thresholds);
    assert_eq!(
        a.store.set(1).unwrap().templates,
        b.store.set(1).unwrap().templates
    );
    // All three Table II template sets exist and validate.
    for k in 1..=3 {
        assert!(a.store.set(k).unwrap().num_templates() >= a.store.num_classes);
    }
}

/// End-to-end serving without artifacts: dynamic batcher + worker thread.
#[test]
fn server_round_trip_without_artifacts() {
    let mut c = cfg(Backend::FeatureCount);
    c.batch.max_batch = 4;
    c.batch.max_wait_us = 500;
    let server = Server::start(c).unwrap();
    let handle = server.handle.clone();
    let p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let (images, _) = workload(&p, 8, 77);
    let img_len = p.image_len();
    let rxs: Vec<_> = (0..8)
        .map(|i| {
            handle
                .submit(hec::api::ClassifyRequest::new(
                    images[i * img_len..(i + 1) * img_len].to_vec(),
                ))
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let res = rx.recv().unwrap().unwrap();
        assert!(res.top1().class < 10);
        assert!(res.energy.total_nj() > 0.0);
    }
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.responses, 8);
    assert_eq!(snap.errors, 0);
    drop(handle);
    server.shutdown();
}

/// Without the `pjrt` feature, selecting the pjrt engine is a config error
/// with an actionable message (not a crash or a silent fallback).
#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_engine_errors_without_feature() {
    let mut c = cfg(Backend::FeatureCount);
    c.engine = Engine::Pjrt;
    let err = Pipeline::new(&c).err().expect("must fail");
    assert!(err.to_string().contains("pjrt"), "{err}");
}

/// Engine parsing round-trips through the CLI-facing names.
#[test]
fn engine_names_parse() {
    assert_eq!("interp".parse::<Engine>().unwrap(), Engine::Interp);
    assert_eq!("interp-fast".parse::<Engine>().unwrap(), Engine::InterpFast);
    assert_eq!("pjrt".parse::<Engine>().unwrap(), Engine::Pjrt);
}

/// The interp-fast engine serves the full artifact-free pipeline and
/// predicts identically to the scalar engine (same weights, same
/// bootstrapped templates, fp-equivalent features).
#[test]
fn fast_engine_serves_and_matches_scalar_predictions() {
    let mut scalar = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let mut c = cfg(Backend::FeatureCount);
    c.engine = Engine::InterpFast;
    let mut fast = Pipeline::new(&c).unwrap();
    assert_eq!(fast.engine_name(), "interp-fast");
    let n = 16;
    let (images, _) = workload(&scalar, n, 1_000_003);
    let p_scalar: Vec<usize> = scalar
        .classify_batch(&images, n)
        .unwrap()
        .into_iter()
        .map(|r| r.top1().class)
        .collect();
    let p_fast: Vec<usize> = fast
        .classify_batch(&images, n)
        .unwrap()
        .into_iter()
        .map(|r| r.top1().class)
        .collect();
    assert_eq!(p_scalar, p_fast);
}

/// ROADMAP bootstrap sweep (closes the "8 samples/class sweep" item):
/// bootstrapped template quality as a function of the per-class sample
/// budget.  For each budget in {1, 2, 4, 8}, build a store through the
/// synthetic fallback engine and grade it on the full 8-per-class
/// bootstrap workload with the digital Eq. 8 matcher.  Monotone-ish
/// quality contract: every budget classifies no worse than chance, and the
/// full 8-sample budget at least matches the 2x-chance bar the serving
/// assertion below enforces.
#[test]
fn bootstrap_sweep_accuracy_over_samples_per_class() {
    use hec::coordinator::pipeline::{bootstrap_store_with, BOOTSTRAP_DATA_SEED};
    use hec::dataset::NUM_CLASSES;
    use hec::runtime::Meta;

    let c = cfg(Backend::FeatureCount);
    let meta = Meta::synthetic();
    let mut engine = hec::runtime::backend::create(&c, &meta).unwrap();

    // The grading workload: the same 8-per-class bootstrap set the
    // existing accuracy assertion uses (budgets < 8 are therefore graded
    // partly out-of-sample — their templates saw only a prefix of it).
    let n = 8 * NUM_CLASSES;
    let ds = SyntheticDataset::new(
        BOOTSTRAP_DATA_SEED,
        n,
        meta.norm.mean as f32,
        meta.norm.std as f32,
    );
    let (images, labels) = ds.batch(0, n);
    let feats = engine.extract_features(&images, n).unwrap();
    let nf = meta.artifacts.n_features;

    let chance = 1.0 / NUM_CLASSES as f64;
    let mut accuracies = Vec::new();
    for per_class in [1usize, 2, 4, 8] {
        let store =
            bootstrap_store_with(engine.as_mut(), &meta, c.acam.seed, per_class).unwrap();
        let set = store.set(1).unwrap();
        let correct = feats
            .chunks_exact(nf)
            .zip(&labels)
            .filter(|(row, &label)| {
                let bits = store.binarize(row);
                matching::classify_feature_count(&bits, set, NUM_CLASSES) == label
            })
            .count();
        let acc = correct as f64 / n as f64;
        assert!(
            acc >= chance,
            "{per_class} samples/class: accuracy {acc:.3} below chance {chance:.2}"
        );
        accuracies.push((per_class, acc));
    }
    let acc8 = accuracies.last().unwrap().1;
    assert!(
        acc8 >= 2.0 * chance,
        "8 samples/class must at least match the serving assertion's 2x-chance bar, \
         got {acc8:.3} (sweep: {accuracies:?})"
    );
    // The deployed budget (8) is also what Pipeline::new bootstraps, so the
    // sweep's top row is the production configuration.
    assert_eq!(
        hec::coordinator::pipeline::BOOTSTRAP_PER_CLASS,
        8,
        "sweep top must stay in sync with the deployed bootstrap budget"
    );
}

/// Sanity (ROADMAP): the synthetic-weight + bootstrapped-template fallback
/// is not just self-consistent but *accurate* on the samples its templates
/// were bootstrapped from — well above the 10% chance floor — on both
/// interpreter engines.
#[test]
fn bootstrap_samples_classified_above_chance_on_both_engines() {
    use hec::coordinator::pipeline::{BOOTSTRAP_DATA_SEED, BOOTSTRAP_PER_CLASS};
    use hec::dataset::NUM_CLASSES;
    for engine in [Engine::Interp, Engine::InterpFast] {
        let mut c = cfg(Backend::FeatureCount);
        c.engine = engine;
        let mut p = Pipeline::new(&c).unwrap();
        let n = BOOTSTRAP_PER_CLASS * NUM_CLASSES;
        let ds = SyntheticDataset::new(
            BOOTSTRAP_DATA_SEED,
            n,
            p.meta.norm.mean as f32,
            p.meta.norm.std as f32,
        );
        let (images, labels) = ds.batch(0, n);
        let eval = p.evaluate(&images, &labels, 16).unwrap();
        let chance = 1.0 / NUM_CLASSES as f64;
        assert!(
            eval.accuracy >= 2.0 * chance,
            "{engine:?}: bootstrap-sample accuracy {:.3} not above chance ({chance:.2})",
            eval.accuracy
        );
    }
}

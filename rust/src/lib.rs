//! # Hybrid Edge Classifier — Rust coordinator (Layer 3)
//!
//! Reproduction of *"A Hybrid Edge Classifier: Combining TinyML-Optimised CNN
//! with RRAM-CMOS ACAM for Energy-Efficient Inference"* (Woodward et al.,
//! 2025).
//!
//! The serving runtime is self-contained — it runs on a clean checkout
//! with no artifacts at all (synthetic weights + bootstrapped templates),
//! and picks up the real `make artifacts` outputs when they exist:
//!
//! * [`runtime`] hosts the pluggable front-end execution backends behind
//!   the [`runtime::FrontEnd`] trait: a pure-Rust interpreter that ports
//!   the Python reference kernels (the default engine everywhere), and the
//!   PJRT path that compiles AOT-exported HLO text modules (cargo feature
//!   `pjrt`).  Python is never invoked at runtime either way.
//! * [`acam`] is a circuit-level behavioural simulator of the RRAM-CMOS
//!   TXL-ACAM back-end (6T4R charging and 3T1R precharging cells, matchline
//!   dynamics, sense amplifiers, analogue winner-take-all) standing in for
//!   the paper's fabricated 180 nm hardware (DESIGN.md §Substitutions).
//! * [`matching`] implements the paper's digital matching models (Eq. 8-12)
//!   bit-exactly, including a packed 64-features-per-word popcount path.
//! * [`backend`] is the back-end mirror of the front-end seam: the
//!   [`backend::MatchingBackend`] trait with four selectable variants —
//!   the TXL ACAM (default), the 9T4R graded ACAM, the RBF-neuron
//!   classifier, and the exact digital matcher — each with its own
//!   search/re-program energy constants (`--backend`, `HEC_BACKEND`).
//! * [`api`] is the versioned (v1) public classification protocol: typed
//!   requests/responses with ranked predictions, per-stage energy, timings,
//!   and stable machine-readable error codes, plus the JSON wire form.
//! * [`coordinator`] owns the event loop: request router, dynamic batcher,
//!   back-end dispatch, metrics — and the sharded scale-out
//!   ([`coordinator::shard`]): N independent worker pipelines behind one
//!   routed submit surface with spill backpressure and panic-restart
//!   shard health.
//! * [`gateway`] is the dependency-free HTTP/1.1 + JSON front door
//!   (`POST /v1/classify`, `/v1/classify/batch`, `GET /healthz`,
//!   `GET /metrics`) funneling into the same bounded queue as in-process
//!   callers.
//! * [`loadgen`] is the synthetic open-loop load generator behind the
//!   `loadtest` bench: Zipf hot-key skew over a seeded image pool, bursty
//!   arrivals, slow/chunked clients, per-request deadlines, and
//!   client-side latency percentiles (`BENCH_loadtest.json`).
//! * [`faults`] is the deterministic fault-injection subsystem: seeded
//!   [`faults::FaultPlan`] schedules (conductance drift, stuck-at-G cells,
//!   read-noise escalation, worker stalls) replayed against live shards,
//!   and the [`faults::BackendState`] degradation ladder the canary state
//!   machine walks (`Healthy` → `Reprogramming` → `DigitalFallback`).
//! * [`store`] is the multi-tenant template-store registry: versioned
//!   immutable [`templates::TemplateStore`] snapshots behind an atomic
//!   epoch-swap (shards adopt a publish at batch boundaries, never
//!   mid-batch), per-tenant admission quotas, and online re-fit from
//!   labelled probes — surfaced over `PUT/GET /v1/stores/{id}`.
//! * [`energy`] is the Horowitz-constant energy ledger behind §V.D.
//! * [`dataset`], [`templates`], [`kmeans`], [`config`] are supporting
//!   substrates (synthetic workload generator mirrored from Python, template
//!   store, on-device clustering, configuration).

//!
//! Offline-environment note: the default build has **zero external
//! dependencies** — [`jsonlite`] (JSON), [`rng`] (SplitMix64 + Box-Muller)
//! and [`benchkit`] (timing harness) replace serde / rand / criterion, the
//! serving loop is built on `std::thread` + bounded channels instead of
//! tokio, and the CLI is hand-parsed instead of clap.  The `xla` crate is
//! only referenced behind the `pjrt` cargo feature (see Cargo.toml).

pub mod acam;
pub mod api;
pub mod backend;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod energy;
pub mod error;
pub mod faults;
pub mod gateway;
pub mod jsonlite;
pub mod kmeans;
pub mod loadgen;
pub mod matching;
pub mod rng;
pub mod runtime;
pub mod store;
pub mod templates;

pub use error::{Error, Result};

//! Sharded serving: N independent [`Pipeline`] workers behind one routed
//! submit surface.
//!
//! Each shard owns a full pipeline — its own front-end engine instance,
//! ACAM array, RNG stream (seeded `acam.seed + shard_index`) and bounded
//! request queue — so shards never contend on model state and a shard
//! failure cannot poison its neighbours.  The [`ShardHandle`] is the
//! [`super::ClassifySurface`] the gateway serves; it routes each request
//! with a pluggable [`RoutePolicy`], spills a full queue to the next-best
//! healthy shard before surfacing `QUEUE_FULL`, and keeps per-shard
//! metrics for the `shard`-labelled Prometheus series.
//!
//! **Determinism is the design constraint.**  Routing depends only on the
//! policy, the submit order (the round-robin ticket), and the observed
//! queue occupancy — never on wall-clock time.  Because shard `i` runs the
//! base config with `acam.seed + i`, an N-shard deployment's predictions
//! and energy splits are bitwise identical to N independent single-pipeline
//! runs fed the same routed request subsequences — the property
//! `rust/tests/shard.rs` enforces for N in {1, 2, 4} on both interpreter
//! engines.
//!
//! **Shard health.**  A worker panic (engine bug, poisoned state) is caught
//! per batch: the shard is marked unhealthy *before* the failing requests
//! are answered (`INTERNAL`), its queue is drained (every queued request
//! fails fast with `INTERNAL` instead of hanging), the pipeline is rebuilt
//! from config, and the shard rejoins the rotation — all without dropping
//! the other shards.  `/healthz` reports `degraded` for exactly the
//! unhealthy window.
//!
//! The [`Gate`] + [`ShardHooks`] types are the deterministic concurrency
//! test harness: they let tests park a worker at a known point or inject a
//! panic on a chosen request, replacing sleeps with explicit barriers.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{ApiError, ClassifyRequest, ClassifyResponse, ErrorCode};
use crate::config::{Backend, RoutePolicy, ServeConfig};
use crate::error::Result;
use crate::faults::{BackendState, FaultInjector, FaultKind, FaultPlan};
use crate::runtime::Meta;
use crate::store::{StoreAdmin, StoreRegistry};

use super::batcher;
use super::metrics::{prometheus_histograms, prometheus_ladder, prometheus_shards, Metrics, Snapshot};
use super::oneshot;
use super::pipeline::Pipeline;
use super::server::{
    admit_tenant, deliver_batch, drop_expired_jobs, fail_job, pack_batch_into, validate_request,
    Caps, Job,
};
use super::{ClassifySurface, HealthReport, ShardStatus};

// ---------------------------------------------------------------------------
// Deterministic test harness
// ---------------------------------------------------------------------------

/// A counting rendezvous for deterministic concurrency tests: workers
/// `pass()` (announce arrival, then block until released) or
/// `arrive_only()` (announce a checkpoint without blocking); the test
/// thread `await_arrivals(n)` to synchronise and `release()` to let a
/// parked worker continue.  No timeouts, no sleeps — every ordering the
/// tests assert is forced, not raced.
#[derive(Default)]
pub struct Gate {
    /// (arrivals, releases)
    state: Mutex<(u64, u64)>,
    cv: Condvar,
}

impl Gate {
    pub fn new() -> Arc<Gate> {
        Arc::new(Gate::default())
    }

    /// Announce arrival `n` (1-based) and block until `release` has been
    /// called at least `n` times.
    pub fn pass(&self) {
        let mut st = self.state.lock().unwrap();
        st.0 += 1;
        let my = st.0;
        self.cv.notify_all();
        while st.1 < my {
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Announce a checkpoint without blocking.
    pub fn arrive_only(&self) {
        let mut st = self.state.lock().unwrap();
        st.0 += 1;
        self.cv.notify_all();
    }

    /// Block until at least `n` arrivals have been announced.
    pub fn await_arrivals(&self, n: u64) {
        let mut st = self.state.lock().unwrap();
        while st.0 < n {
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Unblock the next parked `pass()` caller.
    pub fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 += 1;
        self.cv.notify_all();
    }

    /// Arrivals announced so far.
    pub fn arrivals(&self) -> u64 {
        self.state.lock().unwrap().0
    }
}

/// Test instrumentation threaded into every shard worker.  All hooks match
/// on `request_id`, so production requests (which pick their own ids) are
/// unaffected unless an operator deliberately wires a trigger; the default
/// is fully inert.  These knobs are Rust-level only — they have no config
/// file or CLI surface.
#[derive(Default, Clone)]
pub struct ShardHooks {
    /// A request whose `request_id` equals this panics the worker mid-batch
    /// (stands in for an engine bug) — exercising the unhealthy -> drain ->
    /// restart path.
    pub panic_on: Option<String>,
    /// A request whose `request_id` equals this parks the worker on the
    /// gate before computing, so tests can fill its queue deterministically.
    pub hold: Option<(String, Arc<Gate>)>,
    /// When set, a restarting worker `pass()`es this gate after draining
    /// (letting tests observe the degraded window) and `arrive_only()`s
    /// once healthy again (letting tests await recovery).
    pub restart_gate: Option<Arc<Gate>>,
    /// When set, the worker `arrive_only()`s after every completed canary
    /// probe, so tests can await "N probes have happened" without sleeps.
    pub canary_gate: Option<Arc<Gate>>,
    /// When set, a demoting worker `pass()`es this gate immediately after
    /// publishing `Reprogramming` (before re-fitting the array), so tests
    /// can observe the intermediate ladder state deterministically.
    pub reprogram_gate: Option<Arc<Gate>>,
}

// ---------------------------------------------------------------------------
// Routing
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit — the sticky-routing hash (stable across platforms and
/// releases; part of the routing contract, do not change).
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Pure routing plan: the candidate shard order for one request, best
/// first.  Depends only on (policy, round-robin ticket, request id, queue
/// depths, shard health) — no clocks, no randomness — so policies are unit
/// testable without threads.
///
/// Unhealthy shards never appear.  With `spill`, the plan lists every
/// healthy shard (primary first, then the spill order: cyclic successors
/// for round-robin/hash, ascending depth for least-depth); without it, the
/// plan is just the primary.  An empty plan means no healthy shard exists.
pub fn plan_route(
    policy: RoutePolicy,
    ticket: u64,
    request_id: Option<&str>,
    queue_depths: &[u64],
    healthy: &[bool],
    spill: bool,
) -> Vec<usize> {
    debug_assert_eq!(queue_depths.len(), healthy.len());
    let alive: Vec<usize> = (0..healthy.len()).filter(|&i| healthy[i]).collect();
    if alive.is_empty() {
        return Vec::new();
    }
    let mut order: Vec<usize> = match policy {
        RoutePolicy::LeastQueueDepth => {
            let mut sorted = alive;
            // Stable ascending by depth; the stable sort makes the lowest
            // index win ties.
            sorted.sort_by_key(|&i| queue_depths[i]);
            sorted
        }
        RoutePolicy::RoundRobin | RoutePolicy::Hash => {
            let start = match (policy, request_id) {
                (RoutePolicy::Hash, Some(id)) => (fnv1a(id) % alive.len() as u64) as usize,
                // Round-robin, and hash's fallback for id-less requests.
                _ => (ticket % alive.len() as u64) as usize,
            };
            (0..alive.len()).map(|k| alive[(start + k) % alive.len()]).collect()
        }
    };
    if !spill {
        order.truncate(1);
    }
    order
}

// ---------------------------------------------------------------------------
// The shard set
// ---------------------------------------------------------------------------

/// Lock-free ladder observations shared between a shard worker (the only
/// writer) and the handle (readers: `/healthz`, `/metrics`, tests).
#[derive(Clone)]
struct LadderCells {
    /// `BackendState` as its `u8` repr.
    state: Arc<AtomicU8>,
    /// Most recent canary accuracy as `f64` bits; NaN until the first probe.
    accuracy: Arc<AtomicU64>,
    /// Completed array re-programs.
    reprograms: Arc<AtomicU64>,
}

impl LadderCells {
    fn new() -> LadderCells {
        LadderCells {
            state: Arc::new(AtomicU8::new(BackendState::Healthy as u8)),
            accuracy: Arc::new(AtomicU64::new(f64::NAN.to_bits())),
            reprograms: Arc::new(AtomicU64::new(0)),
        }
    }

    fn state(&self) -> BackendState {
        BackendState::from_u8(self.state.load(Ordering::SeqCst))
    }
}

/// Canary knobs resolved once at startup; `Some` iff the degradation ladder
/// is active for this deployment (ACAM backend + `canary_every > 0`).
#[derive(Clone)]
struct LadderParams {
    /// Probe after every this-many served requests.
    canary_every: u64,
    /// Canary probes per class (probe set size = `per_class * num_classes`).
    per_class: usize,
    /// Canary accuracy below this demotes the shard.
    threshold: f64,
}

/// Fault/ladder context threaded into one shard worker.
struct ShardFaultCtx {
    /// Deterministic fault schedule (injector seed derives from the shard
    /// index, so shards age independently but reproducibly).
    plan: Option<FaultPlan>,
    ladder: Option<LadderParams>,
    cells: LadderCells,
}

struct ShardSlot {
    tx: SyncSender<Job>,
    metrics: Arc<Metrics>,
    healthy: Arc<AtomicBool>,
    ladder: LadderCells,
}

struct Inner {
    shards: Vec<ShardSlot>,
    policy: RoutePolicy,
    spill: bool,
    /// Round-robin ticket counter (also the hash policy's fallback for
    /// requests without an id).
    rr: AtomicU64,
    /// Submits rejected at the router itself (no healthy shard, or every
    /// candidate queue full) — deployment-level load shedding that no
    /// single shard saw, so it is counted here rather than skewing any
    /// shard's `requests`/`errors` series.
    rejected: AtomicU64,
    caps: Caps,
    /// Whether the canary/degradation ladder is active for this deployment.
    /// When false, no ladder series/fields are ever surfaced — keeping the
    /// `/metrics` text and v1 responses bitwise identical to a build without
    /// the faults subsystem.
    ladder_active: bool,
    /// Template-store admin surface (`/v1/stores`); also the tenant
    /// admission point.  Every shard shares the one registry — a publish is
    /// adopted by each shard at its next batch boundary.
    admin: StoreAdmin,
    /// Whether the per-shard feature cache is enabled — gates the
    /// `hec_cache_*` block in `/metrics` so cache-off exposition text stays
    /// byte-identical to a cache-free build.
    cache_on: bool,
}

/// Cloneable submit surface over the shard set — the sharded counterpart
/// of [`super::Handle`], and a [`ClassifySurface`] the gateway can serve.
#[derive(Clone)]
pub struct ShardHandle {
    inner: Arc<Inner>,
}

/// The running shard set (worker threads + routed handle).
pub struct ShardSet {
    pub handle: ShardHandle,
    workers: Vec<JoinHandle<()>>,
}

impl ShardSet {
    /// Start `cfg.resolve_shards()` worker pipelines.  Shard `i` runs the
    /// base config with `acam.seed + i`, so a 1-shard set is bitwise
    /// identical to a plain single-pipeline deployment.
    pub fn start(cfg: &ServeConfig) -> Result<ShardSet> {
        Self::start_with_hooks(cfg, ShardHooks::default())
    }

    /// [`ShardSet::start`] with test instrumentation (see [`ShardHooks`]).
    pub fn start_with_hooks(cfg: &ServeConfig, hooks: ShardHooks) -> Result<ShardSet> {
        cfg.validate()?;
        let count = cfg.resolve_shards();
        let max_wait = Duration::from_micros(cfg.batch.max_wait_us);
        // Faults/ladder wiring resolved once: every shard shares the plan
        // (each derives its own injector stream from its index) and the
        // canary knobs.  The ladder only arms on the ACAM backend with an
        // analogue MatchingBackend variant — the digital backends have no
        // analogue hardware to age or re-program, and the `digital` variant
        // *is* the canary's reference (it would always agree with itself).
        let plan = cfg.resolve_fault_plan()?;
        let canary_every = cfg.resolve_canary_every();
        let variant = cfg.resolve_backend_variant()?;
        let ladder = (canary_every > 0 && cfg.backend == Backend::AcamSim && variant.analogue())
            .then(|| LadderParams {
            canary_every,
            per_class: cfg.faults.canary_per_class,
            threshold: cfg.faults.canary_threshold,
        });
        let ladder_active = ladder.is_some();
        // One registry for the whole deployment: shards resolve the active
        // store per batch via the epoch counter, so a publish lands on every
        // shard at its next batch boundary (never mid-batch).
        let meta = Meta::load_or_synthetic(&cfg.artifacts_dir)?;
        let registry = StoreRegistry::from_config(cfg, &meta)?;
        let admin = StoreAdmin::new(Arc::clone(&registry), Arc::new(cfg.clone()));
        let mut slots = Vec::with_capacity(count);
        let mut workers = Vec::with_capacity(count);
        let mut caps: Option<Caps> = None;
        for index in 0..count {
            let mut scfg = cfg.clone();
            scfg.acam.seed = cfg.acam.seed.wrapping_add(index as u64);
            let (tx, rx) = sync_channel::<Job>(cfg.batch.queue_depth);
            let metrics = Arc::new(Metrics::default());
            let healthy = Arc::new(AtomicBool::new(true));
            let cells = LadderCells::new();
            let (ready_tx, ready_rx) = oneshot::channel::<Result<Caps>>();
            let m = Arc::clone(&metrics);
            let h = Arc::clone(&healthy);
            let shard_hooks = hooks.clone();
            let max_batch = cfg.batch.max_batch;
            let fctx = ShardFaultCtx {
                plan: plan.clone(),
                ladder: ladder.clone(),
                cells: cells.clone(),
            };
            let reg = Arc::clone(&registry);
            let worker = std::thread::Builder::new()
                .name(format!("hec-shard-{index}"))
                .spawn(move || {
                    shard_worker(
                        index,
                        scfg,
                        rx,
                        m,
                        h,
                        shard_hooks,
                        max_batch,
                        max_wait,
                        fctx,
                        reg,
                        ready_tx,
                    )
                })
                .expect("spawn shard worker");
            let shard_caps = ready_rx.recv().map_err(|_| {
                crate::error::Error::Request(format!("shard {index} died during startup"))
            })??;
            match &caps {
                None => caps = Some(shard_caps),
                Some(c) => {
                    // All shards run the same config (modulo RNG seed), so
                    // their caps must agree; a mismatch means the shards
                    // would serve different deployments behind one surface.
                    if *c != shard_caps {
                        return Err(crate::error::Error::Config(format!(
                            "shard {index} caps diverge from shard 0"
                        )));
                    }
                }
            }
            slots.push(ShardSlot {
                tx,
                metrics,
                healthy,
                ladder: cells,
            });
            workers.push(worker);
        }
        Ok(ShardSet {
            handle: ShardHandle {
                inner: Arc::new(Inner {
                    shards: slots,
                    policy: cfg.shards.policy,
                    spill: cfg.shards.spill,
                    rr: AtomicU64::new(0),
                    rejected: AtomicU64::new(0),
                    caps: caps.expect("count >= 1"),
                    ladder_active,
                    admin,
                    cache_on: cfg.resolve_cache().is_some(),
                }),
            },
            workers,
        })
    }

    /// Stop accepting requests and join the workers.  (Outstanding
    /// [`ShardHandle`] clones keep the channels open; workers exit once the
    /// last clone drops.)
    pub fn shutdown(self) {
        let ShardSet { handle, workers } = self;
        drop(handle);
        for w in workers {
            let _ = w.join();
        }
    }
}

impl ShardHandle {
    /// Number of worker shards.
    pub fn shard_count(&self) -> usize {
        self.inner.shards.len()
    }

    /// One shard's metrics (tests and dashboards).
    pub fn shard_metrics(&self, shard: usize) -> &Arc<Metrics> {
        &self.inner.shards[shard].metrics
    }

    /// Whether one shard is currently serving (not draining/restarting).
    pub fn shard_healthy(&self, shard: usize) -> bool {
        self.inner.shards[shard].healthy.load(Ordering::SeqCst)
    }

    /// Per-shard snapshots paired with health, in shard order.
    pub fn shard_snapshots(&self) -> Vec<(Snapshot, bool)> {
        self.inner
            .shards
            .iter()
            .map(|s| (s.metrics.snapshot(), s.healthy.load(Ordering::SeqCst)))
            .collect()
    }

    /// Aggregate deployment-wide snapshot (see [`Snapshot::merge`]),
    /// including router-level rejections in `requests`/`errors` so the
    /// aggregate keeps the single-pipeline handle's accounting semantics
    /// (a shed submit still counts as a request and an error).
    pub fn snapshot(&self) -> Snapshot {
        let snaps: Vec<Snapshot> = self.shard_snapshots().into_iter().map(|(s, _)| s).collect();
        let mut out = Snapshot::merge(&snaps);
        let rejected = self.inner.rejected.load(Ordering::Relaxed);
        out.requests += rejected;
        out.errors += rejected;
        out
    }

    /// Submits rejected at the router itself (no healthy shard / every
    /// candidate queue full).
    pub fn router_rejections(&self) -> u64 {
        self.inner.rejected.load(Ordering::Relaxed)
    }

    /// Per-shard degradation-ladder observations, in shard order:
    /// `(backend_state, last canary accuracy, completed re-programs)`.
    /// `None` when the ladder is inactive (no canary configured, or a
    /// digital backend) — callers must surface nothing in that case so the
    /// faults-off wire/metrics output stays byte-identical.  Accuracy is
    /// NaN until a shard's first probe.
    pub fn shard_ladder(&self) -> Option<Vec<(BackendState, f64, u64)>> {
        if !self.inner.ladder_active {
            return None;
        }
        Some(
            self.inner
                .shards
                .iter()
                .map(|s| {
                    (
                        s.ladder.state(),
                        f64::from_bits(s.ladder.accuracy.load(Ordering::SeqCst)),
                        s.ladder.reprograms.load(Ordering::Relaxed),
                    )
                })
                .collect(),
        )
    }

    /// Convenience for synchronous callers: top-1 classify on the
    /// deployment backend, blocking (mirrors [`super::Handle`]).
    pub fn classify_blocking(
        &self,
        image: Vec<f32>,
    ) -> std::result::Result<ClassifyResponse, ApiError> {
        ClassifySurface::submit_blocking(self, ClassifyRequest::new(image))
    }
}

impl ClassifySurface for ShardHandle {
    fn caps(&self) -> &Caps {
        &self.inner.caps
    }

    #[allow(clippy::type_complexity)]
    fn submit(
        &self,
        req: ClassifyRequest,
    ) -> std::result::Result<
        oneshot::Receiver<std::result::Result<ClassifyResponse, ApiError>>,
        ApiError,
    > {
        let inner = &self.inner;
        validate_request(&inner.caps, &req)?;
        // Tenant admission before routing: a quota-exceeded submit is
        // rejected here (QUOTA_EXCEEDED) without consuming a round-robin
        // ticket or touching any shard queue.  The ticket rides the job
        // through spills — if every candidate queue is full the job (and
        // its quota slot) is dropped together.
        let (tenant, route) = admit_tenant(inner.admin.registry(), &req)?;
        let depths: Vec<u64> = inner
            .shards
            .iter()
            .map(|s| s.metrics.queue_depth.load(Ordering::SeqCst))
            .collect();
        let healthy: Vec<bool> = inner
            .shards
            .iter()
            .map(|s| s.healthy.load(Ordering::SeqCst))
            .collect();
        // The ticket only advances when the plan consumes it, so sticky and
        // least-depth traffic does not perturb the round-robin rotation.
        let ticket = match (inner.policy, req.request_id.as_deref()) {
            (RoutePolicy::RoundRobin, _) | (RoutePolicy::Hash, None) => {
                inner.rr.fetch_add(1, Ordering::SeqCst)
            }
            _ => 0,
        };
        let plan = plan_route(
            inner.policy,
            ticket,
            req.request_id.as_deref(),
            &depths,
            &healthy,
            inner.spill,
        );
        if plan.is_empty() {
            inner.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ApiError::new(
                ErrorCode::QueueFull,
                "no healthy shard available (all draining/restarting), retry later",
            ));
        }
        let (tx, rx) = oneshot::channel();
        let mut job = Job {
            req,
            enqueued: Instant::now(),
            resp: tx,
            tenant,
            route,
        };
        for &s in &plan {
            let slot = &inner.shards[s];
            // Gauges go up BEFORE the job becomes visible to the worker
            // (same invariant as the single-pipeline handle: a late
            // increment after a successful try_send could race the worker's
            // decrement and drift the gauge upward permanently).
            slot.metrics.queue_depth.fetch_add(1, Ordering::SeqCst);
            slot.metrics.in_flight.fetch_add(1, Ordering::SeqCst);
            match slot.tx.try_send(job) {
                Ok(()) => {
                    slot.metrics.requests.fetch_add(1, Ordering::Relaxed);
                    return Ok(rx);
                }
                Err(e) => {
                    Metrics::gauge_dec(&slot.metrics.queue_depth, 1);
                    Metrics::gauge_dec(&slot.metrics.in_flight, 1);
                    match e {
                        // Spill: try the next-best shard in the plan.
                        TrySendError::Full(j) | TrySendError::Disconnected(j) => job = j,
                    }
                }
            }
        }
        inner.rejected.fetch_add(1, Ordering::Relaxed);
        Err(ApiError::new(
            ErrorCode::QueueFull,
            if inner.spill {
                "queue full on every healthy shard (backpressure)"
            } else {
                "queue full (backpressure)"
            },
        ))
    }

    fn health(&self) -> HealthReport {
        let ladder_active = self.inner.ladder_active;
        let shards: Vec<ShardStatus> = self
            .inner
            .shards
            .iter()
            .enumerate()
            .map(|(index, s)| {
                let snap = s.metrics.snapshot();
                ShardStatus {
                    index,
                    healthy: s.healthy.load(Ordering::SeqCst),
                    restarts: snap.restarts,
                    queue_depth: snap.queue_depth,
                    in_flight: snap.in_flight,
                    backend_state: ladder_active.then(|| s.ladder.state().as_str()),
                    backend_variant: self.inner.caps.backend_variant.name(),
                }
            })
            .collect();
        let ladder_degraded = ladder_active
            && self
                .inner
                .shards
                .iter()
                .any(|s| s.ladder.state() != BackendState::Healthy);
        HealthReport {
            degraded: shards.iter().any(|s| !s.healthy) || ladder_degraded,
            shards,
        }
    }

    fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = self.snapshot().prometheus();
        let name = "hec_router_rejections_total";
        let _ = writeln!(
            out,
            "# HELP {name} Submits rejected at the shard router (no healthy shard / all queues full)"
        );
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {}", self.router_rejections());
        out.push_str(&prometheus_shards(&self.shard_snapshots()));
        let shard_metrics: Vec<Arc<Metrics>> = self
            .inner
            .shards
            .iter()
            .map(|s| Arc::clone(&s.metrics))
            .collect();
        prometheus_histograms(&shard_metrics, true, &mut out);
        if self.inner.cache_on {
            super::metrics::prometheus_cache(&shard_metrics, true, &mut out);
        }
        if let Some(variant) = self.inner.caps.advertised_variant() {
            super::metrics::prometheus_variant(variant, &shard_metrics, true, &mut out);
        }
        if let Some(ladder) = self.shard_ladder() {
            out.push_str(&prometheus_ladder(&ladder));
        }
        // Store/tenant series only once the registry advertises (a publish
        // happened or tenants are configured) — a default deployment's
        // exposition stays byte-identical to a registry-less build.
        let reg = self.inner.admin.registry();
        if reg.advertises() {
            reg.prometheus(&mut out);
        }
        out
    }

    fn store_admin(&self) -> Option<StoreAdmin> {
        Some(self.inner.admin.clone())
    }
}

// ---------------------------------------------------------------------------
// The shard worker
// ---------------------------------------------------------------------------

/// One canary cycle for a shard that just crossed its probe interval:
/// score the canary set, publish accuracy, and — below threshold — walk
/// the ladder: `Reprogramming` (re-fit + re-program the array, charging
/// the RRAM programming energy) then re-probe; a verify pass promotes back
/// to `Healthy`, a verify failure (e.g. sticky stuck-at cells the
/// re-program cannot heal) lands in `DigitalFallback` and routes matching
/// through the digital back-end from then on.
fn ladder_step(
    pipeline: &mut Pipeline,
    canary_bits: &[Vec<u8>],
    params: &LadderParams,
    cells: &LadderCells,
    injector: Option<&mut FaultInjector>,
    m: &Metrics,
    hooks: &ShardHooks,
) {
    let report = match pipeline.canary_probe(canary_bits) {
        Ok(r) => r,
        Err(_) => return, // no array programmed — nothing to score
    };
    m.add_energy_nj(report.energy_nj);
    cells
        .accuracy
        .store(report.accuracy.to_bits(), Ordering::SeqCst);
    if let Some(g) = &hooks.canary_gate {
        g.arrive_only();
    }
    if report.accuracy >= params.threshold {
        return;
    }
    // Demote.  The intermediate state is published (and gate-observable)
    // before the expensive re-fit starts.
    cells
        .state
        .store(BackendState::Reprogramming as u8, Ordering::SeqCst);
    if let Some(g) = &hooks.reprogram_gate {
        g.pass();
    }
    let recovered = match pipeline.reprogram() {
        Ok(energy_nj) => {
            m.add_energy_nj(energy_nj);
            cells.reprograms.fetch_add(1, Ordering::Relaxed);
            // Stuck filaments do not heal: re-apply every sticky fault the
            // injector has materialised, then verify against the canaries.
            if let Some(inj) = injector {
                pipeline.apply_sticky(inj.sticky_sets());
            }
            match pipeline.canary_probe(canary_bits) {
                Ok(verify) => {
                    m.add_energy_nj(verify.energy_nj);
                    cells
                        .accuracy
                        .store(verify.accuracy.to_bits(), Ordering::SeqCst);
                    verify.accuracy >= params.threshold
                }
                Err(_) => false,
            }
        }
        Err(_) => false,
    };
    if recovered {
        cells
            .state
            .store(BackendState::Healthy as u8, Ordering::SeqCst);
    } else {
        // Terminal until restart: correct digital matching, without the
        // analogue back-end's 1.45 nJ budget.
        pipeline.set_digital_fallback(true);
        cells
            .state
            .store(BackendState::DigitalFallback as u8, Ordering::SeqCst);
    }
}

/// One shard's serving loop: the single-pipeline worker body plus the
/// panic boundary.  Compute runs inside `catch_unwind`; the job batch stays
/// outside, so a panic fails every affected request with an explicit
/// `INTERNAL` error (never a hung waiter) and the gauges stay exact.
///
/// With faults armed, the worker additionally keeps a served-request clock:
/// due [`FaultPlan`] events apply to the array *before* the batch that
/// crosses their trigger, and a canary probe (plus ladder step) runs after
/// every `canary_every` served requests.  With no plan and no canary, none
/// of this code touches the pipeline or its RNG streams — the faults-off
/// path is bitwise identical to a build without the subsystem.
#[allow(clippy::too_many_arguments)]
fn shard_worker(
    index: usize,
    cfg: ServeConfig,
    rx: Receiver<Job>,
    m: Arc<Metrics>,
    healthy: Arc<AtomicBool>,
    hooks: ShardHooks,
    max_batch: usize,
    max_wait: Duration,
    fctx: ShardFaultCtx,
    registry: Arc<StoreRegistry>,
    ready_tx: oneshot::Sender<Result<Caps>>,
) {
    use std::sync::atomic::Ordering::Relaxed;
    // Pipeline + canary probe set, together: building the canary bits runs
    // the front-end once over the bootstrap samples (deterministic, no
    // shared RNG), and a panic-restart must rebuild both.  The registry is
    // re-attached on every rebuild so a restarted shard re-adopts the
    // current store versions on its first batch.
    let build = |cfg: &ServeConfig| -> Result<(Pipeline, Vec<Vec<u8>>)> {
        let mut p = Pipeline::new(cfg)?;
        p.attach_registry(Arc::clone(&registry));
        let canary = match &fctx.ladder {
            Some(l) => p.canary_bits(l.per_class)?.0,
            None => Vec::new(),
        };
        Ok((p, canary))
    };
    let (mut pipeline, mut canary_bits) = match build(&cfg) {
        Ok((p, c)) => {
            let caps = Caps {
                image_len: p.image_len(),
                num_classes: p.store.num_classes,
                engine: p.engine_name(),
                backend: p.backend(),
                acam_available: p.backend_available(crate::config::Backend::AcamSim),
                backend_variant: p.backend_variant(),
            };
            let _ = ready_tx.send(Ok(caps));
            (p, c)
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let engine = pipeline.engine_name();
    let image_len = pipeline.image_len();
    let variant = (pipeline.backend_available(Backend::AcamSim)
        && pipeline.backend_variant() != crate::backend::BackendVariant::Acam)
        .then(|| pipeline.backend_variant().name());
    let mut injector = fctx.plan.clone().map(|p| FaultInjector::new(p, index));
    // Served-request clock for the fault schedule and canary cadence.
    let mut served: u64 = 0;
    let mut since_probe: u64 = 0;
    let mut buf: Vec<f32> = Vec::new();
    let mut opts: Vec<crate::api::ClassifyOptions> = Vec::new();
    let mut routes: Vec<Option<Arc<str>>> = Vec::new();
    // Content-hash feature cache (None = off: the loop below is then
    // bitwise identical to a cache-free build).  The cache outlives worker
    // rebuilds so its counters stay monotone across panic-restarts; the
    // restart path flushes the entries (the new engine invalidates them).
    let mut cache = cfg
        .resolve_cache()
        .map(|cap| super::cache::FeatureCache::new(cap, cfg.acam.seed ^ 0xCAC4E));
    while let Some(mut batch) = batcher::assemble(&rx, max_batch, max_wait) {
        let assembled = batch.len();
        Metrics::gauge_dec(&m.queue_depth, assembled as u64);
        drop_expired_jobs(&mut batch, &m);
        if batch.is_empty() {
            continue;
        }
        let n = batch.len();
        m.batches.fetch_add(1, Relaxed);
        m.batched_items.fetch_add(n as u64, Relaxed);

        pack_batch_into(&batch, image_len, &mut buf, &mut opts);
        routes.clear();
        if batch.iter().any(|j| j.route.is_some()) {
            routes.extend(batch.iter().map(|j| j.route.clone()));
        }
        let padded = pipeline.padding_for(n);
        m.padded_slots.fetch_add(padded as u64, Relaxed);

        // Hot-swap barrier: adopt pending store publishes between batches,
        // never within one — every request in this batch serves one
        // consistent (store, version) pair.  This runs *before* the hold
        // hook, so a gate-parked batch is already pinned to its version and
        // a publish while it is parked lands on the next batch.
        // Publish-time validation makes adoption infallible; a failure
        // keeps the previous store.
        let store_version = pipeline.default_store_version();
        if let Ok(nj) = pipeline.sync_stores() {
            if nj > 0.0 {
                m.add_energy_nj(nj);
            }
        }
        if let Some(c) = cache.as_mut() {
            // Cached bits are binarised under the old store's thresholds:
            // a default-store hot-swap invalidates every entry.
            if pipeline.default_store_version() != store_version {
                c.flush();
            }
        }

        if let Some((id, gate)) = &hooks.hold {
            if batch
                .iter()
                .any(|j| j.req.request_id.as_deref() == Some(id.as_str()))
            {
                gate.pass();
            }
        }
        let inject = hooks
            .panic_on
            .as_deref()
            .is_some_and(|p| batch.iter().any(|j| j.req.request_id.as_deref() == Some(p)));

        // Due fault events strike before the batch that crosses their
        // trigger ("fires once the shard has served `at_request` requests").
        if let Some(inj) = injector.as_mut() {
            for kind in inj.due(served) {
                if let FaultKind::Stall { millis } = kind {
                    // A wedged worker, not an array fault: the shard simply
                    // stops draining its queue for a while (deadline and
                    // spill behaviour take it from there).
                    std::thread::sleep(Duration::from_millis(millis));
                } else {
                    pipeline.apply_fault(&kind, inj);
                }
            }
        }
        let ladder_state = fctx.ladder.as_ref().map(|_| {
            let s = fctx.cells.state();
            (s != BackendState::Healthy, s.as_str())
        });

        let dispatched = Instant::now();
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            if inject {
                panic!("injected shard panic (ShardHooks::panic_on)");
            }
            match cache.as_mut() {
                Some(c) => pipeline.classify_batch_cached(&buf, n, &opts, &routes, c),
                None => pipeline.classify_batch_routed(&buf, n, &opts, &routes),
            }
        }));
        let compute_us = dispatched.elapsed().as_micros() as u64;
        m.execute.record_us(compute_us);
        if let Some(c) = cache.as_ref() {
            c.publish_to(&m);
        }

        match result {
            Ok(res) => {
                deliver_batch(
                    batch,
                    res.map_err(ApiError::from),
                    &m,
                    engine,
                    dispatched,
                    compute_us,
                    Some(index),
                    ladder_state,
                    variant,
                );
                served += n as u64;
                since_probe += n as u64;
                if let Some(params) = &fctx.ladder {
                    if since_probe >= params.canary_every
                        && fctx.cells.state() != BackendState::DigitalFallback
                    {
                        since_probe = 0;
                        ladder_step(
                            &mut pipeline,
                            &canary_bits,
                            params,
                            &fctx.cells,
                            injector.as_mut(),
                            &m,
                            &hooks,
                        );
                    }
                }
            }
            Err(_panic) => {
                // Unhealthy BEFORE the failures are answered: a caller that
                // observes INTERNAL is guaranteed to find /healthz already
                // degraded (the oneshot send orders the flag store).
                healthy.store(false, Ordering::SeqCst);
                m.restarts.fetch_add(1, Relaxed);
                let err = ApiError::new(
                    ErrorCode::Internal,
                    format!("shard {index} worker panicked; request failed during restart"),
                );
                for job in batch {
                    fail_job(job, err.clone(), &m);
                }
                // Drain: fail everything already queued (the router stopped
                // routing here the moment `healthy` flipped, but jobs
                // accepted before the flip are still in the channel) so the
                // gauges return to zero instead of leaking.
                while let Ok(job) = rx.try_recv() {
                    Metrics::gauge_dec(&m.queue_depth, 1);
                    fail_job(job, err.clone(), &m);
                }
                if let Some(g) = &hooks.restart_gate {
                    g.pass();
                }
                // Restart: rebuild the pipeline from config.  A rebuild
                // failure (or panic) leaves the shard permanently unhealthy
                // and closes its queue — the other shards keep serving.
                match std::panic::catch_unwind(AssertUnwindSafe(|| build(&cfg))) {
                    Ok(Ok((p, c))) => {
                        pipeline = p;
                        canary_bits = c;
                        // The rebuilt engine invalidates cached bits; flush
                        // and re-publish so the entries gauge drops to zero
                        // while the hit/miss totals stay monotone.
                        if let Some(fc) = cache.as_mut() {
                            fc.flush();
                            fc.publish_to(&m);
                        }
                        // A restart re-programs a clean array, so the ladder
                        // returns to Healthy; the fault schedule keeps its
                        // cursor (already-fired events died with the old
                        // array) and sticky stuck sets re-apply on the next
                        // ladder re-program, not here.
                        fctx.cells
                            .state
                            .store(BackendState::Healthy as u8, Ordering::SeqCst);
                        healthy.store(true, Ordering::SeqCst);
                        if let Some(g) = &hooks.restart_gate {
                            g.arrive_only();
                        }
                    }
                    _ => {
                        // Terminal exit: best-effort final drain so a job
                        // that raced past the first drain (submitted before
                        // the router observed `healthy = false`) fails with
                        // INTERNAL and its gauges are released rather than
                        // leaking on a permanently-dead shard.  Anything
                        // arriving after this sees the dropped receiver at
                        // try_send time, and the submit path rolls its
                        // gauge increments back on Disconnected.
                        while let Ok(job) = rx.try_recv() {
                            Metrics::gauge_dec(&m.queue_depth, 1);
                            fail_job(job, err.clone(), &m);
                        }
                        return;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [bool; 3] = [true, true, true];

    #[test]
    fn fnv1a_is_the_reference_function() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn round_robin_cycles_in_ticket_order() {
        let picks: Vec<usize> = (0..6)
            .map(|t| plan_route(RoutePolicy::RoundRobin, t, None, &[0, 0, 0], &ALL, false)[0])
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_unhealthy_shards() {
        let healthy = [true, false, true];
        let picks: Vec<usize> = (0..4)
            .map(|t| plan_route(RoutePolicy::RoundRobin, t, None, &[0, 0, 0], &healthy, false)[0])
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn least_depth_picks_minimum_and_lowest_index_ties() {
        assert_eq!(
            plan_route(RoutePolicy::LeastQueueDepth, 0, None, &[2, 1, 1], &ALL, false),
            vec![1],
            "lowest index wins the tie"
        );
        assert_eq!(
            plan_route(RoutePolicy::LeastQueueDepth, 0, None, &[3, 2, 1], &ALL, true),
            vec![2, 1, 0],
            "spill order is ascending depth"
        );
        // The ticket never affects least-depth.
        for t in 0..5 {
            assert_eq!(
                plan_route(RoutePolicy::LeastQueueDepth, t, None, &[5, 0, 9], &ALL, false),
                vec![1]
            );
        }
    }

    #[test]
    fn hash_routing_is_sticky_and_depth_blind() {
        let id = Some("tenant-42");
        let first = plan_route(RoutePolicy::Hash, 0, id, &[0, 0, 0], &ALL, false);
        for (ticket, depths) in [(1u64, [9u64, 9, 9]), (7, [0, 5, 0]), (1000, [1, 2, 3])] {
            assert_eq!(
                plan_route(RoutePolicy::Hash, ticket, id, &depths, &ALL, false),
                first,
                "same id must stick to the same shard regardless of ticket/depths"
            );
        }
        // Different ids spread (not all onto one shard).
        let picks: std::collections::BTreeSet<usize> = (0..32)
            .map(|i| {
                plan_route(
                    RoutePolicy::Hash,
                    0,
                    Some(&format!("req-{i}")),
                    &[0, 0, 0],
                    &ALL,
                    false,
                )[0]
            })
            .collect();
        assert!(picks.len() > 1, "32 distinct ids all hashed to one shard");
        // Id-less requests fall back to the round-robin ticket.
        assert_eq!(
            plan_route(RoutePolicy::Hash, 4, None, &[0, 0, 0], &ALL, false),
            vec![1]
        );
    }

    #[test]
    fn spill_order_is_cyclic_from_primary() {
        assert_eq!(
            plan_route(RoutePolicy::RoundRobin, 1, None, &[0, 0, 0], &ALL, true),
            vec![1, 2, 0]
        );
        let plan = plan_route(RoutePolicy::Hash, 0, Some("x"), &[0, 0, 0], &ALL, true);
        assert_eq!(plan.len(), 3);
        let p = plan[0];
        assert_eq!(plan, vec![p, (p + 1) % 3, (p + 2) % 3]);
    }

    #[test]
    fn no_healthy_shard_returns_empty_plan() {
        assert!(plan_route(
            RoutePolicy::RoundRobin,
            0,
            None,
            &[0, 0],
            &[false, false],
            true
        )
        .is_empty());
    }

    #[test]
    fn gate_orders_arrivals_and_releases() {
        let gate = Gate::new();
        let g = Arc::clone(&gate);
        let worker = std::thread::spawn(move || {
            g.pass(); // blocks until released
            g.arrive_only();
            "done"
        });
        gate.await_arrivals(1);
        assert_eq!(gate.arrivals(), 1);
        gate.release();
        gate.await_arrivals(2);
        assert_eq!(worker.join().unwrap(), "done");
    }
}

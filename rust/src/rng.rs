//! Deterministic RNG substrate (the registry has no `rand` crate offline).
//!
//! [`Rng`] is a SplitMix64 generator — statistically solid for simulation
//! workloads, trivially seedable, `Copy`-cheap — with uniform, range,
//! Gaussian (Box-Muller) and shuffle helpers.  Distinct from
//! [`crate::dataset::synthetic::Lcg`], which is the *Python-mirrored*
//! generator whose exact sequence is part of the dataset contract; this one
//! is free to evolve.

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed,
            gauss_spare: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn u01(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / 9007199254740992.0)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.u01()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.u01();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.u01();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/sigma.
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gauss()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Weighted index sample proportional to `weights` (all >= 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut r = self.u01() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn u01_in_range_and_spread() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..10_000).map(|_| r.u01()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20_000).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_bounded() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
        assert_ne!(xs, (0..50).collect::<Vec<u32>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::new(5);
        for _ in 0..200 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }
}

//! Minimal HTTP/1.1 message plumbing for the gateway (no hyper offline) —
//! just enough of RFC 9112 for a JSON API: request line + headers +
//! `Content-Length` bodies, keep-alive by default, bounded reads so a slow
//! or hostile peer cannot balloon memory.
//!
//! Deliberately not supported (requests using them get a clean 4xx/close
//! instead of undefined behaviour): chunked transfer encoding, multi-line
//! header folding, pipelining beyond sequential keep-alive.

use std::io::{BufRead, Write};

/// Largest accepted request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Largest accepted body (a 32x32 image batch of ~1k requests fits well
/// under this; anything bigger should be split).
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path only (the query string, if any, is split off and kept verbatim).
    pub path: String,
    pub query: Option<String>,
    headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the client asked to close the connection after this exchange.
    pub close: bool,
}

impl Request {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum ReadError {
    /// Peer closed (or timed out) before sending a request line — normal
    /// end of a keep-alive connection, not an error to report.
    Eof,
    /// Malformed or over-limit request; respond with this status and close.
    Bad(u16, &'static str),
}

/// Read one request from a buffered stream.  Blocks until a full head is
/// available (the caller sets a socket read timeout to bound this).
pub fn read_request<R: BufRead>(reader: &mut R) -> Result<Request, ReadError> {
    // -- head: read until CRLFCRLF with a hard cap ------------------------
    let mut head = Vec::with_capacity(512);
    loop {
        let buf = match reader.fill_buf() {
            Ok(b) => b,
            Err(_) => return Err(ReadError::Eof),
        };
        if buf.is_empty() {
            return Err(ReadError::Eof); // clean close between requests
        }
        // Consume up to (and including) the terminator if present.
        let start = head.len().saturating_sub(3); // terminator may straddle
        head.extend_from_slice(buf);
        let consumed = buf.len();
        if let Some(pos) = find_crlfcrlf(&head[start..]) {
            let end = start + pos + 4;
            if end > MAX_HEAD_BYTES {
                return Err(ReadError::Bad(431, "request head too large"));
            }
            let overshoot = head.len() - end;
            reader.consume(consumed - overshoot);
            head.truncate(end);
            break;
        }
        reader.consume(consumed);
        if head.len() > MAX_HEAD_BYTES {
            return Err(ReadError::Bad(431, "request head too large"));
        }
    }

    let head_text =
        std::str::from_utf8(&head).map_err(|_| ReadError::Bad(400, "non-UTF8 request head"))?;
    let mut lines = head_text.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or_default().to_string();
    let target = parts.next().unwrap_or_default().to_string();
    let version = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(ReadError::Bad(400, "malformed request line"));
    }

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue; // the blank terminator line
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(ReadError::Bad(400, "malformed header line"));
        };
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }

    // -- body: Content-Length only ----------------------------------------
    // RFC 9112 §6.3: conflicting duplicate Content-Length headers must be
    // rejected, not first-one-wins — behind a proxy that honors the other
    // copy, disagreeing about framing desyncs the keep-alive stream.
    let mut content_length = None;
    for (_, v) in headers
        .iter()
        .filter(|(k, _)| k.eq_ignore_ascii_case("content-length"))
    {
        let n = v
            .parse::<usize>()
            .map_err(|_| ReadError::Bad(400, "bad Content-Length"))?;
        if content_length.is_some_and(|seen| seen != n) {
            return Err(ReadError::Bad(400, "conflicting Content-Length headers"));
        }
        content_length = Some(n);
    }
    let content_length = content_length.unwrap_or(0);
    if headers.iter().any(|(k, v)| {
        k.eq_ignore_ascii_case("transfer-encoding") && !v.eq_ignore_ascii_case("identity")
    }) {
        return Err(ReadError::Bad(501, "chunked bodies not supported"));
    }
    if content_length > MAX_BODY_BYTES {
        return Err(ReadError::Bad(413, "body too large"));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        std::io::Read::read_exact(reader, &mut body)
            .map_err(|_| ReadError::Bad(400, "body shorter than Content-Length"))?;
    }

    let close = version == "HTTP/1.0"
        || headers
            .iter()
            .any(|(k, v)| k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close"));

    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target, None),
    };
    Ok(Request {
        method,
        path,
        query,
        headers,
        body,
        close,
    })
}

fn find_crlfcrlf(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Write one response (always with `Content-Length`; `close` controls the
/// `Connection` header).
pub fn write_response<W: Write>(
    out: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    close: bool,
) -> std::io::Result<()> {
    let reason = reason_phrase(status);
    write!(
        out,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        body.len(),
        if close { "close" } else { "keep-alive" },
    )?;
    out.write_all(body)?;
    out.flush()
}

pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(bytes: &[u8]) -> Result<Request, ReadError> {
        read_request(&mut BufReader::new(bytes))
    }

    #[test]
    fn parses_get_without_body() {
        let r = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert_eq!(r.query, None);
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.body.is_empty());
        assert!(!r.close);
    }

    #[test]
    fn parses_post_with_body_and_close() {
        let r = parse(
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: 4\r\nConnection: close\r\n\r\n{\"a\"",
        )
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"{\"a\"");
        assert!(r.close);
    }

    #[test]
    fn splits_query_string() {
        let r = parse(b"GET /metrics?format=prom HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(r.path, "/metrics");
        assert_eq!(r.query.as_deref(), Some("format=prom"));
    }

    #[test]
    fn http10_implies_close() {
        let r = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(r.close);
    }

    #[test]
    fn keep_alive_reads_two_requests_sequentially() {
        let bytes =
            b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi".to_vec();
        let mut reader = BufReader::new(&bytes[..]);
        let r1 = read_request(&mut reader).unwrap();
        assert_eq!(r1.path, "/a");
        let r2 = read_request(&mut reader).unwrap();
        assert_eq!(r2.path, "/b");
        assert_eq!(r2.body, b"hi");
        assert!(matches!(read_request(&mut reader), Err(ReadError::Eof)));
    }

    #[test]
    fn rejects_malformed_and_oversize() {
        assert!(matches!(parse(b""), Err(ReadError::Eof)));
        assert!(matches!(
            parse(b"NOPE\r\n\r\n"),
            Err(ReadError::Bad(400, _))
        ));
        assert!(matches!(
            parse(b"GET / HTTP/2\r\n\r\n"),
            Err(ReadError::Bad(400, _))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: zap\r\n\r\n"),
            Err(ReadError::Bad(400, _))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n"),
            Err(ReadError::Bad(413, _))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(ReadError::Bad(501, _))
        ));
        // RFC 9112: conflicting duplicates are rejected; agreeing ones pass.
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 30\r\n\r\nhello"),
            Err(ReadError::Bad(400, _))
        ));
        let r = parse(b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi")
            .unwrap();
        assert_eq!(r.body, b"hi");
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort"),
            Err(ReadError::Bad(400, _))
        ));
        let huge = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES));
        assert!(matches!(
            parse(huge.as_bytes()),
            Err(ReadError::Bad(431, _))
        ));
    }

    #[test]
    fn response_writing_shape() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", b"x", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Connection: close\r\n"));
    }
}

//! Equivalence properties for the interpreter fast-path: the blocked
//! im2col/matmul kernels and the `FastBackend` engine must match the
//! scalar oracle (`kernels::conv2d` / `InterpBackend`) within 1e-5
//! relative tolerance across randomized shapes — SAME and VALID padding,
//! even and odd kernels, channel counts that are not multiples of the
//! 8-wide block, batch sizes 1..8, and any thread count.
//!
//! Hand-rolled generator loops from fixed seeds (proptest is unavailable
//! offline), matching the style of `properties.rs`.

use hec::rng::Rng;
use hec::runtime::backend::fast::{self, FastBackend};
use hec::runtime::backend::interp::{Conv, InterpBackend, StudentParams};
use hec::runtime::backend::kernels::{self, Padding};
use hec::runtime::FrontEnd;

const REL_TOL: f32 = 1e-5;

fn assert_close(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert!(
            (g - w).abs() <= REL_TOL + REL_TOL * w.abs(),
            "{ctx}: element {i}: got {g}, want {w}"
        );
    }
}

fn random_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.range(-1.0, 1.0) as f32).collect()
}

/// Property: blocked matmul == scalar matmul for random (m, k, n) around
/// and across the MR/NR/KC block boundaries, at thread counts 1..4.
#[test]
fn prop_matmul_blocked_equals_scalar() {
    let mut rng = Rng::new(0xB10C);
    for case in 0..120 {
        let m = 1 + rng.below(40);
        let k = 1 + rng.below(300);
        let n = 1 + rng.below(40);
        let threads = 1 + rng.below(4);
        let a = random_vec(&mut rng, m * k);
        let b = random_vec(&mut rng, k * n);
        let want = kernels::matmul(&a, m, k, &b, n);
        let mut got = vec![0f32; m * n];
        fast::matmul_blocked(&a, m, k, &b, n, threads, &mut got);
        assert_close(&got, &want, &format!("case {case}: m={m} k={k} n={n} t={threads}"));
    }
}

/// Property: im2col + blocked matmul + bias == scalar conv2d for random
/// shapes, both paddings, even and odd kernels, ragged channel counts.
#[test]
fn prop_fast_conv_equals_scalar_conv() {
    let mut rng = Rng::new(0xC04F);
    for case in 0..80 {
        let kh = 1 + rng.below(5);
        let kw = 1 + rng.below(5);
        let h = kh + rng.below(10);
        let w = kw + rng.below(10);
        let cin = 1 + rng.below(9);
        let cout = 1 + rng.below(19); // deliberately not 8-aligned
        let pad = if rng.u01() < 0.5 { Padding::Same } else { Padding::Valid };
        let x = random_vec(&mut rng, h * w * cin);
        let wt = random_vec(&mut rng, kh * kw * cin * cout);
        let bias = random_vec(&mut rng, cout);
        let (want, ho, wo) = kernels::conv2d(&x, h, w, cin, &wt, kh, kw, cout, &bias, pad);

        let mut patches = Vec::new();
        let (gho, gwo) = fast::im2col(&x, h, w, cin, kh, kw, pad, &mut patches);
        assert_eq!((gho, gwo), (ho, wo), "case {case}: output dims");
        let mut got = vec![0f32; ho * wo * cout];
        let threads = 1 + rng.below(3);
        fast::matmul_blocked(&patches, ho * wo, kh * kw * cin, &wt, cout, threads, &mut got);
        for row in got.chunks_exact_mut(cout) {
            for (o, &bv) in row.iter_mut().zip(bias.iter()) {
                *o += bv;
            }
        }
        let ctx = format!(
            "case {case}: h={h} w={w} cin={cin} k={kh}x{kw} cout={cout} pad={pad:?}"
        );
        assert_close(&got, &want, &ctx);
    }
}

fn random_conv(rng: &mut Rng, kh: usize, kw: usize, cin: usize, cout: usize) -> Conv {
    Conv {
        w: random_vec(rng, kh * kw * cin * cout),
        b: random_vec(rng, cout),
        kh,
        kw,
        cin,
        cout,
    }
}

/// Random full student with ragged channel widths (valid at `image_size`
/// divisible by 4; conv4 is 2x2 VALID like the paper's).
fn random_student(rng: &mut Rng) -> StudentParams {
    let f1 = 1 + rng.below(7);
    let f2 = 1 + rng.below(9);
    let f3 = 1 + rng.below(11);
    let f4 = 1 + rng.below(6);
    let mut sp = StudentParams::synthetic(1); // shapes overwritten below
    sp.conv1 = random_conv(rng, 3, 3, 1, f1);
    sp.conv2 = random_conv(rng, 3, 3, f1, f2);
    sp.conv3 = random_conv(rng, 3, 3, f2, f3);
    sp.conv4 = random_conv(rng, 2, 2, f3, f4);
    sp.bn1 = hec::runtime::backend::interp::BatchNorm {
        gamma: random_vec(rng, f1),
        beta: random_vec(rng, f1),
        mean: random_vec(rng, f1),
        var: (0..f1).map(|_| 0.5 + rng.u01() as f32).collect(),
    };
    sp.bn2 = hec::runtime::backend::interp::BatchNorm {
        gamma: random_vec(rng, f2),
        beta: random_vec(rng, f2),
        mean: random_vec(rng, f2),
        var: (0..f2).map(|_| 0.5 + rng.u01() as f32).collect(),
    };
    sp.head = None;
    sp
}

/// Property: the full FastBackend forward pass (im2col + blocked matmul +
/// scratch arenas + batch sharding) matches the scalar InterpBackend on
/// random students, image sizes, batch sizes 1..8, and thread counts 1..4.
#[test]
fn prop_fast_backend_equals_scalar_backend() {
    let mut rng = Rng::new(0xFA57);
    for case in 0..25 {
        let image = [8, 12, 16][rng.below(3)];
        let sp = random_student(&mut rng);
        let n = 1 + rng.below(8);
        let threads = 1 + rng.below(4);
        let images = random_vec(&mut rng, n * image * image);
        let mut scalar = InterpBackend::from_params(sp.clone(), image);
        let mut fastb = FastBackend::from_params(sp, image, threads);
        let want = scalar.extract_features(&images, n).unwrap();
        let got = fastb.extract_features(&images, n).unwrap();
        assert_close(
            &got,
            &want,
            &format!("case {case}: image={image} n={n} t={threads}"),
        );
    }
}

/// Property: thread count is numerically invisible — the fast backend
/// returns bitwise-identical features for 1 thread and many.
#[test]
fn prop_fast_backend_thread_count_invariant() {
    let mut rng = Rng::new(0x7EAD);
    for case in 0..10 {
        let sp = random_student(&mut rng);
        let n = 1 + rng.below(8);
        let images = random_vec(&mut rng, n * 16 * 16);
        let mut serial = FastBackend::from_params(sp.clone(), 16, 1);
        let mut threaded = FastBackend::from_params(sp, 16, 4);
        let a = serial.extract_features(&images, n).unwrap();
        let b = threaded.extract_features(&images, n).unwrap();
        assert_eq!(a, b, "case {case}: thread count changed the bits");
    }
}

/// Property: fast logits (blocked dense head) match the scalar head.
#[test]
fn prop_fast_logits_equal_scalar_logits() {
    let mut rng = Rng::new(0x10615);
    for case in 0..10 {
        // The synthetic student carries a head sized for image 32.
        let sp = StudentParams::synthetic(1000 + case as u64);
        let n = 1 + rng.below(4);
        let images = random_vec(&mut rng, n * 32 * 32);
        let mut scalar = InterpBackend::from_params(sp.clone(), 32);
        let mut fastb = FastBackend::from_params(sp, 32, 1 + rng.below(3));
        let want = scalar.logits(&images, n, hec::dataset::NUM_CLASSES).unwrap();
        let got = fastb.logits(&images, n, hec::dataset::NUM_CLASSES).unwrap();
        assert_close(&got, &want, &format!("case {case}: n={n}"));
    }
}

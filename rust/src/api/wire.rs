//! JSON wire form of the v1 API types (over [`crate::jsonlite`], no serde).
//!
//! Decode is strict about types but lenient about extras: unknown fields are
//! ignored (additive evolution), wrong-typed or out-of-range fields fail
//! with a structured [`ApiError`] rather than a parse panic.  Encode is
//! total — every in-memory value has a JSON form ([`crate::jsonlite`] writes
//! non-finite numbers as `null`).

use std::collections::BTreeMap;

use crate::config::Backend;
use crate::jsonlite::Value;

use super::{
    ApiError, ClassifyRequest, ClassifyResponse, EnergyBreakdown, ErrorCode, Prediction, Timing,
    API_VERSION,
};

fn bad(msg: impl Into<String>) -> ApiError {
    ApiError::new(ErrorCode::InvalidArgument, msg)
}

impl ClassifyRequest {
    /// Decode from a parsed JSON document.
    pub fn from_value(v: &Value) -> Result<ClassifyRequest, ApiError> {
        let obj = v
            .as_object()
            .ok_or_else(|| bad("request body must be a JSON object"))?;
        let image = match obj.get("image") {
            Some(img) => img
                .as_f32_vec()
                .ok_or_else(|| bad("'image' must be an array of numbers"))?,
            None => return Err(bad("missing required field 'image'")),
        };
        let mut req = ClassifyRequest::new(image);
        if let Some(k) = obj.get("top_k") {
            let k = k
                .as_f64()
                .filter(|f| f.fract() == 0.0 && *f >= 0.0)
                .ok_or_else(|| bad("'top_k' must be a non-negative integer"))?
                as usize;
            if k == 0 {
                return Err(bad("'top_k' must be >= 1"));
            }
            req.top_k = k;
        }
        if let Some(b) = obj.get("backend") {
            let name = b
                .as_str()
                .ok_or_else(|| bad("'backend' must be a string"))?;
            req.backend = Some(
                name.parse::<Backend>()
                    .map_err(|_| bad(format!("unknown backend: {name}")))?,
            );
        }
        if let Some(f) = obj.get("return_features") {
            req.return_features = f
                .as_bool()
                .ok_or_else(|| bad("'return_features' must be a boolean"))?;
        }
        if let Some(id) = obj.get("request_id") {
            req.request_id = Some(
                id.as_str()
                    .ok_or_else(|| bad("'request_id' must be a string"))?
                    .to_string(),
            );
        }
        if let Some(d) = obj.get("deadline_ms") {
            let d = d
                .as_f64()
                .filter(|f| f.fract() == 0.0 && *f >= 0.0)
                .ok_or_else(|| bad("'deadline_ms' must be a non-negative integer"))?
                as u64;
            if d == 0 {
                return Err(bad("'deadline_ms' must be >= 1 (omit it for no deadline)"));
            }
            req.deadline_ms = Some(d);
        }
        Ok(req)
    }

    /// Encode (the CLI demo driver and test clients use this).
    pub fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert(
            "image".to_string(),
            Value::Arr(self.image.iter().map(|&p| Value::Num(p as f64)).collect()),
        );
        m.insert("top_k".to_string(), Value::Num(self.top_k as f64));
        if let Some(b) = self.backend {
            m.insert("backend".to_string(), Value::Str(b.name().to_string()));
        }
        if self.return_features {
            m.insert("return_features".to_string(), Value::Bool(true));
        }
        if let Some(id) = &self.request_id {
            m.insert("request_id".to_string(), Value::Str(id.clone()));
        }
        if let Some(d) = self.deadline_ms {
            m.insert("deadline_ms".to_string(), Value::Num(d as f64));
        }
        Value::Obj(m)
    }
}

impl ClassifyResponse {
    pub fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("api".to_string(), Value::Str(API_VERSION.to_string()));
        if let Some(id) = &self.request_id {
            m.insert("request_id".to_string(), Value::Str(id.clone()));
        }
        m.insert(
            "predictions".to_string(),
            Value::Arr(
                self.predictions
                    .iter()
                    .map(|p| {
                        Value::Obj(BTreeMap::from([
                            ("class".to_string(), Value::Num(p.class as f64)),
                            ("score".to_string(), Value::Num(p.score)),
                        ]))
                    })
                    .collect(),
            ),
        );
        m.insert(
            "energy".to_string(),
            Value::Obj(BTreeMap::from([
                (
                    "front_end_nj".to_string(),
                    Value::Num(self.energy.front_end_nj),
                ),
                (
                    "back_end_nj".to_string(),
                    Value::Num(self.energy.back_end_nj),
                ),
                ("total_nj".to_string(), Value::Num(self.energy.total_nj())),
            ])),
        );
        m.insert(
            "timing".to_string(),
            Value::Obj(BTreeMap::from([
                (
                    "queue_us".to_string(),
                    Value::Num(self.timing.queue_us as f64),
                ),
                (
                    "compute_us".to_string(),
                    Value::Num(self.timing.compute_us as f64),
                ),
            ])),
        );
        m.insert("engine".to_string(), Value::Str(self.engine.to_string()));
        m.insert(
            "backend".to_string(),
            Value::Str(self.backend.name().to_string()),
        );
        if let Some(v) = self.backend_variant {
            m.insert("backend_variant".to_string(), Value::Str(v.to_string()));
        }
        if let Some(feats) = &self.features {
            m.insert(
                "features".to_string(),
                Value::Arr(feats.iter().map(|&f| Value::Num(f as f64)).collect()),
            );
        }
        if let Some(shard) = self.shard {
            m.insert("shard".to_string(), Value::Num(shard as f64));
        }
        if let Some(d) = self.degraded {
            m.insert("degraded".to_string(), Value::Bool(d));
        }
        if let Some(s) = &self.backend_state {
            m.insert("backend_state".to_string(), Value::Str(s.clone()));
        }
        if let Some(s) = &self.store {
            m.insert("store".to_string(), Value::Str(s.clone()));
        }
        if let Some(v) = self.store_version {
            m.insert("store_version".to_string(), Value::Num(v as f64));
        }
        if let Some(c) = self.cache {
            m.insert("cache".to_string(), Value::Bool(c));
        }
        Value::Obj(m)
    }

    /// Decode (test clients / downstream consumers).  The `engine` string is
    /// matched back to a static name; unknown engines decode as `"unknown"`.
    pub fn from_value(v: &Value) -> Result<ClassifyResponse, ApiError> {
        let obj = v
            .as_object()
            .ok_or_else(|| bad("response must be a JSON object"))?;
        let predictions = obj
            .get("predictions")
            .and_then(Value::as_array)
            .ok_or_else(|| bad("missing 'predictions' array"))?
            .iter()
            .map(|p| {
                Some(Prediction {
                    class: p.get("class")?.as_usize()?,
                    score: p.get("score")?.as_f64()?,
                })
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| bad("malformed prediction entry"))?;
        if predictions.is_empty() {
            return Err(bad("'predictions' must be non-empty"));
        }
        let energy = obj.get("energy").ok_or_else(|| bad("missing 'energy'"))?;
        let energy = EnergyBreakdown {
            front_end_nj: energy
                .get("front_end_nj")
                .and_then(Value::as_f64)
                .ok_or_else(|| bad("missing 'energy.front_end_nj'"))?,
            back_end_nj: energy
                .get("back_end_nj")
                .and_then(Value::as_f64)
                .ok_or_else(|| bad("missing 'energy.back_end_nj'"))?,
        };
        let timing = match obj.get("timing") {
            Some(t) => Timing {
                queue_us: t.get("queue_us").and_then(Value::as_u64).unwrap_or(0),
                compute_us: t.get("compute_us").and_then(Value::as_u64).unwrap_or(0),
            },
            None => Timing::default(),
        };
        let engine = match obj.get("engine").and_then(Value::as_str) {
            Some("interp") => "interp",
            Some("interp-fast") => "interp-fast",
            Some("pjrt") => "pjrt",
            _ => "unknown",
        };
        let backend = obj
            .get("backend")
            .and_then(Value::as_str)
            .and_then(|s| s.parse::<Backend>().ok())
            .ok_or_else(|| bad("missing or unknown 'backend'"))?;
        Ok(ClassifyResponse {
            request_id: obj
                .get("request_id")
                .and_then(Value::as_str)
                .map(str::to_string),
            predictions,
            energy,
            timing,
            engine,
            backend,
            backend_variant: obj
                .get("backend_variant")
                .and_then(Value::as_str)
                .and_then(|s| s.parse::<crate::backend::BackendVariant>().ok())
                .map(|v| v.name()),
            features: obj.get("features").and_then(Value::as_f32_vec),
            shard: obj.get("shard").and_then(Value::as_usize),
            degraded: obj.get("degraded").and_then(Value::as_bool),
            backend_state: obj
                .get("backend_state")
                .and_then(Value::as_str)
                .map(str::to_string),
            store: obj.get("store").and_then(Value::as_str).map(str::to_string),
            store_version: obj.get("store_version").and_then(Value::as_u64),
            cache: obj.get("cache").and_then(Value::as_bool),
        })
    }
}

impl ApiError {
    /// The error envelope every non-2xx gateway response carries.
    pub fn to_value(&self) -> Value {
        Value::Obj(BTreeMap::from([(
            "error".to_string(),
            Value::Obj(BTreeMap::from([
                (
                    "code".to_string(),
                    Value::Str(self.code.as_str().to_string()),
                ),
                ("message".to_string(), Value::Str(self.message.clone())),
            ])),
        )]))
    }

    /// Decode an error envelope (test clients).
    pub fn from_value(v: &Value) -> Option<ApiError> {
        let e = v.get("error")?;
        Some(ApiError {
            code: ErrorCode::parse(e.get("code")?.as_str()?)?,
            message: e.get("message")?.as_str()?.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonlite;

    #[test]
    fn request_roundtrip() {
        let mut req = ClassifyRequest::new(vec![0.5, -1.25, 3.0]);
        req.top_k = 3;
        req.backend = Some(Backend::Similarity);
        req.return_features = true;
        req.request_id = Some("req-7".into());
        req.deadline_ms = Some(250);
        let back =
            ClassifyRequest::from_value(&jsonlite::parse(&req.to_value().to_json()).unwrap())
                .unwrap();
        assert_eq!(back.image, req.image);
        assert_eq!(back.top_k, 3);
        assert_eq!(back.backend, Some(Backend::Similarity));
        assert!(back.return_features);
        assert_eq!(back.request_id.as_deref(), Some("req-7"));
        assert_eq!(back.deadline_ms, Some(250));
    }

    #[test]
    fn request_defaults_and_unknown_fields_ignored() {
        let v = jsonlite::parse(r#"{"image": [1, 2], "future_field": {"x": 1}}"#).unwrap();
        let req = ClassifyRequest::from_value(&v).unwrap();
        assert_eq!(req.image, vec![1.0, 2.0]);
        assert_eq!(req.top_k, 1);
        assert!(req.backend.is_none());
    }

    #[test]
    fn request_decode_rejections() {
        for (body, needle) in [
            (r#"{}"#, "image"),
            (r#"{"image": "nope"}"#, "image"),
            (r#"{"image": [1], "top_k": 0}"#, "top_k"),
            (r#"{"image": [1], "top_k": 1.5}"#, "top_k"),
            (r#"{"image": [1], "backend": "cuda"}"#, "backend"),
            (r#"{"image": [1], "request_id": 7}"#, "request_id"),
            (r#"{"image": [1], "deadline_ms": -5}"#, "deadline_ms"),
            (r#"{"image": [1], "deadline_ms": 1.5}"#, "deadline_ms"),
            (r#"{"image": [1], "deadline_ms": 0}"#, "deadline_ms"),
            (r#"[1, 2]"#, "object"),
        ] {
            let err = ClassifyRequest::from_value(&jsonlite::parse(body).unwrap())
                .expect_err(body);
            assert_eq!(err.code, ErrorCode::InvalidArgument, "{body}");
            assert!(err.message.contains(needle), "{body} -> {err}");
        }
    }

    #[test]
    fn response_roundtrip_and_energy_total() {
        let resp = ClassifyResponse {
            request_id: Some("abc".into()),
            predictions: vec![
                Prediction {
                    class: 3,
                    score: 712.0,
                },
                Prediction {
                    class: 1,
                    score: 700.0,
                },
            ],
            energy: EnergyBreakdown {
                front_end_nj: 1.25,
                back_end_nj: 1.45,
            },
            timing: Timing {
                queue_us: 120,
                compute_us: 800,
            },
            engine: "interp",
            backend: Backend::FeatureCount,
            backend_variant: Some("rbf"),
            features: Some(vec![0.5, 1.5]),
            shard: Some(2),
            degraded: Some(true),
            backend_state: Some("digital_fallback".into()),
            store: Some("default".into()),
            store_version: Some(3),
            cache: Some(true),
        };
        let text = resp.to_value().to_json();
        let v = jsonlite::parse(&text).unwrap();
        assert_eq!(v.get("api").unwrap().as_str(), Some("v1"));
        assert!(
            (v.at(&["energy", "total_nj"]).unwrap().as_f64().unwrap() - 2.7).abs() < 1e-12
        );
        let back = ClassifyResponse::from_value(&v).unwrap();
        assert_eq!(back.predictions, resp.predictions);
        assert_eq!(back.backend, Backend::FeatureCount);
        assert_eq!(back.engine, "interp");
        assert_eq!(back.timing, resp.timing);
        assert_eq!(back.features, resp.features);
        assert_eq!(back.shard, Some(2));
        assert_eq!(back.backend_variant, Some("rbf"));
        assert_eq!(back.degraded, Some(true));
        assert_eq!(back.backend_state.as_deref(), Some("digital_fallback"));
        assert_eq!(back.store.as_deref(), Some("default"));
        assert_eq!(back.store_version, Some(3));
        assert_eq!(back.cache, Some(true));
        // Un-sharded / ladder-off / single-default-store responses omit the
        // optional fields and decode back to None (v1 wire compatibility is
        // additive).
        let mut unsharded = resp;
        unsharded.shard = None;
        unsharded.backend_variant = None;
        unsharded.degraded = None;
        unsharded.backend_state = None;
        unsharded.store = None;
        unsharded.store_version = None;
        unsharded.cache = None;
        let v = jsonlite::parse(&unsharded.to_value().to_json()).unwrap();
        assert!(v.get("shard").is_none());
        assert!(v.get("backend_variant").is_none());
        assert!(v.get("degraded").is_none());
        assert!(v.get("backend_state").is_none());
        assert!(v.get("store").is_none());
        assert!(v.get("store_version").is_none());
        assert!(v.get("cache").is_none());
        let back = ClassifyResponse::from_value(&v).unwrap();
        assert_eq!(back.shard, None);
        assert_eq!(back.backend_variant, None);
        assert_eq!(back.degraded, None);
        assert_eq!(back.backend_state, None);
        assert_eq!(back.store, None);
        assert_eq!(back.store_version, None);
        assert_eq!(back.cache, None);
    }

    #[test]
    fn error_envelope_roundtrip() {
        let e = ApiError::new(ErrorCode::QueueFull, "queue full (backpressure)");
        let v = jsonlite::parse(&e.to_value().to_json()).unwrap();
        assert_eq!(v.at(&["error", "code"]).unwrap().as_str(), Some("QUEUE_FULL"));
        assert_eq!(ApiError::from_value(&v), Some(e));
    }
}

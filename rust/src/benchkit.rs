//! Timing harness substrate (criterion is unavailable offline).
//!
//! [`bench`] runs a closure through warmup + timed iterations, reports
//! mean / p50 / p99 / min wall time per iteration, and returns the
//! [`BenchResult`] so bench binaries can print paper-style comparison rows
//! and assert shape properties (who wins, by what factor).

use std::time::{Duration, Instant};

/// One benchmark's timing summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// Iterations per second at the mean.
    pub fn throughput(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            f64::INFINITY
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<38} mean {:>10.2?}  p50 {:>10.2?}  p99 {:>10.2?}  min {:>10.2?}  ({:.0}/s)",
            self.name,
            self.mean,
            self.p50,
            self.p99,
            self.min,
            self.throughput()
        )
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(name, &mut samples)
}

/// Adaptive variant: keeps iterating until `budget` wall time is spent
/// (at least `min_iters`), so slow PJRT paths don't stall the suite.
pub fn bench_for<F: FnMut()>(
    name: &str,
    warmup: usize,
    min_iters: usize,
    budget: Duration,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let t_start = Instant::now();
    while samples.len() < min_iters || t_start.elapsed() < budget {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    summarize(name, &mut samples)
}

fn summarize(name: &str, samples: &mut [Duration]) -> BenchResult {
    samples.sort_unstable();
    let iters = samples.len();
    let total: Duration = samples.iter().sum();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p99: samples[(iters * 99) / 100],
        min: samples[0],
    };
    println!("{r}");
    r
}

/// Print a section header in the bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one paper-vs-measured comparison row.
pub fn paper_row(label: &str, paper: f64, measured: f64, unit: &str) {
    let ratio = if paper != 0.0 { measured / paper } else { f64::NAN };
    println!("{label:<34} paper {paper:>12.4} {unit:<4} measured {measured:>12.4} {unit:<4} (x{ratio:.3})");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + measured
        assert!(r.min <= r.p50 && r.p50 <= r.p99);
    }

    #[test]
    fn bench_for_respects_min_iters() {
        let r = bench_for("noop", 0, 5, Duration::from_millis(0), || {});
        assert!(r.iters >= 5);
    }

    #[test]
    fn throughput_is_inverse_mean() {
        let r = bench("sleepless", 0, 3, || std::thread::sleep(Duration::from_micros(200)));
        let tp = r.throughput();
        assert!(tp > 1000.0 && tp < 6000.0, "{tp}");
    }
}

//! Serving metrics: counters, log-bucketed latency histogram, energy ledger.
//!
//! Lock-free on the hot path (atomics only); `snapshot()` gives a consistent
//! read for the CLI / benches.

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram with power-of-two microsecond buckets:
/// bucket i covers [2^i, 2^(i+1)) µs; bucket 0 covers [0, 2) µs.
const BUCKETS: usize = 24; // up to ~8.4 s

#[derive(Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize).min(BUCKETS) - 1;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate percentile from the bucket histogram (upper bucket edge).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }
}

/// All serving counters.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Gauge: requests accepted into the bounded queue but not yet pulled
    /// into a batch by the worker.
    pub queue_depth: AtomicU64,
    /// Gauge: requests accepted but not yet answered (queued + computing).
    pub in_flight: AtomicU64,
    /// End-to-end request latency.
    pub latency: Histogram,
    /// PJRT execute() time per batch.
    pub execute: Histogram,
    /// Back-end (ACAM / matcher) time per batch.
    pub backend: Histogram,
    /// Modelled energy, micro-nJ integer (nJ * 1e3) to stay in atomics.
    energy_mnj: AtomicU64,
}

impl Metrics {
    pub fn add_energy_nj(&self, nj: f64) {
        self.energy_mnj
            .fetch_add((nj * 1e3).round() as u64, Ordering::Relaxed);
    }

    pub fn energy_nj(&self) -> f64 {
        self.energy_mnj.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Saturating gauge decrement (gauges never wrap below zero even if a
    /// racing snapshot observes an intermediate state).
    pub fn gauge_dec(gauge: &AtomicU64, by: u64) {
        let mut cur = gauge.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(by);
            match gauge.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batched_items.load(Ordering::Relaxed);
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches > 0 {
                items as f64 / batches as f64
            } else {
                0.0
            },
            pad_fraction: if items > 0 {
                self.padded_slots.load(Ordering::Relaxed) as f64
                    / (items + self.padded_slots.load(Ordering::Relaxed)) as f64
            } else {
                0.0
            },
            latency_mean_us: self.latency.mean_us(),
            latency_p50_us: self.latency.percentile_us(0.50),
            latency_p99_us: self.latency.percentile_us(0.99),
            execute_mean_us: self.execute.mean_us(),
            backend_mean_us: self.backend.mean_us(),
            energy_nj: self.energy_nj(),
        }
    }
}

/// A consistent point-in-time read of the metrics.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub queue_depth: u64,
    pub in_flight: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub pad_fraction: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    pub execute_mean_us: f64,
    pub backend_mean_us: f64,
    pub energy_nj: f64,
}

impl Snapshot {
    /// Render as Prometheus text exposition format (version 0.0.4) — the
    /// payload of the gateway's `GET /metrics`.
    pub fn prometheus(&self) -> String {
        fn push(out: &mut String, kind: &str, name: &str, help: &str, v: f64) {
            use std::fmt::Write as _;
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
            let _ = writeln!(out, "{name} {v}");
        }
        let mut out = String::new();
        let counters: [(&str, &str, f64); 5] = [
            (
                "hec_requests_total",
                "Requests accepted by the handle",
                self.requests as f64,
            ),
            (
                "hec_responses_total",
                "Successful classifications",
                self.responses as f64,
            ),
            (
                "hec_errors_total",
                "Failed or rejected requests",
                self.errors as f64,
            ),
            (
                "hec_batches_total",
                "Batches dispatched to the engine",
                self.batches as f64,
            ),
            (
                "hec_energy_nanojoules_total",
                "Modelled inference energy (nJ)",
                self.energy_nj,
            ),
        ];
        for (name, help, v) in counters {
            push(&mut out, "counter", name, help, v);
        }
        let gauges: [(&str, &str, f64); 6] = [
            (
                "hec_queue_depth",
                "Requests queued but not yet batched",
                self.queue_depth as f64,
            ),
            (
                "hec_in_flight",
                "Requests accepted but not yet answered",
                self.in_flight as f64,
            ),
            (
                "hec_batch_size_mean",
                "Mean dispatched batch size",
                self.mean_batch,
            ),
            (
                "hec_latency_mean_microseconds",
                "Mean end-to-end request latency (us)",
                self.latency_mean_us,
            ),
            (
                "hec_latency_p50_microseconds",
                "p50 end-to-end latency upper bound (us)",
                self.latency_p50_us as f64,
            ),
            (
                "hec_latency_p99_microseconds",
                "p99 end-to-end latency upper bound (us)",
                self.latency_p99_us as f64,
            ),
        ];
        for (name, help, v) in gauges {
            push(&mut out, "gauge", name, help, v);
        }
        out
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests={} responses={} errors={} queued={} in_flight={} batches={} \
             mean_batch={:.2} pad={:.1}%",
            self.requests,
            self.responses,
            self.errors,
            self.queue_depth,
            self.in_flight,
            self.batches,
            self.mean_batch,
            self.pad_fraction * 100.0
        )?;
        writeln!(
            f,
            "latency mean={:.0}us p50<{}us p99<{}us  (execute {:.0}us, backend {:.0}us per batch)",
            self.latency_mean_us,
            self.latency_p50_us,
            self.latency_p99_us,
            self.execute_mean_us,
            self.backend_mean_us
        )?;
        write!(f, "modelled energy total={:.2} nJ", self.energy_nj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_percentiles() {
        let h = Histogram::default();
        for us in [1u64, 2, 4, 8, 1000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 203.0).abs() < 1.0);
        assert!(h.percentile_us(0.5) <= 8);
        assert!(h.percentile_us(0.99) >= 1000);
    }

    #[test]
    fn histogram_zero_is_safe() {
        let h = Histogram::default();
        h.record_us(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile_us(1.0), 2);
    }

    #[test]
    fn energy_accumulates_in_millinj() {
        let m = Metrics::default();
        m.add_energy_nj(1.45);
        m.add_energy_nj(1.45);
        assert!((m.energy_nj() - 2.9).abs() < 1e-9);
    }

    #[test]
    fn gauges_track_and_saturate() {
        let m = Metrics::default();
        m.queue_depth.fetch_add(3, Ordering::Relaxed);
        m.in_flight.fetch_add(5, Ordering::Relaxed);
        Metrics::gauge_dec(&m.queue_depth, 2);
        let s = m.snapshot();
        assert_eq!(s.queue_depth, 1);
        assert_eq!(s.in_flight, 5);
        // Saturating: decrementing past zero pins at zero, never wraps.
        Metrics::gauge_dec(&m.queue_depth, 100);
        assert_eq!(m.snapshot().queue_depth, 0);
    }

    #[test]
    fn prometheus_rendering_exposes_counters_and_gauges() {
        let m = Metrics::default();
        m.requests.fetch_add(7, Ordering::Relaxed);
        m.responses.fetch_add(6, Ordering::Relaxed);
        m.errors.fetch_add(1, Ordering::Relaxed);
        m.queue_depth.fetch_add(2, Ordering::Relaxed);
        m.in_flight.fetch_add(4, Ordering::Relaxed);
        m.add_energy_nj(1.5);
        let text = m.snapshot().prometheus();
        for line in [
            "hec_requests_total 7",
            "hec_responses_total 6",
            "hec_errors_total 1",
            "hec_queue_depth 2",
            "hec_in_flight 4",
            "hec_energy_nanojoules_total 1.5",
            "# TYPE hec_queue_depth gauge",
            "# TYPE hec_requests_total counter",
        ] {
            assert!(text.contains(line), "missing {line:?} in:\n{text}");
        }
        // Every sample line is "name value" with a parseable float.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.split_whitespace();
            let name = parts.next().unwrap();
            let value = parts.next().unwrap();
            assert!(name.starts_with("hec_"), "bad metric name in {line:?}");
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            assert!(parts.next().is_none(), "trailing tokens in {line:?}");
        }
    }

    #[test]
    fn snapshot_batch_stats() {
        let m = Metrics::default();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batched_items.fetch_add(10, Ordering::Relaxed);
        m.padded_slots.fetch_add(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.mean_batch - 5.0).abs() < 1e-9);
        assert!((s.pad_fraction - 6.0 / 16.0).abs() < 1e-9);
    }
}

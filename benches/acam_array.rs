//! ACAM simulator bench + ablations: search latency vs array geometry, the
//! two cell designs, variability-vs-accuracy, and the analogue energy
//! accounting (Eq. 14) — the design-choice ablations DESIGN.md calls out.

use hec::acam::cell::CellKind;
use hec::acam::program::{binary_query_voltages, program_array, WindowMode};
use hec::acam::{ArrayConfig, Variability};
use hec::benchkit::{bench, section};
use hec::rng::Rng;
use hec::templates::{pack_bits, TemplateSet};

fn toy_set(m: usize, n: usize, rng: &mut Rng) -> TemplateSet {
    let templates: Vec<Vec<u8>> = (0..m)
        .map(|_| (0..n).map(|_| u8::from(rng.u01() < 0.5)).collect())
        .collect();
    let w = n.div_ceil(64);
    TemplateSet {
        packed: templates.iter().flat_map(|t| pack_bits(t, w)).collect(),
        words_per_row: w,
        lo: vec![vec![0.0; n]; m],
        hi: vec![vec![1.0; n]; m],
        bin_lo: templates
            .iter()
            .map(|t| t.iter().map(|&b| b as f32 - 0.5).collect())
            .collect(),
        bin_hi: templates
            .iter()
            .map(|t| t.iter().map(|&b| b as f32 + 0.5).collect())
            .collect(),
        class_of: (0..m).collect(),
        silhouette: vec![],
        templates,
    }
}

fn main() {
    let mut rng = Rng::new(17);

    section("search latency vs geometry (6T4R, ideal devices)");
    for (m, n) in [(10usize, 784usize), (30, 784), (10, 1568), (100, 784)] {
        let set = toy_set(m, n, &mut rng);
        let mut arr = program_array(
            &set,
            WindowMode::Binary,
            ArrayConfig::default(),
            Variability::ideal(),
            1,
        );
        let q: Vec<u8> = (0..n).map(|_| u8::from(rng.u01() < 0.5)).collect();
        let qv = binary_query_voltages(&q);
        bench(&format!("search {m}x{n}"), 3, 30, || {
            std::hint::black_box(arr.search(std::hint::black_box(&qv)));
        });
    }

    section("cell design comparison (10x784, ideal)");
    let set = toy_set(10, 784, &mut rng);
    let q: Vec<u8> = (0..784).map(|_| u8::from(rng.u01() < 0.5)).collect();
    let qv = binary_query_voltages(&q);
    for kind in [CellKind::Charging6T4R, CellKind::Precharging3T1R] {
        let mut arr = program_array(
            &set,
            WindowMode::Binary,
            ArrayConfig { kind, ..Default::default() },
            Variability::ideal(),
            1,
        );
        let out = arr.search(&qv);
        bench(&format!("search {kind:?}"), 3, 30, || {
            std::hint::black_box(arr.search(std::hint::black_box(&qv)));
        });
        println!(
            "    energy {:.3} nJ  (Eq. 14: 10 x 784 x 185 fJ = 1.4504 nJ)",
            out.energy_nj
        );
        assert!((out.energy_nj - 1.4504).abs() < 0.01);
    }

    section("variability ablation: decision stability vs ideal (10x784)");
    let mut ideal_arr = program_array(
        &set,
        WindowMode::Binary,
        ArrayConfig::default(),
        Variability::ideal(),
        7,
    );
    println!("{:>8} {:>12} {:>12}", "level", "6T4R", "3T1R");
    for level in [0.0, 0.5, 1.0, 2.0, 4.0] {
        let mut stab = Vec::new();
        for kind in [CellKind::Charging6T4R, CellKind::Precharging3T1R] {
            let mut arr = program_array(
                &set,
                WindowMode::Binary,
                ArrayConfig { kind, ..Default::default() },
                Variability::at_level(level),
                7,
            );
            let mut agree = 0usize;
            let trials = 100;
            let mut qrng = Rng::new(31);
            for _ in 0..trials {
                let q: Vec<u8> = (0..784).map(|_| u8::from(qrng.u01() < 0.5)).collect();
                let qv = binary_query_voltages(&q);
                let ideal_out = ideal_arr.search(&qv);
                let out = arr.search(&qv);
                let am = |sims: &[f64]| {
                    sims.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0
                };
                agree += usize::from(am(&out.similarity) == am(&ideal_out.similarity));
            }
            stab.push(agree as f64 / trials as f64);
        }
        println!("{level:>8.1} {:>12.2} {:>12.2}", stab[0], stab[1]);
        if level == 0.0 {
            assert!(stab[0] > 0.99, "ideal 6T4R must match the ideal argmax");
        }
    }
    println!("\nacam_array: PASS");
}

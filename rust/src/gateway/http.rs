//! Minimal HTTP/1.1 message plumbing for the gateway (no hyper offline) —
//! just enough of RFC 9112 for a JSON API: request line + headers +
//! `Content-Length` or `chunked` bodies, keep-alive by default, bounded
//! reads so a slow or hostile peer cannot balloon memory.
//!
//! Chunked transfer encoding is consumed incrementally: every chunk-size
//! line is capped ([`MAX_CHUNK_LINE`]), the declared size is checked against
//! the running body total *before* its data is read (an over-cap upload is
//! rejected at the chunk header, not after buffering 16 MiB), trailers are
//! consumed-but-ignored under the head budget, and a connection that dies
//! mid-body is a clean 400, never a hang.  Strictness notes: chunk sizes
//! must be bare hex (no sign, no surrounding whitespace; extensions after
//! `;` are ignored), and every line must terminate with CRLF.
//!
//! Deliberately not supported (requests using them get a clean 4xx/5xx +
//! close instead of undefined behaviour): transfer codings other than
//! `chunked`/`identity`, multi-line header folding, pipelining beyond
//! sequential keep-alive.

use std::io::{BufRead, Write};
use std::time::{Duration, Instant};

/// Largest accepted request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Largest accepted body (a 32x32 image batch of ~1k requests fits well
/// under this; anything bigger should be split).
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;
/// Largest accepted chunk-size line (hex size + extensions).  Generous —
/// real clients emit well under 20 bytes.
pub const MAX_CHUNK_LINE: usize = 256;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path only (the query string, if any, is split off and kept verbatim).
    pub path: String,
    pub query: Option<String>,
    headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the client asked to close the connection after this exchange.
    pub close: bool,
}

impl Request {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum ReadError {
    /// Peer closed (or timed out) before sending a request line — normal
    /// end of a keep-alive connection, not an error to report.
    Eof,
    /// Malformed or over-limit request; respond with this status and close.
    Bad(u16, &'static str),
}

/// Read one request with no body-read deadline (tests and non-network
/// callers).  The gateway itself uses [`read_request_with_deadline`].
pub fn read_request<R: BufRead>(reader: &mut R) -> Result<Request, ReadError> {
    read_request_with_deadline(reader, None)
}

/// The wall-clock budget for a body read, measured from the end of the
/// head.  `None` = unbounded.  The per-`read` socket timeout alone cannot
/// bound a slow-drip upload (a byte every 29 s keeps resetting it); this
/// deadline caps the *total* body transfer so a wedged or hostile client
/// cannot pin a connection thread.  Tripping it fails with 408 (mapped to
/// the stable `DEADLINE_EXCEEDED` code by the gateway) and closes the
/// connection.
pub fn read_request_with_deadline<R: BufRead>(
    reader: &mut R,
    body_budget: Option<Duration>,
) -> Result<Request, ReadError> {
    // -- head: read until CRLFCRLF with a hard cap ------------------------
    let mut head = Vec::with_capacity(512);
    loop {
        let buf = match reader.fill_buf() {
            Ok(b) => b,
            Err(_) => return Err(ReadError::Eof),
        };
        if buf.is_empty() {
            return Err(ReadError::Eof); // clean close between requests
        }
        // Consume up to (and including) the terminator if present.
        let start = head.len().saturating_sub(3); // terminator may straddle
        head.extend_from_slice(buf);
        let consumed = buf.len();
        if let Some(pos) = find_crlfcrlf(&head[start..]) {
            let end = start + pos + 4;
            if end > MAX_HEAD_BYTES {
                return Err(ReadError::Bad(431, "request head too large"));
            }
            let overshoot = head.len() - end;
            reader.consume(consumed - overshoot);
            head.truncate(end);
            break;
        }
        reader.consume(consumed);
        if head.len() > MAX_HEAD_BYTES {
            return Err(ReadError::Bad(431, "request head too large"));
        }
    }

    let head_text =
        std::str::from_utf8(&head).map_err(|_| ReadError::Bad(400, "non-UTF8 request head"))?;
    let mut lines = head_text.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or_default().to_string();
    let target = parts.next().unwrap_or_default().to_string();
    let version = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(ReadError::Bad(400, "malformed request line"));
    }

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue; // the blank terminator line
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(ReadError::Bad(400, "malformed header line"));
        };
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }

    // -- body: Content-Length or chunked -----------------------------------
    // RFC 9112 §6.3: conflicting duplicate Content-Length headers must be
    // rejected, not first-one-wins — behind a proxy that honors the other
    // copy, disagreeing about framing desyncs the keep-alive stream.
    let mut content_length = None;
    for (_, v) in headers
        .iter()
        .filter(|(k, _)| k.eq_ignore_ascii_case("content-length"))
    {
        let n = v
            .parse::<usize>()
            .map_err(|_| ReadError::Bad(400, "bad Content-Length"))?;
        if content_length.is_some_and(|seen| seen != n) {
            return Err(ReadError::Bad(400, "conflicting Content-Length headers"));
        }
        content_length = Some(n);
    }
    let mut chunked = false;
    for (_, v) in headers
        .iter()
        .filter(|(k, _)| k.eq_ignore_ascii_case("transfer-encoding"))
    {
        let v = v.trim();
        if v.eq_ignore_ascii_case("chunked") {
            chunked = true;
        } else if !v.eq_ignore_ascii_case("identity") {
            return Err(ReadError::Bad(501, "unsupported transfer encoding"));
        }
    }
    // The deadline clock starts once the head is parsed: idle keep-alive
    // time is the socket timeout's problem, body transfer time is this
    // deadline's.
    let deadline = body_budget.map(|d| Instant::now() + d);
    let body = if chunked {
        // RFC 9112 §6.3: a message with both framings is a smuggling
        // vector; reject instead of picking one.
        if content_length.is_some() {
            return Err(ReadError::Bad(
                400,
                "Content-Length with chunked transfer encoding",
            ));
        }
        read_chunked_body(reader, deadline)?
    } else {
        // A bodied method with neither Content-Length nor chunked framing
        // has no way to delimit its payload: reading it as empty would
        // desync the keep-alive stream (the body bytes parse as the next
        // request line) and surface as a misleading JSON error.  RFC 9110
        // §8.6: 411 Length Required.  Bodyless methods (GET/HEAD/DELETE)
        // keep their framing-free form.
        let content_length = match content_length {
            Some(n) => n,
            None if method == "POST" || method == "PUT" => {
                return Err(ReadError::Bad(
                    411,
                    "missing Content-Length (or chunked transfer encoding)",
                ));
            }
            None => 0,
        };
        if content_length > MAX_BODY_BYTES {
            return Err(ReadError::Bad(413, "body too large"));
        }
        let mut body = vec![0u8; content_length];
        if content_length > 0 {
            read_body_exact(
                reader,
                &mut body,
                deadline,
                "body shorter than Content-Length",
            )?;
        }
        body
    };

    let close = version == "HTTP/1.0"
        || headers
            .iter()
            .any(|(k, v)| k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close"));

    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target, None),
    };
    Ok(Request {
        method,
        path,
        query,
        headers,
        body,
        close,
    })
}

fn find_crlfcrlf(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// The 408 every stalled body read maps to (deadline elapsed, or the
/// socket read timeout fired mid-body).
fn stalled_read_error() -> ReadError {
    ReadError::Bad(408, "body read deadline exceeded (stalled upload)")
}

/// Fail with 408 once the body deadline has passed.  Checked between
/// `fill_buf` chunks, so the check itself never blocks: progress is only
/// ever interrupted at a chunk boundary.
fn check_deadline(deadline: Option<Instant>) -> Result<(), ReadError> {
    match deadline {
        Some(d) if Instant::now() >= d => Err(stalled_read_error()),
        _ => Ok(()),
    }
}

/// Whether an IO error is the socket read timeout (a stalled peer), as
/// opposed to a real transport failure.
fn is_stall(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Deadline-aware `read_exact` built on `fill_buf` chunks: the body
/// deadline is re-checked between chunks (a slow-drip upload cannot ride
/// one blocking `read_exact` past it), a mid-body socket timeout maps to
/// the same 408, and truncation maps to `truncated` at 400.
fn read_body_exact<R: BufRead>(
    reader: &mut R,
    out: &mut [u8],
    deadline: Option<Instant>,
    truncated: &'static str,
) -> Result<(), ReadError> {
    let mut filled = 0;
    while filled < out.len() {
        check_deadline(deadline)?;
        let buf = match reader.fill_buf() {
            Ok(b) => b,
            Err(e) if is_stall(&e) => return Err(stalled_read_error()),
            Err(_) => return Err(ReadError::Bad(400, truncated)),
        };
        if buf.is_empty() {
            return Err(ReadError::Bad(400, truncated));
        }
        let take = buf.len().min(out.len() - filled);
        out[filled..filled + take].copy_from_slice(&buf[..take]);
        reader.consume(take);
        filled += take;
    }
    Ok(())
}

/// Read a chunked body: size-line / data / CRLF repeated until the zero
/// chunk, then trailers up to the blank line (consumed, ignored, budgeted).
/// Every failure mode — truncation, over-cap, bad framing — maps to a
/// status + message, never a hang or an unbounded buffer.
fn read_chunked_body<R: BufRead>(
    reader: &mut R,
    deadline: Option<Instant>,
) -> Result<Vec<u8>, ReadError> {
    let mut body = Vec::new();
    loop {
        check_deadline(deadline)?;
        let line = read_crlf_line(
            reader,
            MAX_CHUNK_LINE,
            (400, "oversized chunk-size line"),
            deadline,
        )?;
        let size = parse_chunk_size(&line)?;
        if size == 0 {
            break;
        }
        // Enforce the cap on the *declared* total before reading data: a
        // hostile "FFFFFFFF\r\n" costs one line read, not a 4 GiB buffer.
        match body.len().checked_add(size) {
            Some(total) if total <= MAX_BODY_BYTES => {}
            _ => return Err(ReadError::Bad(413, "body too large")),
        }
        let old_len = body.len();
        body.resize(old_len + size, 0);
        read_body_exact(
            reader,
            &mut body[old_len..],
            deadline,
            "truncated chunked body",
        )?;
        let mut crlf = [0u8; 2];
        read_body_exact(reader, &mut crlf, deadline, "truncated chunked body")?;
        if &crlf != b"\r\n" {
            return Err(ReadError::Bad(400, "bad chunk terminator"));
        }
    }
    // Trailer section: consume lines until the blank terminator.  Nothing
    // in the API uses trailers, but they must leave the stream positioned
    // at the next keep-alive request.
    let mut trailer_bytes = 0usize;
    loop {
        let line = read_crlf_line(
            reader,
            MAX_HEAD_BYTES,
            (431, "trailers too large"),
            deadline,
        )?;
        if line.is_empty() {
            break;
        }
        trailer_bytes += line.len() + 2;
        if trailer_bytes > MAX_HEAD_BYTES {
            return Err(ReadError::Bad(431, "trailers too large"));
        }
    }
    Ok(body)
}

/// Read one CRLF-terminated line (CRLF stripped), bounded by `max`; lines
/// over the bound fail with `too_long`, truncation/bare-LF with a 400,
/// stalls against `deadline` with a 408.  Handles terminators straddling
/// `fill_buf` boundaries.
fn read_crlf_line<R: BufRead>(
    reader: &mut R,
    max: usize,
    too_long: (u16, &'static str),
    deadline: Option<Instant>,
) -> Result<Vec<u8>, ReadError> {
    let mut line = Vec::new();
    loop {
        check_deadline(deadline)?;
        let buf = match reader.fill_buf() {
            Ok(b) => b,
            Err(e) if is_stall(&e) => return Err(stalled_read_error()),
            Err(_) => return Err(ReadError::Bad(400, "truncated chunked body")),
        };
        if buf.is_empty() {
            return Err(ReadError::Bad(400, "truncated chunked body"));
        }
        if let Some(i) = buf.iter().position(|&b| b == b'\n') {
            line.extend_from_slice(&buf[..i]);
            reader.consume(i + 1);
            if line.pop() != Some(b'\r') {
                return Err(ReadError::Bad(400, "bad chunk framing"));
            }
            if line.len() > max {
                return Err(ReadError::Bad(too_long.0, too_long.1));
            }
            return Ok(line);
        }
        line.extend_from_slice(buf);
        let n = buf.len();
        reader.consume(n);
        // +1: a trailing CR may still be pending its LF.
        if line.len() > max + 1 {
            return Err(ReadError::Bad(too_long.0, too_long.1));
        }
    }
}

/// Parse a chunk-size line: bare hex digits, optional `;extensions`
/// (ignored).  Strict by design — no sign (`usize::from_str_radix` would
/// accept a leading `+`), no whitespace, non-empty.
fn parse_chunk_size(line: &[u8]) -> Result<usize, ReadError> {
    let end = line
        .iter()
        .position(|&b| b == b';')
        .unwrap_or(line.len());
    let size_part = &line[..end];
    if size_part.is_empty() || !size_part.iter().all(|b| b.is_ascii_hexdigit()) {
        return Err(ReadError::Bad(400, "bad chunk size"));
    }
    // All-hexdigit bytes are valid UTF-8 and a valid radix-16 literal; the
    // only remaining failure is overflow, which is over-cap by definition.
    let text = std::str::from_utf8(size_part).expect("hex digits are ASCII");
    usize::from_str_radix(text, 16).map_err(|_| ReadError::Bad(413, "body too large"))
}

/// Write one response (always with `Content-Length`; `close` controls the
/// `Connection` header).
pub fn write_response<W: Write>(
    out: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    close: bool,
) -> std::io::Result<()> {
    let reason = reason_phrase(status);
    write!(
        out,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        body.len(),
        if close { "close" } else { "keep-alive" },
    )?;
    out.write_all(body)?;
    out.flush()
}

pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(bytes: &[u8]) -> Result<Request, ReadError> {
        read_request(&mut BufReader::new(bytes))
    }

    #[test]
    fn parses_get_without_body() {
        let r = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert_eq!(r.query, None);
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.body.is_empty());
        assert!(!r.close);
    }

    #[test]
    fn parses_post_with_body_and_close() {
        let r = parse(
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: 4\r\nConnection: close\r\n\r\n{\"a\"",
        )
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"{\"a\"");
        assert!(r.close);
    }

    #[test]
    fn splits_query_string() {
        let r = parse(b"GET /metrics?format=prom HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(r.path, "/metrics");
        assert_eq!(r.query.as_deref(), Some("format=prom"));
    }

    #[test]
    fn http10_implies_close() {
        let r = parse(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(r.close);
    }

    #[test]
    fn keep_alive_reads_two_requests_sequentially() {
        let bytes =
            b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi".to_vec();
        let mut reader = BufReader::new(&bytes[..]);
        let r1 = read_request(&mut reader).unwrap();
        assert_eq!(r1.path, "/a");
        let r2 = read_request(&mut reader).unwrap();
        assert_eq!(r2.path, "/b");
        assert_eq!(r2.body, b"hi");
        assert!(matches!(read_request(&mut reader), Err(ReadError::Eof)));
    }

    #[test]
    fn rejects_malformed_and_oversize() {
        assert!(matches!(parse(b""), Err(ReadError::Eof)));
        assert!(matches!(
            parse(b"NOPE\r\n\r\n"),
            Err(ReadError::Bad(400, _))
        ));
        assert!(matches!(
            parse(b"GET / HTTP/2\r\n\r\n"),
            Err(ReadError::Bad(400, _))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: zap\r\n\r\n"),
            Err(ReadError::Bad(400, _))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n"),
            Err(ReadError::Bad(413, _))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n"),
            Err(ReadError::Bad(501, _))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: gzip, chunked\r\n\r\n"),
            Err(ReadError::Bad(501, _))
        ));
        // RFC 9112: conflicting duplicates are rejected; agreeing ones pass.
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 30\r\n\r\nhello"),
            Err(ReadError::Bad(400, _))
        ));
        let r = parse(b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi")
            .unwrap();
        assert_eq!(r.body, b"hi");
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort"),
            Err(ReadError::Bad(400, _))
        ));
        let huge = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES));
        assert!(matches!(
            parse(huge.as_bytes()),
            Err(ReadError::Bad(431, _))
        ));
    }

    #[test]
    fn bodied_method_without_framing_is_411() {
        // POST/PUT with neither Content-Length nor chunked: 411, never a
        // silent empty body (the stray payload would desync keep-alive).
        assert!(matches!(
            parse(b"POST /v1/classify HTTP/1.1\r\nHost: x\r\n\r\n{\"image\": [1]}"),
            Err(ReadError::Bad(411, _))
        ));
        assert!(matches!(
            parse(b"PUT /v1/stores/a HTTP/1.1\r\n\r\n"),
            Err(ReadError::Bad(411, _))
        ));
        // Bodyless methods keep their framing-free form.
        assert!(parse(b"GET /healthz HTTP/1.1\r\n\r\n").is_ok());
        assert!(parse(b"DELETE /v1/stores/a HTTP/1.1\r\n\r\n").is_ok());
        // Explicit zero-length POST stays valid.
        let r = parse(b"POST /v1/classify HTTP/1.1\r\nContent-Length: 0\r\n\r\n").unwrap();
        assert!(r.body.is_empty());
        assert_eq!(reason_phrase(411), "Length Required");
    }

    // ---- chunked transfer encoding --------------------------------------

    fn chunked(body_frames: &str) -> Vec<u8> {
        format!(
            "POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n{body_frames}"
        )
        .into_bytes()
    }

    #[test]
    fn parses_chunked_body() {
        let r = parse(&chunked("4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n")).unwrap();
        assert_eq!(r.body, b"Wikipedia");
        // Zero-length body.
        let r = parse(&chunked("0\r\n\r\n")).unwrap();
        assert!(r.body.is_empty());
        // Hex sizes (both cases) and chunk extensions are accepted.
        let r = parse(&chunked("A;ext=\"v\"\r\n0123456789\r\n0\r\n\r\n")).unwrap();
        assert_eq!(r.body, b"0123456789");
        let r = parse(&chunked("a\r\n0123456789\r\n0\r\n\r\n")).unwrap();
        assert_eq!(r.body.len(), 10);
    }

    #[test]
    fn chunked_trailers_are_consumed_and_keep_alive_survives() {
        let mut bytes = chunked("2\r\nhi\r\n0\r\nX-Trailer: v\r\nX-Other: w\r\n\r\n");
        bytes.extend_from_slice(b"GET /healthz HTTP/1.1\r\n\r\n");
        let mut reader = BufReader::new(&bytes[..]);
        let r1 = read_request(&mut reader).unwrap();
        assert_eq!(r1.body, b"hi");
        // The trailer must not leak into the header set or the stream.
        assert_eq!(r1.header("x-trailer"), None);
        let r2 = read_request(&mut reader).unwrap();
        assert_eq!(r2.path, "/healthz");
    }

    #[test]
    fn chunked_lines_straddling_read_boundaries() {
        // A 3-byte buffer forces every line and terminator to straddle
        // fill_buf calls.
        let bytes = chunked("4\r\nWiki\r\n5\r\npedia\r\n0\r\nX-T: v\r\n\r\n");
        for cap in [1, 2, 3, 5, 7] {
            let mut reader = BufReader::with_capacity(cap, &bytes[..]);
            let r = read_request(&mut reader).unwrap();
            assert_eq!(r.body, b"Wikipedia", "capacity {cap}");
        }
    }

    #[test]
    fn chunked_truncations_fail_cleanly() {
        // Cut the exchange at every byte boundary: each prefix must yield a
        // clean error (or parse, once complete) — never a hang or panic.
        let full = chunked("4\r\nWiki\r\n0\r\n\r\n");
        for cut in 0..full.len() {
            match parse(&full[..cut]) {
                Err(ReadError::Eof) | Err(ReadError::Bad(..)) => {}
                Ok(_) => panic!("prefix of {cut} bytes parsed as a full request"),
            }
        }
        assert!(parse(&full).is_ok());
    }

    #[test]
    fn chunked_rejects_bad_framing() {
        // Bad hex / empty / signed sizes (strict: from_str_radix's '+'
        // leniency must not leak through).
        for frames in ["x\r\nhi\r\n0\r\n\r\n", "\r\n0\r\n\r\n", "+2\r\nhi\r\n0\r\n\r\n", " 2\r\nhi\r\n0\r\n\r\n"] {
            assert!(
                matches!(parse(&chunked(frames)), Err(ReadError::Bad(400, _))),
                "frames {frames:?}"
            );
        }
        // Bare-LF line terminator.
        assert!(matches!(
            parse(&chunked("2\nhi\r\n0\r\n\r\n")),
            Err(ReadError::Bad(400, _))
        ));
        // Chunk data not followed by CRLF.
        assert!(matches!(
            parse(&chunked("2\r\nhixx0\r\n\r\n")),
            Err(ReadError::Bad(400, _))
        ));
        // Oversized chunk-size line (a hostile extension blob).
        let long = format!("2;{}\r\nhi\r\n0\r\n\r\n", "e".repeat(MAX_CHUNK_LINE + 8));
        assert!(matches!(
            parse(&chunked(&long)),
            Err(ReadError::Bad(400, _))
        ));
    }

    #[test]
    fn chunked_enforces_body_cap_at_the_size_line() {
        // Declares 16 MiB + 1 without sending it: rejected at the header.
        let over = format!("{:x}\r\n", MAX_BODY_BYTES + 1);
        assert!(matches!(
            parse(&chunked(&over)),
            Err(ReadError::Bad(413, _))
        ));
        // Overflow-sized declarations too.
        assert!(matches!(
            parse(&chunked("FFFFFFFFFFFFFFFF\r\n")),
            Err(ReadError::Bad(413, _))
        ));
        assert!(matches!(
            parse(&chunked("FFFFFFFFFFFFFFFFFF\r\n")),
            Err(ReadError::Bad(413, _))
        ));
    }

    #[test]
    fn chunked_conflicts_with_content_length() {
        assert!(matches!(
            parse(
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 2\r\n\r\n2\r\nhi\r\n0\r\n\r\n"
            ),
            Err(ReadError::Bad(400, _))
        ));
        // identity + Content-Length still works as before.
        let r = parse(
            b"POST / HTTP/1.1\r\nTransfer-Encoding: identity\r\nContent-Length: 2\r\n\r\nhi",
        )
        .unwrap();
        assert_eq!(r.body, b"hi");
    }

    #[test]
    fn chunked_oversized_trailers_rejected() {
        let mut frames = String::from("2\r\nhi\r\n0\r\n");
        for i in 0..200 {
            frames.push_str(&format!("X-T{i}: {}\r\n", "v".repeat(100)));
        }
        frames.push_str("\r\n");
        assert!(matches!(
            parse(&chunked(&frames)),
            Err(ReadError::Bad(431, _))
        ));
    }

    // ---- body-read deadline ---------------------------------------------

    #[test]
    fn expired_deadline_fails_body_reads_with_408() {
        // Duration::ZERO expires the moment the body read starts — a
        // deterministic stand-in for a stalled upload (no sleeps).
        let post = b"POST /v1/classify HTTP/1.1\r\nContent-Length: 4\r\n\r\n{\"a\"";
        assert!(matches!(
            read_request_with_deadline(&mut BufReader::new(&post[..]), Some(Duration::ZERO)),
            Err(ReadError::Bad(408, _))
        ));
        let chunked = chunked("4\r\nWiki\r\n0\r\n\r\n");
        assert!(matches!(
            read_request_with_deadline(&mut BufReader::new(&chunked[..]), Some(Duration::ZERO)),
            Err(ReadError::Bad(408, _))
        ));
    }

    #[test]
    fn deadline_only_governs_the_body() {
        // Bodyless requests never consult the deadline: the head is under
        // the socket timeout's jurisdiction, not the body budget's.
        let get = b"GET /healthz HTTP/1.1\r\n\r\n";
        let r = read_request_with_deadline(&mut BufReader::new(&get[..]), Some(Duration::ZERO))
            .unwrap();
        assert_eq!(r.path, "/healthz");
        // An ample budget leaves fully-buffered bodies untouched.
        let post = b"POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let r = read_request_with_deadline(
            &mut BufReader::new(&post[..]),
            Some(Duration::from_secs(60)),
        )
        .unwrap();
        assert_eq!(r.body, b"hi");
    }

    #[test]
    fn stalled_socket_timeout_maps_to_408() {
        // A reader whose fill_buf fails with TimedOut mid-body models the
        // per-read socket timeout firing on a wedged peer.
        struct Stall<'a> {
            head: &'a [u8],
        }
        impl std::io::Read for Stall<'_> {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.head.is_empty() {
                    return Err(std::io::Error::new(std::io::ErrorKind::TimedOut, "stall"));
                }
                let n = buf.len().min(self.head.len());
                buf[..n].copy_from_slice(&self.head[..n]);
                self.head = &self.head[n..];
                Ok(n)
            }
        }
        let head = b"POST / HTTP/1.1\r\nContent-Length: 4\r\n\r\n";
        let mut reader = BufReader::new(Stall { head });
        assert!(matches!(
            read_request_with_deadline(&mut reader, Some(Duration::from_secs(60))),
            Err(ReadError::Bad(408, _))
        ));
        // Without a budget the stall still maps to 408 (socket timeout).
        let mut reader = BufReader::new(Stall { head });
        assert!(matches!(
            read_request(&mut reader),
            Err(ReadError::Bad(408, _))
        ));
    }

    #[test]
    fn response_writing_shape() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", b"x", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Connection: close\r\n"));
    }
}

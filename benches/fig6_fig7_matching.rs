//! Fig. 6 (confusion matrix) + Fig. 7 (per-class accuracy) reproduction for
//! the feature-count pattern-matching classifier, evaluated through the full
//! deployed stack (PJRT front-end -> binarise -> packed matcher), plus the
//! §V.B feature-count / similarity equivalence check.

use hec::benchkit::{paper_row, section};
use hec::config::{Backend, ServeConfig};
use hec::coordinator::Pipeline;
use hec::dataset::{SyntheticDataset, CLASS_NAMES};
use hec::runtime::Meta;

fn main() {
    if !std::path::Path::new("artifacts/meta.json").is_file() {
        println!("fig6_fig7_matching: run `make artifacts` first");
        return;
    }
    let meta = Meta::load("artifacts").unwrap();

    let cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::FeatureCount,
        ..Default::default()
    };
    let mut p = Pipeline::new(&cfg).unwrap();
    let n = 500;
    let ds = SyntheticDataset::new(1_000_003, n, p.meta.norm.mean as f32, p.meta.norm.std as f32);
    let (images, labels) = ds.batch(0, n);
    let eval = p.evaluate(&images, &labels, 32).unwrap();

    section("Fig. 6 — confusion matrix (feature-count matching)");
    print!("{:>12}", "");
    for c in CLASS_NAMES {
        print!("{:>6}", &c[..c.len().min(5)]);
    }
    println!();
    for (i, row) in eval.confusion.iter().enumerate() {
        print!("{:>12}", CLASS_NAMES[i]);
        for v in row {
            print!("{v:>6}");
        }
        println!();
    }

    section("Fig. 7 — per-class accuracy");
    for (i, acc) in eval.per_class_accuracy().iter().enumerate() {
        let bar = "#".repeat((acc * 40.0) as usize);
        println!("{:>12} {:>6.3} {bar}", CLASS_NAMES[i], acc);
    }

    section("overall vs paper");
    paper_row("binary matching accuracy", 70.91 / 100.0, eval.accuracy, "acc");

    // §V.B: identical performance of the two matching modes in binary domain.
    section("§V.B — feature count vs similarity (binary domain)");
    let mm = &meta.experiments.matching_modes;
    println!(
        "python-side: fc={:.4} sim={:.4} agreement={:.4}",
        mm.feature_count_acc, mm.similarity_binary_acc, mm.agreement
    );
    let mut sim = Pipeline::new(&ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::Similarity,
        ..Default::default()
    })
    .unwrap();
    let eval_sim = sim.evaluate(&images, &labels, 32).unwrap();
    println!(
        "rust-side:   fc={:.4} sim={:.4}",
        eval.accuracy, eval_sim.accuracy
    );
    assert!(
        (eval.accuracy - eval_sim.accuracy).abs() < 0.02,
        "paper shape: binary fc and similarity must perform identically"
    );
    // Sanity on the confusion matrix itself.
    let total: u64 = eval.confusion.iter().flatten().sum();
    assert_eq!(total as usize, n);
    println!("\nfig6_fig7_matching: PASS");
}

//! Synthetic open-loop load generator for the HTTP gateway.
//!
//! Drives the tail-latency harness (`benches/loadtest.rs`): arrivals are
//! scheduled on a seeded Poisson process with periodic bursts — **open
//! loop**, so a slow server does not throttle the offered load and tail
//! latencies include the queueing a closed loop would hide (coordinated
//! omission).  Traffic is mixed the way the gateway actually sees it:
//!
//! * **Zipf hot-key skew** over a seeded pool of distinct images — repeats
//!   are what the content-hash feature cache feeds on, and the skew pins a
//!   predictable hit-rate floor ([`hit_rate_floor`]);
//! * **bursts**: every `burst_every`-th arrival lands `burst_size` extra
//!   requests at the same instant;
//! * **slow and chunked clients**: a seeded fraction of requests dribble
//!   their bytes or use chunked transfer encoding, exercising the
//!   streaming decode paths under load;
//! * **per-request deadlines** (`deadline_ms >= 1`) on a seeded fraction,
//!   exercising the deadline-drop path.
//!
//! The schedule is fully determined by [`LoadgenConfig::seed`]; only wall
//! time varies between runs.  Latency is recorded two ways per request:
//! `service` (first byte written → response read) and `e2e` (scheduled
//! arrival → response read), the open-loop figure the percentiles in
//! `BENCH_loadtest.json` are built from.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::rng::Rng;

/// Zipf(s) sampler over ranks `0..n` (rank 0 hottest): P(k) ∝ 1/(k+1)^s.
/// Sampling is a binary search over the precomputed CDF — O(log n), no
/// rejection loop, deterministic under the caller's [`Rng`].
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> ZipfSampler {
        assert!(n > 0, "zipf over an empty pool");
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 0..n {
            total += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(total);
        }
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.u01();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// How one request travels: buffered JSON with Content-Length, chunked
/// transfer encoding, or a slow client that dribbles the same buffered
/// bytes in pieces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    Buffered,
    Chunked,
    Slow,
}

/// One scheduled arrival.
#[derive(Debug, Clone)]
pub struct Arrival {
    /// Offset from harness start, microseconds.
    pub at_us: u64,
    /// Index into the image pool.
    pub image: usize,
    pub flavor: Flavor,
    pub deadline_ms: Option<u64>,
}

/// Load-shape knobs.  Everything downstream of `seed` is deterministic.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Distinct images in the pool (cache working-set size).
    pub pool: usize,
    /// Zipf exponent; 0 = uniform, ~1 = classic hot-key skew.
    pub zipf_s: f64,
    /// Total arrivals (bursts included).
    pub requests: usize,
    /// Mean offered load, requests/second (Poisson inter-arrivals).
    pub rps: f64,
    /// Every Nth arrival triggers a burst (0 disables bursts).
    pub burst_every: usize,
    /// Extra back-to-back arrivals per burst.
    pub burst_size: usize,
    /// Fraction of requests sent with chunked transfer encoding.
    pub chunked_ratio: f64,
    /// Fraction of requests sent by a deliberately slow client.
    pub slow_ratio: f64,
    /// Fraction of requests carrying a deadline.
    pub deadline_ratio: f64,
    /// The deadline those requests carry (must be >= 1).
    pub deadline_ms: u64,
    /// Client worker threads (arrivals are dealt round-robin).
    pub workers: usize,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            pool: 32,
            zipf_s: 1.1,
            requests: 400,
            rps: 400.0,
            burst_every: 50,
            burst_size: 8,
            chunked_ratio: 0.10,
            slow_ratio: 0.05,
            deadline_ratio: 0.15,
            deadline_ms: 2_000,
            workers: 8,
            seed: 0x10AD,
        }
    }
}

impl LoadgenConfig {
    /// A fast configuration for CI smoke runs.
    pub fn smoke() -> Self {
        LoadgenConfig {
            pool: 8,
            requests: 120,
            rps: 300.0,
            burst_every: 30,
            burst_size: 4,
            workers: 4,
            ..Default::default()
        }
    }
}

/// Build the deterministic arrival schedule: Poisson inter-arrivals at
/// `rps` with every `burst_every`-th arrival stapling `burst_size` extra
/// requests to the same instant, Zipf-sampled image indices, and seeded
/// flavor/deadline assignment.
pub fn build_schedule(cfg: &LoadgenConfig) -> Vec<Arrival> {
    assert!(cfg.deadline_ms >= 1, "deadline_ms 0 means 'expired on arrival'");
    let zipf = ZipfSampler::new(cfg.pool, cfg.zipf_s);
    let mut rng = Rng::new(cfg.seed);
    let mut out = Vec::with_capacity(cfg.requests);
    let mut t_us = 0f64;
    let mut in_burst = 0usize;
    for i in 0..cfg.requests {
        if in_burst > 0 {
            in_burst -= 1; // burst members share the arrival instant
        } else {
            // Exponential inter-arrival; clamp u away from 0 for ln().
            let u = rng.u01().max(1e-12);
            t_us += -u.ln() / cfg.rps * 1e6;
            if cfg.burst_every > 0 && i > 0 && i % cfg.burst_every == 0 {
                in_burst = cfg.burst_size;
            }
        }
        let image = zipf.sample(&mut rng);
        let f = rng.u01();
        let flavor = if f < cfg.chunked_ratio {
            Flavor::Chunked
        } else if f < cfg.chunked_ratio + cfg.slow_ratio {
            Flavor::Slow
        } else {
            Flavor::Buffered
        };
        let deadline_ms = (rng.u01() < cfg.deadline_ratio).then_some(cfg.deadline_ms);
        out.push(Arrival {
            at_us: t_us as u64,
            image,
            flavor,
            deadline_ms,
        });
    }
    out
}

/// The cache-hit-rate floor the schedule implies when the per-shard cache
/// capacity covers the pool: each of `shards` workers misses each distinct
/// image at most once, every later repeat hits.  Conservative — Zipf skew
/// and routing locality only raise the real rate.
pub fn hit_rate_floor(pool: usize, shards: usize, requests: usize) -> f64 {
    if requests == 0 {
        return 0.0;
    }
    (1.0 - (pool * shards) as f64 / requests as f64).max(0.0)
}

/// Latency percentile over a **sorted** sample set (nearest-rank on the
/// scaled index, the same convention as `benchkit::summarize`).
pub fn percentile_us(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Outcome tallies + client-side latency percentiles for one run.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    pub scheduled: usize,
    pub ok: u64,
    pub http_errors: u64,
    pub deadline_exceeded: u64,
    pub transport_errors: u64,
    pub wall_secs: f64,
    pub achieved_rps: f64,
    /// Service-time percentiles, send → response (us).
    pub service_us: Percentiles,
    /// Open-loop end-to-end percentiles, scheduled arrival → response (us).
    pub e2e_us: Percentiles,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    pub p999: u64,
    pub max: u64,
}

impl Percentiles {
    pub fn from_sorted(sorted: &[u64]) -> Percentiles {
        Percentiles {
            p50: percentile_us(sorted, 0.50),
            p90: percentile_us(sorted, 0.90),
            p99: percentile_us(sorted, 0.99),
            p999: percentile_us(sorted, 0.999),
            max: sorted.last().copied().unwrap_or(0),
        }
    }
}

impl LoadReport {
    /// JSON form for the `BENCH_loadtest.json` extras.
    pub fn to_value(&self) -> crate::jsonlite::Value {
        use crate::jsonlite::Value;
        let pct = |p: &Percentiles| {
            Value::Obj(std::collections::BTreeMap::from([
                ("p50_us".to_string(), Value::Num(p.p50 as f64)),
                ("p90_us".to_string(), Value::Num(p.p90 as f64)),
                ("p99_us".to_string(), Value::Num(p.p99 as f64)),
                ("p999_us".to_string(), Value::Num(p.p999 as f64)),
                ("max_us".to_string(), Value::Num(p.max as f64)),
            ]))
        };
        Value::Obj(std::collections::BTreeMap::from([
            ("scheduled".to_string(), Value::Num(self.scheduled as f64)),
            ("ok".to_string(), Value::Num(self.ok as f64)),
            ("http_errors".to_string(), Value::Num(self.http_errors as f64)),
            (
                "deadline_exceeded".to_string(),
                Value::Num(self.deadline_exceeded as f64),
            ),
            (
                "transport_errors".to_string(),
                Value::Num(self.transport_errors as f64),
            ),
            ("wall_secs".to_string(), Value::Num(self.wall_secs)),
            ("achieved_rps".to_string(), Value::Num(self.achieved_rps)),
            ("client_service".to_string(), pct(&self.service_us)),
            ("client_e2e".to_string(), pct(&self.e2e_us)),
        ]))
    }
}

/// Sum every sample of a Prometheus metric family across its label sets
/// (`hec_cache_hits_total` and `hec_cache_hits_total{shard="1"}` alike).
/// Used by the bench and CI to assert cache behaviour from `/metrics`.
pub fn metric_total(prom_text: &str, name: &str) -> f64 {
    let mut total = 0.0;
    for line in prom_text.lines() {
        if line.starts_with('#') {
            continue;
        }
        let matches = line
            .strip_prefix(name)
            .is_some_and(|rest| rest.starts_with(' ') || rest.starts_with('{'));
        if !matches {
            continue;
        }
        if let Some(v) = line.rsplit(' ').next().and_then(|t| t.parse::<f64>().ok()) {
            total += v;
        }
    }
    total
}

// ---------------------------------------------------------------------------
// HTTP client side.
// ---------------------------------------------------------------------------

enum Outcome {
    Ok,
    HttpError,
    DeadlineExceeded,
    Transport,
}

/// Serialise one classify body from a pre-rendered image JSON array.
fn body_for(img_json: &str, deadline_ms: Option<u64>) -> String {
    match deadline_ms {
        Some(d) => format!("{{\"image\": {img_json}, \"deadline_ms\": {d}}}"),
        None => format!("{{\"image\": {img_json}}}"),
    }
}

fn read_status_and_body(stream: &mut TcpStream) -> std::io::Result<(u16, String)> {
    let bad = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        stream.read_exact(&mut byte)?;
        head.push(byte[0]);
        if head.len() > 64 * 1024 {
            return Err(bad("unterminated response head"));
        }
    }
    let head = String::from_utf8(head).map_err(|_| bad("non-utf8 head"))?;
    let status: u16 = head
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("bad status line"))?;
    let content_length: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().ok())?
        })
        .ok_or_else(|| bad("missing Content-Length"))?;
    let mut body = vec![0u8; content_length];
    stream.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

/// Send one request per the arrival's flavor on a fresh connection.
fn fire(addr: SocketAddr, body: &str, flavor: Flavor) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    match flavor {
        Flavor::Buffered => {
            let wire = format!(
                "POST /v1/classify HTTP/1.1\r\nHost: hec-loadgen\r\nConnection: close\r\n\
                 Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            stream.write_all(wire.as_bytes())?;
        }
        Flavor::Slow => {
            // Same bytes as Buffered, dribbled in thirds with short stalls.
            let wire = format!(
                "POST /v1/classify HTTP/1.1\r\nHost: hec-loadgen\r\nConnection: close\r\n\
                 Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let bytes = wire.as_bytes();
            let third = bytes.len().div_ceil(3);
            for piece in bytes.chunks(third) {
                stream.write_all(piece)?;
                stream.flush()?;
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        Flavor::Chunked => {
            let mut wire = String::from(
                "POST /v1/classify HTTP/1.1\r\nHost: hec-loadgen\r\nConnection: close\r\n\
                 Content-Type: application/json\r\nTransfer-Encoding: chunked\r\n\r\n",
            );
            for piece in body.as_bytes().chunks(512) {
                wire.push_str(&format!("{:x}\r\n", piece.len()));
                wire.push_str(std::str::from_utf8(piece).unwrap());
                wire.push_str("\r\n");
            }
            wire.push_str("0\r\n\r\n");
            stream.write_all(wire.as_bytes())?;
        }
    }
    read_status_and_body(&mut stream)
}

fn classify_outcome(result: std::io::Result<(u16, String)>) -> Outcome {
    match result {
        Ok((200, _)) => Outcome::Ok,
        Ok((_, body)) if body.contains("DEADLINE_EXCEEDED") => Outcome::DeadlineExceeded,
        Ok(_) => Outcome::HttpError,
        Err(_) => Outcome::Transport,
    }
}

/// Run the open-loop harness against a live gateway: fire every scheduled
/// arrival at its instant (workers never wait for responses before the
/// next arrival is due on another worker), tally outcomes, and fold the
/// client-side latency samples into percentiles.
pub fn run(addr: SocketAddr, cfg: &LoadgenConfig, images_json: &[String]) -> LoadReport {
    assert_eq!(images_json.len(), cfg.pool, "one JSON image per pool slot");
    let schedule = Arc::new(build_schedule(cfg));
    let images: Arc<Vec<String>> = Arc::new(images_json.to_vec());
    let workers = cfg.workers.max(1);
    let start = Instant::now();
    let joins: Vec<_> = (0..workers)
        .map(|w| {
            let schedule = Arc::clone(&schedule);
            let images = Arc::clone(&images);
            std::thread::spawn(move || {
                let mut service = Vec::new();
                let mut e2e = Vec::new();
                let mut tallies = [0u64; 4]; // ok, http, deadline, transport
                for a in schedule.iter().skip(w).step_by(workers) {
                    let due = Duration::from_micros(a.at_us);
                    let now = start.elapsed();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    let body = body_for(&images[a.image], a.deadline_ms);
                    let t_send = Instant::now();
                    let outcome = classify_outcome(fire(addr, &body, a.flavor));
                    let done = start.elapsed();
                    service.push(t_send.elapsed().as_micros() as u64);
                    e2e.push(done.saturating_sub(due).as_micros() as u64);
                    let slot = match outcome {
                        Outcome::Ok => 0,
                        Outcome::HttpError => 1,
                        Outcome::DeadlineExceeded => 2,
                        Outcome::Transport => 3,
                    };
                    tallies[slot] += 1;
                }
                (service, e2e, tallies)
            })
        })
        .collect();

    let mut service = Vec::with_capacity(schedule.len());
    let mut e2e = Vec::with_capacity(schedule.len());
    let mut tallies = [0u64; 4];
    for j in joins {
        let (s, e, t) = j.join().expect("loadgen worker panicked");
        service.extend(s);
        e2e.extend(e);
        for (acc, v) in tallies.iter_mut().zip(t) {
            *acc += v;
        }
    }
    let wall_secs = start.elapsed().as_secs_f64();
    service.sort_unstable();
    e2e.sort_unstable();
    LoadReport {
        scheduled: schedule.len(),
        ok: tallies[0],
        http_errors: tallies[1],
        deadline_exceeded: tallies[2],
        transport_errors: tallies[3],
        wall_secs,
        achieved_rps: if wall_secs > 0.0 {
            schedule.len() as f64 / wall_secs
        } else {
            0.0
        },
        service_us: Percentiles::from_sorted(&service),
        e2e_us: Percentiles::from_sorted(&e2e),
    }
}

/// Render one pool image as a JSON array fragment (`[0.1,0.2,...]`).
pub fn image_json(image: &[f32]) -> String {
    let mut s = String::with_capacity(image.len() * 10);
    s.push('[');
    for (i, px) in image.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        // f32 -> f64 is exact, and Display round-trips, so the gateway
        // decodes bit-identical pixels; identical pool slots therefore
        // produce identical content hashes server-side.
        s.push_str(&format!("{}", *px as f64));
    }
    s.push(']');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_prefers_low_ranks() {
        let z = ZipfSampler::new(16, 1.1);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 16];
        for _ in 0..4000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[8] * 3, "{counts:?}");
        assert!(counts[0] > 4000 / 16, "{counts:?}");
        // Every draw lands in range (partition_point edge at u ~ 1.0).
        assert_eq!(counts.iter().sum::<usize>(), 4000);
    }

    #[test]
    fn zipf_zero_exponent_is_roughly_uniform() {
        let z = ZipfSampler::new(8, 0.0);
        let mut rng = Rng::new(2);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for (k, &c) in counts.iter().enumerate() {
            assert!((700..1300).contains(&c), "rank {k}: {c} of 8000");
        }
    }

    #[test]
    fn schedule_is_deterministic_and_monotone() {
        let cfg = LoadgenConfig {
            requests: 200,
            ..Default::default()
        };
        let a = build_schedule(&cfg);
        let b = build_schedule(&cfg);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_us, y.at_us);
            assert_eq!(x.image, y.image);
            assert_eq!(x.flavor, y.flavor);
            assert_eq!(x.deadline_ms, y.deadline_ms);
        }
        assert!(a.windows(2).all(|w| w[0].at_us <= w[1].at_us), "non-monotone schedule");
        assert!(a.iter().all(|x| x.image < cfg.pool));
        assert!(a
            .iter()
            .all(|x| x.deadline_ms.map_or(true, |d| d >= 1)));
    }

    #[test]
    fn schedule_contains_bursts_and_mixed_flavors() {
        let cfg = LoadgenConfig {
            requests: 400,
            burst_every: 20,
            burst_size: 5,
            ..Default::default()
        };
        let sched = build_schedule(&cfg);
        // Bursts: some arrival instants repeat burst_size+ times.
        let max_same_instant = {
            let mut best = 1;
            let mut run = 1;
            for w in sched.windows(2) {
                if w[0].at_us == w[1].at_us {
                    run += 1;
                    best = best.max(run);
                } else {
                    run = 1;
                }
            }
            best
        };
        assert!(max_same_instant > cfg.burst_size, "no burst found");
        let chunked = sched.iter().filter(|a| a.flavor == Flavor::Chunked).count();
        let slow = sched.iter().filter(|a| a.flavor == Flavor::Slow).count();
        let with_deadline = sched.iter().filter(|a| a.deadline_ms.is_some()).count();
        assert!(chunked > 0 && slow > 0 && with_deadline > 0);
    }

    #[test]
    fn percentiles_are_order_statistics() {
        let sorted: Vec<u64> = (1..=1000).collect();
        assert_eq!(percentile_us(&sorted, 0.0), 1);
        assert_eq!(percentile_us(&sorted, 1.0), 1000);
        assert_eq!(percentile_us(&sorted, 0.5), 500);
        assert!(percentile_us(&sorted, 0.999) >= 998);
        assert_eq!(percentile_us(&[], 0.5), 0);
        let p = Percentiles::from_sorted(&sorted);
        assert!(p.p50 <= p.p90 && p.p90 <= p.p99 && p.p99 <= p.p999 && p.p999 <= p.max);
    }

    #[test]
    fn hit_rate_floor_matches_miss_budget() {
        assert_eq!(hit_rate_floor(8, 3, 120), 1.0 - 24.0 / 120.0);
        assert_eq!(hit_rate_floor(100, 3, 120), 0.0); // more keys than requests
        assert_eq!(hit_rate_floor(8, 3, 0), 0.0);
    }

    #[test]
    fn metric_total_sums_labeled_and_bare_series() {
        let text = "# HELP hec_cache_hits_total x\n\
                    # TYPE hec_cache_hits_total counter\n\
                    hec_cache_hits_total{shard=\"0\"} 3\n\
                    hec_cache_hits_total{shard=\"1\"} 4\n\
                    hec_cache_hits_totally_not 99\n\
                    hec_cache_misses_total 7\n";
        assert_eq!(metric_total(text, "hec_cache_hits_total"), 7.0);
        assert_eq!(metric_total(text, "hec_cache_misses_total"), 7.0);
        assert_eq!(metric_total(text, "hec_cache_evictions_total"), 0.0);
    }

    #[test]
    fn image_json_round_trips_pixel_bits() {
        let img = [0.5f32, -1.25, 0.1307, -0.0, 3.4e-5];
        let frag = image_json(&img);
        let v = crate::jsonlite::parse(&frag).unwrap();
        let back = v.as_f32_vec().unwrap();
        assert_eq!(back.len(), img.len());
        for (a, b) in img.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }
}

"""Pallas binary-quantisation kernel (Section II-C feature-map binarisation).

One grid step binarises a (BB, BN) feature tile against the per-feature
threshold row — a pure VPU elementwise op; the kernel exists so the full
inference path (conv -> binarise -> match) lowers into one HLO module with no
host round-trip between front-end and back-end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BB, BN = 32, 256


def _quant_kernel(x_ref, th_ref, o_ref):
    o_ref[...] = (x_ref[...] > th_ref[...]).astype(jnp.float32)


def binary_quantize(features: jnp.ndarray, thresholds: jnp.ndarray) -> jnp.ndarray:
    """features [B,N] f32, thresholds [N] f32 -> {0,1} f32 [B,N] (matches
    ``ref.binary_quantize``)."""
    b, n = features.shape
    bb, bn = min(BB, b), min(BN, n)
    p0, p1 = (-b) % bb, (-n) % bn
    xp = jnp.pad(features, ((0, p0), (0, p1)))
    # Pad thresholds with +inf so padded columns binarise to 0.
    thp = jnp.pad(thresholds[None, :], ((0, 0), (0, p1)), constant_values=jnp.inf)
    out = pl.pallas_call(
        _quant_kernel,
        grid=(xp.shape[0] // bb, xp.shape[1] // bn),
        in_specs=[
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, thp)
    return out[:b, :n]

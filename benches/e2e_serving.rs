//! End-to-end serving bench: throughput/latency of the full coordinator
//! (dynamic batcher -> PJRT front-end -> back-end) across batching policies
//! and back-ends — the systems-side evaluation the paper's Fig. 2
//! architecture implies.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use hec::api::ClassifyRequest;
use hec::benchkit::section;
use hec::config::{Backend, ServeConfig};
use hec::coordinator::Server;
use hec::dataset::SyntheticDataset;
use hec::runtime::Meta;

fn run(cfg: ServeConfig, requests: usize, clients: usize) -> (f64, f64, u64) {
    let server = Server::start(cfg).unwrap();
    let meta = Meta::load("artifacts").unwrap();
    let ds = SyntheticDataset::new(1_000_003, 256, meta.norm.mean as f32, meta.norm.std as f32);
    let pool: Arc<Vec<Vec<f32>>> = Arc::new((0..256).map(|i| ds.image(i)).collect());
    let done = Arc::new(AtomicUsize::new(0));

    let t0 = std::time::Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let handle = server.handle.clone();
            let pool = Arc::clone(&pool);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                for r in 0..requests / clients {
                    let img = pool[(c + r) % pool.len()].clone();
                    let rx = loop {
                        match handle.submit(ClassifyRequest::new(img.clone())) {
                            Ok(rx) => break rx,
                            Err(_) => std::thread::sleep(std::time::Duration::from_micros(100)),
                        }
                    };
                    if rx.recv().is_ok() {
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let snap = server.handle.metrics.snapshot();
    let n = done.load(Ordering::Relaxed);
    drop(server.handle.clone());
    server.shutdown();
    (n as f64 / secs, snap.latency_mean_us, snap.latency_p99_us)
}

fn main() {
    if !std::path::Path::new("artifacts/meta.json").is_file() {
        println!("e2e_serving: run `make artifacts` first");
        return;
    }
    let base = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::FeatureCount,
        ..Default::default()
    };
    let requests = 600;

    section("batching policy sweep (feature-count backend)");
    println!(
        "{:>10} {:>10} {:>12} {:>14} {:>14}",
        "max_batch", "wait_us", "req/s", "mean_lat_us", "p99_lat_us"
    );
    let mut results = Vec::new();
    for (max_batch, wait_us, clients) in
        [(1usize, 0u64, 4usize), (8, 500, 16), (32, 1000, 32)]
    {
        let mut cfg = base.clone();
        cfg.batch.max_batch = max_batch;
        cfg.batch.max_wait_us = wait_us;
        let (tput, mean_lat, p99) = run(cfg, requests, clients);
        println!(
            "{max_batch:>10} {wait_us:>10} {tput:>12.0} {mean_lat:>14.0} {p99:>14}   ({clients} clients)"
        );
        results.push(tput);
    }
    // The batching trade-off depends on offered concurrency: on this
    // single-core testbed client threads contend with the PJRT worker, so
    // we assert completion + sane throughput rather than a fixed ordering,
    // and report the sweep (the deadline-padding interaction is the
    // interesting systems result — underfilled big batches pay padding).
    assert!(results.iter().all(|&t| t > 50.0), "all configs must sustain >50 req/s");

    section("backend sweep (batcher 32/2ms)");
    println!(
        "{:>14} {:>12} {:>14} {:>14}",
        "backend", "req/s", "mean_lat_us", "p99_lat_us"
    );
    for backend in [Backend::FeatureCount, Backend::Similarity, Backend::AcamSim, Backend::Softmax] {
        let mut cfg = base.clone();
        cfg.backend = backend;
        cfg.batch.max_batch = 32;
        cfg.batch.max_wait_us = 2000;
        let (tput, mean_lat, p99) = run(cfg, requests, 4);
        println!("{backend:>14?} {tput:>12.0} {mean_lat:>14.0} {p99:>14}");
    }
    println!("\ne2e_serving: PASS");
}

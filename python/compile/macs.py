"""MAC / parameter accounting (Eq. 13) plus the paper-scale constants used by
the Table I and §V.D reproductions.

Two accounting modes:

* **as-built** — exact Eq.-13 walk over the models actually trained in this
  environment (CPU-scaled widths/dataset);
* **paper-scale** — the constants the paper reports for its ResNet-50 teacher
  and Fig.-5 student, used so the §V.D energy arithmetic reproduces the
  published 792x figure independent of our training scale.

The same constants are mirrored in ``rust/src/energy/constants.rs`` (the Rust
side owns the serving-time energy ledger); `python/tests/test_macs.py` pins
them so the two languages cannot drift apart.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Paper-reported constants (Table I + Section V.D)
# ---------------------------------------------------------------------------

PAPER = {
    "teacher_color": {"params": 26_215_810, "macs": 3_858_551_808, "accuracy": 93.77},
    "teacher_gray": {"params": 26_209_538, "macs": 3_808_375_808, "accuracy": 91.04},
    "student_base": {"params": 380_314, "macs": 23_785_120, "accuracy": 76.29},
    "student_opt": {"params": 380_314, "macs": 4_757_024, "accuracy": 82.22},
    # Section V.D energy accounting inputs
    "softmax_head_ops": 7_850,  # 784*10 + 10, removed when ACAM replaces the head
    "frontend_ops_acam": 4_749_174,  # 4,757,024 - 7,850
    "sparsity": 0.80,
    "acam_cell_energy_fj": 185.0,
    "n_templates": 10,
    "n_features": 784,
    # Horowitz ISSCC'14 8-bit energies
    "mul8_pj": 0.2,
    "add8_pj": 0.03,
    "mem32k_pj": 20.0,
    # Published results
    "e_backend_nj": 1.45,
    "e_frontend_nj": 96.07,
    "e_total_nj": 97.52,
    "e_teacher_uj": 78.06,
    "energy_reduction": 792.0,
    "match_accuracy_binary": 70.91,
    "multi_template_accuracy": {1: 70.91, 2: 71.64, 3: 71.60},
}


# ---------------------------------------------------------------------------
# Eq. 13 walk over layer descriptions
# ---------------------------------------------------------------------------


@dataclass
class ConvLayer:
    h_out: int
    w_out: int
    kh: int
    kw: int
    cin: int
    cout: int
    name: str = ""

    @property
    def macs(self) -> int:
        """Eq. 13: MACs = Ho*Wo*Kh*Kw*Cin*Cout."""
        return self.h_out * self.w_out * self.kh * self.kw * self.cin * self.cout

    @property
    def params(self) -> int:
        return self.kh * self.kw * self.cin * self.cout + self.cout


@dataclass
class DenseLayer:
    din: int
    dout: int
    name: str = ""

    @property
    def macs(self) -> int:
        return self.din * self.dout

    @property
    def params(self) -> int:
        return self.din * self.dout + self.dout


def student_layers(filters=(32, 128, 256, 16), in_ch=1, size=32) -> List:
    """The Fig.-5 student: conv/BN/pool x2, conv, 2x2-valid conv, dense head."""
    f1, f2, f3, f4 = filters
    s2, s4 = size // 2, size // 4
    feat = (s4 - 1) ** 2 * f4
    return [
        ConvLayer(size, size, 3, 3, in_ch, f1, "conv1"),
        ConvLayer(s2, s2, 3, 3, f1, f2, "conv2"),
        ConvLayer(s4, s4, 3, 3, f2, f3, "conv3"),
        ConvLayer(s4 - 1, s4 - 1, 2, 2, f3, f4, "conv4"),
        DenseLayer(feat, 10, "head"),
    ]


def teacher_layers(width=16, blocks_per_stage=1, in_ch=1, size=32) -> List:
    layers: List = [ConvLayer(size, size, 3, 3, in_ch, width, "stem")]
    cin, s = width, size
    for si, w in enumerate((width, width * 2, width * 4)):
        for bi in range(blocks_per_stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            s_out = s // stride
            layers.append(ConvLayer(s_out, s_out, 3, 3, cin, w, f"s{si}b{bi}a"))
            layers.append(ConvLayer(s_out, s_out, 3, 3, w, w, f"s{si}b{bi}b"))
            if cin != w:
                layers.append(ConvLayer(s_out, s_out, 1, 1, cin, w, f"s{si}b{bi}proj"))
            cin, s = w, s_out
    layers.append(DenseLayer(width * 4, 10, "head"))
    return layers


def total_macs(layers: List) -> int:
    return sum(l.macs for l in layers)


def total_params(layers: List, bn_channels: int = 0) -> int:
    return sum(l.params for l in layers) + 2 * bn_channels  # gamma+beta per channel


def model_summary(layers: List) -> Dict:
    return {
        "layers": [
            {"name": l.name, "macs": l.macs, "params": l.params} for l in layers
        ],
        "macs": total_macs(layers),
        "params": total_params(layers),
    }


def effective_macs(macs: int, sparsity: float) -> int:
    """Pruned-weight MACs are skipped entirely (the paper's 80%-sparsity
    argument for the 4.76M effective-ops figure)."""
    return int(round(macs * (1.0 - sparsity)))

"""L1 — Pallas kernels for the hybrid edge classifier's compute hot-spots.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); each is validated against the pure-jnp oracle in ``ref.py``.
"""

from . import ref  # noqa: F401
from .conv2d import conv2d  # noqa: F401
from .matmul import matmul  # noqa: F401
from .pattern_match import match_feature_count, match_similarity  # noqa: F401
from .quantize import binary_quantize  # noqa: F401

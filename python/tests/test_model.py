"""Model definitions: shapes, Fig.-5 feature width, pallas/jnp path equality,
BatchNorm state threading, teacher block wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.config import StudentConfig, TeacherConfig
from compile.model import (
    init_student,
    init_teacher,
    l2_penalty,
    student_features,
    student_logits,
    student_param_count,
    teacher_logits,
)

RNG = np.random.default_rng(1)


@pytest.fixture(scope="module")
def student():
    cfg = StudentConfig()
    params, state = init_student(cfg, jax.random.PRNGKey(0))
    return cfg, params, state


@pytest.fixture(scope="module")
def teacher():
    cfg = TeacherConfig(width=8, blocks_per_stage=1)
    params, state = init_teacher(cfg, jax.random.PRNGKey(1))
    return cfg, params, state


def test_student_feature_dim_is_784(student):
    cfg, params, state = student
    x = jnp.asarray(RNG.normal(size=(2, 32, 32, 1)).astype(np.float32))
    feats, _ = student_features(params, state, x)
    assert feats.shape == (2, 784)


def test_student_logits_shape(student):
    cfg, params, state = student
    x = jnp.asarray(RNG.normal(size=(3, 32, 32, 1)).astype(np.float32))
    logits, _ = student_logits(params, state, x)
    assert logits.shape == (3, 10)


def test_student_pallas_path_matches_jnp(student):
    """The AOT export uses the Pallas path; training uses jnp — they must be
    numerically identical (same im2col layout, same contraction)."""
    cfg, params, state = student
    x = jnp.asarray(RNG.normal(size=(2, 32, 32, 1)).astype(np.float32))
    f_jnp, _ = student_features(params, state, x, use_pallas=False)
    f_pl, _ = student_features(params, state, x, use_pallas=True)
    assert_allclose(np.asarray(f_jnp), np.asarray(f_pl), rtol=1e-4, atol=1e-4)


def test_student_param_count_matches_fig5(student):
    """Fig. 5 arithmetic: conv1 320 + bn1 64 + conv2 36,992 + bn2 256 +
    conv3 295,168 + conv4 16,400 + head 7,850."""
    cfg, params, state = student
    expect = (
        (3 * 3 * 1 * 32 + 32)
        + 2 * 32
        + (3 * 3 * 32 * 128 + 128)
        + 2 * 128
        + (3 * 3 * 128 * 256 + 256)
        + (2 * 2 * 256 * 16 + 16)
        + (784 * 10 + 10)
    )
    assert student_param_count(params) == expect


def test_bn_state_updates_only_in_training(student):
    cfg, params, state = student
    x = jnp.asarray(RNG.normal(size=(4, 32, 32, 1)).astype(np.float32))
    _, s_train = student_features(params, state, x, training=True)
    _, s_infer = student_features(params, state, x, training=False)
    assert not np.allclose(np.asarray(s_train["bn1"]["mean"]), np.asarray(state["bn1"]["mean"]))
    assert_allclose(np.asarray(s_infer["bn1"]["mean"]), np.asarray(state["bn1"]["mean"]))


def test_teacher_shapes(teacher):
    cfg, params, state = teacher
    x = jnp.asarray(RNG.normal(size=(2, 32, 32, 1)).astype(np.float32))
    logits, new_state = teacher_logits(params, state, x, cfg)
    assert logits.shape == (2, 10)
    assert set(new_state) == set(state)


def test_teacher_color_input():
    cfg = TeacherConfig(width=8)
    params, state = init_teacher(cfg, jax.random.PRNGKey(2), in_channels=3)
    x = jnp.asarray(RNG.normal(size=(2, 32, 32, 3)).astype(np.float32))
    logits, _ = teacher_logits(params, state, x, cfg)
    assert logits.shape == (2, 10)


def test_teacher_stage_downsampling(teacher):
    """Stages 1 and 2 halve spatial dims: 32 -> 16 -> 8 before GAP."""
    cfg, params, state = teacher
    # Probe by checking a projection conv exists exactly where widths change.
    assert "proj" in params["s1b0"] and "proj" in params["s2b0"]
    assert "proj" not in params["s0b0"]


def test_l2_penalty_positive_and_weight_only(teacher):
    cfg, params, state = teacher
    p = l2_penalty(params)
    assert float(p) > 0
    # Zeroing biases must not change the penalty.
    import jax.tree_util as jtu

    params2 = jtu.tree_map_with_path(
        lambda path, x: jnp.zeros_like(x) if path[-1].key == "b" else x, params
    )
    assert_allclose(float(l2_penalty(params2)), float(p), rtol=1e-6)


def test_student_grad_flows(student):
    cfg, params, state = student
    x = jnp.asarray(RNG.normal(size=(2, 32, 32, 1)).astype(np.float32))
    y = jnp.asarray(np.array([1, 3]))

    def loss(p):
        logits, _ = student_logits(p, state, x, training=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    g = jax.grad(loss)(params)
    norms = [float(jnp.abs(leaf).sum()) for leaf in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(norms)) and sum(norms) > 0

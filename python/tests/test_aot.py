"""AOT export path: HLO text emission and a micro end-to-end pipeline run."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import export_hlo, run_pipeline, to_hlo_text
from compile.config import PipelineConfig


def test_to_hlo_text_smoke(tmp_path):
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_export_hlo_writes_file(tmp_path):
    def fn(x):
        return (x * 2.0,)

    spec = jax.ShapeDtypeStruct((4,), jnp.float32)
    p = str(tmp_path / "mul.hlo.txt")
    n = export_hlo(fn, (spec,), p)
    assert n > 0 and os.path.getsize(p) == n


def test_pallas_kernel_lowers_to_hlo_text():
    """interpret=True Pallas must lower to plain HLO ops (no Mosaic custom
    calls) so the CPU PJRT client can execute the artifact."""
    from compile.kernels import match_feature_count

    q = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    t = jax.ShapeDtypeStruct((10, 64), jnp.float32)
    text = to_hlo_text(jax.jit(lambda a, b: (match_feature_count(a, b),)).lower(q, t))
    assert "custom-call" not in text.lower() or "Mosaic" not in text


@pytest.mark.slow
def test_micro_pipeline(tmp_path):
    """Full Section-II pipeline at micro scale: trains, prunes, quantises,
    generates templates, exports artifacts — the same driver `make artifacts`
    runs, shrunk to ~1 min."""
    cfg = PipelineConfig.fast()
    cfg.data.train_samples = 300
    cfg.data.test_samples = 100
    cfg.teacher.epochs = 1
    cfg.student.epochs = 1
    cfg.distill.epochs = 1
    cfg.prune.pruning_steps = 2
    cfg.prune.finetune_steps_per_prune = 3
    cfg.prune.final_finetune_epochs = 0
    cfg.quant.qat_epochs = 1
    cfg.export_batch_sizes = (1,)
    meta = run_pipeline(cfg, str(tmp_path))

    for f in (
        "student_fwd_b1.hlo.txt",
        "student_softmax_b1.hlo.txt",
        "student_binary_b1.hlo.txt",
        "match_fc_b1.hlo.txt",
        "match_sim_b1.hlo.txt",
        "teacher_fwd_b8.hlo.txt",
        "templates.json",
        "meta.json",
        "train_log.json",
    ):
        assert (tmp_path / f).exists(), f

    with open(tmp_path / "templates.json") as fh:
        tj = json.load(fh)
    assert tj["n_features"] == 784
    assert set(tj["stores"]) == {"1", "2", "3"}
    assert len(tj["stores"]["1"]["templates"]) == 10
    assert len(tj["stores"]["3"]["templates"]) == 30
    assert len(tj["thresholds"]) == 784

    t1 = meta["experiments"]["table1"]
    for row in ("teacher_color", "teacher_gray", "student_base", "student_opt"):
        assert 0.0 <= t1[row]["accuracy"] <= 1.0
    # The optimised student really is ~80% sparse.
    assert meta["macs"]["as_built"]["achieved_sparsity"] > 0.75
    # Multi-template sweep covers Table II.
    assert set(meta["experiments"]["table2_multi_template"]) == {1, 2, 3}

"""Pallas tiled matmul kernel — the MXU-shaped primitive under conv2d and the
softmax head.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks (M/BM, N/BN)
output tiles; for each, the innermost grid axis loops the K dimension in BK
slabs so a (BM, BK) x (BK, BN) product lands on the MXU systolic array with
all three operands resident in VMEM.  BlockSpec carries the HBM->VMEM
schedule that a CUDA implementation would express with threadblocks +
shared-memory staging.  Because the output index_map is invariant in the K
grid axis, the (BM, BN) output block stays VMEM-resident across the K loop
and serves as the accumulator (the canonical Pallas matmul pattern).

CPU note: lowered with ``interpret=True`` (Mosaic custom-calls cannot run on
the CPU PJRT plugin), so the structure — not interpret wallclock — is the
optimisation target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the 128x128 MXU tile / 8x128 VPU lane
# layout.  (BM, BK, BN) = (128, 128, 128) keeps the three VMEM-resident
# operands at 3 * 128*128*4 B = 192 KiB, far under the ~16 MiB VMEM budget,
# leaving headroom for the Mosaic compiler's double-buffered pipelining.
BM, BK, BN = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (BM, BN) output tile; grid axis 2 walks the K slabs."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def matmul(a: jnp.ndarray, b: jnp.ndarray, bm: int = BM, bk: int = BK, bn: int = BN):
    """f32 [M,K] x [K,N] -> [M,N] via the Pallas grid; pads to tile multiples
    and slices the result back, so arbitrary shapes are accepted.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = min(bm, max(m, 8)), min(bk, max(k, 8)), min(bn, max(n, 8))
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    mp, kp = ap.shape
    _, np_ = bp.shape
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]

//! RRAM-CMOS ACAM behavioural circuit simulator (Section III).
//!
//! Stands in for the paper's fabricated 180 nm TXL-ACAM (DESIGN.md
//! §Substitutions).  The simulator is organised the way the silicon is:
//!
//! * [`rram`] — the non-volatile resistive devices: programmable
//!   conductances with programming variability, read noise and drift;
//! * [`cell`] — the two published TXL pixels: the 6T4R *charging* cell
//!   (Fig. 4a, sparse-activation friendly) and the 3T1R *precharging* cell
//!   (Fig. 4b, area-optimised, differentiable thresholds).  Each cell holds
//!   a `[lo, hi]` matching window in its RRAM conductance pair(s);
//! * [`array`] — rows of cells sharing a matchline: explicit-timestep RC
//!   integration of the matchline voltage, sense-amplifier thresholding,
//!   per-search energy accounting (185 fJ/cell);
//! * [`wta`] — the analogue winner-take-all that computes Eq. 12's argmax
//!   in the analogue domain (one-hot output, offset noise);
//! * [`program`] — "program-once-read-many": maps a
//!   [`crate::templates::TemplateSet`] onto target conductances, then
//!   programs the array through the variability model.
//!
//! Fidelity contract (pinned by tests): with *ideal* devices the simulated
//! ACAM classification is identical to the digital Eq. 8/Eq. 12 reference in
//! [`crate::matching`]; with realistic variability the accuracy degrades
//! gracefully (the `acam_explore` example and the variability ablation bench
//! quantify this).

pub mod array;
pub mod cell;
pub mod program;
pub mod rram;
pub mod variability;
pub mod wta;

pub use array::{AcamArray, ArrayConfig, SearchOutput};
pub use cell::{AcamCell, CellKind};
pub use program::program_array;
pub use rram::RramDevice;
pub use variability::Variability;
pub use wta::winner_take_all;

/// Supply voltage of the 180 nm process the TXL-ACAM is designed in.
pub const VDD: f64 = 1.8;

/// Feature -> input-line voltage map: `V = V_OFF + f * V_GAIN`.
///
/// The offset keeps every representable window bound strictly positive — the
/// hybrid inverter threshold `VDD * g_up / (g_up + g_dn)` can only reach
/// `[VDD/(1 + G_MAX/G_MIN), VDD/(1 + G_MIN/G_MAX)] ~ [0.018, 1.78] V`, so a
/// zero-volt encoding of bit 0 would sit below the representable range.
/// With the offset, bit 0 -> 0.3 V and bit 1 -> 1.3 V, both comfortably
/// inside it.
pub const V_OFF: f64 = 0.3;
/// Gain of the feature -> voltage map (V per feature unit).
pub const V_GAIN: f64 = 1.0;

/// Encode a feature value (binary 0/1 or real-valued in [0, ~1]) as an input
/// line voltage.
pub fn feature_to_voltage(f: f32) -> f64 {
    V_OFF + (f as f64).clamp(-0.5, 1.5) * V_GAIN
}

//! `FastBackend` — the blocked, multithreaded interpreter fast-path.
//!
//! Same model, same numbers, different loop nest: every convolution is
//! lowered to an explicit `im2col` patch matrix and dispatched to a
//! cache-blocked matmul with an unroll-by-8 register-tile microkernel
//! ([`matmul_blocked`]), batch-norm arrives pre-folded into the conv
//! weights ([`super::interp::FoldedStudent`]), and all intermediate
//! tensors live in a per-worker [`Scratch`] arena so the hot loop performs
//! zero heap allocations after warm-up.
//!
//! Parallelism (dependency-free, `std::thread::scope`):
//!
//! * **batch sharding** — `extract_features` / `logits` split a server
//!   batch into contiguous image shards, one worker (and one `Scratch`)
//!   per shard;
//! * **row-band matmul** — for single-image requests the microkernel
//!   splits the im2col row dimension (output pixels) into bands instead.
//!
//! Both schemes assign every output element to exactly one worker and
//! never reduce across threads, so results are **bitwise identical for
//! every thread count** — `threads = 1` (the deterministic serial path
//! the config guarantees) is a scheduling special case, not a different
//! numeric path.  The scalar [`super::interp::InterpBackend`] remains the
//! oracle; `rust/tests/kernels_fast.rs` property-tests this module
//! against it across randomized shapes.

use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::runtime::meta::Meta;

use super::interp::{load_student_params, FoldedStudent, StudentParams};
use super::kernels::Padding;
use super::FrontEnd;

/// Microkernel register-tile rows (im2col patch rows per tile).
const MR: usize = 8;
/// Microkernel unroll width: 8 output channels accumulated per row, in
/// registers (one 256-bit lane of f32, two SSE lanes on baseline x86-64).
const NR: usize = 8;
/// K-dimension cache block: `KC * NR` floats of the B panel (~8 KiB) stay
/// L1-resident across an MR-row sweep.
const KC: usize = 256;

/// Lower one `[h, w, cin]` image into its `[ho * wo, kh * kw * cin]` patch
/// matrix (row-major), reusing `out`'s allocation.  Out-of-bounds taps stay
/// zero, reproducing [`super::kernels::conv2d`]'s padding arithmetic
/// (asymmetric SAME split for even kernels).  Returns `(ho, wo)`.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    padding: Padding,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    debug_assert_eq!(x.len(), h * w * cin);
    let (ho, wo, ph, pw) = match padding {
        Padding::Same => (h, w, (kh - 1) / 2, (kw - 1) / 2),
        Padding::Valid => (h - kh + 1, w - kw + 1, 0, 0),
    };
    let k = kh * kw * cin;
    out.clear();
    out.resize(ho * wo * k, 0.0);
    for oy in 0..ho {
        for dy in 0..kh {
            let iy = oy as isize + dy as isize - ph as isize;
            if iy < 0 || iy >= h as isize {
                continue; // padded row: the resize above left zeros
            }
            let x_row = &x[iy as usize * w * cin..(iy as usize + 1) * w * cin];
            for ox in 0..wo {
                let patch = (oy * wo + ox) * k + dy * kw * cin;
                let ix0 = ox as isize - pw as isize;
                if ix0 >= 0 && ix0 as usize + kw <= w {
                    // Fully interior along x: one contiguous kw*cin copy.
                    let src = ix0 as usize * cin;
                    out[patch..patch + kw * cin].copy_from_slice(&x_row[src..src + kw * cin]);
                } else {
                    for dx in 0..kw {
                        let ix = ix0 + dx as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ix as usize * cin;
                        out[patch + dx * cin..patch + (dx + 1) * cin]
                            .copy_from_slice(&x_row[src..src + cin]);
                    }
                }
            }
        }
    }
    (ho, wo)
}

/// Full MRxNR register tile: accumulators live in `acc` (which LLVM keeps
/// in vector registers), each k-step costs one contiguous NR-wide B load
/// plus MR broadcast-FMAs — the memory-traffic win over the naive conv
/// loop, whose accumulator row round-trips through cache every k-step.
#[inline]
#[allow(clippy::too_many_arguments)]
fn tile_full(
    a: &[f32],
    i0: usize,
    lda: usize,
    k0: usize,
    kc: usize,
    b: &[f32],
    j0: usize,
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    let mut rows: [&[f32]; MR] = [&[]; MR];
    for (i, r) in rows.iter_mut().enumerate() {
        *r = &a[(i0 + i) * lda + k0..(i0 + i) * lda + k0 + kc];
    }
    let mut acc = [[0f32; NR]; MR];
    for kk in 0..kc {
        let bb: [f32; NR] = b[(k0 + kk) * ldb + j0..(k0 + kk) * ldb + j0 + NR]
            .try_into()
            .unwrap();
        for (r, row) in rows.iter().zip(acc.iter_mut()) {
            let av = r[kk];
            for (o, &bv) in row.iter_mut().zip(bb.iter()) {
                *o += av * bv;
            }
        }
    }
    for (i, row) in acc.iter().enumerate() {
        let dst = &mut out[(i0 + i) * ldo + j0..(i0 + i) * ldo + j0 + NR];
        for (o, &v) in dst.iter_mut().zip(row.iter()) {
            *o += v;
        }
    }
}

/// Edge tile with dynamic `rows x cols` extent (plain loops; by
/// construction this covers < MR rows or < NR columns, so its cost is
/// marginal).  Each output element uses the same arithmetic as
/// [`tile_full`] — a fresh accumulator per KC block, summed over `kk` in
/// order, added to `out` once — so an element produces identical bits
/// whether band splitting lands it in a full or an edge tile.
#[allow(clippy::too_many_arguments)]
fn tile_edge(
    a: &[f32],
    i0: usize,
    rows: usize,
    lda: usize,
    k0: usize,
    kc: usize,
    b: &[f32],
    j0: usize,
    cols: usize,
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    for i in 0..rows {
        let ar = &a[(i0 + i) * lda + k0..(i0 + i) * lda + k0 + kc];
        let dst = &mut out[(i0 + i) * ldo + j0..(i0 + i) * ldo + j0 + cols];
        for (j, o) in dst.iter_mut().enumerate() {
            let mut acc = 0f32;
            for (kk, &av) in ar.iter().enumerate() {
                acc += av * b[(k0 + kk) * ldb + j0 + j];
            }
            *o += acc;
        }
    }
}

/// One serial k-blocked band: `out[0..rows] += a[0..rows] x b`.
fn matmul_band(a: &[f32], rows: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    let mut k0 = 0;
    while k0 < k {
        let kc = KC.min(k - k0);
        let mut i = 0;
        while i + MR <= rows {
            let mut j = 0;
            while j + NR <= n {
                tile_full(a, i, k, k0, kc, b, j, n, out, n);
                j += NR;
            }
            if j < n {
                tile_edge(a, i, MR, k, k0, kc, b, j, n - j, n, out, n);
            }
            i += MR;
        }
        if i < rows {
            tile_edge(a, i, rows - i, k, k0, kc, b, 0, n, n, out, n);
        }
        k0 += kc;
    }
}

/// Cache-blocked matmul `out = a [m, k] x b [k, n]` (row-major), with the
/// row dimension split into bands across `threads` scoped workers.  Band
/// assignment never changes an element's accumulation order, so the result
/// is bitwise independent of `threads`.
pub fn matmul_blocked(
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // A band under 2 row-tiles is not worth a thread spawn.
    let threads = threads.clamp(1, m.div_ceil(2 * MR).max(1));
    if threads == 1 {
        matmul_band(a, m, k, b, n, out);
        return;
    }
    let band = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, out_band) in out.chunks_mut(band * n).enumerate() {
            let rows = out_band.len() / n;
            scope.spawn(move || matmul_band_shifted(a, t * band, rows, k, b, n, out_band));
        }
    });
}

/// Like [`matmul_band`] but writing into a band-local `out` slice whose row
/// 0 corresponds to global row `i0` of `a`.
fn matmul_band_shifted(
    a: &[f32],
    i0: usize,
    rows: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
) {
    // Re-slice `a` so band row r lives at a[r * k..]: the tile kernels can
    // then treat the band as a standalone matmul.
    matmul_band(&a[i0 * k..], rows, k, b, n, out);
}

/// Add the per-channel bias and (optionally) apply ReLU in one pass.
fn bias_relu(out: &mut [f32], cout: usize, bias: &[f32], relu: bool) {
    for row in out.chunks_exact_mut(cout) {
        for (o, &b) in row.iter_mut().zip(bias.iter()) {
            let v = *o + b;
            *o = if relu && v < 0.0 { 0.0 } else { v };
        }
    }
}

/// 2x2 stride-2 max pool into a reused buffer (even `h`, `w`).
fn maxpool2_into(x: &[f32], h: usize, w: usize, c: usize, out: &mut Vec<f32>) -> (usize, usize) {
    debug_assert!(h % 2 == 0 && w % 2 == 0, "maxpool2 needs even h, w");
    let (ho, wo) = (h / 2, w / 2);
    out.clear();
    out.resize(ho * wo * c, 0.0);
    for oy in 0..ho {
        let top = &x[(2 * oy) * w * c..(2 * oy + 1) * w * c];
        let bot = &x[(2 * oy + 1) * w * c..(2 * oy + 2) * w * c];
        let orow = &mut out[oy * wo * c..(oy + 1) * wo * c];
        for ox in 0..wo {
            for ch in 0..c {
                let i = (2 * ox) * c + ch;
                let m = top[i].max(top[i + c]).max(bot[i]).max(bot[i + c]);
                orow[ox * c + ch] = m;
            }
        }
    }
    (ho, wo)
}

/// Per-worker scratch arena: im2col patches plus two ping-pong activation
/// buffers.  All `Vec`s keep their capacity across requests, so steady-state
/// inference allocates nothing.
#[derive(Default)]
pub struct Scratch {
    patches: Vec<f32>,
    a: Vec<f32>,
    b: Vec<f32>,
}

/// conv -> bias -> ReLU via im2col + blocked matmul, into a reused buffer.
#[allow(clippy::too_many_arguments)]
fn conv_fast(
    x: &[f32],
    h: usize,
    w: usize,
    layer: &super::interp::Conv,
    pad: Padding,
    threads: usize,
    patches: &mut Vec<f32>,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let (ho, wo) = im2col(x, h, w, layer.cin, layer.kh, layer.kw, pad, patches);
    out.clear();
    out.resize(ho * wo * layer.cout, 0.0);
    conv_matmul(patches, ho * wo, layer, threads, out);
    (ho, wo)
}

/// The matmul half of a conv: HWIO weights flattened row-major are exactly
/// the `[kh * kw * cin, cout]` B matrix, so no repacking is needed.
fn conv_matmul(
    patches: &[f32],
    m: usize,
    layer: &super::interp::Conv,
    threads: usize,
    out: &mut [f32],
) {
    let k = layer.kh * layer.kw * layer.cin;
    matmul_blocked(patches, m, k, &layer.w, layer.cout, threads, out);
    bias_relu(out, layer.cout, &layer.b, true);
}

/// One full forward pass; `inner_threads` drives row-band matmul
/// parallelism (1 when the caller already shards at batch level).
fn forward_one(
    p: &FoldedStudent,
    image_size: usize,
    inner_threads: usize,
    sc: &mut Scratch,
    img: &[f32],
    out: &mut [f32],
) {
    let s = image_size;
    let Scratch { patches, a, b } = sc;
    let (hh, ww) = conv_fast(img, s, s, &p.conv1, Padding::Same, inner_threads, patches, a);
    let (hh, ww) = maxpool2_into(a, hh, ww, p.conv1.cout, b);
    let (hh, ww) = conv_fast(b, hh, ww, &p.conv2, Padding::Same, inner_threads, patches, a);
    let (hh, ww) = maxpool2_into(a, hh, ww, p.conv2.cout, b);
    let (hh, ww) = conv_fast(b, hh, ww, &p.conv3, Padding::Same, inner_threads, patches, a);
    // conv4 (VALID) writes its ho*wo*cout output — exactly the feature
    // row — straight into the caller's output slice.
    let (ho, wo) = im2col(a, hh, ww, p.conv4.cin, p.conv4.kh, p.conv4.kw, Padding::Valid, patches);
    debug_assert_eq!(out.len(), ho * wo * p.conv4.cout);
    conv_matmul(patches, ho * wo, &p.conv4, inner_threads, out);
}

/// The blocked + threaded interpreter engine (`--engine interp-fast`).
pub struct FastBackend {
    folded: FoldedStudent,
    image_size: usize,
    n_features: usize,
    threads: usize,
    scratch: Vec<Scratch>,
}

impl FastBackend {
    /// Same weight resolution as [`super::interp::InterpBackend::new`];
    /// `threads` comes from [`ServeConfig::resolve_threads`].
    pub fn new(cfg: &ServeConfig, meta: &Meta) -> Result<FastBackend> {
        let backend = Self::from_params(
            load_student_params(cfg, meta)?,
            meta.artifacts.image_size,
            cfg.resolve_threads(),
        );
        if backend.n_features != meta.artifacts.n_features {
            return Err(Error::Artifact(format!(
                "interp-fast front-end produces {} features, meta.json says {}",
                backend.n_features, meta.artifacts.n_features
            )));
        }
        Ok(backend)
    }

    /// Build directly from a parameter set (benches and tests).
    pub fn from_params(params: StudentParams, image_size: usize, threads: usize) -> FastBackend {
        let folded = FoldedStudent::from_params(&params);
        let n_features = folded.feature_len(image_size);
        FastBackend {
            folded,
            image_size,
            n_features,
            threads: threads.max(1),
            scratch: Vec::new(),
        }
    }
}

impl FrontEnd for FastBackend {
    fn name(&self) -> &'static str {
        "interp-fast"
    }

    fn extract_features(&mut self, images: &[f32], n: usize) -> Result<Vec<f32>> {
        let img_len = self.image_size * self.image_size;
        if images.len() != n * img_len {
            return Err(Error::Request(format!(
                "batch buffer has {} floats, expected {} ({n} images)",
                images.len(),
                n * img_len
            )));
        }
        let nf = self.n_features;
        let mut out = vec![0f32; n * nf];
        // Shard the batch across workers; a lone image instead threads the
        // matmul row bands (inside forward_one).
        let workers = if n == 0 { 1 } else { self.threads.min(n) };
        while self.scratch.len() < workers {
            self.scratch.push(Scratch::default());
        }
        let (folded, size) = (&self.folded, self.image_size);
        if workers == 1 {
            let inner = self.threads;
            let sc = &mut self.scratch[0];
            for (img, o) in images.chunks_exact(img_len).zip(out.chunks_exact_mut(nf)) {
                forward_one(folded, size, inner, sc, img, o);
            }
        } else {
            let shard = n.div_ceil(workers);
            // Leftover thread budget (threads > n) goes to row-band matmul
            // parallelism inside each shard; still bitwise invariant.
            let inner = (self.threads / workers).max(1);
            std::thread::scope(|scope| {
                for ((imgs, outs), sc) in images
                    .chunks(shard * img_len)
                    .zip(out.chunks_mut(shard * nf))
                    .zip(self.scratch.iter_mut())
                {
                    scope.spawn(move || {
                        for (img, o) in imgs.chunks_exact(img_len).zip(outs.chunks_exact_mut(nf)) {
                            forward_one(folded, size, inner, sc, img, o);
                        }
                    });
                }
            });
        }
        Ok(out)
    }

    fn logits(&mut self, images: &[f32], n: usize, num_classes: usize) -> Result<Vec<f32>> {
        let feats = self.extract_features(images, n)?;
        let head = self.folded.head.as_ref().ok_or_else(|| {
            Error::Artifact(
                "softmax head unavailable (feature-extractor-only parameter set)".into(),
            )
        })?;
        if head.dout != num_classes {
            return Err(Error::Config(format!(
                "head emits {} classes, pipeline expects {num_classes}",
                head.dout
            )));
        }
        if head.din != self.n_features {
            return Err(Error::Artifact(format!(
                "head expects {} features, front-end produces {}",
                head.din, self.n_features
            )));
        }
        let mut out = vec![0f32; n * head.dout];
        matmul_blocked(&feats, n, head.din, &head.w, head.dout, self.threads, &mut out);
        bias_relu(&mut out, head.dout, &head.b, false);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::kernels;
    use super::*;

    fn seq(n: usize, scale: f64, off: f64) -> Vec<f32> {
        (0..n).map(|i| (i as f64 * scale + off) as f32).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len(), "length mismatch");
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() <= tol + tol * w.abs(),
                "element {i}: got {g}, want {w}"
            );
        }
    }

    #[test]
    fn matmul_blocked_matches_scalar_matmul() {
        for &(m, k, n, threads) in
            &[(1, 1, 1, 1), (9, 17, 23, 1), (16, 32, 8, 2), (65, 300, 19, 3)]
        {
            let a = seq(m * k, 0.01, -0.7);
            let b = seq(k * n, 0.02, -0.9);
            let want = kernels::matmul(&a, m, k, &b, n);
            let mut got = vec![0f32; m * n];
            matmul_blocked(&a, m, k, &b, n, threads, &mut got);
            assert_close(&got, &want, 1e-5);
        }
    }

    #[test]
    fn matmul_blocked_is_thread_count_invariant() {
        // k > KC exercises multi-block accumulation: band splitting moves
        // rows between full and edge tiles, which must not change any
        // element's rounding (tile_edge mirrors tile_full's block sums).
        for &(m, k, n) in &[(33usize, 130usize, 21usize), (41, 600, 13)] {
            let a = seq(m * k, 0.013, -0.4);
            let b = seq(k * n, 0.007, -0.2);
            let mut one = vec![0f32; m * n];
            let mut four = vec![0f32; m * n];
            matmul_blocked(&a, m, k, &b, n, 1, &mut one);
            matmul_blocked(&a, m, k, &b, n, 4, &mut four);
            assert_eq!(one, four, "threading must be bitwise invisible (m={m})");
        }
    }

    #[test]
    fn im2col_reproduces_conv_via_matmul() {
        // conv2d == im2col x flattened-HWIO for both paddings.
        let (h, w, cin, kh, kw, cout) = (5, 6, 3, 3, 2, 4);
        let x = seq(h * w * cin, 0.03, -1.0);
        let wt = seq(kh * kw * cin * cout, 0.02, -0.5);
        let bias = seq(cout, 0.1, -0.2);
        for pad in [Padding::Same, Padding::Valid] {
            let (want, ho, wo) = kernels::conv2d(&x, h, w, cin, &wt, kh, kw, cout, &bias, pad);
            let mut patches = Vec::new();
            let (gho, gwo) = im2col(&x, h, w, cin, kh, kw, pad, &mut patches);
            assert_eq!((gho, gwo), (ho, wo));
            let mut got = vec![0f32; ho * wo * cout];
            matmul_blocked(&patches, ho * wo, kh * kw * cin, &wt, cout, 1, &mut got);
            for (row, b) in got.chunks_exact_mut(cout).zip(std::iter::repeat(&bias)) {
                for (o, &bv) in row.iter_mut().zip(b.iter()) {
                    *o += bv;
                }
            }
            assert_close(&got, &want, 1e-5);
        }
    }

    #[test]
    fn maxpool_into_matches_kernel() {
        let x = seq(8 * 6 * 3, 0.05, -0.6);
        let (want, ho, wo) = kernels::maxpool2(&x, 8, 6, 3);
        let mut got = Vec::new();
        let (gho, gwo) = maxpool2_into(&x, 8, 6, 3, &mut got);
        assert_eq!((gho, gwo), (ho, wo));
        assert_eq!(got, want);
    }

    #[test]
    fn fast_backend_matches_scalar_interp() {
        let params = StudentParams::synthetic(11);
        let mut scalar = super::super::interp::InterpBackend::from_params(params.clone(), 32);
        let mut fast = FastBackend::from_params(params, 32, 2);
        let img = seq(32 * 32, 0.002, -1.0);
        let want = scalar.extract_features(&img, 1).unwrap();
        let got = fast.extract_features(&img, 1).unwrap();
        assert_close(&got, &want, 1e-5);
    }
}

//! Raw-binary request encoding (`application/x-hec-f32`) — pixels as
//! little-endian f32, no JSON number parsing on the bulk of the body.
//!
//! The JSON path spends nearly all of its time lexing pixel numbers; an edge
//! client that already holds f32 pixels can skip that entirely.  The framing
//! is length-prefixed throughout so the decoder never scans:
//!
//! ```text
//! header:   "HECB"  u8 version=1  u32 count          (little-endian u32s)
//! per item: u32 meta_len   meta_len bytes of JSON metadata (may be 0)
//!           u32 image_len  image_len * 4 bytes of f32 LE pixels
//! ```
//!
//! The metadata object carries the non-pixel request fields (`top_k`,
//! `backend`, `return_features`, `request_id`) with exactly the JSON
//! request's semantics; `meta_len == 0` means all defaults, and an `image`
//! key inside the meta is rejected.  Responses are the ordinary JSON
//! [`ClassifyResponse`] — identical, byte for byte, to what the same pixels
//! submitted as JSON produce (f32 → f64 → shortest-decimal JSON → f64 → f32
//! round-trips exactly, so both paths feed the pipeline the same bits).
//!
//! Error model: *framing* errors (bad magic/version, truncation, trailing
//! bytes) fail the whole call with `MALFORMED_REQUEST`; *meta* errors are
//! per-item — the length prefixes let the decoder resynchronise to the next
//! item, which the JSON path cannot do after a syntax error.

use super::{stream, ApiError, ClassifyRequest, ErrorCode};
use crate::jsonlite::Value;
use std::collections::BTreeMap;

/// The content type the gateway dispatches on.
pub const CONTENT_TYPE: &str = "application/x-hec-f32";

/// Frame magic (first four body bytes).
pub const MAGIC: [u8; 4] = *b"HECB";

/// Current frame version.
pub const VERSION: u8 = 1;

fn malformed(msg: impl Into<String>) -> ApiError {
    ApiError::new(ErrorCode::MalformedRequest, msg)
}

/// Encode a batch of requests into one frame (test clients, the CLI
/// driver, and SDK examples; the decode side is the hot path).
pub fn encode_batch(reqs: &[ClassifyRequest]) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        9 + reqs.iter().map(|r| 8 + 4 * r.image.len() + 64).sum::<usize>(),
    );
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(reqs.len() as u32).to_le_bytes());
    for req in reqs {
        let meta = encode_meta(req);
        out.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        out.extend_from_slice(meta.as_bytes());
        out.extend_from_slice(&(req.image.len() as u32).to_le_bytes());
        for &p in &req.image {
            out.extend_from_slice(&p.to_le_bytes());
        }
    }
    out
}

/// The meta JSON for one request: the non-pixel fields, or `""` (length 0
/// on the wire) when everything is at its default.
fn encode_meta(req: &ClassifyRequest) -> String {
    if req.top_k == 1
        && req.backend.is_none()
        && !req.return_features
        && req.request_id.is_none()
        && req.deadline_ms.is_none()
    {
        return String::new();
    }
    let mut m = BTreeMap::new();
    m.insert("top_k".to_string(), Value::Num(req.top_k as f64));
    if let Some(b) = req.backend {
        m.insert("backend".to_string(), Value::Str(b.name().to_string()));
    }
    if req.return_features {
        m.insert("return_features".to_string(), Value::Bool(true));
    }
    if let Some(id) = &req.request_id {
        m.insert("request_id".to_string(), Value::Str(id.clone()));
    }
    if let Some(d) = req.deadline_ms {
        m.insert("deadline_ms".to_string(), Value::Num(d as f64));
    }
    Value::Obj(m).to_json()
}

/// Decode a frame, handing each item to `submit` as soon as it is decoded
/// (the binary twin of [`stream::decode_batch_envelope`]'s pipelining).
/// Per-item meta failures go to `submit` as `Err`; framing failures abort
/// the whole call.
pub fn decode_batch_with<P>(
    body: &[u8],
    mut submit: impl FnMut(Result<ClassifyRequest, ApiError>) -> P,
) -> Result<Vec<P>, ApiError> {
    let mut r = FrameReader { body, pos: 0 };
    let magic = r.take(4)?;
    if magic != MAGIC {
        return Err(malformed("bad magic (expected 'HECB')"));
    }
    let version = r.take(1)?[0];
    if version != VERSION {
        return Err(malformed(format!("unsupported binary version {version}")));
    }
    let count = r.u32()?;
    let mut out = Vec::with_capacity(count.min(1024) as usize);
    for _ in 0..count {
        let meta_len = r.u32()? as usize;
        let meta = r.take(meta_len)?;
        let image_len = r.u32()? as usize;
        let pixels = r.take(image_len.checked_mul(4).ok_or_else(|| {
            malformed("binary body truncated")
        })?)?;
        let item = decode_item(meta, pixels);
        out.push(submit(item));
    }
    if r.pos != body.len() {
        return Err(malformed("trailing bytes after last item"));
    }
    Ok(out)
}

/// Decode a frame into per-item results (no submission pipelining).
pub fn decode_batch(body: &[u8]) -> Result<Vec<Result<ClassifyRequest, ApiError>>, ApiError> {
    decode_batch_with(body, |r| r)
}

/// Decode a single-request frame (`POST /v1/classify` with the binary
/// content type): the frame must contain exactly one item.
pub fn decode_single(body: &[u8]) -> Result<ClassifyRequest, ApiError> {
    let mut items = decode_batch(body)?;
    if items.len() != 1 {
        return Err(ApiError::new(
            ErrorCode::InvalidArgument,
            format!(
                "binary body must contain exactly 1 item for /v1/classify (got {})",
                items.len()
            ),
        ));
    }
    items.pop().unwrap()
}

fn decode_item(meta: &[u8], pixels: &[u8]) -> Result<ClassifyRequest, ApiError> {
    let mut req = if meta.is_empty() {
        ClassifyRequest::new(Vec::new())
    } else {
        let text = std::str::from_utf8(meta).map_err(|_| malformed("meta is not UTF-8"))?;
        stream::decode_meta(text)?
    };
    req.image = pixels
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(req)
}

/// Bounds-checked cursor over the frame; any read past the end is the
/// stable whole-call truncation error.
struct FrameReader<'a> {
    body: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ApiError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.body.len())
            .ok_or_else(|| malformed("binary body truncated"))?;
        let s = &self.body[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, ApiError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Backend;

    fn sample() -> ClassifyRequest {
        let mut req = ClassifyRequest::new(vec![0.5, -1.25, 3.0e-3, f32::MIN_POSITIVE]);
        req.top_k = 3;
        req.backend = Some(Backend::Similarity);
        req.return_features = true;
        req.request_id = Some("bin-7".into());
        req
    }

    #[test]
    fn roundtrip_batch() {
        let reqs = vec![sample(), ClassifyRequest::new(vec![1.0, 2.0])];
        let body = encode_batch(&reqs);
        let back = decode_batch(&body).unwrap();
        assert_eq!(back.len(), 2);
        for (orig, got) in reqs.iter().zip(&back) {
            let got = got.as_ref().unwrap();
            let ob: Vec<u32> = orig.image.iter().map(|p| p.to_bits()).collect();
            let gb: Vec<u32> = got.image.iter().map(|p| p.to_bits()).collect();
            assert_eq!(ob, gb);
            assert_eq!(orig.top_k, got.top_k);
            assert_eq!(orig.backend, got.backend);
            assert_eq!(orig.return_features, got.return_features);
            assert_eq!(orig.request_id, got.request_id);
        }
    }

    #[test]
    fn default_request_has_empty_meta() {
        let req = ClassifyRequest::new(vec![1.0]);
        let body = encode_batch(std::slice::from_ref(&req));
        // header(9) + meta_len(4) + 0 meta + image_len(4) + 4 pixel bytes
        assert_eq!(body.len(), 9 + 4 + 4 + 4);
        let back = decode_single(&body).unwrap();
        assert_eq!(back.image, vec![1.0]);
        assert_eq!(back.top_k, 1);
        assert!(back.backend.is_none());
    }

    #[test]
    fn framing_errors_are_whole_call() {
        // Too short / bad magic / bad version.
        assert_eq!(
            decode_batch(b"HEC").unwrap_err().code,
            ErrorCode::MalformedRequest
        );
        let mut body = encode_batch(&[ClassifyRequest::new(vec![1.0])]);
        body[0] = b'X';
        let e = decode_batch(&body).unwrap_err();
        assert!(e.message.contains("magic"), "{e}");
        let mut body = encode_batch(&[ClassifyRequest::new(vec![1.0])]);
        body[4] = 9;
        let e = decode_batch(&body).unwrap_err();
        assert!(e.message.contains("version"), "{e}");
        // Truncations at every prefix length fail cleanly.
        let body = encode_batch(&[sample()]);
        for cut in 0..body.len() {
            let e = decode_batch(&body[..cut]).unwrap_err();
            assert_eq!(e.code, ErrorCode::MalformedRequest, "cut at {cut}");
        }
        // Trailing bytes.
        let mut body = encode_batch(&[ClassifyRequest::new(vec![1.0])]);
        body.push(0);
        let e = decode_batch(&body).unwrap_err();
        assert!(e.message.contains("trailing"), "{e}");
    }

    #[test]
    fn meta_errors_are_per_item() {
        // Item 0: bad meta JSON; item 1: fine.  The call succeeds with a
        // per-item error.
        let good = ClassifyRequest::new(vec![2.0]);
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC);
        body.push(VERSION);
        body.extend_from_slice(&2u32.to_le_bytes());
        let meta = b"{not json";
        body.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        body.extend_from_slice(meta);
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1.5f32.to_le_bytes());
        let one = encode_batch(std::slice::from_ref(&good));
        body.extend_from_slice(&one[9..]);
        let items = decode_batch(&body).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].as_ref().unwrap_err().code, ErrorCode::MalformedRequest);
        assert_eq!(items[1].as_ref().unwrap().image, vec![2.0]);
    }

    #[test]
    fn image_key_forbidden_in_meta() {
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC);
        body.push(VERSION);
        body.extend_from_slice(&1u32.to_le_bytes());
        let meta = br#"{"image": [1, 2]}"#;
        body.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        body.extend_from_slice(meta);
        body.extend_from_slice(&0u32.to_le_bytes());
        let e = decode_single(&body).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidArgument);
        assert!(e.message.contains("image"), "{e}");
    }

    #[test]
    fn single_requires_exactly_one() {
        let body = encode_batch(&[
            ClassifyRequest::new(vec![1.0]),
            ClassifyRequest::new(vec![2.0]),
        ]);
        let e = decode_single(&body).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidArgument);
        assert!(e.message.contains("exactly 1"), "{e}");
        let empty = encode_batch(&[]);
        assert!(decode_single(&empty).is_err());
    }
}

//! Multi-tenant template-store registry with atomic hot-swap and online
//! re-fit.
//!
//! The paper's energy asymmetry (96.23 nJ front-end vs 1.45 nJ back-end,
//! re-programming at ~80 pJ/cell, Section IV) means a deployed device can
//! cheaply carry *many* template sets and retarget the ACAM back-end per
//! workload.  This module is the control plane for that versatility:
//!
//! * [`StoreRegistry`] — versioned, immutable [`TemplateStore`] snapshots
//!   (id + monotonically increasing version).  Shards observe publishes via
//!   a single atomic epoch load per batch ([`StoreRegistry::epoch`]); the
//!   registry mutex is only taken on publish and on the (per-epoch-change)
//!   snapshot read, never per request.  In-flight batches finish on the old
//!   version, the next batch sees the new one — the swap barrier is pinned
//!   deterministically by the Gate harness in `rust/tests/store.rs`.
//! * Per-tenant stores keyed off the existing `request_id` routing seam
//!   (`"tenant/rest"` prefix), with concurrent-in-flight quotas
//!   ([`TenantState::admit`], `QUOTA_EXCEEDED`) and served/rejected
//!   counters surfaced as `hec_tenant_*` metrics.
//! * Online re-fit ([`StoreAdmin::refit`]) — builds a candidate store from
//!   fresh labelled probes via the existing k-means template builder,
//!   verifies it against the deployment's active
//!   [`crate::backend::MatchingBackend`] variant at the ideal device
//!   corner (bit-identical to the old digital check for the default `acam`
//!   variant), and publishes it through the same swap path.  Adoption
//!   charges the variant's re-programming energy (80 pJ/cell for the ACAM
//!   pixels, 40 pJ/cell for RBF synapses, 0 for the digital matcher) per
//!   back-end unit actually re-programmed.
//!
//! Version 0 marks the bootstrap store each shard builds for itself at
//! startup; until something is published (version >= 1) or tenants are
//! configured, the registry is inert and serving is byte-identical to a
//! registry-free build ([`StoreRegistry::advertises`]).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::api::{ApiError, ErrorCode};
use crate::config::{ServeConfig, TenantSpec};
use crate::coordinator::pipeline::BOOTSTRAP_DATA_SEED;
use crate::coordinator::shard::fnv1a;
use crate::energy::EnergyModel;
use crate::jsonlite::Value;
use crate::runtime::Meta;
use crate::templates::TemplateStore;
use crate::{Error, Result};

/// Store id charset: `[A-Za-z0-9_-]+`, non-empty.  Keeps ids safe for URL
/// path segments, Prometheus label values, and `<id>.json` filenames.
pub fn valid_store_id(id: &str) -> bool {
    !id.is_empty()
        && id.len() <= 64
        && id
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-')
}

/// The registry entry for the default single-store serving path.
pub const DEFAULT_STORE_ID: &str = "default";

/// An immutable view of one registry entry at a point in time.
///
/// `store` is `None` at version 0: the bootstrap marker.  Each shard keeps
/// serving the store it built for itself at startup, so the pre-registry
/// byte-for-byte behaviour is preserved; shards converge on a shared
/// snapshot only after an explicit publish.
#[derive(Clone)]
pub struct StoreSnapshot {
    pub id: Arc<str>,
    pub version: u64,
    /// Where this version came from: `"bootstrap"`, `"dir"`, `"put"`,
    /// `"refit"`.
    pub origin: &'static str,
    pub store: Option<Arc<TemplateStore>>,
}

impl StoreSnapshot {
    /// Admin-API JSON form (`GET /v1/stores/{id}`).
    pub fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Value::Str(self.id.to_string()));
        m.insert("version".to_string(), Value::Num(self.version as f64));
        m.insert("origin".to_string(), Value::Str(self.origin.to_string()));
        m.insert("resident".to_string(), Value::Bool(self.store.is_some()));
        if let Some(s) = &self.store {
            m.insert("num_classes".to_string(), Value::Num(s.num_classes as f64));
            m.insert("n_features".to_string(), Value::Num(s.n_features as f64));
            let templates: usize = s.sets.values().map(|t| t.num_templates()).sum();
            m.insert("templates".to_string(), Value::Num(templates as f64));
        }
        Value::Obj(m)
    }
}

struct StoreEntry {
    version: u64,
    origin: &'static str,
    store: Option<Arc<TemplateStore>>,
}

/// Per-tenant admission state.  `quota` bounds *concurrent in-flight*
/// requests (0 = unlimited); `served`/`rejected` are lifetime counters
/// surfaced on `/metrics`.
pub struct TenantState {
    pub name: String,
    pub store_id: Arc<str>,
    pub quota: u64,
    in_flight: AtomicU64,
    served: AtomicU64,
    rejected: AtomicU64,
}

impl TenantState {
    fn new(spec: &TenantSpec) -> Arc<Self> {
        Arc::new(TenantState {
            name: spec.name.clone(),
            store_id: Arc::from(spec.store.as_str()),
            quota: spec.quota,
            in_flight: AtomicU64::new(0),
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        })
    }

    /// Admit one request under the quota, or reject with `QUOTA_EXCEEDED`.
    ///
    /// The returned [`TenantTicket`] decrements `in_flight` on drop, so the
    /// gauge stays drift-free across delivery, expiry, panic-drain, and
    /// queue-full rollback alike.
    pub fn admit(self: &Arc<Self>) -> std::result::Result<TenantTicket, ApiError> {
        loop {
            let cur = self.in_flight.load(Ordering::Acquire);
            if self.quota > 0 && cur >= self.quota {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ApiError::new(
                    ErrorCode::QuotaExceeded,
                    format!(
                        "tenant '{}' quota exceeded ({} in flight, quota {})",
                        self.name, cur, self.quota
                    ),
                ));
            }
            if self
                .in_flight
                .compare_exchange(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Ok(TenantTicket(Arc::clone(self)));
            }
        }
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Acquire)
    }
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }
}

/// RAII admission ticket: holds one `in_flight` slot for its tenant.
pub struct TenantTicket(Arc<TenantState>);

impl TenantTicket {
    /// The store id this tenant is pinned to.
    pub fn store_id(&self) -> &Arc<str> {
        &self.0.store_id
    }
    pub fn tenant_name(&self) -> &str {
        &self.0.name
    }
    /// Count one successfully delivered response for this tenant.
    pub fn mark_served(&self) {
        self.0.served.fetch_add(1, Ordering::Relaxed);
    }
}

impl Drop for TenantTicket {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl std::fmt::Debug for TenantTicket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TenantTicket({})", self.0.name)
    }
}

/// Versioned template-store registry shared by every shard and the gateway
/// admin surface.
pub struct StoreRegistry {
    /// Bumped on every publish; shards compare against their cached value
    /// once per batch — the entire hot-path cost of the registry.
    epoch: AtomicU64,
    swaps: AtomicU64,
    advertise: AtomicBool,
    inner: Mutex<BTreeMap<Arc<str>, StoreEntry>>,
    tenants: Vec<Arc<TenantState>>,
    num_classes: usize,
    n_features: usize,
    /// `templates_per_class` — every published store must carry this set.
    k: usize,
}

impl StoreRegistry {
    /// Build the registry from serve config + model geometry.  Entries are
    /// created at version 0 for `"default"` and every tenant-referenced
    /// store id; `stores.dir` files (`<id>.json`) are published at
    /// version 1 with origin `"dir"`.
    pub fn from_config(cfg: &ServeConfig, meta: &Meta) -> Result<Arc<Self>> {
        let tenants: Vec<Arc<TenantState>> = cfg
            .resolve_tenants()?
            .iter()
            .map(TenantState::new)
            .collect();
        let mut entries: BTreeMap<Arc<str>, StoreEntry> = BTreeMap::new();
        let mut seed_entry = |id: &str| {
            entries.entry(Arc::from(id)).or_insert(StoreEntry {
                version: 0,
                origin: "bootstrap",
                store: None,
            });
        };
        seed_entry(DEFAULT_STORE_ID);
        for t in &tenants {
            seed_entry(&t.store_id);
        }
        let reg = Arc::new(StoreRegistry {
            epoch: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            advertise: AtomicBool::new(!tenants.is_empty()),
            inner: Mutex::new(entries),
            tenants,
            num_classes: crate::dataset::NUM_CLASSES,
            n_features: meta.artifacts.n_features,
            k: cfg.templates_per_class,
        });
        if let Some(dir) = cfg.resolve_stores_dir() {
            let mut names: Vec<String> = Vec::new();
            for e in std::fs::read_dir(&dir)
                .map_err(|e| Error::Config(format!("stores dir {dir}: {e}")))?
            {
                let p = e
                    .map_err(|e| Error::Config(format!("stores dir {dir}: {e}")))?
                    .path();
                if p.extension().and_then(|x| x.to_str()) == Some("json") {
                    if let Some(stem) = p.file_stem().and_then(|x| x.to_str()) {
                        names.push(stem.to_string());
                    }
                }
            }
            names.sort();
            for id in names {
                if !valid_store_id(&id) {
                    return Err(Error::Config(format!(
                        "stores dir {dir}: invalid store id '{id}'"
                    )));
                }
                let path = std::path::Path::new(&dir).join(format!("{id}.json"));
                let store = TemplateStore::load(&path)?;
                reg.publish(&id, store, "dir")?;
            }
        }
        Ok(reg)
    }

    /// A registry with no tenants, no dir, default geometry — the inert
    /// single-default-store configuration.
    pub fn single_default(cfg: &ServeConfig, meta: &Meta) -> Result<Arc<Self>> {
        Self::from_config(cfg, meta)
    }

    /// Current publish epoch.  One relaxed load; shards poll this per batch.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Total successful publishes (`hec_store_swaps_total`).
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Whether the registry changes observable output: true once tenants
    /// are configured or any store has been published.  While false, wire
    /// bytes and `/metrics` are identical to a registry-free build.
    pub fn advertises(&self) -> bool {
        self.advertise.load(Ordering::Relaxed)
    }

    /// Registry geometry `(num_classes, n_features, templates_per_class)`.
    pub fn geometry(&self) -> (usize, usize, usize) {
        (self.num_classes, self.n_features, self.k)
    }

    /// Resolve the tenant from a request id of the form `"tenant/rest"`.
    /// No separator, or an unknown prefix, means the anonymous default
    /// tenant (no quota, default store).
    pub fn resolve_tenant(&self, request_id: Option<&str>) -> Option<Arc<TenantState>> {
        let id = request_id?;
        let prefix = id.split_once('/')?.0;
        self.tenants
            .iter()
            .find(|t| t.name == prefix)
            .map(Arc::clone)
    }

    pub fn tenants(&self) -> &[Arc<TenantState>] {
        &self.tenants
    }

    /// Store ids referenced by at least one tenant (excluding `default`).
    pub fn tenant_store_ids(&self) -> BTreeSet<Arc<str>> {
        self.tenants
            .iter()
            .filter(|t| &*t.store_id != DEFAULT_STORE_ID)
            .map(|t| Arc::clone(&t.store_id))
            .collect()
    }

    /// Snapshot one entry.
    pub fn get(&self, id: &str) -> Option<StoreSnapshot> {
        let inner = self.inner.lock().unwrap();
        inner.get_key_value(id).map(|(key, e)| StoreSnapshot {
            id: Arc::clone(key),
            version: e.version,
            origin: e.origin,
            store: e.store.clone(),
        })
    }

    /// Snapshot every entry, id-sorted.
    pub fn list(&self) -> Vec<StoreSnapshot> {
        let inner = self.inner.lock().unwrap();
        inner
            .iter()
            .map(|(key, e)| StoreSnapshot {
                id: Arc::clone(key),
                version: e.version,
                origin: e.origin,
                store: e.store.clone(),
            })
            .collect()
    }

    /// Snapshot the serving set — `default` plus every tenant-referenced
    /// id — under a single lock, so one shard sync observes one consistent
    /// registry state.
    pub fn serving_set(&self) -> Vec<StoreSnapshot> {
        let mut ids: BTreeSet<&str> = BTreeSet::new();
        ids.insert(DEFAULT_STORE_ID);
        for t in &self.tenants {
            ids.insert(&t.store_id);
        }
        let inner = self.inner.lock().unwrap();
        ids.iter()
            .filter_map(|id| {
                inner.get_key_value(*id).map(|(key, e)| StoreSnapshot {
                    id: Arc::clone(key),
                    version: e.version,
                    origin: e.origin,
                    store: e.store.clone(),
                })
            })
            .collect()
    }

    /// Publish a new immutable version of `id` and bump the swap epoch.
    ///
    /// Validates the store against registry geometry before anything
    /// becomes visible; on success the previous version is unreachable for
    /// new batches while in-flight batches finish on the snapshot they
    /// already resolved.
    pub fn publish(
        &self,
        id: &str,
        store: TemplateStore,
        origin: &'static str,
    ) -> Result<StoreSnapshot> {
        if !valid_store_id(id) {
            return Err(Error::Request(format!("invalid store id '{id}'")));
        }
        if store.num_classes != self.num_classes || store.n_features != self.n_features {
            return Err(Error::Request(format!(
                "store geometry {}x{} does not match deployment {}x{}",
                store.num_classes, store.n_features, self.num_classes, self.n_features
            )));
        }
        if store.set(self.k).is_err() {
            return Err(Error::Request(format!(
                "store has no k={} template set (templates_per_class)",
                self.k
            )));
        }
        let store = Arc::new(store);
        let snap = {
            let mut inner = self.inner.lock().unwrap();
            let key: Arc<str> = match inner.get_key_value(id) {
                Some((k, _)) => Arc::clone(k),
                None => Arc::from(id),
            };
            let e = inner.entry(Arc::clone(&key)).or_insert(StoreEntry {
                version: 0,
                origin: "bootstrap",
                store: None,
            });
            e.version += 1;
            e.origin = origin;
            e.store = Some(Arc::clone(&store));
            StoreSnapshot {
                id: key,
                version: e.version,
                origin,
                store: Some(store),
            }
        };
        self.advertise.store(true, Ordering::Relaxed);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        // Epoch bump is the release edge shards synchronise on; it must
        // happen after the entry is in place.
        self.epoch.fetch_add(1, Ordering::Release);
        Ok(snap)
    }

    /// Render `hec_store_*` / `hec_tenant_*` metrics.  Callers gate this on
    /// [`Self::advertises`] so the default configuration's `/metrics` stays
    /// byte-identical to pre-registry builds.
    pub fn prometheus(&self, out: &mut String) {
        use std::fmt::Write;
        out.push_str("# HELP hec_store_version Published version of each template store (0 = per-shard bootstrap).\n");
        out.push_str("# TYPE hec_store_version gauge\n");
        for s in self.list() {
            let _ = writeln!(out, "hec_store_version{{store=\"{}\"}} {}", s.id, s.version);
        }
        out.push_str("# HELP hec_store_swaps_total Successful store publishes (hot swaps).\n");
        out.push_str("# TYPE hec_store_swaps_total counter\n");
        let _ = writeln!(out, "hec_store_swaps_total {}", self.swaps());
        if !self.tenants.is_empty() {
            out.push_str("# HELP hec_tenant_served_total Responses delivered per tenant.\n");
            out.push_str("# TYPE hec_tenant_served_total counter\n");
            for t in &self.tenants {
                let _ = writeln!(
                    out,
                    "hec_tenant_served_total{{tenant=\"{}\"}} {}",
                    t.name,
                    t.served()
                );
            }
            out.push_str("# HELP hec_tenant_rejected_total Requests rejected by tenant quota.\n");
            out.push_str("# TYPE hec_tenant_rejected_total counter\n");
            for t in &self.tenants {
                let _ = writeln!(
                    out,
                    "hec_tenant_rejected_total{{tenant=\"{}\"}} {}",
                    t.name,
                    t.rejected()
                );
            }
            out.push_str("# HELP hec_tenant_in_flight Requests currently admitted per tenant.\n");
            out.push_str("# TYPE hec_tenant_in_flight gauge\n");
            for t in &self.tenants {
                let _ = writeln!(
                    out,
                    "hec_tenant_in_flight{{tenant=\"{}\"}} {}",
                    t.name,
                    t.in_flight()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Raw template upload: `application/x-hec-f32`, magic "HECT".
// ---------------------------------------------------------------------------

/// Magic for the raw template-upload frame (the classify frame uses
/// `"HECB"`; both travel as `application/x-hec-f32`).
pub const HECT_MAGIC: &[u8; 4] = b"HECT";
const HECT_VERSION: u8 = 1;
const HECT_MAX_ROWS: u32 = 65_536;
const HECT_MAX_FEATURES: u32 = 1 << 20;

/// Encode labelled feature rows as a `HECT` upload frame:
/// `"HECT"` · `u8` version (=1) · `u32` num_classes · `u32` n_features ·
/// `u32` rows · rows × (`u32` label · n_features × `f32`), all
/// little-endian.  The server re-fits thresholds/windows and k-means
/// templates from the rows via [`TemplateStore::from_features`].
pub fn encode_hect(num_classes: u32, n_features: u32, labels: &[u32], feats: &[f32]) -> Vec<u8> {
    assert_eq!(feats.len(), labels.len() * n_features as usize);
    let mut out = Vec::with_capacity(17 + labels.len() * (4 + 4 * n_features as usize));
    out.extend_from_slice(HECT_MAGIC);
    out.push(HECT_VERSION);
    out.extend_from_slice(&num_classes.to_le_bytes());
    out.extend_from_slice(&n_features.to_le_bytes());
    out.extend_from_slice(&(labels.len() as u32).to_le_bytes());
    for (i, label) in labels.iter().enumerate() {
        out.extend_from_slice(&label.to_le_bytes());
        for f in &feats[i * n_features as usize..(i + 1) * n_features as usize] {
            out.extend_from_slice(&f.to_le_bytes());
        }
    }
    out
}

/// Decode a `HECT` frame and build a [`TemplateStore`] from its rows.
pub fn decode_hect(body: &[u8], seed: u64) -> Result<TemplateStore> {
    let err = |m: &str| Error::Request(format!("HECT frame: {m}"));
    if body.len() < 17 {
        return Err(err("truncated header"));
    }
    if &body[0..4] != HECT_MAGIC {
        return Err(err("bad magic (expected \"HECT\")"));
    }
    if body[4] != HECT_VERSION {
        return Err(err("unsupported version"));
    }
    let u32_at = |o: usize| u32::from_le_bytes([body[o], body[o + 1], body[o + 2], body[o + 3]]);
    let num_classes = u32_at(5);
    let n_features = u32_at(9);
    let rows = u32_at(13);
    if rows == 0 || rows > HECT_MAX_ROWS {
        return Err(err("row count out of range"));
    }
    if n_features == 0 || n_features > HECT_MAX_FEATURES {
        return Err(err("n_features out of range"));
    }
    if num_classes == 0 {
        return Err(err("num_classes must be >= 1"));
    }
    let row_bytes = 4 + 4 * n_features as usize;
    let expect = 17 + rows as usize * row_bytes;
    if body.len() != expect {
        return Err(err(&format!(
            "length {} does not match {} rows x {} features ({} bytes)",
            body.len(),
            rows,
            n_features,
            expect
        )));
    }
    let mut labels = Vec::with_capacity(rows as usize);
    let mut feats = Vec::with_capacity(rows as usize * n_features as usize);
    for r in 0..rows as usize {
        let o = 17 + r * row_bytes;
        let label = u32_at(o);
        if label >= num_classes {
            return Err(err(&format!("row {r} label {label} >= num_classes")));
        }
        labels.push(label as usize);
        for j in 0..n_features as usize {
            let fo = o + 4 + 4 * j;
            feats.push(f32::from_le_bytes([
                body[fo],
                body[fo + 1],
                body[fo + 2],
                body[fo + 3],
            ]));
        }
    }
    TemplateStore::from_features(
        &feats,
        &labels,
        n_features as usize,
        num_classes as usize,
        seed,
    )
}

// ---------------------------------------------------------------------------
// Admin surface + online re-fit.
// ---------------------------------------------------------------------------

/// Outcome of one [`StoreAdmin::refit`] pass.
#[derive(Debug, Clone)]
pub struct RefitOutcome {
    pub id: String,
    /// Whether the candidate passed verification against the active
    /// back-end variant and was published.
    pub published: bool,
    /// Accuracy of the candidate on the held-out probe set, scored by the
    /// active [`crate::backend::MatchingBackend`] variant at ideal devices
    /// (identical to the digital matcher for the default `acam` variant).
    pub accuracy: f64,
    /// New version when published.
    pub version: Option<u64>,
    /// Expected re-programming energy per back-end unit that adopts the
    /// new store, at the active variant's per-cell programming cost
    /// (80 pJ ACAM / 40 pJ RBF / 0 digital), in nJ.
    pub reprogram_nj: f64,
}

impl RefitOutcome {
    pub fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Value::Str(self.id.clone()));
        m.insert("published".to_string(), Value::Bool(self.published));
        m.insert("accuracy".to_string(), Value::Num(self.accuracy));
        m.insert(
            "version".to_string(),
            match self.version {
                Some(v) => Value::Num(v as f64),
                None => Value::Null,
            },
        );
        m.insert("reprogram_nj".to_string(), Value::Num(self.reprogram_nj));
        Value::Obj(m)
    }
}

/// Gateway-facing handle for the store admin API (`/v1/stores`).  Cloned
/// per connection; all state lives behind the shared registry.
#[derive(Clone)]
pub struct StoreAdmin {
    registry: Arc<StoreRegistry>,
    cfg: Arc<ServeConfig>,
}

impl StoreAdmin {
    pub fn new(registry: Arc<StoreRegistry>, cfg: Arc<ServeConfig>) -> Self {
        StoreAdmin { registry, cfg }
    }

    pub fn registry(&self) -> &Arc<StoreRegistry> {
        &self.registry
    }

    pub fn get(&self, id: &str) -> Option<StoreSnapshot> {
        self.registry.get(id)
    }

    pub fn list(&self) -> Vec<StoreSnapshot> {
        self.registry.list()
    }

    /// `PUT /v1/stores/{id}` with a JSON body in the `templates.json`
    /// schema.
    pub fn put_json(&self, id: &str, body: &str) -> std::result::Result<StoreSnapshot, ApiError> {
        let store = TemplateStore::from_json_str(body)
            .map_err(|e| ApiError::new(ErrorCode::InvalidArgument, e.to_string()))?;
        self.publish(id, store, "put")
    }

    /// `PUT /v1/stores/{id}` with a raw `application/x-hec-f32` `HECT`
    /// frame of labelled feature rows; templates are re-fit server-side.
    pub fn put_binary(&self, id: &str, body: &[u8]) -> std::result::Result<StoreSnapshot, ApiError> {
        let store = decode_hect(body, self.cfg.acam.seed)
            .map_err(|e| ApiError::new(ErrorCode::InvalidArgument, e.to_string()))?;
        self.publish(id, store, "put")
    }

    fn publish(
        &self,
        id: &str,
        store: TemplateStore,
        origin: &'static str,
    ) -> std::result::Result<StoreSnapshot, ApiError> {
        let snap = self
            .registry
            .publish(id, store, origin)
            .map_err(|e| ApiError::new(ErrorCode::InvalidArgument, e.to_string()))?;
        self.persist(&snap)?;
        Ok(snap)
    }

    /// Persist an accepted publish into the stores directory (when one is
    /// configured) so it survives a restart: `StoreRegistry::from_config`
    /// republishes every `<id>.json` at boot with origin `"dir"`.  The
    /// write is atomic — serialise to `.tmp-<id>` in the same directory,
    /// then rename over `<id>.json` — so a crash mid-write never leaves a
    /// torn file for the loader to choke on.
    fn persist(&self, snap: &StoreSnapshot) -> std::result::Result<(), ApiError> {
        let Some(dir) = self.cfg.resolve_stores_dir() else {
            return Ok(());
        };
        let Some(store) = &snap.store else {
            return Ok(());
        };
        let dir = std::path::Path::new(&dir);
        let tmp = dir.join(format!(".tmp-{}", snap.id));
        let fin = dir.join(format!("{}.json", snap.id));
        let io = |e: std::io::Error| {
            ApiError::new(
                ErrorCode::Internal,
                format!(
                    "store '{}' v{} is live but could not be persisted to {}: {e}",
                    snap.id,
                    snap.version,
                    fin.display()
                ),
            )
        };
        std::fs::write(&tmp, store.to_json()).map_err(io)?;
        std::fs::rename(&tmp, &fin).map_err(io)
    }

    /// Online re-fit: draw fresh labelled probes, build a candidate store
    /// with the k-means template builder, verify it against the active
    /// [`crate::backend::MatchingBackend`] variant (ideal device corner) on
    /// a held-out probe set, and publish iff the accuracy clears
    /// `stores.refit_min_accuracy`.
    ///
    /// Deterministic: probe data, k-means seed, and the verification set
    /// depend only on config, store id, and the candidate version.
    pub fn refit(&self, id: &str) -> std::result::Result<RefitOutcome, ApiError> {
        let arg = |m: String| ApiError::new(ErrorCode::InvalidArgument, m);
        let internal = |m: String| ApiError::new(ErrorCode::Internal, m);
        if !valid_store_id(id) {
            return Err(arg(format!("invalid store id '{id}'")));
        }
        let (num_classes, n_features, k) = self.registry.geometry();
        let next_version = self.registry.get(id).map(|s| s.version).unwrap_or(0) + 1;
        let meta = Meta::load_or_synthetic(&self.cfg.artifacts_dir)
            .map_err(|e| internal(e.to_string()))?;
        let mut engine = crate::runtime::create_backend(&self.cfg, &meta)
            .map_err(|e| internal(e.to_string()))?;

        // "Recent labelled probes": a fresh draw per (id, version) so
        // successive re-fits track drift rather than replaying one batch.
        let per_class = self.cfg.stores.refit_per_class;
        let n = per_class * num_classes;
        let probe_seed = BOOTSTRAP_DATA_SEED ^ fnv1a(id) ^ (next_version << 8);
        let ds = crate::dataset::SyntheticDataset::new(
            probe_seed,
            n,
            meta.norm.mean as f32,
            meta.norm.std as f32,
        );
        let (images, labels) = ds.batch(0, n);
        let feats = engine
            .extract_features(&images, n)
            .map_err(|e| internal(e.to_string()))?;
        let kmeans_seed = self
            .cfg
            .acam
            .seed
            .wrapping_add(fnv1a(id))
            .wrapping_add(next_version);
        let candidate =
            TemplateStore::from_features(&feats, &labels, n_features, num_classes, kmeans_seed)
                .map_err(|e| arg(e.to_string()))?;

        // Held-out verification against the *active* MatchingBackend
        // variant at the ideal device corner (deterministic: no program or
        // read noise, no WTA offsets).  For the default `acam` variant this
        // is bit-identical to the previous digital Eq. 8 check by the
        // ideal-device agreement contract (`backend::build_unit` tests);
        // for the other variants the candidate is vetted by the engine that
        // will actually serve it.
        let variant = self
            .cfg
            .resolve_backend_variant()
            .map_err(|e| arg(e.to_string()))?;
        let n_eval = (2 * per_class).max(4) * num_classes;
        let eval = crate::dataset::SyntheticDataset::new(
            BOOTSTRAP_DATA_SEED ^ 0xE7A1,
            n_eval,
            meta.norm.mean as f32,
            meta.norm.std as f32,
        );
        let (eval_images, eval_labels) = eval.batch(0, n_eval);
        let eval_feats = engine
            .extract_features(&eval_images, n_eval)
            .map_err(|e| internal(e.to_string()))?;
        let set = candidate
            .set(k)
            .map_err(|e| internal(e.to_string()))?;
        let ideal = crate::acam::Variability::ideal();
        let unit_seed = self.cfg.acam.seed ^ fnv1a(id) ^ (next_version << 16);
        let mut unit =
            crate::backend::build_unit(variant, self.cfg.acam.cell_kind, set, &ideal, unit_seed);
        let mut wta_rng = crate::rng::Rng::new(unit_seed ^ 0x5EED);
        let energy = EnergyModel::default();
        let mut correct = 0usize;
        for (i, label) in eval_labels.iter().enumerate() {
            let bits = candidate.binarize(&eval_feats[i * n_features..(i + 1) * n_features]);
            let out = unit.score(&bits, set, num_classes, 1, &energy, &ideal, &mut wta_rng);
            if out.ranked.first().map(|(c, _)| *c) == Some(*label) {
                correct += 1;
            }
        }
        let accuracy = correct as f64 / n_eval as f64;
        let reprogram_nj =
            unit.reprogram_nj(set.num_templates() as u64, n_features as u64);

        if accuracy < self.cfg.stores.refit_min_accuracy {
            return Ok(RefitOutcome {
                id: id.to_string(),
                published: false,
                accuracy,
                version: None,
                reprogram_nj,
            });
        }
        let snap = self.publish(id, candidate, "refit")?;
        Ok(RefitOutcome {
            id: id.to_string(),
            published: true,
            accuracy,
            version: Some(snap.version),
            reprogram_nj,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TenantSpec;

    fn test_cfg() -> ServeConfig {
        let mut cfg = ServeConfig::default();
        cfg.artifacts_dir = std::path::PathBuf::from("/nonexistent-hec-artifacts");
        cfg
    }

    fn registry_with(tenants: Vec<TenantSpec>) -> Arc<StoreRegistry> {
        let mut cfg = test_cfg();
        cfg.stores.tenants = tenants;
        let meta = Meta::load_or_synthetic(&cfg.artifacts_dir).unwrap();
        StoreRegistry::from_config(&cfg, &meta).unwrap()
    }

    fn sample_store(reg: &StoreRegistry) -> TemplateStore {
        let (num_classes, n_features, _) = reg.geometry();
        let per_class = 4;
        let n = per_class * num_classes;
        let mut rng = crate::rng::Rng::new(7);
        let mut feats = vec![0f32; n * n_features];
        let mut labels = vec![0usize; n];
        for (i, l) in labels.iter_mut().enumerate() {
            *l = i % num_classes;
            for j in 0..n_features {
                // Class-dependent mean so templates are separable.
                feats[i * n_features + j] =
                    (*l as f32) * 0.3 + rng.u01() as f32 + if j % num_classes == *l { 1.5 } else { 0.0 };
            }
        }
        TemplateStore::from_features(&feats, &labels, n_features, num_classes, 11).unwrap()
    }

    #[test]
    fn bootstrap_registry_is_inert() {
        let reg = registry_with(vec![]);
        assert!(!reg.advertises());
        assert_eq!(reg.epoch(), 0);
        assert_eq!(reg.swaps(), 0);
        let d = reg.get(DEFAULT_STORE_ID).unwrap();
        assert_eq!(d.version, 0);
        assert_eq!(d.origin, "bootstrap");
        assert!(d.store.is_none());
        assert_eq!(reg.list().len(), 1);
        assert!(reg.resolve_tenant(Some("t1/abc")).is_none());
    }

    #[test]
    fn publish_bumps_version_epoch_and_advertises() {
        let reg = registry_with(vec![]);
        let store = sample_store(&reg);
        let snap = reg.publish("default", store.clone(), "put").unwrap();
        assert_eq!(snap.version, 1);
        assert_eq!(reg.epoch(), 1);
        assert_eq!(reg.swaps(), 1);
        assert!(reg.advertises());
        let snap2 = reg.publish("default", store.clone(), "refit").unwrap();
        assert_eq!(snap2.version, 2);
        assert_eq!(reg.get("default").unwrap().origin, "refit");
        // New id starts at version 1.
        let snap3 = reg.publish("alt", store, "put").unwrap();
        assert_eq!(snap3.version, 1);
        assert_eq!(reg.list().len(), 2);
    }

    #[test]
    fn publish_rejects_geometry_and_id_mismatches() {
        let reg = registry_with(vec![]);
        let store = sample_store(&reg);
        assert!(reg.publish("bad/id", store.clone(), "put").is_err());
        assert!(reg.publish("", store.clone(), "put").is_err());
        let mut wrong = store.clone();
        wrong.n_features += 1;
        assert!(reg.publish("default", wrong, "put").is_err());
        let mut no_set = store;
        no_set.sets.remove(&1);
        assert!(reg.publish("default", no_set, "put").is_err());
        // Nothing leaked into the registry.
        assert_eq!(reg.epoch(), 0);
        assert_eq!(reg.swaps(), 0);
    }

    #[test]
    fn tenant_resolution_uses_request_id_prefix() {
        let reg = registry_with(vec![
            TenantSpec {
                name: "acme".into(),
                store: "acme-store".into(),
                quota: 2,
            },
            TenantSpec {
                name: "beta".into(),
                store: "default".into(),
                quota: 0,
            },
        ]);
        assert!(reg.advertises());
        assert_eq!(reg.list().len(), 2); // default + acme-store
        let t = reg.resolve_tenant(Some("acme/req-1")).unwrap();
        assert_eq!(t.name, "acme");
        assert_eq!(&*t.store_id, "acme-store");
        assert!(reg.resolve_tenant(Some("acme")).is_none()); // no '/'
        assert!(reg.resolve_tenant(Some("other/x")).is_none());
        assert!(reg.resolve_tenant(None).is_none());
        let ids = reg.tenant_store_ids();
        assert_eq!(ids.len(), 1);
        assert!(ids.iter().any(|i| &**i == "acme-store"));
    }

    #[test]
    fn quota_admission_and_ticket_drop_are_drift_free() {
        let reg = registry_with(vec![TenantSpec {
            name: "t".into(),
            store: "default".into(),
            quota: 2,
        }]);
        let t = reg.resolve_tenant(Some("t/a")).unwrap();
        let a = t.admit().unwrap();
        let b = t.admit().unwrap();
        assert_eq!(t.in_flight(), 2);
        let err = t.admit().unwrap_err();
        assert_eq!(err.code, ErrorCode::QuotaExceeded);
        assert_eq!(t.rejected(), 1);
        drop(a);
        assert_eq!(t.in_flight(), 1);
        let c = t.admit().unwrap();
        c.mark_served();
        drop(c);
        drop(b);
        assert_eq!(t.in_flight(), 0);
        assert_eq!(t.served(), 1);
        assert_eq!(t.rejected(), 1);
    }

    #[test]
    fn hect_roundtrip_and_rejections() {
        let num_classes = 4u32;
        let n_features = 8u32;
        let rows = 16usize;
        let mut rng = crate::rng::Rng::new(3);
        let labels: Vec<u32> = (0..rows).map(|i| (i as u32) % num_classes).collect();
        let mut feats = vec![0f32; rows * n_features as usize];
        for (i, f) in feats.iter_mut().enumerate() {
            let class = labels[i / n_features as usize] as f32;
            *f = class * 0.5 + rng.u01() as f32;
        }
        let frame = encode_hect(num_classes, n_features, &labels, &feats);
        let store = decode_hect(&frame, 42).unwrap();
        assert_eq!(store.num_classes, 4);
        assert_eq!(store.n_features, 8);
        assert!(store.set(1).is_ok());

        assert!(decode_hect(b"HECB", 42).is_err()); // classify magic
        assert!(decode_hect(&frame[..frame.len() - 1], 42).is_err());
        let mut bad_label = frame.clone();
        bad_label[17..21].copy_from_slice(&99u32.to_le_bytes());
        assert!(decode_hect(&bad_label, 42).is_err());
        let mut bad_ver = frame.clone();
        bad_ver[4] = 9;
        assert!(decode_hect(&bad_ver, 42).is_err());
        // Row 0's first feature lives at byte 21 (17-byte header + u32
        // label); a NaN payload must be rejected before template build.
        let mut nan_feat = frame;
        nan_feat[21..25].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(decode_hect(&nan_feat, 42).is_err());
    }

    #[test]
    fn admin_uploads_reject_non_finite_values() {
        let cfg = Arc::new(test_cfg());
        let meta = Meta::load_or_synthetic(&cfg.artifacts_dir).unwrap();
        let reg = StoreRegistry::from_config(&cfg, &meta).unwrap();
        let admin = StoreAdmin::new(Arc::clone(&reg), Arc::clone(&cfg));

        // HECT frame with one NaN feature: stable INVALID_ARGUMENT, no swap.
        let labels: Vec<u32> = (0..8).map(|i| i % 2).collect();
        let mut feats = vec![0.5f32; 8 * 4];
        feats[3] = f32::NAN;
        let frame = encode_hect(2, 4, &labels, &feats);
        let err = admin.put_binary("default", &frame).unwrap_err();
        assert_eq!(err.code, ErrorCode::InvalidArgument);
        assert_eq!(reg.get("default").unwrap().version, 0);
    }

    #[test]
    fn prometheus_block_renders_store_and_tenant_series() {
        let reg = registry_with(vec![TenantSpec {
            name: "t1".into(),
            store: "default".into(),
            quota: 4,
        }]);
        let t = reg.resolve_tenant(Some("t1/x")).unwrap();
        let ticket = t.admit().unwrap();
        ticket.mark_served();
        let store = sample_store(&reg);
        reg.publish("default", store, "put").unwrap();
        let mut out = String::new();
        reg.prometheus(&mut out);
        assert!(out.contains("hec_store_version{store=\"default\"} 1"));
        assert!(out.contains("hec_store_swaps_total 1"));
        assert!(out.contains("hec_tenant_served_total{tenant=\"t1\"} 1"));
        assert!(out.contains("hec_tenant_rejected_total{tenant=\"t1\"} 0"));
        assert!(out.contains("hec_tenant_in_flight{tenant=\"t1\"} 1"));
        drop(ticket);
        let mut out2 = String::new();
        reg.prometheus(&mut out2);
        assert!(out2.contains("hec_tenant_in_flight{tenant=\"t1\"} 0"));
    }

    #[test]
    fn serving_set_covers_default_and_tenant_stores() {
        let reg = registry_with(vec![TenantSpec {
            name: "acme".into(),
            store: "acme-store".into(),
            quota: 0,
        }]);
        let set = reg.serving_set();
        let ids: Vec<&str> = set.iter().map(|s| &*s.id).collect();
        assert_eq!(ids, vec!["acme-store", "default"]);
        assert!(set.iter().all(|s| s.version == 0 && s.store.is_none()));
    }

    #[test]
    fn admin_put_json_and_refit_lifecycle() {
        let cfg = Arc::new({
            let mut c = test_cfg();
            c.stores.refit_per_class = 4;
            c.stores.refit_min_accuracy = 0.0; // always publish
            c
        });
        let meta = Meta::load_or_synthetic(&cfg.artifacts_dir).unwrap();
        let reg = StoreRegistry::from_config(&cfg, &meta).unwrap();
        let admin = StoreAdmin::new(Arc::clone(&reg), Arc::clone(&cfg));

        let bad = admin.put_json("default", "{not json");
        assert_eq!(bad.unwrap_err().code, ErrorCode::InvalidArgument);

        let o1 = admin.refit("default").unwrap();
        assert!(o1.published);
        assert_eq!(o1.version, Some(1));
        assert!(o1.reprogram_nj > 0.0);
        // Deterministic accuracy: a second registry replaying the same
        // refit sequence reports the identical outcome.
        let reg2 = StoreRegistry::from_config(&cfg, &meta).unwrap();
        let admin2 = StoreAdmin::new(Arc::clone(&reg2), Arc::clone(&cfg));
        let o1b = admin2.refit("default").unwrap();
        assert_eq!(o1.accuracy, o1b.accuracy);
        assert_eq!(o1.reprogram_nj, o1b.reprogram_nj);

        // Next refit draws different probes (version-salted) and bumps to 2.
        let o2 = admin.refit("default").unwrap();
        assert_eq!(o2.version, Some(2));
        assert_eq!(reg.get("default").unwrap().origin, "refit");
        assert_eq!(reg.swaps(), 2);
    }

    #[test]
    fn refit_below_threshold_is_not_published() {
        let cfg = Arc::new({
            let mut c = test_cfg();
            c.stores.refit_per_class = 2;
            c.stores.refit_min_accuracy = 1.01; // unreachable
            c
        });
        let meta = Meta::load_or_synthetic(&cfg.artifacts_dir).unwrap();
        let reg = StoreRegistry::from_config(&cfg, &meta).unwrap();
        let admin = StoreAdmin::new(Arc::clone(&reg), cfg);
        let o = admin.refit("default").unwrap();
        assert!(!o.published);
        assert!(o.version.is_none());
        assert_eq!(reg.get("default").unwrap().version, 0);
        assert_eq!(reg.swaps(), 0);
    }
}

//! Cross-variant `MatchingBackend` seam tests (ISSUE 10's acceptance
//! suite): deterministic, sleep-free, Gate-synchronised — the style of
//! `rust/tests/faults.rs`.
//!
//! The contract under test, in order:
//!
//! 1. **Variant matrix**: every [`hec::backend::BackendVariant`] serves
//!    through the sharded coordinator; non-default variants advertise
//!    themselves on the response, `/healthz`, and `/metrics`, while the
//!    default `acam` variant leaves all three byte-identical to a
//!    pre-seam build.
//! 2. **Digital anchor**: the deployable `digital` variant answers
//!    bitwise-identically to the degradation ladder's `digital_fallback`
//!    serving path — the same Eq. 8 popcount matcher at the same energy
//!    envelope, reached through two different doors.
//! 3. **Variant pinning**: the selected variant survives a worker
//!    panic-restart and a template-store hot-swap — both rebuild the
//!    matching unit, neither may silently change the hardware model.

use std::sync::Arc;

use hec::api::{ClassifyRequest, ErrorCode};
use hec::backend::BackendVariant;
use hec::config::{Backend, Engine, RoutePolicy, ServeConfig};
use hec::coordinator::shard::{Gate, ShardHooks};
use hec::coordinator::{ClassifySurface, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::faults::BackendState;
use hec::templates::TemplateStore;

/// An artifacts directory that never exists -> synthetic fallback.
const NO_ARTIFACTS: &str = "/nonexistent-hec-artifacts";

/// A serve config pinned to an explicit variant.  Pinning (rather than
/// leaving `backend_variant: None`) keeps every test deterministic under
/// the CI `backend-matrix` job, which sweeps `HEC_BACKEND` through the
/// process environment.
fn cfg(variant: BackendVariant, shards: usize) -> ServeConfig {
    let mut c = ServeConfig {
        artifacts_dir: NO_ARTIFACTS.into(),
        backend: Backend::AcamSim,
        engine: Engine::Interp,
        ..Default::default()
    };
    c.backend_variant = Some(variant);
    c.batch.max_batch = 1; // serial submits -> singleton batches, no timing
    c.batch.max_wait_us = 0;
    c.shards.count = shards;
    c.shards.policy = RoutePolicy::RoundRobin;
    c
}

fn workload(n: usize, seed: u64) -> (Vec<f32>, usize) {
    let meta = hec::runtime::Meta::synthetic();
    let ds = SyntheticDataset::new(seed, n, meta.norm.mean as f32, meta.norm.std as f32);
    let (images, _) = ds.batch(0, n);
    let s = meta.artifacts.image_size;
    (images, s * s)
}

// ---------------------------------------------------------------------------
// 1. The variant matrix
// ---------------------------------------------------------------------------

/// Every variant serves end-to-end, reports itself consistently across the
/// response / `/healthz` / `/metrics` surfaces, and carries its own energy
/// constant — while the default `acam` variant stays invisible on the wire
/// (the bitwise-parity gate's observable half).
#[test]
fn variant_matrix_serves_and_advertises_consistently() {
    let requests = 4;
    let (images, img_len) = workload(requests, 101_010);
    let mut per_op = std::collections::BTreeMap::new();
    for variant in BackendVariant::ALL {
        let c = cfg(variant, 1);
        let set = ShardSet::start(&c).unwrap();
        let advertised = (variant != BackendVariant::Acam).then(|| variant.name());
        for i in 0..requests {
            let resp = set
                .handle
                .classify_blocking(images[i * img_len..(i + 1) * img_len].to_vec())
                .unwrap();
            assert_eq!(resp.backend, Backend::AcamSim);
            assert_eq!(
                resp.backend_variant, advertised,
                "{}: response advertisement",
                variant.name()
            );
            let json = resp.to_value().to_json();
            match advertised {
                Some(name) => assert!(
                    json.contains(&format!("\"backend_variant\":\"{name}\"")),
                    "{json}"
                ),
                None => assert!(
                    !json.contains("backend_variant"),
                    "default variant leaked into the wire bytes: {json}"
                ),
            }
            assert!(!resp.predictions.is_empty());
            assert!(resp.energy.back_end_nj > 0.0);
            per_op.insert(variant.name(), resp.energy.back_end_nj);
        }

        // /healthz names the variant per shard unconditionally (health is
        // not part of the parity gate — operators always see the truth).
        let health = set.handle.health();
        assert_eq!(health.shards[0].backend_variant, variant.name());

        // /metrics: per-variant series exist iff the variant is advertised.
        let text = set.handle.prometheus_text();
        match advertised {
            Some(name) => {
                let needle =
                    format!("hec_variant_energy_nanojoules_total{{variant=\"{name}\",shard=\"0\"}}");
                assert!(text.contains(&needle), "missing {needle:?} in:\n{text}");
                assert!(
                    text.contains("hec_variant_latency_microseconds_count"),
                    "{text}"
                );
            }
            None => assert!(
                !text.contains("hec_variant_"),
                "default variant leaked into /metrics:\n{text}"
            ),
        }
        set.shutdown();
    }

    // Per-op energy ordering follows the per-cell constants over the same
    // array geometry: 9T4R (278 fJ) > TXL (185 fJ) > RBF (92 fJ).
    assert!(per_op["acam-9t4r"] > per_op["acam"], "{per_op:?}");
    assert!(per_op["acam"] > per_op["rbf"], "{per_op:?}");
    assert!(per_op["digital"] > 0.0, "{per_op:?}");
}

// ---------------------------------------------------------------------------
// 2. The digital anchor: variant == ladder fallback, bitwise
// ---------------------------------------------------------------------------

/// The `digital` variant is the ladder's `digital_fallback` path promoted
/// to a first-class deployment: drive one shard set into `DigitalFallback`
/// via sticky stuck-at faults, serve the same images through a `digital`
/// variant deployment, and require bitwise-equal predictions, scores, and
/// back-end energy.  Only the *door* differs — the fallback deployment is
/// degraded, the digital deployment is healthy by construction (nothing to
/// decay, so the ladder never arms).
#[test]
fn digital_variant_is_bitwise_equal_to_ladder_fallback() {
    let (images, img_len) = workload(10, 565_656);
    let img = |i: usize| images[i * img_len..(i + 1) * img_len].to_vec();

    // Ladder deployment on the default ACAM variant: every cell stuck
    // after 2 served requests, probe after 4 -> re-program fails ->
    // DigitalFallback before request 5.
    let mut lc = cfg(BackendVariant::Acam, 1);
    lc.faults.plan = Some("stuck@2=1.0".into());
    lc.faults.canary_every = 4;
    let ladder = ShardSet::start(&lc).unwrap();
    for i in 0..5 {
        ladder.handle.classify_blocking(img(i)).unwrap();
    }
    assert_eq!(
        ladder.handle.shard_ladder().unwrap()[0].0,
        BackendState::DigitalFallback
    );

    // Digital-variant deployment: same store (same seeds), no ladder.
    let dc = cfg(BackendVariant::Digital, 1);
    let digital = ShardSet::start(&dc).unwrap();
    assert!(
        digital.handle.shard_ladder().is_none(),
        "the canary ladder must not arm on a digital deployment"
    );

    for i in 5..10 {
        let fall = ladder.handle.classify_blocking(img(i)).unwrap();
        let dig = digital.handle.classify_blocking(img(i)).unwrap();
        assert_eq!(fall.backend_state.as_deref(), Some("digital_fallback"));
        assert_eq!(fall.backend_variant, None, "default variant stays silent");
        assert_eq!(dig.backend_state, None);
        assert_eq!(dig.backend_variant, Some("digital"));
        assert_eq!(dig.predictions[0].class, fall.predictions[0].class);
        assert_eq!(dig.predictions[0].score, fall.predictions[0].score);
        assert_eq!(dig.energy.back_end_nj, fall.energy.back_end_nj);
        assert_eq!(dig.energy.front_end_nj, fall.energy.front_end_nj);
    }
    assert!(ladder.handle.health().degraded);
    assert!(!digital.handle.health().degraded);
    ladder.shutdown();
    digital.shutdown();
}

// ---------------------------------------------------------------------------
// 3. Variant pinning across restart and hot-swap
// ---------------------------------------------------------------------------

/// A worker panic-restart rebuilds the pipeline (and with it the matching
/// unit) from the same config: the selected variant must come back
/// identical, and a repeated image must classify identically to before the
/// panic (the rebuilt unit re-programs from the same seeds).
#[test]
fn variant_selection_survives_panic_restart() {
    let restart_gate = Gate::new();
    let c = cfg(BackendVariant::Rbf, 1);
    let (images, img_len) = workload(2, 737_373);
    let img = |i: usize| images[i * img_len..(i + 1) * img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            panic_on: Some("boom".into()),
            restart_gate: Some(Arc::clone(&restart_gate)),
            ..Default::default()
        },
    )
    .unwrap();

    let before = set.handle.classify_blocking(img(0)).unwrap();
    assert_eq!(before.backend_variant, Some("rbf"));
    assert_eq!(set.handle.health().shards[0].backend_variant, "rbf");

    let mut req = ClassifyRequest::new(img(1));
    req.request_id = Some("boom".into());
    assert_eq!(
        set.handle.submit_blocking(req).err().map(|e| e.code),
        Some(ErrorCode::Internal)
    );
    restart_gate.await_arrivals(1);
    restart_gate.release();
    restart_gate.await_arrivals(2);

    let after = set.handle.classify_blocking(img(0)).unwrap();
    assert_eq!(
        after.backend_variant,
        Some("rbf"),
        "restart must not change the deployed variant"
    );
    assert_eq!(set.handle.health().shards[0].backend_variant, "rbf");
    assert_eq!(after.predictions[0].class, before.predictions[0].class);
    assert_eq!(after.predictions[0].score, before.predictions[0].score);
    assert_eq!(after.energy.back_end_nj, before.energy.back_end_nj);
    set.shutdown();
}

/// A template-store publish re-programs the active unit from the new set
/// at the batch boundary: the variant is pinned across the swap, the
/// post-swap responses are tagged with the published version, and serving
/// never misses a beat.
#[test]
fn variant_selection_survives_store_hot_swap() {
    let c = cfg(BackendVariant::Acam9T4R, 1);
    let (images, img_len) = workload(4, 929_292);
    let img = |i: usize| images[i * img_len..(i + 1) * img_len].to_vec();
    let set = ShardSet::start(&c).unwrap();

    let pre = set.handle.classify_blocking(img(0)).unwrap();
    assert_eq!(pre.backend_variant, Some("acam-9t4r"));
    assert_eq!(pre.store_version, None, "nothing published yet");

    // Publish a replacement store built over the registry's geometry.
    let admin = set.handle.store_admin().expect("sharded surface carries the admin");
    let reg = admin.registry();
    let (num_classes, n_features, _) = reg.geometry();
    let per_class = 4;
    let n = per_class * num_classes;
    let labels: Vec<usize> = (0..n).map(|i| i % num_classes).collect();
    let mut rng = hec::rng::Rng::new(31_337);
    let mut feats = vec![0.0f32; n * n_features];
    for (i, l) in labels.iter().enumerate() {
        for j in 0..n_features {
            feats[i * n_features + j] = (*l as f32) * 0.3
                + rng.u01() as f32
                + if j % num_classes == *l { 1.5 } else { 0.0 };
        }
    }
    let store = TemplateStore::from_features(&feats, &labels, n_features, num_classes, 7).unwrap();
    let snap = reg.publish("default", store, "put").unwrap();
    assert_eq!(snap.version, 1);

    for i in 1..4 {
        let resp = set.handle.classify_blocking(img(i)).unwrap();
        assert_eq!(
            resp.backend_variant,
            Some("acam-9t4r"),
            "hot-swap must not change the deployed variant"
        );
        assert_eq!(resp.store.as_deref(), Some("default"));
        assert_eq!(resp.store_version, Some(1), "post-publish batch must serve v1");
        assert!(!resp.predictions.is_empty());
    }
    assert_eq!(set.handle.health().shards[0].backend_variant, "acam-9t4r");
    set.shutdown();
}

"""Pallas conv2d kernel: im2col patch extraction + MXU-tiled matmul.

The student CNN's compute hot-spot (Eq. 13: MACs = Ho*Wo*Kh*Kw*Cin*Cout per
layer) is a convolution.  On TPU the profitable mapping is *not* a direct
sliding-window loop (that under-utilises the MXU); it is im2col: gather the
(dy, dx, cin) patch for every output pixel into a [B*Ho*Wo, Kh*Kw*Cin] matrix
and contract it against the [Kh*Kw*Cin, Cout] filter matrix on the systolic
array.  Patch extraction is pure data movement — XLA fuses the
pad+slice+concat into the surrounding graph — while the FLOPs all land in the
Pallas matmul grid (see kernels/matmul.py for the VMEM/MXU tiling rationale).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .matmul import matmul


def conv2d(x: jnp.ndarray, w: jnp.ndarray, padding: str = "SAME") -> jnp.ndarray:
    """x: [B,H,W,Cin], w: [Kh,Kw,Cin,Cout] -> [B,Ho,Wo,Cout] (f32).

    Matches ``ref.conv2d`` exactly (same im2col layout); the contraction runs
    in the Pallas matmul kernel.
    """
    kh, kw, cin, cout = w.shape
    cols = ref.im2col(x, kh, kw, padding)  # [B,Ho,Wo,K]
    b, ho, wo, k = cols.shape
    out = matmul(cols.reshape(b * ho * wo, k), w.reshape(kh * kw * cin, cout))
    return out.reshape(b, ho, wo, cout)

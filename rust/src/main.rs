//! `hec` — the hybrid edge classifier launcher (hand-rolled CLI; clap is
//! unavailable offline).
//!
//! Subcommands:
//! * `serve`     — run the serving loop against a synthetic request stream
//!   and print throughput/latency/energy metrics; with `--http ADDR` (or
//!   `http.addr` in the config file, or `HEC_HTTP_ADDR`) it instead exposes
//!   the v1 HTTP/JSON gateway and blocks until killed;
//! * `classify`  — classify a few synthetic samples and print predictions;
//! * `eval`      — accuracy + confusion matrix of the deployed backend over
//!   a labelled test workload (Fig. 6 / Fig. 7 data);
//! * `energy`    — the §V.D energy report at paper scale and as-built scale;
//! * `acam-sim`  — ACAM variability sweep (accuracy vs device non-ideality);
//! * `info`      — artifact inventory and metadata.
//!
//! Global flags: `--artifacts DIR` `--engine interp|interp-fast|pjrt`
//! `--backend acam|acam-9t4r|rbf|digital|fc|sim|softmax` (route names or
//! MatchingBackend variant names — a variant implies the acam route)
//! `--templates K` `--threads N`
//! `--variability LEVEL` `--config serve.json` `--shards N`
//! `--shard-policy round_robin|least_queue_depth|hash`.
//!
//! `serve` runs the sharded coordinator (`hec::coordinator::shard`): N
//! independent worker pipelines behind one routed submit surface.  The
//! default (`--shards 1`, or `HEC_SHARDS` unset) is a single-pipeline
//! deployment whose *predictions and energy splits* are bitwise identical
//! to the pre-sharding behaviour; on the wire it additionally carries the
//! additive v1 fields (`shard: 0` in responses, a `shards` array in
//! `/healthz`, `hec_shard_*` series in `/metrics`).
//!
//! Every subcommand works without an artifacts directory: the default
//! interp engine then serves from synthetic weights and bootstrapped
//! templates (see `hec::coordinator::Pipeline`).

use std::collections::HashMap;

use hec::config::{Backend, Engine, ServeConfig};
use hec::coordinator::{ClassifySurface, Pipeline, ShardSet};
use hec::dataset::{SyntheticDataset, CLASS_NAMES};
use hec::energy::{EnergyModel, Scale};
use hec::runtime::Meta;
use hec::Error;

const USAGE: &str = "usage: hec [--artifacts DIR] [--engine interp|interp-fast|pjrt] \
[--backend acam|acam-9t4r|rbf|digital|fc|sim|softmax] [--templates K] [--threads N] [--variability L] \
[--frontend fast|pallas] [--config FILE] \
[--shards N] [--shard-policy round_robin|least_queue_depth|hash] \
[--stores-dir DIR] [--tenants name=store[:quota],...] [--cache CAPACITY] \
<serve|classify|eval|energy|acam-sim|info> [--requests N] [--concurrency N] \
[--http ADDR] [--max-connections N] \
[--count N] [--samples N] [--batch N] [--levels 0,1,2]";

/// Minimal flag parser: `--key value` pairs plus one positional subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

fn parse_args() -> Result<Args, String> {
    let mut cmd = None;
    let mut flags = HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = it
                .next()
                .ok_or_else(|| format!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val);
        } else if cmd.is_none() {
            cmd = Some(a);
        } else {
            return Err(format!("unexpected argument: {a}"));
        }
    }
    Ok(Args {
        cmd: cmd.ok_or_else(|| USAGE.to_string())?,
        flags,
    })
}

impl Args {
    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("bad value for --{key}: {v}")),
        }
    }
}

fn serve_config(args: &Args) -> hec::Result<ServeConfig> {
    let mut cfg = match args.flags.get("config") {
        Some(path) => ServeConfig::load(path)?,
        None => ServeConfig::default(),
    };
    if let Some(dir) = args.flags.get("artifacts") {
        cfg.artifacts_dir = dir.into();
    }
    if let Some(e) = args.flags.get("engine") {
        cfg.engine = e.parse::<Engine>()?;
    }
    if let Some(b) = args.flags.get("backend") {
        // Route names first (`acam` selects the AcamSim route with the
        // default variant), then MatchingBackend variant names, which imply
        // the acam route (`--backend rbf` == route acam + variant rbf).
        match b.parse::<Backend>() {
            Ok(route) => cfg.backend = route,
            Err(_) => match b.parse::<hec::backend::BackendVariant>() {
                Ok(v) => {
                    cfg.backend = Backend::AcamSim;
                    cfg.backend_variant = Some(v);
                }
                Err(_) => {
                    return Err(Error::Config(format!(
                        "unknown backend '{b}' (routes: acam | fc | sim | softmax; \
                         variants: acam | acam-9t4r | rbf | digital)"
                    )))
                }
            },
        }
    }
    cfg.templates_per_class = args
        .get("templates", cfg.templates_per_class)
        .map_err(Error::Config)?;
    cfg.threads = args.get("threads", cfg.threads).map_err(Error::Config)?;
    if let Some(f) = args.flags.get("frontend") {
        if cfg.engine != Engine::Pjrt {
            return Err(Error::Config(
                "--frontend only applies to the pjrt engine (pass --engine pjrt); \
                 the interp engine has no fast/pallas artifact split"
                    .into(),
            ));
        }
        cfg.use_fast_frontend = match f.as_str() {
            "fast" => true,
            "pallas" => false,
            other => {
                return Err(Error::Config(format!(
                    "--frontend must be fast|pallas, got {other}"
                )))
            }
        };
    }
    cfg.acam.variability_level = args
        .get("variability", cfg.acam.variability_level)
        .map_err(Error::Config)?;
    cfg.shards.count = args.get("shards", cfg.shards.count).map_err(Error::Config)?;
    if let Some(p) = args.flags.get("shard-policy") {
        cfg.shards.policy = p.parse::<hec::config::RoutePolicy>()?;
    }
    if let Some(dir) = args.flags.get("stores-dir") {
        cfg.stores.dir = Some(dir.clone());
    }
    if let Some(spec) = args.flags.get("tenants") {
        cfg.stores.tenants = hec::config::parse_tenant_list(spec)?;
    }
    if args.flags.contains_key("cache") {
        cfg.cache.enabled = true;
        cfg.cache.capacity = args.get("cache", cfg.cache.capacity).map_err(Error::Config)?;
    }
    if let Some(addr) = args.flags.get("http") {
        cfg.http.addr = Some(addr.clone());
    }
    cfg.http.max_connections = args
        .get("max-connections", cfg.http.max_connections)
        .map_err(Error::Config)?;
    cfg.validate()?;
    Ok(cfg)
}

fn test_workload(meta: &Meta, n: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
    let ds = SyntheticDataset::new(seed, n, meta.norm.mean as f32, meta.norm.std as f32);
    ds.batch(0, n)
}

fn main() -> hec::Result<()> {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let cfg = serve_config(&args)?;

    match args.cmd.as_str() {
        "info" => {
            let meta = Meta::load_or_synthetic(&cfg.artifacts_dir)?;
            if meta.dataset.source == "synthetic-fallback" {
                println!(
                    "(no artifacts at {} — synthetic fallback deployment)",
                    cfg.artifacts_dir.display()
                );
            }
            println!("engine: {:?}", cfg.engine);
            println!(
                "dataset: {} (train={}, test={})",
                meta.dataset.source, meta.dataset.train, meta.dataset.test
            );
            println!(
                "features: {}  templates: {}  image: {}x{}",
                meta.artifacts.n_features,
                meta.artifacts.n_templates,
                meta.artifacts.image_size,
                meta.artifacts.image_size
            );
            println!(
                "batch variants: {:?}  pallas: {}",
                meta.artifacts.batch_sizes, meta.artifacts.use_pallas
            );
            println!(
                "as-built sparsity: {:.3}  effective MACs: {}",
                meta.macs.as_built.achieved_sparsity, meta.macs.as_built.student_effective
            );
            let mut rows: Vec<_> = meta.experiments.table1.iter().collect();
            rows.sort_by_key(|(k, _)| (*k).clone());
            for (name, row) in rows {
                println!(
                    "table1 {name:>14}: acc={:.4} params={} macs={}",
                    row.accuracy, row.params, row.macs
                );
            }
        }
        "energy" => {
            let model = EnergyModel::default();
            println!("=== §V.D energy report (paper scale) ===");
            println!("{}", model.report(Scale::Paper));
            if let Ok(meta) = Meta::load_or_synthetic(&cfg.artifacts_dir) {
                println!("\n=== as-built scale ===");
                println!(
                    "{}",
                    model.report(Scale::AsBuilt {
                        frontend_ops: meta.macs.as_built.student_effective,
                        teacher_macs: meta.macs.as_built.teacher_gray.macs,
                        n_templates: meta.artifacts.n_templates as u64,
                        n_features: meta.artifacts.n_features as u64,
                    })
                );
            }
        }
        "classify" => {
            let count: usize = args.get("count", 10).map_err(Error::Config)?;
            let mut pipeline = Pipeline::new(&cfg)?;
            let (images, labels) = test_workload(&pipeline.meta, count, 999);
            let img_len = pipeline.image_len();
            for i in 0..count {
                let res = pipeline.classify_batch(&images[i * img_len..(i + 1) * img_len], 1)?;
                let top = res[0].top1();
                println!(
                    "sample {i}: predicted={} ({}) truth={} energy={:.2} nJ \
                     (front {:.2} + back {:.2})",
                    top.class,
                    CLASS_NAMES[top.class],
                    labels[i],
                    res[0].energy.total_nj(),
                    res[0].energy.front_end_nj,
                    res[0].energy.back_end_nj,
                );
            }
        }
        "eval" => {
            let samples: usize = args.get("samples", 600).map_err(Error::Config)?;
            let batch: usize = args.get("batch", 32).map_err(Error::Config)?;
            let mut pipeline = Pipeline::new(&cfg)?;
            let (images, labels) = test_workload(&pipeline.meta, samples, 1_000_003);
            let eval = pipeline.evaluate(&images, &labels, batch)?;
            println!(
                "engine={} backend={:?} k={} samples={}",
                pipeline.engine_name(),
                cfg.backend,
                cfg.templates_per_class,
                eval.n
            );
            println!("accuracy = {:.4}", eval.accuracy);
            println!(
                "energy   = {:.2} nJ total ({:.2} nJ / inference)",
                eval.total_energy_nj,
                eval.total_energy_nj / eval.n as f64
            );
            println!(
                "wall     = {:.2} s ({:.0} inf/s)",
                eval.wall_secs,
                eval.n as f64 / eval.wall_secs
            );
            println!(
                "per-class accuracy: {:?}",
                eval.per_class_accuracy()
                    .iter()
                    .map(|a| (a * 100.0).round() / 100.0)
                    .collect::<Vec<_>>()
            );
            println!("confusion:");
            for row in &eval.confusion {
                println!("  {row:?}");
            }
        }
        "acam-sim" => {
            let samples: usize = args.get("samples", 300).map_err(Error::Config)?;
            let levels_s = args
                .flags
                .get("levels")
                .cloned()
                .unwrap_or_else(|| "0,0.5,1,2,4".to_string());
            let levels: Vec<f64> = levels_s
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect();
            println!("{:>10} {:>10}", "level", "accuracy");
            for level in levels {
                let mut c = cfg.clone();
                c.backend = Backend::AcamSim;
                c.acam.variability_level = level;
                let mut pipeline = Pipeline::new(&c)?;
                let (images, labels) = test_workload(&pipeline.meta, samples, 1_000_003);
                let eval = pipeline.evaluate(&images, &labels, 32)?;
                println!("{level:>10.2} {:>10.4}", eval.accuracy);
            }
        }
        "serve" => {
            let requests: usize = args.get("requests", 2000).map_err(Error::Config)?;
            let concurrency: usize = args.get("concurrency", 64).map_err(Error::Config)?;
            let shards = cfg.resolve_shards();
            let set = ShardSet::start(&cfg)?;
            let handle = set.handle.clone();
            if let Some(addr) = cfg.resolve_http_addr() {
                // Gateway mode: expose the v1 HTTP/JSON API and block until
                // killed (the synthetic driver below is the no-HTTP mode).
                let mut http = cfg.http.clone();
                http.addr = Some(addr);
                let gateway = hec::gateway::Gateway::start(handle.clone(), &http)?;
                let caps = handle.caps().clone();
                println!(
                    "hec {} gateway listening on {} (engine {}, backend {}, variant {}, \
                     image_len {}, shards {} [{}{}])",
                    hec::api::API_VERSION,
                    gateway.local_addr(),
                    caps.engine,
                    caps.backend.name(),
                    caps.backend_variant.name(),
                    caps.image_len,
                    shards,
                    cfg.shards.policy.name(),
                    if cfg.shards.spill { ", spill" } else { "" },
                );
                println!(
                    "routes: POST /v1/classify  POST /v1/classify/batch  GET /healthz  GET /metrics  \
                     GET|PUT /v1/stores/{{id}}  POST /v1/stores/{{id}}/refit"
                );
                use std::io::Write as _;
                let _ = std::io::stdout().flush();
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(60));
                    println!("{}", handle.snapshot());
                    let _ = std::io::stdout().flush();
                }
            }
            let meta = Meta::load_or_synthetic(&cfg.artifacts_dir)?;
            let (images, _) = test_workload(&meta, 256, 77);
            let img_len = meta.artifacts.image_size * meta.artifacts.image_size;

            let t0 = std::time::Instant::now();
            let mut inflight = std::collections::VecDeque::new();
            let mut done = 0usize;
            let mut submitted = 0usize;
            while done < requests {
                while inflight.len() < concurrency && submitted < requests {
                    let idx = submitted % 256;
                    let img = images[idx * img_len..(idx + 1) * img_len].to_vec();
                    match handle.submit(hec::api::ClassifyRequest::new(img)) {
                        Ok(rx) => {
                            inflight.push_back(rx);
                            submitted += 1;
                        }
                        Err(_) => break, // backpressure: drain one first
                    }
                }
                if let Some(rx) = inflight.pop_front() {
                    let _ = rx.recv();
                    done += 1;
                }
            }
            let secs = t0.elapsed().as_secs_f64();
            println!(
                "=== serving metrics ({requests} requests, concurrency {concurrency}, \
                 {shards} shard{}) ===",
                if shards == 1 { "" } else { "s" }
            );
            println!("{}", handle.snapshot());
            println!("throughput = {:.0} req/s", requests as f64 / secs);
            drop(handle);
            set.shutdown();
        }
        other => {
            eprintln!("unknown subcommand: {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

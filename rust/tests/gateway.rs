//! Gateway end-to-end tests: the HTTP/JSON front door must serve the exact
//! same classifications as the in-process v1 API, under concurrent clients,
//! with the documented error codes — all artifact-free (synthetic fallback
//! deployment), so they run on a clean checkout.
//!
//! The parity test here is the PR's acceptance gate: for a fixed synthetic
//! workload, predictions over HTTP equal `classify_blocking` in-process
//! results, and the per-stage energy split sums to the pre-v1 single
//! `energy_nj` figure (front-end Eq. 13 + back-end Eq. 14).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use hec::api::{binary, ApiError, ClassifyRequest, ClassifyResponse, ErrorCode};
use hec::config::{Backend, HttpConfig, ServeConfig};
use hec::coordinator::shard::{Gate, ShardHooks};
use hec::coordinator::{ClassifySurface, Pipeline, Server, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::energy::EnergyModel;
use hec::gateway::Gateway;
use hec::jsonlite;

/// An artifacts directory that never exists -> synthetic fallback.
const NO_ARTIFACTS: &str = "/nonexistent-hec-artifacts";

fn cfg(backend: Backend) -> ServeConfig {
    let mut c = ServeConfig {
        artifacts_dir: NO_ARTIFACTS.into(),
        backend,
        ..Default::default()
    };
    c.batch.max_batch = 8;
    c.batch.max_wait_us = 500;
    c
}

fn start(backend: Backend) -> (Server, Gateway) {
    let server = Server::start(cfg(backend)).unwrap();
    let http = HttpConfig {
        addr: Some("127.0.0.1:0".to_string()),
        max_connections: 32,
    };
    let gateway = Gateway::start(server.handle.clone(), &http).unwrap();
    (server, gateway)
}

fn workload(p: &Pipeline, n: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
    SyntheticDataset::new(seed, n, p.meta.norm.mean as f32, p.meta.norm.std as f32).batch(0, n)
}

/// Read one HTTP/1.1 response off a stream (status, body) using
/// Content-Length framing, leaving the stream usable for keep-alive.
fn read_response(stream: &mut TcpStream) -> (u16, String) {
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        stream.read_exact(&mut byte).unwrap();
        head.push(byte[0]);
        assert!(head.len() < 64 * 1024, "unterminated response head");
    }
    let head = String::from_utf8(head).unwrap();
    let status: u16 = head.split(' ').nth(1).unwrap().parse().unwrap();
    let content_length: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().unwrap())
        })
        .expect("response must carry Content-Length");
    let mut body = vec![0u8; content_length];
    stream.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

fn send_request(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    body: Option<&str>,
    close: bool,
) {
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: hec-test\r\n");
    if close {
        req.push_str("Connection: close\r\n");
    }
    if let Some(b) = body {
        req.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            b.len()
        ));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    stream.write_all(req.as_bytes()).unwrap();
}

/// One-shot request helper (Connection: close).
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    send_request(&mut stream, method, path, body, true);
    read_response(&mut stream)
}

#[test]
fn healthz_reports_deployment_facts() {
    let (server, gateway) = start(Backend::FeatureCount);
    let (status, body) = http(gateway.local_addr(), "GET", "/healthz", None);
    assert_eq!(status, 200);
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(v.get("api").unwrap().as_str(), Some("v1"));
    assert_eq!(v.get("engine").unwrap().as_str(), Some("interp"));
    assert_eq!(v.get("backend").unwrap().as_str(), Some("fc"));
    assert_eq!(
        v.get("image_len").unwrap().as_usize(),
        Some(server.handle.caps().image_len)
    );
    assert_eq!(v.get("acam_available").unwrap().as_bool(), Some(false));
    assert_eq!(v.get("backend_variant").unwrap().as_str(), Some("acam"));
    gateway.shutdown();
    server.shutdown();
}

#[test]
fn metrics_exposes_prometheus_text() {
    let (server, gateway) = start(Backend::FeatureCount);
    // Drive one request through so counters are non-zero.
    let img = vec![0.0f32; server.handle.caps().image_len];
    let body = ClassifyRequest::new(img).to_value().to_json();
    let (status, _) = http(gateway.local_addr(), "POST", "/v1/classify", Some(&body));
    assert_eq!(status, 200);
    let (status, text) = http(gateway.local_addr(), "GET", "/metrics", None);
    assert_eq!(status, 200);
    for needle in [
        "hec_requests_total",
        "hec_responses_total",
        "hec_queue_depth",
        "hec_in_flight",
        "# TYPE hec_in_flight gauge",
    ] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
    gateway.shutdown();
    server.shutdown();
}

/// THE parity gate: concurrent HTTP clients vs in-process classify_blocking
/// on a fixed synthetic workload — identical predictions, and the response's
/// front/back energy split sums to the pre-v1 single energy figure.
#[test]
fn http_parity_with_in_process_api_under_concurrency() {
    let (server, gateway) = start(Backend::FeatureCount);
    let p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let n = 24;
    let (images, _) = workload(&p, n, 1_000_003);
    let img_len = p.image_len();

    // In-process ground truth through the same running server.
    let expected: Vec<(usize, f64)> = (0..n)
        .map(|i| {
            let r = server
                .handle
                .classify_blocking(images[i * img_len..(i + 1) * img_len].to_vec())
                .unwrap();
            (r.top1().class, r.energy.total_nj())
        })
        .collect();

    // The pre-v1 energy figure, reconstructed independently: Eq. 13
    // front-end + Eq. 14 back-end at this deployment's template scale.
    let em = EnergyModel::default();
    let set = p.store.set(1).unwrap();
    let legacy_energy_nj = em.frontend_nj(p.meta.macs.as_built.student_effective)
        + em.backend_nj(set.num_templates() as u64, set.num_features() as u64);

    // Concurrent HTTP clients replaying the same workload.
    let addr = gateway.local_addr();
    let clients = 4;
    let per_client = n / clients;
    let images = std::sync::Arc::new(images);
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let images = std::sync::Arc::clone(&images);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                for r in 0..per_client {
                    let i = c * per_client + r;
                    let mut req =
                        ClassifyRequest::new(images[i * img_len..(i + 1) * img_len].to_vec());
                    req.request_id = Some(format!("client{c}-req{r}"));
                    let body = req.to_value().to_json();
                    let (status, text) = http(addr, "POST", "/v1/classify", Some(&body));
                    assert_eq!(status, 200, "client {c} req {r}: {text}");
                    let resp =
                        ClassifyResponse::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
                    assert_eq!(resp.request_id.as_deref(), Some(&*format!("client{c}-req{r}")));
                    assert_eq!(resp.engine, "interp");
                    assert_eq!(resp.backend, Backend::FeatureCount);
                    got.push((i, resp.top1().class, resp.energy));
                }
                got
            })
        })
        .collect();

    for j in joins {
        for (i, class, energy) in j.join().unwrap() {
            assert_eq!(class, expected[i].0, "sample {i} diverged over HTTP");
            let total = energy.total_nj();
            assert!(
                (total - expected[i].1).abs() < 1e-9,
                "sample {i}: HTTP energy {total} vs in-process {}",
                expected[i].1
            );
            assert!(
                (total - legacy_energy_nj).abs() < 1e-9,
                "sample {i}: front {} + back {} must sum to the pre-v1 figure {legacy_energy_nj}",
                energy.front_end_nj,
                energy.back_end_nj
            );
            assert!(energy.front_end_nj > 0.0 && energy.back_end_nj > 0.0);
        }
    }
    gateway.shutdown();
    server.shutdown();
}

#[test]
fn batch_endpoint_matches_single_requests() {
    let (server, gateway) = start(Backend::FeatureCount);
    let p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let n = 6;
    let (images, _) = workload(&p, n, 777);
    let img_len = p.image_len();

    let singles: Vec<usize> = (0..n)
        .map(|i| {
            server
                .handle
                .classify_blocking(images[i * img_len..(i + 1) * img_len].to_vec())
                .unwrap()
                .top1()
                .class
        })
        .collect();

    let reqs: Vec<String> = (0..n)
        .map(|i| {
            ClassifyRequest::new(images[i * img_len..(i + 1) * img_len].to_vec())
                .to_value()
                .to_json()
        })
        .collect();
    let body = format!("{{\"requests\": [{}]}}", reqs.join(","));
    let (status, text) = http(gateway.local_addr(), "POST", "/v1/classify/batch", Some(&body));
    assert_eq!(status, 200, "{text}");
    let v = jsonlite::parse(&text).unwrap();
    let responses = v.get("responses").unwrap().as_array().unwrap();
    assert_eq!(responses.len(), n);
    for (i, rv) in responses.iter().enumerate() {
        let resp = ClassifyResponse::from_value(rv).unwrap();
        assert_eq!(resp.top1().class, singles[i], "batch item {i}");
    }

    // A malformed item inside a batch fails alone, not the whole call.
    let body = format!(
        "{{\"requests\": [{}, {{\"image\": [1, 2, 3]}}]}}",
        reqs[0]
    );
    let (status, text) = http(gateway.local_addr(), "POST", "/v1/classify/batch", Some(&body));
    assert_eq!(status, 200);
    let v = jsonlite::parse(&text).unwrap();
    let responses = v.get("responses").unwrap().as_array().unwrap();
    assert!(ClassifyResponse::from_value(&responses[0]).is_ok());
    let err = ApiError::from_value(&responses[1]).expect("second item must be an error envelope");
    assert_eq!(err.code, ErrorCode::InvalidShape);
    gateway.shutdown();
    server.shutdown();
}

#[test]
fn top_k_features_and_overrides_over_http() {
    let (server, gateway) = start(Backend::FeatureCount);
    let p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let (images, _) = workload(&p, 1, 999);
    let caps = server.handle.caps().clone();

    // top_k = 3 with features: ranked predictions, descending scores, and
    // the raw feature vector.
    let mut req = ClassifyRequest::new(images.clone());
    req.top_k = 3;
    req.return_features = true;
    let (status, text) = http(
        gateway.local_addr(),
        "POST",
        "/v1/classify",
        Some(&req.to_value().to_json()),
    );
    assert_eq!(status, 200, "{text}");
    let resp = ClassifyResponse::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(resp.predictions.len(), 3);
    assert!(resp.predictions[0].score >= resp.predictions[1].score);
    assert!(resp.predictions[1].score >= resp.predictions[2].score);
    let top1 = server.handle.classify_blocking(images.clone()).unwrap();
    assert_eq!(resp.top1().class, top1.top1().class, "top-1 pinned to argmax");
    assert_eq!(
        resp.features.as_ref().map(Vec::len),
        Some(p.meta.artifacts.n_features)
    );

    // Per-request backend override onto the similarity matcher.
    let mut req = ClassifyRequest::new(images.clone());
    req.backend = Some(Backend::Similarity);
    let (status, text) = http(
        gateway.local_addr(),
        "POST",
        "/v1/classify",
        Some(&req.to_value().to_json()),
    );
    assert_eq!(status, 200, "{text}");
    let resp = ClassifyResponse::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(resp.backend, Backend::Similarity);

    // ACAM was not programmed in this fc deployment -> 503 + stable code.
    assert!(!caps.acam_available);
    let mut req = ClassifyRequest::new(images);
    req.backend = Some(Backend::AcamSim);
    let (status, text) = http(
        gateway.local_addr(),
        "POST",
        "/v1/classify",
        Some(&req.to_value().to_json()),
    );
    assert_eq!(status, 503);
    let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(err.code, ErrorCode::BackendUnavailable);
    gateway.shutdown();
    server.shutdown();
}

#[test]
fn error_paths_return_stable_codes() {
    let (server, gateway) = start(Backend::FeatureCount);
    let addr = gateway.local_addr();

    // Wrong image shape -> 400 INVALID_SHAPE.
    let body = ClassifyRequest::new(vec![1.0, 2.0]).to_value().to_json();
    let (status, text) = http(addr, "POST", "/v1/classify", Some(&body));
    assert_eq!(status, 400);
    let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(err.code, ErrorCode::InvalidShape);

    // Bad JSON -> 400 MALFORMED_REQUEST.
    let (status, text) = http(addr, "POST", "/v1/classify", Some("{not json"));
    assert_eq!(status, 400);
    let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(err.code, ErrorCode::MalformedRequest);

    // top_k 0 -> 400 INVALID_ARGUMENT.
    let img_len = server.handle.caps().image_len;
    let body = format!(
        "{{\"image\": [{}], \"top_k\": 0}}",
        vec!["0"; img_len].join(",")
    );
    let (status, text) = http(addr, "POST", "/v1/classify", Some(&body));
    assert_eq!(status, 400);
    let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(err.code, ErrorCode::InvalidArgument);

    // Unknown route -> 404; wrong method -> 405.
    let (status, text) = http(addr, "GET", "/v2/classify", None);
    assert_eq!(status, 404);
    let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(err.code, ErrorCode::NotFound);
    let (status, text) = http(addr, "GET", "/v1/classify", None);
    assert_eq!(status, 405);
    let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(err.code, ErrorCode::MethodNotAllowed);
    gateway.shutdown();
    server.shutdown();
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    let (server, gateway) = start(Backend::FeatureCount);
    let mut stream = TcpStream::connect(gateway.local_addr()).unwrap();
    send_request(&mut stream, "GET", "/healthz", None, false);
    let (status, _) = read_response(&mut stream);
    assert_eq!(status, 200);
    let img = vec![0.0f32; server.handle.caps().image_len];
    let body = ClassifyRequest::new(img).to_value().to_json();
    send_request(&mut stream, "POST", "/v1/classify", Some(&body), false);
    let (status, text) = read_response(&mut stream);
    assert_eq!(status, 200);
    assert!(ClassifyResponse::from_value(&jsonlite::parse(&text).unwrap()).is_ok());
    send_request(&mut stream, "GET", "/healthz", None, true);
    let (status, _) = read_response(&mut stream);
    assert_eq!(status, 200);
    gateway.shutdown();
    server.shutdown();
}

/// Sharded parity over HTTP (the PR 4 gate): 4 concurrent clients against
/// a 3-shard gateway produce exactly the same response *set* as the
/// in-process single-shard run — same (sample -> class) assignments and a
/// shard-invariant energy split — and every response names a valid shard.
///
/// (With the default single-template store, bootstrapped templates are
/// seed-independent — k = 1 is the majority-vote template — so every
/// shard's answers are identical and routing nondeterminism under
/// concurrency cannot leak into the response set.)
#[test]
fn sharded_gateway_parity_with_single_shard_under_concurrency() {
    let mut c = cfg(Backend::FeatureCount);
    c.shards.count = 3;
    let set = ShardSet::start(&c).unwrap();
    let http = HttpConfig {
        addr: Some("127.0.0.1:0".to_string()),
        max_connections: 32,
    };
    let gateway = Gateway::start(set.handle.clone(), &http).unwrap();

    // Single-shard in-process ground truth on the same fixed workload.
    let mut p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let n = 24;
    let (images, _) = workload(&p, n, 1_000_003);
    let img_len = p.image_len();
    let expected: Vec<(usize, f64, f64)> = p
        .classify_batch(&images, n)
        .unwrap()
        .into_iter()
        .map(|r| (r.top1().class, r.energy.front_end_nj, r.energy.back_end_nj))
        .collect();

    let addr = gateway.local_addr();
    let clients = 4;
    let per_client = n / clients;
    let images = std::sync::Arc::new(images);
    let joins: Vec<_> = (0..clients)
        .map(|cl| {
            let images = std::sync::Arc::clone(&images);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                for r in 0..per_client {
                    let i = cl * per_client + r;
                    let req =
                        ClassifyRequest::new(images[i * img_len..(i + 1) * img_len].to_vec());
                    let body = req.to_value().to_json();
                    let (status, text) = http(addr, "POST", "/v1/classify", Some(&body));
                    assert_eq!(status, 200, "client {cl} req {r}: {text}");
                    let resp =
                        ClassifyResponse::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
                    got.push((i, resp));
                }
                got
            })
        })
        .collect();

    let mut served_shards = std::collections::BTreeSet::new();
    for j in joins {
        for (i, resp) in j.join().unwrap() {
            let shard = resp.shard.expect("sharded responses carry a shard index");
            assert!(shard < 3, "sample {i}: shard {shard} out of range");
            served_shards.insert(shard);
            assert_eq!(
                resp.top1().class,
                expected[i].0,
                "sample {i} diverged from the single-shard run (served by shard {shard})"
            );
            // The energy split is shard-invariant: bitwise equal to the
            // single-shard figures, whichever shard served the sample.
            assert_eq!(resp.energy.front_end_nj, expected[i].1, "sample {i}");
            assert_eq!(resp.energy.back_end_nj, expected[i].2, "sample {i}");
        }
    }
    assert!(
        served_shards.len() > 1,
        "24 requests from 4 clients all landed on one shard: {served_shards:?}"
    );

    // /healthz names every shard healthy; /metrics carries the labelled
    // per-shard series over HTTP.
    let (status, body) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
    let shards = v.get("shards").unwrap().as_array().unwrap();
    assert_eq!(shards.len(), 3);
    for s in shards {
        assert_eq!(s.get("healthy").unwrap().as_bool(), Some(true));
    }
    let (status, text) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    for needle in [
        "hec_shard_queue_depth{shard=\"2\"}",
        "hec_shard_in_flight{shard=\"0\"}",
        "hec_shard_restarts_total{shard=\"1\"} 0",
        "hec_requests_total 24",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    gateway.shutdown();
    set.shutdown();
}

/// `/healthz` over HTTP flips to `degraded` for exactly the window a
/// shard is down, then recovers — gated on the restart Gate, not timed.
#[test]
fn healthz_reports_degraded_while_a_shard_restarts() {
    let gate = Gate::new();
    let mut c = cfg(Backend::FeatureCount);
    c.shards.count = 2;
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            panic_on: Some("boom".into()),
            restart_gate: Some(std::sync::Arc::clone(&gate)),
            ..Default::default()
        },
    )
    .unwrap();
    let http_cfg = HttpConfig {
        addr: Some("127.0.0.1:0".to_string()),
        max_connections: 8,
    };
    let gateway = Gateway::start(set.handle.clone(), &http_cfg).unwrap();
    let addr = gateway.local_addr();
    let img_len = set.handle.caps().image_len;

    // Trip the panic over HTTP: the request fails with the documented
    // INTERNAL envelope (HTTP 500), never a hang.
    let mut req = ClassifyRequest::new(vec![0.0; img_len]);
    req.request_id = Some("boom".into());
    let (status, text) = http(addr, "POST", "/v1/classify", Some(&req.to_value().to_json()));
    assert_eq!(status, 500, "{text}");
    let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
    assert_eq!(err.code, ErrorCode::Internal);

    // The restart is parked on the gate: /healthz must say degraded and
    // name the down shard.
    gate.await_arrivals(1);
    let (status, body) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("degraded"));
    let down: Vec<bool> = v
        .get("shards")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|s| s.get("healthy").unwrap().as_bool().unwrap())
        .collect();
    assert!(down.contains(&false), "one shard must report unhealthy");
    assert!(down.contains(&true), "the other shard keeps serving");
    // The healthy shard still serves requests while degraded.
    let body = ClassifyRequest::new(vec![0.0; img_len]).to_value().to_json();
    let (status, _) = http(addr, "POST", "/v1/classify", Some(&body));
    assert_eq!(status, 200);

    // Release the restart; once recovery is signalled, /healthz is ok again
    // and the restart shows up in the labelled metrics.
    gate.release();
    gate.await_arrivals(2);
    let (status, body) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
    let (_, text) = http(addr, "GET", "/metrics", None);
    assert!(text.contains("hec_restarts_total 1"), "{text}");
    gateway.shutdown();
    set.shutdown();
}

/// Send raw request bytes on a fresh connection and read one response.
/// `half_close` shuts the write side first, so the server sees EOF on a
/// deliberately truncated body instead of waiting for more bytes.
fn send_raw(addr: SocketAddr, bytes: &[u8], half_close: bool) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(bytes).unwrap();
    if half_close {
        stream.shutdown(std::net::Shutdown::Write).unwrap();
    }
    read_response(&mut stream)
}

/// A POST with an arbitrary (possibly binary) body and content type.
fn raw_post(path: &str, content_type: &str, body: &[u8]) -> Vec<u8> {
    let mut req = format!(
        "POST {path} HTTP/1.1\r\nHost: hec-test\r\nConnection: close\r\n\
         Content-Type: {content_type}\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )
    .into_bytes();
    req.extend_from_slice(body);
    req
}

/// A chunked POST: `frames` are pre-formatted chunk lines joined with CRLF
/// (the trailing `0` chunk and blank line must be included by the caller).
fn chunked_post(path: &str, extra_headers: &str, frames: &str) -> Vec<u8> {
    format!(
        "POST {path} HTTP/1.1\r\nHost: hec-test\r\nConnection: close\r\n\
         Content-Type: application/json\r\n{extra_headers}\
         Transfer-Encoding: chunked\r\n\r\n{}",
        frames.replace('\n', "\r\n")
    )
    .into_bytes()
}

/// Split a JSON body into 7-byte chunks of valid chunked framing.
fn chunk_frames(body: &str) -> String {
    let mut out = String::new();
    for piece in body.as_bytes().chunks(7) {
        out.push_str(&format!("{:x}\n", piece.len()));
        out.push_str(std::str::from_utf8(piece).unwrap());
        out.push('\n');
    }
    out.push_str("0\n\n");
    out
}

/// Drop every `timing` subobject (queue/compute micros are the one
/// legitimately nondeterministic part of a response) so the rest can be
/// compared byte-for-byte.
fn strip_timing(v: &jsonlite::Value) -> jsonlite::Value {
    match v {
        jsonlite::Value::Obj(m) => jsonlite::Value::Obj(
            m.iter()
                .filter(|(k, _)| k.as_str() != "timing")
                .map(|(k, x)| (k.clone(), strip_timing(x)))
                .collect(),
        ),
        jsonlite::Value::Arr(a) => jsonlite::Value::Arr(a.iter().map(strip_timing).collect()),
        other => other.clone(),
    }
}

fn comparable(text: &str) -> String {
    strip_timing(&jsonlite::parse(text).unwrap()).to_json()
}

/// The tentpole's wire-parity gate: the same logical request sent three
/// ways — buffered JSON, chunked JSON, raw binary — must produce
/// byte-identical response JSON (timing subobject aside), for both
/// `/v1/classify` and `/v1/classify/batch`.
#[test]
fn streaming_chunked_and_binary_ingestion_are_byte_identical() {
    let (server, gateway) = start(Backend::FeatureCount);
    let addr = gateway.local_addr();
    let p = Pipeline::new(&cfg(Backend::FeatureCount)).unwrap();
    let n = 4;
    let (images, _) = workload(&p, n, 424_242);
    let img_len = p.image_len();

    let reqs: Vec<ClassifyRequest> = (0..n)
        .map(|i| {
            let mut r = ClassifyRequest::new(images[i * img_len..(i + 1) * img_len].to_vec());
            r.top_k = 1 + (i % 3);
            r.request_id = Some(format!("parity-{i}"));
            if i == 1 {
                r.return_features = true;
            }
            r
        })
        .collect();

    // --- /v1/classify, all three encodings of request 0 ------------------
    let body = reqs[0].to_value().to_json();
    let (s1, buffered) = http(addr, "POST", "/v1/classify", Some(&body));
    let (s2, chunked) = send_raw(
        addr,
        &chunked_post("/v1/classify", "", &chunk_frames(&body)),
        false,
    );
    let (s3, bin) = send_raw(
        addr,
        &raw_post(
            "/v1/classify",
            binary::CONTENT_TYPE,
            &binary::encode_batch(&reqs[..1]),
        ),
        false,
    );
    assert_eq!((s1, s2, s3), (200, 200, 200), "{buffered} {chunked} {bin}");
    assert_eq!(comparable(&buffered), comparable(&chunked));
    assert_eq!(comparable(&buffered), comparable(&bin));

    // --- /v1/classify/batch, all three encodings of the full set ---------
    let items: Vec<String> = reqs.iter().map(|r| r.to_value().to_json()).collect();
    let body = format!("{{\"requests\": [{}]}}", items.join(","));
    let (s1, buffered) = http(addr, "POST", "/v1/classify/batch", Some(&body));
    let (s2, chunked) = send_raw(
        addr,
        &chunked_post("/v1/classify/batch", "", &chunk_frames(&body)),
        false,
    );
    let (s3, bin) = send_raw(
        addr,
        &raw_post(
            "/v1/classify/batch",
            binary::CONTENT_TYPE,
            &binary::encode_batch(&reqs),
        ),
        false,
    );
    assert_eq!((s1, s2, s3), (200, 200, 200), "{buffered} {chunked} {bin}");
    assert_eq!(comparable(&buffered), comparable(&chunked));
    assert_eq!(comparable(&buffered), comparable(&bin));

    // Response ordering and ids survive every encoding.
    let v = jsonlite::parse(&bin).unwrap();
    let responses = v.get("responses").unwrap().as_array().unwrap();
    for (i, rv) in responses.iter().enumerate() {
        let resp = ClassifyResponse::from_value(rv).unwrap();
        assert_eq!(resp.request_id.as_deref(), Some(&*format!("parity-{i}")));
    }
    gateway.shutdown();
    server.shutdown();
}

/// Every new malformed-input class maps to its documented status + stable
/// error code over a real socket — no hangs, no connection resets without
/// a response.
#[test]
fn streaming_error_paths_return_stable_codes() {
    let (server, gateway) = start(Backend::FeatureCount);
    let addr = gateway.local_addr();
    let assert_err = |(status, text): (u16, String), want_status: u16, want_code: ErrorCode| {
        assert_eq!(status, want_status, "{text}");
        let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
        assert_eq!(err.code, want_code, "{text}");
    };

    // Bad chunk size line -> 400 MALFORMED_REQUEST.
    assert_err(
        send_raw(addr, &chunked_post("/v1/classify", "", "zz\n{}\n0\n\n"), false),
        400,
        ErrorCode::MalformedRequest,
    );
    // A chunk size declared over the body cap fails fast -> 413.
    assert_err(
        send_raw(
            addr,
            &chunked_post("/v1/classify", "", "ffffffffff\n"),
            false,
        ),
        413,
        ErrorCode::MalformedRequest,
    );
    // Truncated chunked body (client half-closes mid-chunk) -> 400.
    assert_err(
        send_raw(
            addr,
            &chunked_post("/v1/classify", "", "a\n{\"image\""),
            true,
        ),
        400,
        ErrorCode::MalformedRequest,
    );
    // Oversized chunk-size line -> 400.
    let long_line = format!("2;{}\nok\n0\n\n", "e".repeat(400));
    assert_err(
        send_raw(addr, &chunked_post("/v1/classify", "", &long_line), false),
        400,
        ErrorCode::MalformedRequest,
    );
    // Unsupported transfer coding -> 501.
    assert_err(
        send_raw(
            addr,
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n",
            false,
        ),
        501,
        ErrorCode::MalformedRequest,
    );
    // Content-Length alongside chunked -> 400.
    assert_err(
        send_raw(
            addr,
            &chunked_post("/v1/classify", "Content-Length: 5\r\n", "0\n\n"),
            false,
        ),
        400,
        ErrorCode::MalformedRequest,
    );
    // Binary: bad magic -> 400 MALFORMED_REQUEST.
    assert_err(
        send_raw(
            addr,
            &raw_post("/v1/classify", binary::CONTENT_TYPE, b"NOPE\x01\x00\x00\x00\x00"),
            false,
        ),
        400,
        ErrorCode::MalformedRequest,
    );
    // Binary: truncated frame -> 400 MALFORMED_REQUEST.
    let whole = binary::encode_batch(&[ClassifyRequest::new(vec![0.5; 4])]);
    assert_err(
        send_raw(
            addr,
            &raw_post("/v1/classify", binary::CONTENT_TYPE, &whole[..whole.len() - 3]),
            false,
        ),
        400,
        ErrorCode::MalformedRequest,
    );
    // Binary: two items on the single endpoint -> 400 INVALID_ARGUMENT.
    let two = binary::encode_batch(&[
        ClassifyRequest::new(vec![0.0; 4]),
        ClassifyRequest::new(vec![1.0; 4]),
    ]);
    assert_err(
        send_raw(addr, &raw_post("/v1/classify", binary::CONTENT_TYPE, &two), false),
        400,
        ErrorCode::InvalidArgument,
    );
    // Non-UTF8 JSON body -> 400 MALFORMED_REQUEST.
    assert_err(
        send_raw(
            addr,
            &raw_post("/v1/classify", "application/json", b"{\"image\": [\xff\xfe]}"),
            false,
        ),
        400,
        ErrorCode::MalformedRequest,
    );
    // A POST with a body but neither Content-Length nor Transfer-Encoding
    // -> 411 LENGTH_REQUIRED.  (Regression: this used to be read as an
    // empty body and misreported as a parse error.)
    assert_err(
        send_raw(
            addr,
            b"POST /v1/classify HTTP/1.1\r\nHost: hec-test\r\nConnection: close\r\n\
              Content-Type: application/json\r\n\r\n{\"image\": [0.0]}",
            true,
        ),
        411,
        ErrorCode::LengthRequired,
    );
    // An explicit zero deadline is a client bug, rejected at decode time
    // -> 400 INVALID_ARGUMENT (uniform across tree/streaming/binary; the
    // decoder-level parity lives in rust/tests/ingest_fuzz.rs).
    assert_err(
        http(
            addr,
            "POST",
            "/v1/classify",
            Some("{\"image\": [0.0], \"deadline_ms\": 0}"),
        ),
        400,
        ErrorCode::InvalidArgument,
    );
    gateway.shutdown();
    server.shutdown();
}

/// Chunked trailers are consumed, not leaked into the next request: a
/// keep-alive connection survives a trailered chunked upload.
#[test]
fn chunked_trailers_and_keep_alive_interoperate() {
    let (server, gateway) = start(Backend::FeatureCount);
    let img_len = server.handle.caps().image_len;
    let body = ClassifyRequest::new(vec![0.0; img_len]).to_value().to_json();

    let mut frames = String::new();
    for piece in body.as_bytes().chunks(100) {
        frames.push_str(&format!("{:x}\n", piece.len()));
        frames.push_str(std::str::from_utf8(piece).unwrap());
        frames.push('\n');
    }
    frames.push_str("0\nX-Checksum: ab\nX-Other: cd\n\n");
    let wire = format!(
        "POST /v1/classify HTTP/1.1\r\nHost: hec-test\r\n\
         Content-Type: application/json\r\nTransfer-Encoding: chunked\r\n\r\n{}",
        frames.replace('\n', "\r\n")
    );

    let mut stream = TcpStream::connect(gateway.local_addr()).unwrap();
    stream.write_all(wire.as_bytes()).unwrap();
    let (status, text) = read_response(&mut stream);
    assert_eq!(status, 200, "{text}");
    // Same connection, next request: the trailers must not poison it.
    send_request(&mut stream, "GET", "/healthz", None, true);
    let (status, _) = read_response(&mut stream);
    assert_eq!(status, 200);
    gateway.shutdown();
    server.shutdown();
}

#[test]
fn in_process_override_rejection_matches_http_semantics() {
    // The same BACKEND_UNAVAILABLE contract, without the network in the
    // loop: the submit-time check fires before anything is queued.
    let server = Server::start(cfg(Backend::FeatureCount)).unwrap();
    let mut req = ClassifyRequest::new(vec![0.0; server.handle.caps().image_len]);
    req.backend = Some(Backend::AcamSim);
    let err = server.handle.submit(req).err().expect("must be rejected");
    assert_eq!(err.code, ErrorCode::BackendUnavailable);
    let snap = server.handle.metrics.snapshot();
    assert_eq!(snap.in_flight, 0, "rejected request must not leak in_flight");
    server.shutdown();
}

//! Fig. 1 reproduction: mean- vs median-based per-feature thresholds and
//! their downstream effect on matching accuracy.
//!
//! The paper's argument: ReLU sparsity drags the per-feature *mean* below
//! the *median*, so mean-thresholding preserves informative low-magnitude
//! activations and classifies better.  We regenerate both threshold vectors
//! (they ship in templates.json), print the distributional comparison, and
//! assert mean-threshold accuracy >= median-threshold accuracy (within
//! noise) as the paper found.

use hec::benchkit::{bench, paper_row, section};
use hec::runtime::Meta;
use hec::templates::TemplateStore;

fn main() {
    if !std::path::Path::new("artifacts/meta.json").is_file() {
        println!("fig1_thresholding: run `make artifacts` first");
        return;
    }
    let meta = Meta::load("artifacts").unwrap();
    let store = TemplateStore::load("artifacts/templates.json").unwrap();

    section("Fig. 1 — threshold vector comparison (mean vs median)");
    let mean = &store.thresholds_mean;
    let median = &store.thresholds_median;
    let n = mean.len();
    let mean_below = mean
        .iter()
        .zip(median.iter())
        .filter(|(m, d)| m < d)
        .count();
    let avg_mean: f64 = mean.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    let avg_median: f64 = median.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    println!("features: {n}");
    println!("avg mean threshold   = {avg_mean:.4}");
    println!("avg median threshold = {avg_median:.4}");
    println!(
        "features where mean < median: {mean_below}/{n} ({:.0}%)",
        100.0 * mean_below as f64 / n as f64
    );

    section("downstream matching accuracy (threshold mode ablation)");
    let fig1 = &meta.experiments.fig1_threshold_accuracy;
    let acc_mean = fig1["mean"];
    let acc_median = fig1["median"];
    // Paper reports 70.91% with the deployed mean thresholds (§V.B); the
    // median variant underperforms it (Fig. 1's conclusion).
    paper_row("mean-threshold", 70.91 / 100.0, acc_mean, "acc");
    println!("median-threshold measured: {acc_median:.4}");
    assert!(
        acc_mean >= acc_median - 0.02,
        "paper shape: mean thresholding must not lose to median (got {acc_mean:.4} vs {acc_median:.4})"
    );

    section("binarisation throughput (deployed thresholds)");
    let mut rng = hec::rng::Rng::new(5);
    let feats: Vec<f32> = (0..n).map(|_| rng.range(0.0, 2.0) as f32).collect();
    bench("binarize 784 features", 1000, 50000, || {
        std::hint::black_box(store.binarize(std::hint::black_box(&feats)));
    });
    println!("\nfig1_thresholding: PASS");
}

//! Gateway ingestion perf: tree-parse (jsonlite `Value` + `from_value`) vs
//! the streaming pull-parser decode vs the raw-binary frame decode, on a
//! realistic batch-classify body (64 normalised 32x32 images).
//!
//! The decode paths must agree bit-for-bit before anything is timed — the
//! streaming path is only admissible because it is indistinguishable from
//! the tree path on the wire.
//!
//! Emits `BENCH_gateway_ingest.json` (override with `HEC_BENCH_OUT`) and
//! asserts the acceptance bar: streaming >= 3x tree-parse throughput on the
//! batch decode.  `HEC_BENCH_SMOKE=1` shrinks the timing budget;
//! `HEC_BENCH_NO_ASSERT=1` reports without gating.

use std::time::Duration;

use hec::api::{binary, stream, ApiError, ClassifyRequest};
use hec::benchkit::{self, bench_for, section, BenchResult};
use hec::dataset::{SyntheticDataset, IMAGE_SIZE};
use hec::jsonlite::{self, Value};

const ITEMS: usize = 64;
const PIXELS: usize = IMAGE_SIZE * IMAGE_SIZE;

/// The gateway's pre-streaming decode path, kept verbatim as the baseline
/// and oracle: full `Value` tree, then `from_value` per item.
fn tree_decode_batch(text: &str) -> Vec<Result<ClassifyRequest, ApiError>> {
    let doc = jsonlite::parse(text).expect("bench body is valid JSON");
    doc.get("requests")
        .and_then(Value::as_array)
        .expect("bench body is an envelope")
        .iter()
        .map(ClassifyRequest::from_value)
        .collect()
}

fn requests() -> Vec<ClassifyRequest> {
    let ds = SyntheticDataset::new(7, ITEMS, 0.1307, 0.3081);
    (0..ITEMS)
        .map(|i| {
            let mut req = ClassifyRequest::new(ds.image(i));
            req.top_k = 3;
            req
        })
        .collect()
}

fn envelope_json(reqs: &[ClassifyRequest]) -> String {
    let items: Vec<Value> = reqs.iter().map(ClassifyRequest::to_value).collect();
    Value::Obj(std::collections::BTreeMap::from([(
        "requests".to_string(),
        Value::Arr(items),
    )]))
    .to_json()
}

fn assert_same(a: &[Result<ClassifyRequest, ApiError>], b: &[Result<ClassifyRequest, ApiError>]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.image.len(), y.image.len());
        assert!(
            x.image
                .iter()
                .zip(&y.image)
                .all(|(p, q)| p.to_bits() == q.to_bits()),
            "pixel bits diverge between decode paths"
        );
        assert_eq!(x.top_k, y.top_k);
        assert_eq!(x.backend, y.backend);
        assert_eq!(x.return_features, y.return_features);
        assert_eq!(x.request_id, y.request_id);
    }
}

fn main() {
    let smoke = std::env::var("HEC_BENCH_SMOKE").is_ok();
    let budget = if smoke {
        Duration::from_millis(300)
    } else {
        Duration::from_millis(1500)
    };

    let reqs = requests();
    let json_body = envelope_json(&reqs);
    let bin_body = binary::encode_batch(&reqs);

    // -- correctness gate before timing -----------------------------------
    let tree_items = tree_decode_batch(&json_body);
    let stream_items =
        stream::decode_batch_envelope(&json_body, PIXELS, |r| r).expect("stream decode");
    let bin_items = binary::decode_batch(&bin_body).expect("binary decode");
    assert_same(&tree_items, &stream_items);
    assert_same(&tree_items, &bin_items);
    drop((tree_items, stream_items, bin_items));

    section(&format!(
        "batch decode: {ITEMS} x {PIXELS}px (JSON {} KiB, binary {} KiB)",
        json_body.len() / 1024,
        bin_body.len() / 1024
    ));
    let tree = bench_for("tree decode (jsonlite + from_value)", 1, 3, budget, || {
        let items = tree_decode_batch(&json_body);
        assert_eq!(items.len(), ITEMS);
    });
    let streaming = bench_for("stream decode (pull parser)", 1, 3, budget, || {
        let items = stream::decode_batch_envelope(&json_body, PIXELS, |r| r).unwrap();
        assert_eq!(items.len(), ITEMS);
    });
    let bin = bench_for("binary decode (x-hec-f32)", 1, 3, budget, || {
        let items = binary::decode_batch(&bin_body).unwrap();
        assert_eq!(items.len(), ITEMS);
    });

    let speedup_stream = tree.mean.as_secs_f64() / streaming.mean.as_secs_f64();
    let speedup_binary = tree.mean.as_secs_f64() / bin.mean.as_secs_f64();
    println!(
        "speedup vs tree: {speedup_stream:.2}x streaming JSON, {speedup_binary:.2}x raw binary"
    );

    // Single-request context row (the /v1/classify hot path).
    section("single-request decode: 1 x 1024px");
    let one_json = reqs[0].to_value().to_json();
    let one_bin = binary::encode_batch(&reqs[..1]);
    let tree1 = bench_for("tree decode single", 1, 3, budget, || {
        let v = jsonlite::parse(&one_json).unwrap();
        ClassifyRequest::from_value(&v).unwrap();
    });
    let stream1 = bench_for("stream decode single", 1, 3, budget, || {
        stream::decode_classify_request(&one_json, PIXELS).unwrap();
    });
    let bin1 = bench_for("binary decode single", 1, 3, budget, || {
        binary::decode_single(&one_bin).unwrap();
    });

    let out =
        std::env::var("HEC_BENCH_OUT").unwrap_or_else(|_| "BENCH_gateway_ingest.json".into());
    let extra = vec![
        ("items", Value::Num(ITEMS as f64)),
        ("pixels_per_item", Value::Num(PIXELS as f64)),
        ("json_body_bytes", Value::Num(json_body.len() as f64)),
        ("binary_body_bytes", Value::Num(bin_body.len() as f64)),
        ("speedup_stream", Value::Num(speedup_stream)),
        ("speedup_binary", Value::Num(speedup_binary)),
        ("smoke", Value::Bool(smoke)),
    ];
    let results = [tree, streaming, bin, tree1, stream1, bin1];
    let rows: Vec<&BenchResult> = results.iter().collect();
    benchkit::write_json_report(&out, "hec/gateway-ingest/v1", &extra, &rows)
        .expect("write bench report");
    println!("\nwrote {out}");

    if smoke || std::env::var("HEC_BENCH_NO_ASSERT").is_ok() {
        println!("gateway_ingest: speedup_stream = {speedup_stream:.2}x (assertion disabled)");
    } else {
        assert!(
            speedup_stream >= 3.0,
            "streaming decode must be >= 3x tree decode on batch classify, \
             measured {speedup_stream:.2}x"
        );
        assert!(
            speedup_binary >= speedup_stream,
            "binary decode should not be slower than streaming JSON \
             ({speedup_binary:.2}x vs {speedup_stream:.2}x)"
        );
        println!("gateway_ingest: PASS ({speedup_stream:.2}x >= 3x)");
    }
}

//! Analogue winner-take-all (Fig. 3's final layer): computes the Eq.-12
//! argmax over row similarities in the analogue domain and emits a one-hot
//! vector.
//!
//! Real WTA comparators carry input-referred offsets; the model adds a
//! per-input Gaussian offset of `wta_offset_v` volts, so near-ties can flip
//! under variability — exactly the failure mode a circuit designer budgets
//! the offset for.


use super::variability::Variability;
use super::VDD;

/// One-hot winner over analogue similarities (values in [0, 1], scaled by
/// VDD internally).  Ties break to the lowest index (matches the digital
/// reference in [`crate::matching::classify`]).
pub fn winner_take_all(
    similarities: &[f64],
    var: &Variability,
    rng: &mut crate::rng::Rng,
) -> (usize, Vec<u8>) {
    assert!(!similarities.is_empty(), "WTA needs at least one input");
    let sigma = var.wta_offset_v;
    let mut best = 0usize;
    let mut best_v = f64::NEG_INFINITY;
    for (i, &s) in similarities.iter().enumerate() {
        let mut v = s * VDD;
        if sigma > 0.0 {
            v += rng.normal(0.0, sigma);
        }
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    let mut onehot = vec![0u8; similarities.len()];
    onehot[best] = 1;
    (best, onehot)
}

/// Per-class WTA: reduce template similarities to class similarities
/// (max over each class's templates — the multi-template rule of
/// Section II-D1) and then take the winner.
pub fn winner_take_all_classes(
    similarities: &[f64],
    class_of: &[usize],
    num_classes: usize,
    var: &Variability,
    rng: &mut crate::rng::Rng,
) -> usize {
    rank_classes(similarities, class_of, num_classes, var, rng)[0].0
}

/// Ranked per-class WTA readout: every class with its (offset-noised)
/// comparator voltage normalised back to a [0, 1]-ish similarity, sorted
/// descending with ties to the lower class id.
///
/// Draws the same per-class offset samples in the same order as
/// [`winner_take_all`], so element 0 is exactly the class
/// [`winner_take_all_classes`] would return for the same RNG state — the
/// ranked view is the top-k generalisation, not a different decision rule.
pub fn rank_classes(
    similarities: &[f64],
    class_of: &[usize],
    num_classes: usize,
    var: &Variability,
    rng: &mut crate::rng::Rng,
) -> Vec<(usize, f64)> {
    assert_eq!(similarities.len(), class_of.len());
    assert!(num_classes > 0, "WTA needs at least one class");
    let mut per_class = vec![f64::NEG_INFINITY; num_classes];
    for (&s, &c) in similarities.iter().zip(class_of.iter()) {
        if s > per_class[c] {
            per_class[c] = s;
        }
    }
    let sigma = var.wta_offset_v;
    let mut ranked: Vec<(usize, f64)> = per_class
        .into_iter()
        .enumerate()
        .map(|(c, s)| {
            let mut v = s * VDD;
            if sigma > 0.0 {
                v += rng.normal(0.0, sigma);
            }
            (c, v / VDD)
        })
        .collect();
    ranked.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;
        
    fn rng() -> crate::rng::Rng {
        crate::rng::Rng::new(0)
    }

    #[test]
    fn picks_max_and_onehot() {
        let (w, oh) = winner_take_all(&[0.2, 0.9, 0.5], &Variability::ideal(), &mut rng());
        assert_eq!(w, 1);
        assert_eq!(oh, vec![0, 1, 0]);
    }

    #[test]
    fn tie_breaks_low_index() {
        let (w, _) = winner_take_all(&[0.7, 0.7], &Variability::ideal(), &mut rng());
        assert_eq!(w, 0);
    }

    #[test]
    fn per_class_max_rule() {
        // class 0: (0.1, 0.95); class 1: (0.5, 0.6) -> class 0 wins.
        let w = winner_take_all_classes(
            &[0.1, 0.95, 0.5, 0.6],
            &[0, 0, 1, 1],
            2,
            &Variability::ideal(),
            &mut rng(),
        );
        assert_eq!(w, 0);
    }

    #[test]
    fn rank_classes_top1_equals_winner_and_is_sorted() {
        let sims = [0.1, 0.95, 0.5, 0.6, 0.2, 0.2];
        let class_of = [0, 0, 1, 1, 2, 2];
        let ranked = rank_classes(&sims, &class_of, 3, &Variability::ideal(), &mut rng());
        assert_eq!(ranked.len(), 3);
        assert_eq!(ranked[0].0, 0); // class 0 best 0.95
        assert!(ranked[0].1 >= ranked[1].1 && ranked[1].1 >= ranked[2].1);
        let w = winner_take_all_classes(&sims, &class_of, 3, &Variability::ideal(), &mut rng());
        assert_eq!(ranked[0].0, w);
        // Ideal readout reports the clean per-class similarity.
        assert!((ranked[0].1 - 0.95).abs() < 1e-12);
    }

    #[test]
    fn rank_classes_noisy_matches_winner_for_same_rng_state() {
        let noisy = Variability {
            wta_offset_v: 0.05,
            ..Default::default()
        };
        let sims = [0.5, 0.505, 0.49];
        let class_of = [0, 1, 2];
        for seed in 0..50 {
            let mut r1 = crate::rng::Rng::new(seed);
            let mut r2 = crate::rng::Rng::new(seed);
            let w = winner_take_all_classes(&sims, &class_of, 3, &noisy, &mut r1);
            let ranked = rank_classes(&sims, &class_of, 3, &noisy, &mut r2);
            assert_eq!(ranked[0].0, w, "seed {seed}");
        }
    }

    #[test]
    fn offset_noise_can_flip_near_ties_but_not_clear_wins() {
        let noisy = Variability {
            wta_offset_v: 0.02,
            ..Default::default()
        };
        let mut r = rng();
        // Clear win: 0.9 vs 0.1 (0.8 * VDD = 1.44 V apart >> 20 mV offsets).
        for _ in 0..100 {
            let (w, _) = winner_take_all(&[0.1, 0.9], &noisy, &mut r);
            assert_eq!(w, 1);
        }
        // Near-tie: 1 mV apart — offsets dominate, both outcomes occur.
        let mut winners = std::collections::HashSet::new();
        for _ in 0..200 {
            let (w, _) = winner_take_all(&[0.5, 0.5005], &noisy, &mut r);
            winners.insert(w);
        }
        assert_eq!(winners.len(), 2);
    }
}

//! Template-store registry tests — the multi-tenant hot-swap acceptance
//! gate.
//!
//! Everything runs artifact-free under fixed seeds with **no sleeps**:
//! orderings are forced with the [`hec::coordinator::shard::Gate`]
//! rendezvous, never raced against wall-clock time.  The suite pins four
//! properties:
//!
//! 1. The default single-store, no-tenant configuration is **bitwise
//!    invisible**: predictions, RNG streams, wire JSON, and `/metrics`
//!    are identical to a registry-free build (the registry is attached to
//!    every shard, but inert until a publish or a tenant appears).
//! 2. A publish swaps **atomically at batch boundaries**: a batch parked
//!    mid-flight (Gate) finishes on the version it resolved before
//!    parking; the very next batch serves the published version.
//! 3. Tenant quotas reject with `QUOTA_EXCEEDED` without consuming
//!    queue slots, and the per-tenant gauges stay drift-free across
//!    delivery and panic-restart alike.
//! 4. The `/v1/stores` admin surface round-trips over a real socket:
//!    JSON and raw `HECT` uploads, online re-fit, and tagged classify
//!    responses.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use hec::api::{binary, ApiError, ClassifyRequest, ErrorCode};
use hec::config::{Backend, Engine, HttpConfig, RoutePolicy, ServeConfig, TenantSpec};
use hec::coordinator::shard::{Gate, ShardHooks};
use hec::coordinator::{ClassifySurface, Pipeline, Server, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::gateway::Gateway;
use hec::jsonlite;
use hec::store::{encode_hect, StoreRegistry};
use hec::templates::TemplateStore;

/// An artifacts directory that never exists -> synthetic fallback.
const NO_ARTIFACTS: &str = "/nonexistent-hec-artifacts";

fn cfg(backend: Backend, shards: usize) -> ServeConfig {
    let mut c = ServeConfig {
        artifacts_dir: NO_ARTIFACTS.into(),
        backend,
        engine: Engine::Interp,
        ..Default::default()
    };
    c.batch.max_batch = 4;
    c.batch.max_wait_us = 0; // serial submits -> singleton batches, no timing
    c.shards.count = shards;
    c.shards.policy = RoutePolicy::RoundRobin;
    c
}

fn workload(n: usize, seed: u64) -> (Vec<f32>, usize) {
    let meta = hec::runtime::Meta::synthetic();
    let ds = SyntheticDataset::new(seed, n, meta.norm.mean as f32, meta.norm.std as f32);
    let (images, _) = ds.batch(0, n);
    let s = meta.artifacts.image_size;
    (images, s * s)
}

/// Class-separable labelled rows matching the registry's geometry, for
/// building publishable stores and `HECT` upload frames.
fn labelled_rows(reg: &StoreRegistry, seed: u64) -> (Vec<usize>, Vec<f32>) {
    let (num_classes, n_features, _) = reg.geometry();
    let per_class = 4;
    let n = per_class * num_classes;
    let labels: Vec<usize> = (0..n).map(|i| i % num_classes).collect();
    let mut rng = hec::rng::Rng::new(seed);
    let mut feats = vec![0.0f32; n * n_features];
    for (i, l) in labels.iter().enumerate() {
        for j in 0..n_features {
            feats[i * n_features + j] = (*l as f32) * 0.3
                + rng.u01() as f32
                + if j % num_classes == *l { 1.5 } else { 0.0 };
        }
    }
    (labels, feats)
}

fn publishable_store(reg: &StoreRegistry, seed: u64) -> TemplateStore {
    let (num_classes, n_features, _) = reg.geometry();
    let (labels, feats) = labelled_rows(reg, seed);
    TemplateStore::from_features(&feats, &labels, n_features, num_classes, seed).unwrap()
}

/// Everything parity needs from one response, compared bitwise.
#[derive(Debug, PartialEq)]
struct Outcome {
    predictions: Vec<(usize, f64)>,
    front_end_nj: f64,
    back_end_nj: f64,
}

/// Property 1, digital path: a ShardSet (which now always carries the
/// registry) under the default configuration is bitwise identical to
/// independent registry-free [`Pipeline`]s, responses carry no store
/// fields on the wire, and `/metrics` has no `hec_store_*`/`hec_tenant_*`
/// series — while the (additive-by-design) latency histograms are there.
#[test]
fn default_registry_is_bitwise_invisible() {
    let requests = 8;
    let n_shards = 2;
    let c = cfg(Backend::FeatureCount, n_shards);
    let (images, img_len) = workload(requests, 1_000_003);
    let set = ShardSet::start(&c).unwrap();

    let mut got: Vec<(usize, Outcome)> = Vec::new();
    for i in 0..requests {
        let mut req = ClassifyRequest::new(images[i * img_len..(i + 1) * img_len].to_vec());
        req.top_k = 3;
        let resp = set.handle.submit_blocking(req).unwrap();
        assert_eq!(resp.shard, Some(i % n_shards));
        assert_eq!(resp.store, None, "default config must not tag stores");
        assert_eq!(resp.store_version, None);
        let wire = resp.to_value().to_json();
        assert!(
            !wire.contains("\"store\"") && !wire.contains("\"store_version\""),
            "default-config wire bytes changed: {wire}"
        );
        got.push((
            resp.shard.unwrap(),
            Outcome {
                predictions: resp.predictions.iter().map(|p| (p.class, p.score)).collect(),
                front_end_nj: resp.energy.front_end_nj,
                back_end_nj: resp.energy.back_end_nj,
            },
        ));
    }

    let text = set.handle.prometheus_text();
    assert!(
        !text.contains("hec_store_") && !text.contains("hec_tenant_"),
        "inert registry must not render metrics:\n{text}"
    );
    for needle in [
        "# TYPE hec_latency_microseconds histogram",
        "hec_latency_microseconds_count{shard=\"0\"} 4",
        "hec_latency_microseconds_count{shard=\"1\"} 4",
        "hec_backend_latency_microseconds_count{backend=\"fc\",shard=\"0\"} 4",
        "hec_latency_microseconds_bucket{shard=\"0\",le=\"+Inf\"} 4",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    set.shutdown();

    // N independent single-pipeline runs (no registry anywhere near them),
    // seeds base + shard index, each fed its routed subsequence in order.
    for s in 0..n_shards {
        let mut sc = c.clone();
        sc.shards.count = 1;
        sc.acam.seed = c.acam.seed.wrapping_add(s as u64);
        let mut p = Pipeline::new(&sc).unwrap();
        let mut routed = got.iter().filter(|(shard, _)| *shard == s);
        for i in (0..requests).filter(|i| i % n_shards == s) {
            let opts = hec::api::ClassifyOptions {
                top_k: 3,
                backend: None,
                return_features: false,
            };
            let want = p
                .classify_batch_with(&images[i * img_len..(i + 1) * img_len], 1, &[opts])
                .unwrap()
                .remove(0);
            let want = Outcome {
                predictions: want.predictions.iter().map(|pr| (pr.class, pr.score)).collect(),
                front_end_nj: want.energy.front_end_nj,
                back_end_nj: want.energy.back_end_nj,
            };
            let (_, sharded) = routed.next().expect("subsequence length mismatch");
            assert_eq!(sharded, &want, "request {i} diverged on shard {s}");
        }
        assert!(routed.next().is_none(), "extra responses on shard {s}");
    }
}

/// Property 1, stochastic path: the per-shard ACAM RNG streams advance
/// exactly as a registry-free pipeline's would — attaching the registry
/// must not consume or reorder a single draw.
#[test]
fn acam_rng_streams_unchanged_by_registry() {
    let requests = 8;
    let n_shards = 2;
    let mut c = cfg(Backend::AcamSim, n_shards);
    c.acam.variability_level = 1.0; // exercise programming + read noise
    let (images, img_len) = workload(requests, 424_243);
    let set = ShardSet::start(&c).unwrap();
    let mut got = Vec::new();
    for i in 0..requests {
        let resp = set
            .handle
            .classify_blocking(images[i * img_len..(i + 1) * img_len].to_vec())
            .unwrap();
        assert_eq!(resp.shard, Some(i % n_shards));
        assert_eq!(resp.store, None);
        got.push((
            resp.predictions[0].class,
            resp.predictions[0].score,
            resp.energy.back_end_nj,
        ));
    }
    set.shutdown();
    for s in 0..n_shards {
        let mut sc = c.clone();
        sc.shards.count = 1;
        sc.acam.seed = c.acam.seed.wrapping_add(s as u64);
        let mut p = Pipeline::new(&sc).unwrap();
        for i in (0..requests).filter(|i| i % n_shards == s) {
            let want = p
                .classify_batch(&images[i * img_len..(i + 1) * img_len], 1)
                .unwrap()
                .remove(0);
            assert_eq!(
                got[i],
                (want.top1().class, want.top1().score, want.energy.back_end_nj),
                "request {i}: ACAM RNG stream diverged on shard {s}"
            );
        }
    }
}

/// Property 2: the swap barrier, pinned deterministically.  A batch parked
/// mid-flight on the hold gate has already synchronised against the
/// registry (`sync_stores` runs before the hold hook), so a publish while
/// it is parked cannot touch it — it finishes untagged on the bootstrap
/// store, and the very next batch serves the published version.  No batch
/// can ever mix versions: the (store, version) binding is resolved once
/// per batch, never per item.
#[test]
fn publish_swaps_at_batch_boundaries_never_mid_batch() {
    let gate = Gate::new();
    let mut c = cfg(Backend::FeatureCount, 1);
    c.batch.queue_depth = 8;
    let (images, img_len) = workload(1, 55);
    let img = images[..img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            hold: Some(("hold".into(), Arc::clone(&gate))),
            ..Default::default()
        },
    )
    .unwrap();

    // Park the worker mid-batch: the held batch is pinned to the
    // pre-publish registry state.
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("hold".into());
    let hold_rx = set.handle.submit(req).unwrap();
    gate.await_arrivals(1);

    // Queue traffic behind the parked batch, then publish while parked.
    let queued: Vec<_> = (0..2)
        .map(|_| set.handle.submit(ClassifyRequest::new(img.clone())).unwrap())
        .collect();
    let admin = set.handle.store_admin().expect("sharded surface carries the admin");
    let reg = admin.registry();
    assert_eq!(reg.swaps(), 0);
    let snap = reg
        .publish("default", publishable_store(reg, 4242), "put")
        .unwrap();
    assert_eq!(snap.version, 1);
    assert_eq!(reg.swaps(), 1);

    // Release: the parked batch finishes on its pinned (inert) state — no
    // store tag — and the queued requests form the next batch, which
    // adopts and advertises v1.
    gate.release();
    let hold = hold_rx.recv().unwrap().unwrap();
    assert_eq!(
        hold.store, None,
        "in-flight batch must finish on the version it resolved"
    );
    assert_eq!(hold.store_version, None);
    for rx in queued {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.store.as_deref(), Some("default"));
        assert_eq!(resp.store_version, Some(1), "post-publish batch must serve v1");
    }

    // The swap is visible on /metrics once (and only once) advertised.
    let text = set.handle.prometheus_text();
    for needle in [
        "hec_store_version{store=\"default\"} 1",
        "hec_store_swaps_total 1",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    set.shutdown();
}

/// Property 3: quota admission and gauge integrity.  The quota bounds
/// concurrent in-flight requests; a rejection consumes no queue slot and
/// no ticket; delivery, panic-drain, and restart all release tickets, so
/// `hec_tenant_in_flight` returns to zero whenever the tenant is idle.
#[test]
fn tenant_quota_rejects_and_gauges_stay_drift_free() {
    let hold_gate = Gate::new();
    let restart_gate = Gate::new();
    let mut c = cfg(Backend::FeatureCount, 1);
    c.batch.max_batch = 1;
    c.batch.queue_depth = 8;
    c.stores.tenants = vec![TenantSpec {
        name: "t1".into(),
        store: "default".into(),
        quota: 2,
    }];
    let (images, img_len) = workload(1, 31);
    let img = images[..img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            panic_on: Some("t1/boom".into()),
            hold: Some(("t1/hold".into(), Arc::clone(&hold_gate))),
            restart_gate: Some(Arc::clone(&restart_gate)),
            ..Default::default()
        },
    )
    .unwrap();
    let admin = set.handle.store_admin().unwrap();
    let t1 = admin
        .registry()
        .resolve_tenant(Some("t1/any"))
        .expect("configured tenant must resolve from the request-id prefix");

    // Park t1's first request mid-batch, fill the quota with a second.
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("t1/hold".into());
    let hold_rx = set.handle.submit(req).unwrap();
    hold_gate.await_arrivals(1);
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("t1/fill".into());
    let fill_rx = set.handle.submit(req).unwrap();
    assert_eq!(t1.in_flight(), 2);

    // Quota full: the third submit is rejected before touching any queue.
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("t1/over".into());
    let err = set.handle.submit(req).err().expect("quota must reject");
    assert_eq!(err.code, ErrorCode::QuotaExceeded);
    assert_eq!(err.code.http_status(), 429);
    assert_eq!(t1.in_flight(), 2, "a rejected submit must not consume a slot");
    assert_eq!(t1.rejected(), 1);

    // Drain: both admitted requests complete, tagged with the tenant's
    // store (version 0 — nothing published; tenants alone advertise).
    hold_gate.release();
    let hold = hold_rx.recv().unwrap().unwrap();
    assert_eq!(hold.store.as_deref(), Some("default"));
    assert_eq!(hold.store_version, Some(0));
    assert!(fill_rx.recv().unwrap().is_ok());
    // An untenanted round-trip both proves other traffic is outside t1's
    // quota and serialises past the worker's ticket drops.
    assert!(set.handle.classify_blocking(img.clone()).is_ok());
    assert_eq!(t1.in_flight(), 0, "tickets must release on delivery");
    assert_eq!(t1.served(), 2);

    // A worker panic must release the ticket too, not leak it: the drain
    // completes before the restart gate is passed, so this is race-free.
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("t1/boom".into());
    let err = set.handle.submit_blocking(req).err().expect("panic fails the request");
    assert_eq!(err.code, ErrorCode::Internal);
    restart_gate.await_arrivals(1);
    assert_eq!(t1.in_flight(), 0, "panicked request must release its ticket");
    assert_eq!(t1.served(), 2, "a failed request is not served");
    restart_gate.release();
    restart_gate.await_arrivals(2);

    // Post-restart the tenant serves again and the counters add up.
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("t1/after".into());
    let resp = set.handle.submit_blocking(req).unwrap();
    assert_eq!(resp.store.as_deref(), Some("default"));
    assert!(set.handle.classify_blocking(img).is_ok());
    assert_eq!((t1.served(), t1.rejected(), t1.in_flight()), (3, 1, 0));

    let text = set.handle.prometheus_text();
    for needle in [
        "hec_tenant_served_total{tenant=\"t1\"} 3",
        "hec_tenant_rejected_total{tenant=\"t1\"} 1",
        "hec_tenant_in_flight{tenant=\"t1\"} 0",
        "hec_store_version{store=\"default\"} 0",
        "hec_store_swaps_total 0",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    set.shutdown();
}

// ---------------------------------------------------------------------------
// HTTP plumbing (mirrors rust/tests/gateway.rs).
// ---------------------------------------------------------------------------

/// Read one HTTP/1.1 response (status, body) using Content-Length framing.
fn read_response(stream: &mut TcpStream) -> (u16, String) {
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        stream.read_exact(&mut byte).unwrap();
        head.push(byte[0]);
        assert!(head.len() < 64 * 1024, "unterminated response head");
    }
    let head = String::from_utf8(head).unwrap();
    let status: u16 = head.split(' ').nth(1).unwrap().parse().unwrap();
    let content_length: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().unwrap())
        })
        .expect("response must carry Content-Length");
    let mut body = vec![0u8; content_length];
    stream.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

/// One-shot JSON request (Connection: close).
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: hec-test\r\nConnection: close\r\n");
    if let Some(b) = body {
        req.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            b.len()
        ));
    }
    req.push_str("\r\n");
    if let Some(b) = body {
        req.push_str(b);
    }
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(req.as_bytes()).unwrap();
    read_response(&mut stream)
}

/// One-shot request with an arbitrary (possibly binary) body.
fn http_raw(
    addr: SocketAddr,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> (u16, String) {
    let mut req = format!(
        "{method} {path} HTTP/1.1\r\nHost: hec-test\r\nConnection: close\r\n\
         Content-Type: {content_type}\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )
    .into_bytes();
    req.extend_from_slice(body);
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(&req).unwrap();
    read_response(&mut stream)
}

/// Property 4: the `/v1/stores` admin surface over a real socket — list,
/// snapshot, 404, malformed JSON, raw `HECT` upload, 405, online re-fit,
/// tagged tenant classification, and the registry's `/metrics` series.
#[test]
fn store_admin_round_trips_over_http() {
    let mut c = cfg(Backend::FeatureCount, 1);
    c.batch.max_batch = 8;
    c.batch.max_wait_us = 500;
    c.stores.refit_min_accuracy = 0.0; // publish every candidate: deterministic
    c.stores.tenants = vec![TenantSpec {
        name: "acme".into(),
        store: "default".into(),
        quota: 0,
    }];
    let server = Server::start(c).unwrap();
    let http_cfg = HttpConfig {
        addr: Some("127.0.0.1:0".to_string()),
        max_connections: 32,
    };
    let gateway = Gateway::start(server.handle.clone(), &http_cfg).unwrap();
    let addr = gateway.local_addr();
    let assert_err = |(status, text): (u16, String), want_status: u16, want_code: ErrorCode| {
        assert_eq!(status, want_status, "{text}");
        let err = ApiError::from_value(&jsonlite::parse(&text).unwrap()).unwrap();
        assert_eq!(err.code, want_code, "{text}");
    };

    // List: the seeded default entry at version 0.
    let (status, body) = http(addr, "GET", "/v1/stores", None);
    assert_eq!(status, 200, "{body}");
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("api").unwrap().as_str(), Some("v1"));
    let stores = v.get("stores").unwrap().as_array().unwrap();
    assert!(
        stores.iter().any(|s| s.get("id").unwrap().as_str() == Some("default")),
        "{body}"
    );

    // Snapshot one store; unknown id is 404 NOT_FOUND.
    let (status, body) = http(addr, "GET", "/v1/stores/default", None);
    assert_eq!(status, 200, "{body}");
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("version").unwrap().as_u64(), Some(0));
    assert_eq!(v.get("origin").unwrap().as_str(), Some("bootstrap"));
    assert_eq!(v.get("resident").unwrap().as_bool(), Some(false));
    assert_err(http(addr, "GET", "/v1/stores/nope", None), 404, ErrorCode::NotFound);

    // Malformed JSON body -> 400 INVALID_ARGUMENT; wrong method -> 405.
    assert_err(
        http(addr, "PUT", "/v1/stores/default", Some("{\"not\": \"templates\"}")),
        400,
        ErrorCode::InvalidArgument,
    );
    assert_err(
        http(addr, "DELETE", "/v1/stores/default", None),
        405,
        ErrorCode::MethodNotAllowed,
    );

    // Raw HECT upload: labelled feature rows, re-fit server-side -> v1.
    let reg = server.handle.store_admin().unwrap().registry().clone();
    let (num_classes, n_features, _) = reg.geometry();
    let (labels, feats) = labelled_rows(&reg, 777);
    let labels_u32: Vec<u32> = labels.iter().map(|&l| l as u32).collect();
    let frame = encode_hect(num_classes as u32, n_features as u32, &labels_u32, &feats);
    let (status, body) = http_raw(addr, "PUT", "/v1/stores/default", binary::CONTENT_TYPE, &frame);
    assert_eq!(status, 200, "{body}");
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("version").unwrap().as_u64(), Some(1));
    assert_eq!(v.get("origin").unwrap().as_str(), Some("put"));
    assert_eq!(v.get("resident").unwrap().as_bool(), Some(true));
    // A corrupt frame is rejected without disturbing the published store.
    assert_err(
        http_raw(addr, "PUT", "/v1/stores/default", binary::CONTENT_TYPE, &frame[..13]),
        400,
        ErrorCode::InvalidArgument,
    );

    // Online re-fit: probes drawn, candidate verified digitally, published
    // as v2 (min accuracy 0 makes the publish unconditional).
    let (status, body) = http(addr, "POST", "/v1/stores/default/refit", None);
    assert_eq!(status, 200, "{body}");
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("published").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("version").unwrap().as_u64(), Some(2));
    let acc = v.get("accuracy").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&acc), "accuracy {acc} out of range");
    assert!(
        v.get("reprogram_nj").unwrap().as_f64().unwrap() > 0.0,
        "re-programming energy must be charged"
    );

    // Classify as the tenant: the response advertises the serving store.
    let img_len = server.handle.caps().image_len;
    let mut req = ClassifyRequest::new(vec![0.25f32; img_len]);
    req.request_id = Some("acme/1".into());
    let (status, body) = http(addr, "POST", "/v1/classify", Some(&req.to_value().to_json()));
    assert_eq!(status, 200, "{body}");
    let v = jsonlite::parse(&body).unwrap();
    assert_eq!(v.get("store").unwrap().as_str(), Some("default"));
    assert_eq!(v.get("store_version").unwrap().as_u64(), Some(2));

    // Registry series on /metrics (the single-pipeline Server path).
    let (status, text) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    for needle in [
        "hec_store_version{store=\"default\"} 2",
        "hec_store_swaps_total 2",
        "hec_tenant_served_total{tenant=\"acme\"} 1",
        "hec_tenant_in_flight{tenant=\"acme\"} 0",
        "# TYPE hec_latency_microseconds histogram",
        "hec_latency_microseconds_bucket{le=\"+Inf\"} 1",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }

    gateway.shutdown();
    server.shutdown();
}

/// Publishes survive a restart.  The bug: a store uploaded through the
/// admin surface lived only in the in-memory registry — any restart
/// silently reverted the deployment to the bootstrap store.  The fix:
/// `StoreAdmin` persists every successful publish atomically
/// (`.tmp-<id>` write + rename, so a crash mid-write never leaves a
/// half-readable `<id>.json`) into the configured stores directory, which
/// the next boot's registry reloads at origin `"dir"`.
#[test]
fn publishes_survive_restart_via_stores_dir() {
    let dir = std::env::temp_dir().join(format!("hec-store-persist-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut c = cfg(Backend::FeatureCount, 1);
    c.stores.dir = Some(dir.to_string_lossy().into_owned());

    // Boot 1: publish through the admin surface (the persistence funnel;
    // `registry.publish` alone is the in-memory primitive).
    let server = Server::start(c.clone()).unwrap();
    let admin = server.handle.store_admin().unwrap();
    let published = publishable_store(admin.registry(), 8_888);
    let snap = admin.put_json("default", &published.to_json()).unwrap();
    assert_eq!((snap.version, snap.origin), (1, "put"));
    assert!(
        dir.join("default.json").is_file(),
        "a publish must persist into the stores dir"
    );
    let leftovers: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with(".tmp-"))
        .collect();
    assert!(
        leftovers.is_empty(),
        "the atomic rename must not leave temp debris: {leftovers:?}"
    );

    let (images, img_len) = workload(1, 424_242);
    let img = images[..img_len].to_vec();
    let before = server.handle.classify_blocking(img.clone()).unwrap();
    assert_eq!(before.store_version, Some(1));
    server.shutdown();

    // Boot 2: same config, fresh process state.  The published store comes
    // back from disk — resident, origin "dir", bitwise-identical JSON —
    // and serves the same answers.
    let server = Server::start(c).unwrap();
    let snap = server
        .handle
        .store_admin()
        .unwrap()
        .get("default")
        .expect("persisted store must be listed after reboot");
    assert_eq!(
        (snap.version, snap.origin),
        (1, "dir"),
        "reboot must reload the persisted publish, not the bootstrap store"
    );
    let restored = snap.store.expect("dir-loaded stores are resident");
    assert_eq!(
        restored.to_json(),
        published.to_json(),
        "persisted store must round-trip bitwise"
    );
    let after = server.handle.classify_blocking(img).unwrap();
    assert_eq!(after.store_version, Some(1));
    assert_eq!(
        (after.predictions[0].class, after.predictions[0].score),
        (before.predictions[0].class, before.predictions[0].score),
        "the reloaded store must serve identically to the live publish"
    );
    assert_eq!(after.energy.back_end_nj, before.energy.back_end_nj);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

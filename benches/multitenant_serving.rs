//! Multi-tenant serving bench: two tenants on one shard — `t1` on the
//! deployment's default store, `t2` pinned to its own — with a live store
//! publish landing mid-load and an online re-fit after.  Measures what the
//! registry actually costs and guarantees:
//!
//! * **Swap latency**: wall time of `publish()` itself (registry mutex +
//!   geometry validation) and how many requests it takes a serving shard
//!   to adopt the new version (must be the very next batch).
//! * **Tenant isolation**: publishing `t2`'s store never perturbs `t1` —
//!   every `t1` response before and after the swap is tagged with the
//!   identical `(store, version)`, and per-tenant served counters are
//!   exact.
//! * **Re-programming energy**: adopting a published store on an ACAM
//!   deployment charges the 80 pJ/cell re-program to the shard's energy
//!   meter; the bench asserts the ledger jump and reports the figure.
//!
//! Deterministic under fixed seeds: serial blocking submits
//! (`max_batch = 1`, `max_wait_us = 0`) pin the adopt-at-batch-boundary
//! arithmetic exactly.  `HEC_BENCH_SMOKE=1` shrinks request counts for CI;
//! the JSON artifact (`BENCH_multitenant.json`) is the deliverable.

use std::time::Instant;

use hec::benchkit::{section, BenchResult};
use hec::config::{Backend, ServeConfig, TenantSpec};
use hec::coordinator::{ClassifySurface, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::energy::EnergyModel;
use hec::jsonlite::Value;
use hec::runtime::Meta;
use hec::store::StoreRegistry;
use hec::templates::TemplateStore;

/// Class-separable labelled rows matching the registry's geometry.
fn sample_store(reg: &StoreRegistry, seed: u64) -> TemplateStore {
    let (num_classes, n_features, _) = reg.geometry();
    let per_class = 4;
    let n = per_class * num_classes;
    let labels: Vec<usize> = (0..n).map(|i| i % num_classes).collect();
    let mut rng = hec::rng::Rng::new(seed);
    let mut feats = vec![0.0f32; n * n_features];
    for (i, l) in labels.iter().enumerate() {
        for j in 0..n_features {
            feats[i * n_features + j] = (*l as f32) * 0.3
                + rng.u01() as f32
                + if j % num_classes == *l { 1.5 } else { 0.0 };
        }
    }
    TemplateStore::from_features(&feats, &labels, n_features, num_classes, seed).unwrap()
}

/// Same field mapping as the other serving benches: `mean_us`/`min_us` =
/// 1e6 / request throughput; `p50_us`/`p99_us` = end-to-end latency
/// percentile upper bounds.
fn row(name: &str, requests: usize, secs: f64, p50_us: u64, p99_us: u64) -> BenchResult {
    let tput = requests as f64 / secs;
    let inv = std::time::Duration::from_secs_f64(if tput > 0.0 { 1.0 / tput } else { 0.0 });
    BenchResult {
        name: name.to_string(),
        iters: requests,
        mean: inv,
        p50: std::time::Duration::from_micros(p50_us),
        p99: std::time::Duration::from_micros(p99_us),
        min: inv,
    }
}

fn main() {
    let smoke = std::env::var("HEC_BENCH_SMOKE").is_ok();
    // Alternating t1/t2 traffic; the publish lands exactly halfway.
    let total = if smoke { 24usize } else { 120 };
    let swap_at = total / 2;
    let have_artifacts = std::path::Path::new("artifacts/meta.json").is_file();
    if !have_artifacts {
        println!("multitenant_serving: no artifacts/ — serving the synthetic fallback deployment");
    }

    let mut cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::AcamSim,
        ..Default::default()
    };
    cfg.batch.max_batch = 1; // serial submits -> exact swap-boundary arithmetic
    cfg.batch.max_wait_us = 0;
    cfg.shards.count = 1; // pin: default 0 = auto (HEC_SHARDS-sensitive)
    cfg.stores.refit_min_accuracy = 0.0; // re-fit phase publishes unconditionally
    cfg.stores.tenants = vec![
        TenantSpec {
            name: "t1".into(),
            store: "default".into(),
            quota: 0,
        },
        TenantSpec {
            name: "t2".into(),
            store: "t2store".into(),
            quota: 0,
        },
    ];

    let meta = Meta::load_or_synthetic("artifacts").unwrap();
    let ds = SyntheticDataset::new(2_718_281, total, meta.norm.mean as f32, meta.norm.std as f32);
    let images: Vec<Vec<f32>> = (0..total).map(|i| ds.image(i)).collect();

    let set = ShardSet::start(&cfg).unwrap();
    let admin = set.handle.store_admin().expect("registry-backed surface");
    let reg = admin.registry().clone();
    let t2_store = sample_store(&reg, 0xBEEF);
    let expected_t2_nj = {
        let s = t2_store.set(cfg.templates_per_class).unwrap();
        EnergyModel::default().reprogram_nj(s.num_templates() as u64, s.num_features() as u64)
    };

    section(&format!(
        "phase 1+2: {total} alternating t1/t2 requests, t2store published at request {swap_at}"
    ));
    let serve = |i: usize| {
        let mut req = hec::api::ClassifyRequest::new(images[i].clone());
        req.request_id = Some(format!("t{}/{i}", 1 + i % 2));
        set.handle.submit_blocking(req).unwrap()
    };

    let t0 = Instant::now();
    for i in 0..swap_at {
        let resp = serve(i);
        let want = if i % 2 == 0 { ("default", 0) } else { ("t2store", 0) };
        assert_eq!(resp.store.as_deref(), Some(want.0), "request {i}");
        assert_eq!(resp.store_version, Some(want.1), "request {i}: pre-swap version");
    }
    let pre_secs = t0.elapsed().as_secs_f64();

    // The live swap: energy meter before, publish wall time, then keep
    // serving — t2 must flip to v1 on its next batch, t1 must not move.
    let energy_before_nj = set.handle.shard_metrics(0).energy_nj();
    let t_pub = Instant::now();
    let snap = reg.publish("t2store", t2_store, "put").unwrap();
    let swap_publish_us = t_pub.elapsed().as_micros() as u64;
    assert_eq!(snap.version, 1);

    let t1 = Instant::now();
    for i in swap_at..total {
        let resp = serve(i);
        let want = if i % 2 == 0 { ("default", 0) } else { ("t2store", 1) };
        assert_eq!(resp.store.as_deref(), Some(want.0), "request {i}");
        assert_eq!(
            resp.store_version,
            Some(want.1),
            "request {i}: adoption must land on the first post-publish batch \
             and never disturb the other tenant"
        );
    }
    let post_secs = t1.elapsed().as_secs_f64();
    let energy_after_nj = set.handle.shard_metrics(0).energy_nj();
    let swap_energy_nj = energy_after_nj - energy_before_nj;
    assert!(
        swap_energy_nj >= expected_t2_nj,
        "adopting t2store must charge its re-program ({swap_energy_nj:.1} nJ < {expected_t2_nj:.1} nJ)"
    );
    println!("  publish latency: {swap_publish_us} us");
    println!("  t2 adoption: first post-publish t2 batch (deterministic)");
    println!("  re-program charged: {expected_t2_nj:.1} nJ of {swap_energy_nj:.1} nJ window");

    // Per-tenant accounting is exact under alternating traffic.
    let served: Vec<(String, u64, u64)> = reg
        .tenants()
        .iter()
        .map(|t| (t.name.clone(), t.served(), t.rejected()))
        .collect();
    for (name, s, r) in &served {
        println!("  tenant {name}: served {s}, rejected {r}");
        assert_eq!(*s as usize, total / 2, "tenant {name} served count");
        assert_eq!(*r, 0, "tenant {name} rejections");
    }

    section("phase 3: online re-fit of the default store");
    let t_refit = Instant::now();
    let outcome = admin.refit("default").unwrap();
    let refit_us = t_refit.elapsed().as_micros() as u64;
    assert!(outcome.published, "min_accuracy 0 publishes unconditionally");
    assert_eq!(outcome.version, Some(1));
    // t1's next response serves the re-fit store; t2 is again untouched.
    let resp = serve(0);
    assert_eq!(resp.store.as_deref(), Some("default"));
    assert_eq!(resp.store_version, Some(1), "t1 must adopt the re-fit publish");
    let resp = serve(1);
    assert_eq!((resp.store.as_deref(), resp.store_version), (Some("t2store"), Some(1)));
    println!(
        "  refit: accuracy {:.3}, version {:?}, {refit_us} us, re-program {:.1} nJ/array",
        outcome.accuracy, outcome.version, outcome.reprogram_nj
    );

    let snap_all = set.handle.snapshot();
    let total_energy_nj = set.handle.shard_metrics(0).energy_nj();
    set.shutdown();

    let rows_owned = [
        row("pre_swap_serving", swap_at, pre_secs, snap_all.latency_p50_us, snap_all.latency_p99_us),
        row("post_swap_serving", total - swap_at, post_secs, snap_all.latency_p50_us, snap_all.latency_p99_us),
    ];
    let rows: Vec<&BenchResult> = rows_owned.iter().collect();
    hec::benchkit::write_json_report(
        "BENCH_multitenant.json",
        "hec/multitenant_serving/v1",
        &[
            ("requests", Value::Num(total as f64)),
            ("swap_at_request", Value::Num(swap_at as f64)),
            ("tenants", Value::Num(2.0)),
            ("smoke", Value::Bool(smoke)),
            ("artifacts", Value::Bool(have_artifacts)),
            ("swap_publish_us", Value::Num(swap_publish_us as f64)),
            ("swap_adoption_batches", Value::Num(1.0)),
            ("swap_reprogram_nj", Value::Num(expected_t2_nj)),
            ("refit_publish_us", Value::Num(refit_us as f64)),
            ("refit_accuracy", Value::Num(outcome.accuracy)),
            ("refit_reprogram_nj", Value::Num(outcome.reprogram_nj)),
            ("t1_served", Value::Num(served[0].1 as f64)),
            ("t2_served", Value::Num(served[1].1 as f64)),
            ("total_energy_nj", Value::Num(total_energy_nj)),
            (
                "row_semantics",
                Value::Str(
                    "mean_us/min_us = 1e6/req_throughput; p50_us/p99_us = \
                     end-to-end request latency upper bounds"
                        .to_string(),
                ),
            ),
        ],
        &rows,
    )
    .expect("write BENCH_multitenant.json");
    println!("\nwrote BENCH_multitenant.json ({} rows)", rows.len());
    println!("multitenant_serving: PASS");
}

//! Configuration system: serde-backed, file-loadable, CLI-overridable.
//!
//! A deployment is described by one [`ServeConfig`]; `hec serve --config
//! serve.json` loads it, and every field has a CLI override in `main.rs`.

use std::path::{Path, PathBuf};


use crate::acam::cell::CellKind;
use crate::backend::BackendVariant;
use crate::error::{Error, Result};

/// Which execution engine runs the student CNN front-end
/// (see `rust/src/runtime/backend/`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// Pure-Rust scalar interpreter — the default; zero native
    /// dependencies, runs with or without an artifacts directory, and
    /// serves as the numeric oracle for the fast path.
    #[default]
    Interp,
    /// Blocked + multithreaded interpreter fast-path (im2col lowering,
    /// register-tiled matmul, scratch arenas, batch sharding); same model
    /// and weights as `Interp`, `threads`-configurable.
    InterpFast,
    /// HLO/PJRT runtime — requires the `pjrt` cargo feature and an
    /// artifacts directory.
    Pjrt,
}

impl std::str::FromStr for Engine {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "interp" | "rust" => Ok(Engine::Interp),
            "interp-fast" | "interp_fast" | "fast" => Ok(Engine::InterpFast),
            "pjrt" | "xla" => Ok(Engine::Pjrt),
            _ => Err(Error::Config(format!("unknown engine: {s}"))),
        }
    }
}

/// Which back-end classifies the extracted feature maps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Simulated RRAM-CMOS ACAM (the paper's system).
    AcamSim,
    /// Digital Eq. 8 feature count (packed popcount hot path).
    FeatureCount,
    /// Digital Eq. 9-11 similarity model.
    Similarity,
    /// Baseline: the student's dense softmax head on PJRT.
    Softmax,
}

impl std::str::FromStr for Backend {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "acam" | "acam_sim" => Ok(Backend::AcamSim),
            "fc" | "feature_count" => Ok(Backend::FeatureCount),
            "sim" | "similarity" => Ok(Backend::Similarity),
            "softmax" => Ok(Backend::Softmax),
            _ => Err(Error::Config(format!("unknown backend: {s}"))),
        }
    }
}

impl Backend {
    /// Canonical wire name (round-trips through [`std::str::FromStr`]) —
    /// used by the v1 API's `backend` request/response fields.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::AcamSim => "acam",
            Backend::FeatureCount => "fc",
            Backend::Similarity => "sim",
            Backend::Softmax => "softmax",
        }
    }
}

/// How the shard router picks a worker pipeline for each request
/// (see `crate::coordinator::shard`).  Routing is deterministic by
/// construction: the decision depends only on the policy, the submit
/// order, and the observed queue occupancy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Cycle through healthy shards in submit order.
    #[default]
    RoundRobin,
    /// Pick the healthy shard with the fewest queued requests
    /// (lowest index wins ties).
    LeastQueueDepth,
    /// Sticky routing: FNV-1a hash of `request_id` modulo the healthy
    /// shard count; requests without an id fall back to round-robin.
    Hash,
}

impl std::str::FromStr for RoutePolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "round_robin" | "round-robin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "least_queue_depth" | "least-queue-depth" | "least_depth" => {
                Ok(RoutePolicy::LeastQueueDepth)
            }
            "hash" | "sticky" => Ok(RoutePolicy::Hash),
            _ => Err(Error::Config(format!("unknown routing policy: {s}"))),
        }
    }
}

impl RoutePolicy {
    /// Canonical config/wire name (round-trips through [`std::str::FromStr`]).
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastQueueDepth => "least_queue_depth",
            RoutePolicy::Hash => "hash",
        }
    }
}

/// Sharded serving: N independent worker pipelines behind one submit
/// surface (`crate::coordinator::shard::ShardSet`).
#[derive(Debug, Clone)]
pub struct ShardsConfig {
    /// Worker pipelines: `0` = auto (the `HEC_SHARDS` env var if set, else
    /// 1).  Each shard owns its own engine instance, ACAM array, RNG
    /// stream (seeded `acam.seed + shard_index`) and bounded queue.
    pub count: usize,
    /// Routing policy for the shard router.
    pub policy: RoutePolicy,
    /// Whether a full shard queue spills to the next-best healthy shard
    /// before the submit fails with `QUEUE_FULL`.
    pub spill: bool,
}

impl Default for ShardsConfig {
    fn default() -> Self {
        ShardsConfig {
            count: 0,
            policy: RoutePolicy::RoundRobin,
            spill: true,
        }
    }
}

/// Hard cap on the shard count (each shard owns a full pipeline: weights,
/// templates, queue, worker thread — hundreds would be a config mistake).
pub const MAX_SHARDS: usize = 64;

/// Dynamic batching policy.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Largest batch the batcher will form (must be one of the exported
    /// artifact batch sizes; smaller batches are padded up to the nearest).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before dispatching what
    /// it has (microseconds).
    pub max_wait_us: u64,
    /// Request queue depth before backpressure (submit returns an error).
    pub queue_depth: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 32,
            max_wait_us: 2_000,
            queue_depth: 1024,
        }
    }
}

/// HTTP/JSON gateway front door (`hec serve --http ADDR`).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address (`127.0.0.1:8080`; port 0 asks the OS for a free port).
    /// `None` disables the gateway.
    pub addr: Option<String>,
    /// Concurrent-connection cap; excess connections get an immediate
    /// 429 (`QUEUE_FULL`).
    pub max_connections: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: None,
            max_connections: 64,
        }
    }
}

/// Fault injection + canary health ladder (`crate::faults`,
/// `crate::coordinator::shard`).  Everything defaults to *off*: with no
/// plan and `canary_every == 0`, serving is bitwise identical to a build
/// without this module.
#[derive(Debug, Clone)]
pub struct FaultsConfig {
    /// Fault plan spec (see [`crate::faults::FaultPlan::parse`]), e.g.
    /// `"drift@500=2.0,noise@800=0.05,stuck@1200=0.02"`.  `None` falls back
    /// to the `HEC_FAULT_PLAN` env var; empty/absent disables injection.
    pub plan: Option<String>,
    /// Seed for the fault injector's RNG streams (stuck-cell placement);
    /// independent of `acam.seed` so fault placement does not perturb
    /// serving RNG.
    pub seed: u64,
    /// Canary probe cadence in served requests per shard; `0` disables the
    /// health ladder (falls back to `HEC_CANARY_EVERY`, else off).  The
    /// ladder only arms on the `acam` backend — digital backends have no
    /// analogue array to age.
    pub canary_every: u64,
    /// Canary probes per class (bootstrap samples with known labels).
    pub canary_per_class: usize,
    /// Canary accuracy below which the shard demotes to `Reprogramming`.
    pub canary_threshold: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            plan: None,
            seed: 7,
            canary_every: 0,
            canary_per_class: 2,
            canary_threshold: 0.9,
        }
    }
}

/// One tenant binding: requests whose `request_id` starts with
/// `"{name}/"` serve from `store` under a concurrent-in-flight `quota`.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Template-store id this tenant is pinned to (`"default"` shares the
    /// deployment store).
    pub store: String,
    /// Max concurrent in-flight requests; `0` = unlimited.
    pub quota: u64,
}

/// Template-store registry configuration (see `crate::store`).  Everything
/// defaults to *off*: with no tenants and no stores dir, the registry is
/// inert and serving is bitwise identical to a build without it.
#[derive(Debug, Clone)]
pub struct StoresConfig {
    /// Directory of `<id>.json` template stores published at startup
    /// (version 1, origin `"dir"`).  `None` falls back to the
    /// `HEC_STORES_DIR` env var.
    pub dir: Option<String>,
    /// Digital-matcher accuracy a re-fit candidate must reach on the
    /// held-out probe set before it is published.
    pub refit_min_accuracy: f64,
    /// Labelled probes per class drawn for each online re-fit.
    pub refit_per_class: usize,
    /// Tenant bindings; empty falls back to the `HEC_TENANTS` env var
    /// (`"name=store:quota,name2=store2"`).
    pub tenants: Vec<TenantSpec>,
}

impl Default for StoresConfig {
    fn default() -> Self {
        StoresConfig {
            dir: None,
            refit_min_accuracy: 0.8,
            refit_per_class: 8,
            tenants: Vec::new(),
        }
    }
}

/// Identifier charset shared by tenant names and store ids:
/// `[A-Za-z0-9_-]+`, non-empty, at most 64 bytes.  Keeps them safe for URL
/// path segments, Prometheus label values, and the `request_id` tenant
/// prefix (which reserves `/`).
fn ident_ok(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= 64
        && s.bytes()
            .all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-')
}

/// Parse a `HEC_TENANTS`/`--tenants`-style spec: comma-separated
/// `name=store[:quota]` (quota 0 / omitted = unlimited).
pub fn parse_tenant_list(spec: &str) -> Result<Vec<TenantSpec>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, rest) = part
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("tenant spec '{part}': expected name=store")))?;
        let (store, quota) = match rest.split_once(':') {
            Some((s, q)) => (
                s,
                q.trim()
                    .parse::<u64>()
                    .map_err(|_| Error::Config(format!("tenant spec '{part}': bad quota")))?,
            ),
            None => (rest, 0),
        };
        out.push(TenantSpec {
            name: name.trim().to_string(),
            store: store.trim().to_string(),
            quota,
        });
    }
    Ok(out)
}

fn validate_tenants(tenants: &[TenantSpec]) -> Result<()> {
    let mut seen = std::collections::BTreeSet::new();
    for t in tenants {
        if !ident_ok(&t.name) {
            return Err(Error::Config(format!(
                "tenant name '{}' must be non-empty [A-Za-z0-9_-]",
                t.name
            )));
        }
        if !ident_ok(&t.store) {
            return Err(Error::Config(format!(
                "tenant '{}': store id '{}' must be non-empty [A-Za-z0-9_-]",
                t.name, t.store
            )));
        }
        if !seen.insert(t.name.as_str()) {
            return Err(Error::Config(format!("duplicate tenant name '{}'", t.name)));
        }
    }
    Ok(())
}

/// Per-shard feature cache (see `crate::coordinator::cache`).  Defaults to
/// *off*: with `enabled == false` serving is bitwise identical to a build
/// without the cache — no extra RNG draws, no response field, no metrics
/// series.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Whether repeated images (by content hash) skip the CNN front-end.
    pub enabled: bool,
    /// Max cached feature vectors per shard; a full cache evicts a
    /// seeded-deterministic victim.
    pub capacity: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            enabled: false,
            capacity: 1024,
        }
    }
}

/// ACAM back-end knobs.
#[derive(Debug, Clone)]
pub struct AcamConfig {
    pub cell_kind: CellKind,
    /// Variability level: 0 = ideal, 1 = typical fabricated corner.
    pub variability_level: f64,
    /// RNG seed for programming + read noise.
    pub seed: u64,
}

impl Default for AcamConfig {
    fn default() -> Self {
        AcamConfig {
            cell_kind: CellKind::Charging6T4R,
            variability_level: 0.0,
            seed: 42,
        }
    }
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifacts directory (HLO text + templates.json + meta.json).  May be
    /// absent: the interp engine then serves from synthetic weights and
    /// bootstrapped templates.
    pub artifacts_dir: PathBuf,
    /// Front-end execution engine.
    pub engine: Engine,
    /// Worker threads for the `interp-fast` engine: `0` = auto (the
    /// `HEC_THREADS` env var if set, else `available_parallelism`); any
    /// explicit value is clamped to `available_parallelism` by
    /// [`ServeConfig::resolve_threads`].  `1` forces the deterministic
    /// serial path (though thread count never changes the numbers — see
    /// `runtime::backend::fast`).
    pub threads: usize,
    /// Classification back-end (request routing: acam / fc / sim / softmax).
    pub backend: Backend,
    /// Which hardware variant serves `acam`-routed requests (the
    /// [`crate::backend::MatchingBackend`] seam): `None` resolves through
    /// `HEC_BACKEND`, else the default TXL ACAM — see
    /// [`ServeConfig::resolve_backend_variant`].
    pub backend_variant: Option<BackendVariant>,
    /// Templates per class (Table II: 1, 2 or 3).
    pub templates_per_class: usize,
    /// Serve through the jnp-lowered front-end variant (XLA-native convs —
    /// the fast path on CPU).  `false` routes through the Pallas-lowered
    /// artifact (the TPU-shaped deliverable; interpret lowering is slow on
    /// CPU PJRT).  Both are numerically identical.
    pub use_fast_frontend: bool,
    pub batch: BatchConfig,
    pub acam: AcamConfig,
    pub http: HttpConfig,
    pub shards: ShardsConfig,
    pub faults: FaultsConfig,
    pub stores: StoresConfig,
    pub cache: CacheConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            engine: Engine::default(),
            threads: 0,
            backend: Backend::AcamSim,
            backend_variant: None,
            templates_per_class: 1,
            use_fast_frontend: true,
            batch: BatchConfig::default(),
            acam: AcamConfig::default(),
            http: HttpConfig::default(),
            shards: ShardsConfig::default(),
            faults: FaultsConfig::default(),
            stores: StoresConfig::default(),
            cache: CacheConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Load from a JSON file; absent fields keep their defaults.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let doc = crate::jsonlite::parse(&std::fs::read_to_string(path)?)?;
        let mut cfg = ServeConfig::default();
        if let Some(v) = doc.get("artifacts_dir").and_then(|v| v.as_str()) {
            cfg.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.get("engine").and_then(|v| v.as_str()) {
            cfg.engine = v.parse()?;
        }
        if let Some(v) = doc.get("threads").and_then(|v| v.as_usize()) {
            cfg.threads = v;
        }
        if let Some(b) = doc.get("backend") {
            if let Some(v) = b.as_str() {
                // String form: a route name ("acam"/"fc"/"sim"/"softmax"),
                // or a variant name ("acam-9t4r"/"rbf"/"digital") which
                // implies the acam route on that hardware.
                match v.parse::<Backend>() {
                    Ok(route) => cfg.backend = route,
                    Err(_) => match v.parse::<BackendVariant>() {
                        Ok(variant) => {
                            cfg.backend = Backend::AcamSim;
                            cfg.backend_variant = Some(variant);
                        }
                        Err(_) => {
                            return Err(Error::Config(format!(
                                "unknown backend '{v}' (routes: acam | fc | sim | softmax; \
                                 variants: acam | acam-9t4r | rbf | digital)"
                            )))
                        }
                    },
                }
            } else {
                // Object form: {"route": "...", "variant": "..."}.
                if let Some(v) = b.get("route").and_then(|v| v.as_str()) {
                    cfg.backend = v.parse()?;
                }
                if let Some(v) = b.get("variant").and_then(|v| v.as_str()) {
                    cfg.backend_variant = Some(v.parse()?);
                }
            }
        }
        if let Some(v) = doc.get("templates_per_class").and_then(|v| v.as_usize()) {
            cfg.templates_per_class = v;
        }
        if let Some(v) = doc.get("use_fast_frontend").and_then(|v| v.as_bool()) {
            cfg.use_fast_frontend = v;
        }
        if let Some(b) = doc.get("batch") {
            if let Some(v) = b.get("max_batch").and_then(|v| v.as_usize()) {
                cfg.batch.max_batch = v;
            }
            if let Some(v) = b.get("max_wait_us").and_then(|v| v.as_u64()) {
                cfg.batch.max_wait_us = v;
            }
            if let Some(v) = b.get("queue_depth").and_then(|v| v.as_usize()) {
                cfg.batch.queue_depth = v;
            }
        }
        if let Some(h) = doc.get("http") {
            if let Some(v) = h.get("addr").and_then(|v| v.as_str()) {
                cfg.http.addr = Some(v.to_string());
            }
            if let Some(v) = h.get("max_connections").and_then(|v| v.as_usize()) {
                cfg.http.max_connections = v;
            }
        }
        if let Some(s) = doc.get("shards") {
            if let Some(v) = s.get("count").and_then(|v| v.as_usize()) {
                cfg.shards.count = v;
            }
            if let Some(v) = s.get("policy").and_then(|v| v.as_str()) {
                cfg.shards.policy = v.parse()?;
            }
            if let Some(v) = s.get("spill").and_then(|v| v.as_bool()) {
                cfg.shards.spill = v;
            }
        }
        if let Some(f) = doc.get("faults") {
            if let Some(v) = f.get("plan").and_then(|v| v.as_str()) {
                cfg.faults.plan = Some(v.to_string());
            }
            if let Some(v) = f.get("seed").and_then(|v| v.as_u64()) {
                cfg.faults.seed = v;
            }
            if let Some(v) = f.get("canary_every").and_then(|v| v.as_u64()) {
                cfg.faults.canary_every = v;
            }
            if let Some(v) = f.get("canary_per_class").and_then(|v| v.as_usize()) {
                cfg.faults.canary_per_class = v;
            }
            if let Some(v) = f.get("canary_threshold").and_then(|v| v.as_f64()) {
                cfg.faults.canary_threshold = v;
            }
        }
        if let Some(s) = doc.get("stores") {
            if let Some(v) = s.get("dir").and_then(|v| v.as_str()) {
                cfg.stores.dir = Some(v.to_string());
            }
            if let Some(v) = s.get("refit_min_accuracy").and_then(|v| v.as_f64()) {
                cfg.stores.refit_min_accuracy = v;
            }
            if let Some(v) = s.get("refit_per_class").and_then(|v| v.as_usize()) {
                cfg.stores.refit_per_class = v;
            }
            if let Some(ts) = s.get("tenants").and_then(|v| v.as_array()) {
                for t in ts {
                    let name = t
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| Error::Config("tenant entry needs a name".into()))?;
                    let store = t
                        .get("store")
                        .and_then(|v| v.as_str())
                        .unwrap_or("default");
                    let quota = t.get("quota").and_then(|v| v.as_u64()).unwrap_or(0);
                    cfg.stores.tenants.push(TenantSpec {
                        name: name.to_string(),
                        store: store.to_string(),
                        quota,
                    });
                }
            }
        }
        if let Some(c) = doc.get("cache") {
            if let Some(v) = c.get("enabled").and_then(|v| v.as_bool()) {
                cfg.cache.enabled = v;
            }
            if let Some(v) = c.get("capacity").and_then(|v| v.as_usize()) {
                cfg.cache.capacity = v;
            }
        }
        if let Some(a) = doc.get("acam") {
            if let Some(v) = a.get("cell_kind").and_then(|v| v.as_str()) {
                cfg.acam.cell_kind = match v {
                    "6t4r" | "charging" => CellKind::Charging6T4R,
                    "3t1r" | "precharging" => CellKind::Precharging3T1R,
                    "9t4r" | "analogue" => CellKind::Analogue9T4R,
                    other => return Err(Error::Config(format!("unknown cell kind: {other}"))),
                };
            }
            if let Some(v) = a.get("variability_level").and_then(|v| v.as_f64()) {
                cfg.acam.variability_level = v;
            }
            if let Some(v) = a.get("seed").and_then(|v| v.as_u64()) {
                cfg.acam.seed = v;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Effective worker-thread count for the fast engine.  Precedence:
    /// explicit `threads` (config file / `--threads`) > `HEC_THREADS` env >
    /// `available_parallelism`; the result is always clamped to
    /// `1..=available_parallelism`.
    pub fn resolve_threads(&self) -> usize {
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let requested = if self.threads != 0 {
            self.threads
        } else {
            std::env::var("HEC_THREADS")
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .unwrap_or(0)
        };
        if requested == 0 {
            hw
        } else {
            requested.clamp(1, hw)
        }
    }

    /// Effective shard count.  Precedence: explicit `shards.count`
    /// (config file / `--shards`) > `HEC_SHARDS` env > 1; the result is
    /// always clamped to `1..=MAX_SHARDS`.
    pub fn resolve_shards(&self) -> usize {
        let requested = if self.shards.count != 0 {
            self.shards.count
        } else {
            std::env::var("HEC_SHARDS")
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .unwrap_or(0)
        };
        if requested == 0 {
            1
        } else {
            requested.clamp(1, MAX_SHARDS)
        }
    }

    /// Effective gateway bind address.  Precedence: explicit config/CLI
    /// (`http.addr` / `--http`) > `HEC_HTTP_ADDR` env > disabled.
    pub fn resolve_http_addr(&self) -> Option<String> {
        self.http.addr.clone().or_else(|| {
            std::env::var("HEC_HTTP_ADDR")
                .ok()
                .map(|v| v.trim().to_string())
                .filter(|v| !v.is_empty())
        })
    }

    /// Effective fault plan.  Precedence: explicit `faults.plan` (config
    /// file) > `HEC_FAULT_PLAN` env > none.  The spec is parsed with
    /// `faults.seed`; a malformed spec is a config error either way (a
    /// typo'd chaos experiment must fail loudly at startup, not silently
    /// serve fault-free).
    pub fn resolve_fault_plan(&self) -> Result<Option<crate::faults::FaultPlan>> {
        let spec = self.faults.plan.clone().or_else(|| {
            std::env::var("HEC_FAULT_PLAN")
                .ok()
                .map(|v| v.trim().to_string())
                .filter(|v| !v.is_empty())
        });
        match spec {
            Some(s) => crate::faults::FaultPlan::parse(&s, self.faults.seed)
                .map(Some)
                .map_err(|e| Error::Config(format!("bad fault plan: {e}"))),
            None => Ok(None),
        }
    }

    /// Effective back-end variant for `acam`-routed requests.  Precedence:
    /// explicit `backend_variant` (config file `backend.variant` /
    /// `--backend <variant>`) > `HEC_BACKEND` env > the default TXL
    /// [`BackendVariant::Acam`].  A malformed env value is a config error —
    /// a typo'd variant must fail loudly at startup, not silently serve the
    /// default hardware.
    pub fn resolve_backend_variant(&self) -> Result<BackendVariant> {
        if let Some(v) = self.backend_variant {
            return Ok(v);
        }
        match std::env::var("HEC_BACKEND") {
            Ok(s) if !s.trim().is_empty() => {
                let s = s.trim();
                s.parse().map_err(|_| {
                    Error::Config(format!(
                        "HEC_BACKEND='{s}' is not a backend variant \
                         (acam | acam-9t4r | rbf | digital)"
                    ))
                })
            }
            _ => Ok(BackendVariant::Acam),
        }
    }

    /// Effective canary cadence (requests between probes per shard).
    /// Precedence: explicit `faults.canary_every` > `HEC_CANARY_EVERY` env
    /// > 0 (ladder off).
    pub fn resolve_canary_every(&self) -> u64 {
        if self.faults.canary_every != 0 {
            return self.faults.canary_every;
        }
        std::env::var("HEC_CANARY_EVERY")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(0)
    }

    /// Effective feature-cache capacity: `Some(capacity)` when the cache is
    /// on, `None` when off.  Precedence: explicit `cache.enabled` (config
    /// file / `--cache`) > `HEC_CACHE` env (a positive capacity enables; `0`
    /// or unset leaves it off) > off.
    pub fn resolve_cache(&self) -> Option<usize> {
        if self.cache.enabled {
            return Some(self.cache.capacity);
        }
        std::env::var("HEC_CACHE")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
    }

    /// Effective template-store directory.  Precedence: explicit
    /// `stores.dir` (config file / `--stores-dir`) > `HEC_STORES_DIR` env >
    /// none.
    pub fn resolve_stores_dir(&self) -> Option<String> {
        self.stores.dir.clone().or_else(|| {
            std::env::var("HEC_STORES_DIR")
                .ok()
                .map(|v| v.trim().to_string())
                .filter(|v| !v.is_empty())
        })
    }

    /// Effective tenant bindings.  Precedence: explicit `stores.tenants`
    /// (config file / `--tenants`) > `HEC_TENANTS` env
    /// (`"name=store[:quota],..."`) > none.  A malformed spec is a config
    /// error either way — a typo'd quota must fail loudly at startup, not
    /// silently admit unlimited traffic.
    pub fn resolve_tenants(&self) -> Result<Vec<TenantSpec>> {
        let tenants = if !self.stores.tenants.is_empty() {
            self.stores.tenants.clone()
        } else {
            match std::env::var("HEC_TENANTS") {
                Ok(spec) if !spec.trim().is_empty() => parse_tenant_list(&spec)?,
                _ => Vec::new(),
            }
        };
        validate_tenants(&tenants)?;
        Ok(tenants)
    }

    pub fn validate(&self) -> Result<()> {
        if !(1..=3).contains(&self.templates_per_class) {
            return Err(Error::Config(format!(
                "templates_per_class must be 1..=3, got {}",
                self.templates_per_class
            )));
        }
        if self.batch.max_batch == 0 || self.batch.queue_depth == 0 {
            return Err(Error::Config("batch sizes must be positive".into()));
        }
        if self.acam.variability_level < 0.0 {
            return Err(Error::Config("variability_level must be >= 0".into()));
        }
        if self.http.max_connections == 0 {
            return Err(Error::Config("http.max_connections must be positive".into()));
        }
        if self.shards.count > MAX_SHARDS {
            return Err(Error::Config(format!(
                "shards.count must be <= {MAX_SHARDS}, got {}",
                self.shards.count
            )));
        }
        if self.faults.canary_per_class == 0 {
            return Err(Error::Config(
                "faults.canary_per_class must be positive".into(),
            ));
        }
        if !(0.0..=1.0).contains(&self.faults.canary_threshold) {
            return Err(Error::Config(format!(
                "faults.canary_threshold must be in [0, 1], got {}",
                self.faults.canary_threshold
            )));
        }
        if !(0.0..=1.0).contains(&self.stores.refit_min_accuracy) {
            return Err(Error::Config(format!(
                "stores.refit_min_accuracy must be in [0, 1], got {}",
                self.stores.refit_min_accuracy
            )));
        }
        if self.stores.refit_per_class == 0 {
            return Err(Error::Config(
                "stores.refit_per_class must be positive".into(),
            ));
        }
        if self.cache.enabled && self.cache.capacity == 0 {
            return Err(Error::Config("cache.capacity must be positive".into()));
        }
        validate_tenants(&self.stores.tenants)?;
        // Surface a malformed plan spec at load time, not first use.
        self.resolve_fault_plan()?;
        // Same for a malformed HEC_BACKEND variant.
        self.resolve_backend_variant()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn backend_parses() {
        assert_eq!("acam".parse::<Backend>().unwrap(), Backend::AcamSim);
        assert_eq!("fc".parse::<Backend>().unwrap(), Backend::FeatureCount);
        assert!("nope".parse::<Backend>().is_err());
    }

    #[test]
    fn engine_parses_and_defaults_to_interp() {
        assert_eq!("interp".parse::<Engine>().unwrap(), Engine::Interp);
        assert_eq!("rust".parse::<Engine>().unwrap(), Engine::Interp);
        assert_eq!("interp-fast".parse::<Engine>().unwrap(), Engine::InterpFast);
        assert_eq!("fast".parse::<Engine>().unwrap(), Engine::InterpFast);
        assert_eq!("pjrt".parse::<Engine>().unwrap(), Engine::Pjrt);
        assert!("cuda".parse::<Engine>().is_err());
        assert_eq!(ServeConfig::default().engine, Engine::Interp);
    }

    #[test]
    fn resolve_threads_clamps_to_hardware() {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut c = ServeConfig::default();
        c.threads = 1;
        assert_eq!(c.resolve_threads(), 1, "threads=1 is the serial path");
        c.threads = 100_000;
        assert_eq!(c.resolve_threads(), hw, "explicit requests clamp to hw");
        c.threads = 0;
        let auto = c.resolve_threads();
        assert!((1..=hw).contains(&auto), "auto resolves within 1..=hw");
    }

    #[test]
    fn threads_loads_from_config_file() {
        let dir = std::env::temp_dir().join(format!("hec-thrcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(&path, r#"{"engine": "interp-fast", "threads": 1}"#).unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.engine, Engine::InterpFast);
        assert_eq!(cfg.threads, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn engine_loads_from_config_file() {
        let dir = std::env::temp_dir().join(format!("hec-engcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(&path, r#"{"engine": "pjrt", "backend": "fc"}"#).unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.engine, Engine::Pjrt);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backend_variant_loads_from_string_and_object_forms() {
        let dir = std::env::temp_dir().join(format!("hec-varcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");

        // String form: a variant name implies the acam route.
        std::fs::write(&path, r#"{"backend": "acam-9t4r"}"#).unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.backend, Backend::AcamSim);
        assert_eq!(cfg.backend_variant, Some(BackendVariant::Acam9T4R));
        assert_eq!(
            cfg.resolve_backend_variant().unwrap(),
            BackendVariant::Acam9T4R
        );

        // String form: a route name leaves the variant unset (env/default).
        std::fs::write(&path, r#"{"backend": "fc"}"#).unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.backend, Backend::FeatureCount);
        assert_eq!(cfg.backend_variant, None);

        // Object form: independent route + variant.
        std::fs::write(&path, r#"{"backend": {"route": "acam", "variant": "rbf"}}"#).unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.backend, Backend::AcamSim);
        assert_eq!(cfg.backend_variant, Some(BackendVariant::Rbf));

        // Unknown names are loud errors.
        std::fs::write(&path, r#"{"backend": "warp"}"#).unwrap();
        assert!(ServeConfig::load(&path).is_err());
        std::fs::write(&path, r#"{"backend": {"variant": "warp"}}"#).unwrap();
        assert!(ServeConfig::load(&path).is_err());

        // Default: no explicit variant resolves to the TXL ACAM (unless
        // HEC_BACKEND is set, which the suite never does).
        let d = ServeConfig::default();
        assert_eq!(d.backend_variant, None);
        assert_eq!(d.resolve_backend_variant().unwrap(), BackendVariant::Acam);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cell_kind_9t4r_loads_from_config_file() {
        let dir = std::env::temp_dir().join(format!("hec-9t4rcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(&path, r#"{"acam": {"cell_kind": "9t4r"}}"#).unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.acam.cell_kind, CellKind::Analogue9T4R);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backend_names_roundtrip() {
        for b in [
            Backend::AcamSim,
            Backend::FeatureCount,
            Backend::Similarity,
            Backend::Softmax,
        ] {
            assert_eq!(b.name().parse::<Backend>().unwrap(), b);
        }
    }

    #[test]
    fn http_config_loads_and_validates() {
        let dir = std::env::temp_dir().join(format!("hec-httpcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(
            &path,
            r#"{"http": {"addr": "127.0.0.1:0", "max_connections": 8}}"#,
        )
        .unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.http.addr.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(cfg.http.max_connections, 8);
        assert_eq!(cfg.resolve_http_addr().as_deref(), Some("127.0.0.1:0"));
        let mut bad = ServeConfig::default();
        bad.http.max_connections = 0;
        assert!(bad.validate().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn route_policy_parses_and_roundtrips() {
        for p in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastQueueDepth,
            RoutePolicy::Hash,
        ] {
            assert_eq!(p.name().parse::<RoutePolicy>().unwrap(), p);
        }
        assert_eq!("rr".parse::<RoutePolicy>().unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(
            "least-queue-depth".parse::<RoutePolicy>().unwrap(),
            RoutePolicy::LeastQueueDepth
        );
        assert_eq!("sticky".parse::<RoutePolicy>().unwrap(), RoutePolicy::Hash);
        assert!("random".parse::<RoutePolicy>().is_err());
        assert_eq!(RoutePolicy::default(), RoutePolicy::RoundRobin);
    }

    #[test]
    fn shards_config_loads_and_validates() {
        let dir = std::env::temp_dir().join(format!("hec-shardcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(
            &path,
            r#"{"shards": {"count": 4, "policy": "least_queue_depth", "spill": false}}"#,
        )
        .unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.shards.count, 4);
        assert_eq!(cfg.shards.policy, RoutePolicy::LeastQueueDepth);
        assert!(!cfg.shards.spill);
        assert_eq!(cfg.resolve_shards(), 4);
        let mut bad = ServeConfig::default();
        bad.shards.count = MAX_SHARDS + 1;
        assert!(bad.validate().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resolve_shards_defaults_and_clamps() {
        let mut c = ServeConfig::default();
        assert_eq!(c.shards.count, 0, "default is auto");
        // Auto without HEC_SHARDS set in the test environment resolves to
        // 1 (single-pipeline, the pre-sharding behaviour).  We cannot
        // assert the env-var branch here without racing other tests over
        // the process environment, so only the explicit paths are pinned.
        c.shards.count = 7;
        assert_eq!(c.resolve_shards(), 7);
        c.shards.count = MAX_SHARDS;
        assert_eq!(c.resolve_shards(), MAX_SHARDS);
    }

    #[test]
    fn faults_config_loads_resolves_and_validates() {
        let dir = std::env::temp_dir().join(format!("hec-faultcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(
            &path,
            r#"{"faults": {"plan": "drift@100=2.0,stuck@200=0.05", "seed": 11,
                           "canary_every": 50, "canary_per_class": 3,
                           "canary_threshold": 0.8}}"#,
        )
        .unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.faults.plan.as_deref(), Some("drift@100=2.0,stuck@200=0.05"));
        assert_eq!(cfg.faults.seed, 11);
        assert_eq!(cfg.faults.canary_every, 50);
        assert_eq!(cfg.faults.canary_per_class, 3);
        assert!((cfg.faults.canary_threshold - 0.8).abs() < 1e-12);
        let plan = cfg.resolve_fault_plan().unwrap().expect("plan configured");
        assert_eq!(plan.events.len(), 2);
        assert_eq!(cfg.resolve_canary_every(), 50);

        // Defaults: everything off, plan resolves to None (unless the test
        // environment sets HEC_FAULT_PLAN, which the suite never does).
        let d = ServeConfig::default();
        assert_eq!(d.resolve_canary_every(), 0);

        // Malformed plans fail at validate(), not first use.
        let mut bad = ServeConfig::default();
        bad.faults.plan = Some("warp@10=1".to_string());
        assert!(bad.validate().is_err());
        let mut bad = ServeConfig::default();
        bad.faults.canary_per_class = 0;
        assert!(bad.validate().is_err());
        let mut bad = ServeConfig::default();
        bad.faults.canary_threshold = 1.5;
        assert!(bad.validate().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stores_config_loads_parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("hec-storecfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(
            &path,
            r#"{"stores": {"dir": "/tmp/stores", "refit_min_accuracy": 0.75,
                           "refit_per_class": 4,
                           "tenants": [{"name": "acme", "store": "acme-store", "quota": 16},
                                       {"name": "beta"}]}}"#,
        )
        .unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.stores.dir.as_deref(), Some("/tmp/stores"));
        assert!((cfg.stores.refit_min_accuracy - 0.75).abs() < 1e-12);
        assert_eq!(cfg.stores.refit_per_class, 4);
        assert_eq!(cfg.stores.tenants.len(), 2);
        assert_eq!(cfg.stores.tenants[0].name, "acme");
        assert_eq!(cfg.stores.tenants[0].store, "acme-store");
        assert_eq!(cfg.stores.tenants[0].quota, 16);
        // Omitted store/quota default to the shared store, unlimited.
        assert_eq!(cfg.stores.tenants[1].store, "default");
        assert_eq!(cfg.stores.tenants[1].quota, 0);
        assert_eq!(cfg.resolve_stores_dir().as_deref(), Some("/tmp/stores"));
        assert_eq!(cfg.resolve_tenants().unwrap(), cfg.stores.tenants);

        // Env-style spec string parsing.
        let parsed = parse_tenant_list("t1=default:100, t2=storeA").unwrap();
        assert_eq!(
            parsed,
            vec![
                TenantSpec {
                    name: "t1".into(),
                    store: "default".into(),
                    quota: 100
                },
                TenantSpec {
                    name: "t2".into(),
                    store: "storeA".into(),
                    quota: 0
                },
            ]
        );
        assert!(parse_tenant_list("justaname").is_err());
        assert!(parse_tenant_list("t=s:notanumber").is_err());

        // Validation: bad idents, duplicates, bad refit knobs.
        let mut bad = ServeConfig::default();
        bad.stores.tenants.push(TenantSpec {
            name: "a/b".into(),
            store: "default".into(),
            quota: 0,
        });
        assert!(bad.validate().is_err());
        let mut bad = ServeConfig::default();
        bad.stores.tenants.push(TenantSpec {
            name: "t".into(),
            store: "default".into(),
            quota: 0,
        });
        bad.stores.tenants.push(TenantSpec {
            name: "t".into(),
            store: "other".into(),
            quota: 1,
        });
        assert!(bad.validate().is_err());
        let mut bad = ServeConfig::default();
        bad.stores.refit_min_accuracy = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = ServeConfig::default();
        bad.stores.refit_per_class = 0;
        assert!(bad.validate().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cache_config_loads_resolves_and_validates() {
        let dir = std::env::temp_dir().join(format!("hec-cachecfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(&path, r#"{"cache": {"enabled": true, "capacity": 64}}"#).unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert!(cfg.cache.enabled);
        assert_eq!(cfg.cache.capacity, 64);
        assert_eq!(cfg.resolve_cache(), Some(64));

        // Defaults: off (unless HEC_CACHE is set, which the suite never
        // does — same caveat as the other env-resolved knobs).
        let d = ServeConfig::default();
        assert!(!d.cache.enabled);
        assert_eq!(d.cache.capacity, 1024);

        let mut bad = ServeConfig::default();
        bad.cache.enabled = true;
        bad.cache.capacity = 0;
        assert!(bad.validate().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_rejects_bad_k() {
        let mut c = ServeConfig::default();
        c.templates_per_class = 4;
        assert!(c.validate().is_err());
    }

    #[test]
    fn load_overrides_defaults() {
        let dir = std::env::temp_dir().join(format!("hec-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.json");
        std::fs::write(
            &path,
            r#"{"backend": "fc", "templates_per_class": 2,
                "batch": {"max_batch": 8},
                "acam": {"cell_kind": "3t1r", "variability_level": 1.5}}"#,
        )
        .unwrap();
        let cfg = ServeConfig::load(&path).unwrap();
        assert_eq!(cfg.backend, Backend::FeatureCount);
        assert_eq!(cfg.templates_per_class, 2);
        assert_eq!(cfg.batch.max_batch, 8);
        assert_eq!(cfg.acam.cell_kind, CellKind::Precharging3T1R);
        assert!((cfg.acam.variability_level - 1.5).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }
}

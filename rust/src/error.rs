//! Unified error type for the serving stack.
//!
//! Hand-implemented `Display` / `std::error::Error` (`thiserror` is
//! unavailable offline; the default build carries zero external
//! dependencies).

use std::fmt;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes the coordinator can surface to a caller.
#[derive(Debug)]
pub enum Error {
    /// Execution-backend failures (interpreter shape mismatches; PJRT
    /// compile / execute / literal marshalling when the `pjrt` feature is
    /// enabled).
    Backend(String),

    /// Artifact loading / validation problems (missing files, shape
    /// mismatches between meta.json and the parameter sidecars).
    Artifact(String),

    /// Template store inconsistencies (wrong feature width, empty classes).
    Template(String),

    /// Request-level errors (bad image shape, closed channels, timeouts).
    Request(String),

    /// Configuration errors.
    Config(String),

    /// I/O failures while reading artifacts or configuration files.
    Io(std::io::Error),

    /// JSON syntax errors from [`crate::jsonlite`].
    Json(crate::jsonlite::ParseError),

    /// Schema errors while extracting typed fields from parsed JSON.
    Schema(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Backend(m) => write!(f, "backend: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Template(m) => write!(f, "template: {m}"),
            Error::Request(m) => write!(f, "request: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(e) => write!(f, "json: {e}"),
            Error::Schema(m) => write!(f, "schema: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::jsonlite::ParseError> for Error {
    fn from(e: crate::jsonlite::ParseError) -> Self {
        Error::Json(e)
    }
}

impl Error {
    /// The stable v1 API code this internal error maps onto.  Sites that
    /// know a more specific code (queue full, bad image shape, missing
    /// backend) construct [`crate::api::ApiError`] directly; this is the
    /// fallback for errors that bubble up from inside the stack.
    pub fn api_code(&self) -> crate::api::ErrorCode {
        use crate::api::ErrorCode;
        match self {
            // Config errors reaching a request path mean the request asked
            // for something this deployment cannot do.
            Error::Config(_) => ErrorCode::InvalidArgument,
            Error::Request(_) => ErrorCode::InvalidArgument,
            // Engine / artifact / template / IO / schema failures are not
            // the caller's fault.
            Error::Backend(_)
            | Error::Artifact(_)
            | Error::Template(_)
            | Error::Io(_)
            | Error::Json(_)
            | Error::Schema(_) => ErrorCode::Internal,
        }
    }
}

impl From<Error> for crate::api::ApiError {
    fn from(e: Error) -> Self {
        crate::api::ApiError::new(e.api_code(), e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_prefix() {
        assert_eq!(
            Error::Backend("boom".into()).to_string(),
            "backend: boom"
        );
        assert_eq!(Error::Config("bad".into()).to_string(), "config: bad");
    }

    #[test]
    fn api_code_mapping_is_stable() {
        use crate::api::ErrorCode;
        assert_eq!(Error::Config("x".into()).api_code(), ErrorCode::InvalidArgument);
        assert_eq!(Error::Request("x".into()).api_code(), ErrorCode::InvalidArgument);
        assert_eq!(Error::Backend("x".into()).api_code(), ErrorCode::Internal);
        assert_eq!(Error::Schema("x".into()).api_code(), ErrorCode::Internal);
        let api: crate::api::ApiError = Error::Backend("boom".into()).into();
        assert_eq!(api.code, ErrorCode::Internal);
        assert!(api.message.contains("boom"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().starts_with("io:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}

//! Drift-aware serving bench: ages the ACAM array mid-serving and measures
//! the full degradation ladder end-to-end — accuracy decay after the fault,
//! detection latency (in requests) until the canary probe catches it, the
//! re-programming energy charged to the ledger, and post-recovery accuracy.
//! Phase 2 injects unhealable stuck-at cells and shows the shard landing in
//! `DigitalFallback` while every request keeps succeeding.
//!
//! Everything is deterministic under fixed seeds: serial blocking submits
//! (`max_batch = 1`, `max_wait_us = 0`) make the fault/probe arithmetic
//! exact, and per-request "accuracy" is agreement with a digital
//! `FeatureCount` reference pipeline computed up front.  `HEC_BENCH_SMOKE=1`
//! shrinks the request counts for CI; the JSON artifact (`BENCH_drift.json`)
//! is the deliverable.

use std::time::Instant;

use hec::benchkit::{section, BenchResult};
use hec::config::{Backend, ServeConfig};
use hec::coordinator::{ClassifySurface, Pipeline, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::faults::BackendState;
use hec::jsonlite::Value;
use hec::runtime::Meta;

/// One serving phase under a fault plan: serial blocking requests against a
/// single-shard ACAM deployment, scored per-request against `truth`.
struct PhaseOut {
    /// Per-request agreement with the digital reference.
    agree: Vec<bool>,
    /// First request index whose response carried `degraded: true`.
    degraded_from: Option<usize>,
    state: BackendState,
    canary_accuracy: f64,
    reprograms: u64,
    energy_nj: f64,
    secs: f64,
    p50_us: u64,
    p99_us: u64,
}

fn run_phase(plan: &str, canary_every: u64, images: &[Vec<f32>], truth: &[usize]) -> PhaseOut {
    let mut cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::AcamSim,
        ..Default::default()
    };
    cfg.batch.max_batch = 1; // serial submits -> exact fault/probe arithmetic
    cfg.batch.max_wait_us = 0;
    cfg.faults.plan = Some(plan.to_string());
    cfg.faults.canary_every = canary_every;
    let set = ShardSet::start(&cfg).unwrap();

    let t0 = Instant::now();
    let mut agree = Vec::with_capacity(images.len());
    let mut degraded_from = None;
    for (i, img) in images.iter().enumerate() {
        let resp = set.handle.classify_blocking(img.clone()).unwrap();
        if degraded_from.is_none() && resp.degraded == Some(true) {
            degraded_from = Some(i);
        }
        agree.push(resp.predictions[0].class == truth[i]);
    }
    let secs = t0.elapsed().as_secs_f64();
    let snap = set.handle.snapshot();
    let (state, canary_accuracy, reprograms) = set.handle.shard_ladder().unwrap()[0];
    let energy_nj = set.handle.shard_metrics(0).energy_nj();
    set.shutdown();
    PhaseOut {
        agree,
        degraded_from,
        state,
        canary_accuracy,
        reprograms,
        energy_nj,
        secs,
        p50_us: snap.latency_p50_us,
        p99_us: snap.latency_p99_us,
    }
}

fn rate(agree: &[bool], lo: usize, hi: usize) -> f64 {
    let window = &agree[lo.min(agree.len())..hi.min(agree.len())];
    if window.is_empty() {
        return f64::NAN;
    }
    window.iter().filter(|&&a| a).count() as f64 / window.len() as f64
}

/// Same field mapping as the e2e serving bench: `mean_us`/`min_us` =
/// 1e6 / request throughput; `p50_us`/`p99_us` = end-to-end request
/// latency percentile upper bounds.
fn row(name: &str, requests: usize, secs: f64, p50_us: u64, p99_us: u64) -> BenchResult {
    let tput = requests as f64 / secs;
    let inv = std::time::Duration::from_secs_f64(if tput > 0.0 { 1.0 / tput } else { 0.0 });
    BenchResult {
        name: name.to_string(),
        iters: requests,
        mean: inv,
        p50: std::time::Duration::from_micros(p50_us),
        p99: std::time::Duration::from_micros(p99_us),
        min: inv,
    }
}

fn main() {
    let smoke = std::env::var("HEC_BENCH_SMOKE").is_ok();
    // `fault_at` is a multiple of `every`, so the probe arithmetic is exact:
    // the fault strikes right after a clean probe, and the next probe (one
    // full cadence later) is the one that catches it.
    let (total, fault_at, every) = if smoke { (60usize, 20usize, 10u64) } else { (200, 80, 40) };
    let recover_at = fault_at + every as usize;
    let have_artifacts = std::path::Path::new("artifacts/meta.json").is_file();
    if !have_artifacts {
        println!("drift_serving: no artifacts/ — serving the synthetic fallback deployment");
    }

    // Workload + digital ground truth, computed up front so the serve loops
    // time only the deployment under test.  At the ideal corner the analogue
    // back-end agrees with this reference exactly (the calibration
    // contract), so "agreement" reads directly as relative accuracy.
    let meta = Meta::load_or_synthetic("artifacts").unwrap();
    let ds = SyntheticDataset::new(3_141_593, total, meta.norm.mean as f32, meta.norm.std as f32);
    let images: Vec<Vec<f32>> = (0..total).map(|i| ds.image(i)).collect();
    let ref_cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::FeatureCount,
        ..Default::default()
    };
    let mut reference = Pipeline::new(&ref_cfg).unwrap();
    let truth: Vec<usize> = images
        .iter()
        .map(|img| reference.classify_batch(img, 1).unwrap().remove(0).top1().class)
        .collect();
    let s = reference.store.set(1).unwrap();
    let expected_reprogram_nj = hec::energy::EnergyModel::default()
        .reprogram_nj(s.num_templates() as u64, s.num_features() as u64);

    section(&format!(
        "phase 1: drift at request {fault_at}, canary every {every} -> demote, re-program, recover"
    ));
    let drift = run_phase(&format!("drift@{fault_at}=500"), every, &images, &truth);
    let pre = rate(&drift.agree, 0, fault_at);
    let during = rate(&drift.agree, fault_at, recover_at);
    let post = rate(&drift.agree, recover_at, total);
    // Detection latency: requests served on the degraded array before the
    // ladder healed it = distance from the fault to the last misagreement.
    let last_bad = drift.agree.iter().rposition(|&a| !a);
    let detection = last_bad.map_or(0, |i| i + 1 - fault_at);
    println!("  accuracy pre/during/post: {pre:.3} / {during:.3} / {post:.3}");
    println!("  detection latency: {detection} requests (cadence {every})");
    println!("  reprograms: {} (+{expected_reprogram_nj:.1} nJ each)", drift.reprograms);
    assert_eq!(drift.state, BackendState::Healthy, "ladder must recover");
    assert_eq!(drift.canary_accuracy, 1.0, "verify probe on the re-programmed array");
    assert_eq!(drift.reprograms, 1, "exactly one re-program");
    assert_eq!(pre, 1.0, "ideal-corner serving must match the digital reference");
    assert_eq!(post, 1.0, "recovered serving must match the digital reference");
    assert!(during < 0.9, "drifted window should misclassify (got {during})");
    assert!(detection <= every as usize, "detection within one canary cadence");
    assert!(
        drift.degraded_from.is_none(),
        "sub-cadence recovery never flags a response degraded"
    );

    section(&format!(
        "phase 2: all cells stuck at request {fault_at} -> re-program fails, digital fallback"
    ));
    let stuck = run_phase(&format!("stuck@{fault_at}=1.0"), every, &images, &truth);
    let stuck_pre = rate(&stuck.agree, 0, fault_at);
    let stuck_during = rate(&stuck.agree, fault_at, recover_at);
    let stuck_post = rate(&stuck.agree, recover_at, total);
    println!("  accuracy pre/during/post: {stuck_pre:.3} / {stuck_during:.3} / {stuck_post:.3}");
    println!("  fallback from request: {:?}", stuck.degraded_from);
    assert_eq!(stuck.state, BackendState::DigitalFallback);
    assert_eq!(stuck.reprograms, 1, "the one failed re-program attempt");
    assert!(stuck.canary_accuracy < 0.9, "stuck array cannot verify clean");
    assert_eq!(stuck_pre, 1.0);
    assert_eq!(
        stuck.degraded_from,
        Some(recover_at),
        "fallback onset is exactly one cadence after the fault"
    );
    assert_eq!(stuck_post, 1.0, "digital fallback serves the reference answers");

    let rows_owned = [
        row("drift_recovery", total, drift.secs, drift.p50_us, drift.p99_us),
        row("stuck_fallback", total, stuck.secs, stuck.p50_us, stuck.p99_us),
    ];
    let rows: Vec<&BenchResult> = rows_owned.iter().collect();
    hec::benchkit::write_json_report(
        "BENCH_drift.json",
        "hec/drift_serving/v1",
        &[
            ("requests", Value::Num(total as f64)),
            ("fault_at_request", Value::Num(fault_at as f64)),
            ("canary_every", Value::Num(every as f64)),
            ("smoke", Value::Bool(smoke)),
            ("artifacts", Value::Bool(have_artifacts)),
            ("drift_accuracy_pre", Value::Num(pre)),
            ("drift_accuracy_during", Value::Num(during)),
            ("drift_accuracy_post", Value::Num(post)),
            ("drift_detection_requests", Value::Num(detection as f64)),
            ("drift_reprograms", Value::Num(drift.reprograms as f64)),
            ("drift_energy_nj", Value::Num(drift.energy_nj)),
            ("reprogram_nj", Value::Num(expected_reprogram_nj)),
            ("stuck_accuracy_during", Value::Num(stuck_during)),
            ("stuck_accuracy_post", Value::Num(stuck_post)),
            ("stuck_fallback_from", Value::Num(recover_at as f64)),
            ("stuck_energy_nj", Value::Num(stuck.energy_nj)),
            (
                "row_semantics",
                Value::Str(
                    "mean_us/min_us = 1e6/req_throughput; p50_us/p99_us = \
                     end-to-end request latency upper bounds"
                        .to_string(),
                ),
            ),
        ],
        &rows,
    )
    .expect("write BENCH_drift.json");
    println!("\nwrote BENCH_drift.json ({} rows)", rows.len());
    println!("drift_serving: PASS");
}
